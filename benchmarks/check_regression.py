"""Bench regression gate: compare a fresh BENCH_session_throughput.json
against the committed baseline and exit non-zero on regression.

Gates (CI fails the job instead of merely uploading the artifact):

  * TCN chunking contract — speedup_160_vs_1 >= 5x (absolute floor; the
    bench itself asserts this too, so the gate also catches a stale file);
  * LM chunking contract — speedup_16_vs_1 >= 3x;
  * LM speculative contract — the parallel-verify + n-gram-self-draft
    sweep at K=4 must decode >= 1.3x the tokens/s of plain chunked decode
    of the same requests (acceptance is seed-deterministic, so this gate
    is a timing-ratio floor, not a model-behavior lottery);
  * park/resume cost — within 2x of the baseline, measured as the
    NORMALIZED ratio (park_us + resume_us) / us_per_dispatch(T=1) of the
    same run: raw microseconds are machine-dependent, but the park/resume
    cost relative to a single dispatch on the same machine is stable —
    a 2x growth of that ratio means pack/unpack genuinely got heavier;
  * parked-state bytes — within 2x of baseline (structural, exact on the
    TCN side; O(pos) at the bench's fixed position on the LM side);
  * paged capacity contract — the lm section must carry a "capacity"
    subsection (the paged slot-memory bench), and on it: >= 8x resident
    sessions vs the dense control at equal device cache bytes, admission
    p99 >= 5x lower than dense (O(1) host table setup vs O(seq_cap)
    device scrub), and the in-bench paged==dense bit-identity flag True.
    These are absolute floors of the fresh run — no baseline needed —
    so a stale artifact or a silently-skipped section fails CI;
  * kernel fused fast path (--kernels BENCH_kernels.json) — the fused
    chunk executor must be >= 1.2x the unfused scan on CPU at
    T_chunk=160 for BOTH the fp32 and quantized sweeps, with the bench's
    bit-identity assertion recorded True, and must not fall below 1/3 of
    the committed baseline's speedup (degradation guard, sized to sit
    outside shared-runner timing noise);
  * serving plane (--serve BENCH_serve_load.json) — the async
    continuous-batching load replay must be present (section-missing is
    a hard fail), bit-identical to its synchronous control, lose no
    sessions to churn, keep its TTFR tail bounded and its goodput above
    an absolute floor; p99 TTFR and goodput are additionally held
    within ratio of the committed baseline like-for-like (same smoke
    flag);
  * chaos replay (--chaos BENCH_serve_load.json) — the fault-injected
    replay's "chaos" section must be present (section-missing is a hard
    fail: the fault path must run every merge), with zero lost sessions,
    every crash recovered, every client completed, survivor streams
    bit-identical to the fault-free control, MTTR p99 bounded, and
    goodput-under-faults above a catastrophic floor — all absolute
    properties of the fresh run (the seeded plan makes them
    deterministic), no baseline needed;
  * served CL curve (--cl BENCH_cl_serve.json) — the streaming-enrollment
    continual-learning bench must be present (section-missing is a hard
    fail), its paged tenant bank bit-identical to the dense enroll-once
    control at every checkpoint, its final accuracy above an absolute
    floor, its device bytes/way within the block-granular bound, its
    enroll-latency tail bounded like the dispatch gate, its
    bounded-rehearsal replay within an accuracy margin of the exact
    bank, and (full runs) the curve must actually reach 250 ways; final
    accuracy is additionally held within margin of the committed
    baseline like-for-like (same smoke flag);
  * dispatch-latency telemetry — each section's ``dispatch_latency``
    summary (the repro.obs per-dispatch histograms, post-warmup) must be
    schema-valid (count > 0, p50 <= p99, every by_shape entry carrying
    counts and quantiles) and its tail bounded: p99 <= max(5 x p50,
    p50 + TAIL_SLACK_US).  The absolute-slack arm keeps the RATIO gate
    from tripping on microsecond-scale dispatches, where one scheduler
    hiccup on a shared runner is many multiples of p50; the ratio arm is
    the real contract once dispatches are non-trivial.

Old-schema baselines (pre --service split: no "tcn"/"lm" sections) are
upgraded on the fly; missing baseline metrics are reported and skipped,
so adding metrics never requires a flag day.

    PYTHONPATH=src python -m benchmarks.check_regression \\
        --fresh BENCH_session_throughput.json --baseline baseline.json \\
        [--kernels BENCH_kernels.json --kernels-baseline kb.json]
"""

import argparse
import json
import sys

TCN_MIN_SPEEDUP = 5.0
LM_MIN_SPEEDUP = 3.0
SPEC_MIN_SPEEDUP = 1.3  # speculative K=4 self-draft vs plain decode
CAP_MIN_RATIO = 8.0  # paged resident sessions vs dense at equal bytes
ADMIT_P99_MIN_RATIO = 5.0  # dense admission p99 / paged admission p99
KERNEL_MIN_SPEEDUP = 1.2  # fused vs unfused chunk scan, CPU floor
# degradation guard vs the committed baseline; wide enough to absorb
# shared-runner timing noise (observed ~2x swing under container load) —
# the absolute floor above is the hard contract
KERNEL_RATIO_MAX = 3.0
COST_RATIO_MAX = 2.0
BYTES_RATIO_MAX = 2.0
NOISE_FLOOR = 4.0  # don't fail normalized-cost ratios in the noise band
TAIL_RATIO_MAX = 5.0   # dispatch latency p99 <= 5x p50 ...
TAIL_SLACK_US = 2000.0  # ... OR within p50 + 2ms (shared-runner hiccups)
# serving-plane load bench (--serve BENCH_serve_load.json).  TTFR under
# as-fast-as-possible replay is queueing-dominated, so its tail gate is
# wider than the per-dispatch one; the relative gates vs baseline apply
# like-for-like only (same smoke flag), since a 3k-session smoke replay's
# queueing regime is not comparable to the 100k full run's.
TTFR_TAIL_RATIO = 6.0      # TTFR p99 <= 6x p50 ...
TTFR_SLACK_US = 5_000_000.0  # ... OR within p50 + 5s
TTFR_P99_RATIO_MAX = 3.0   # vs baseline, like-for-like
GOODPUT_RATIO_MIN = 3.0    # >= baseline/3, like-for-like
GOODPUT_FLOOR_TOK_S = 30.0  # absolute catastrophic-regression floor
# served CL curve (--cl BENCH_cl_serve.json).  The accuracy floors are
# absolute catastrophic-regression guards calibrated to the deliberately
# tiny CPU embedder (32-dim, 12x12 synthetic glyphs): the seed-
# deterministic measured values are ~0.30 final at 20 smoke ways and
# ~0.06 at the full 250 (vs 0.05 / 0.004 chance — the floor is >3x
# chance in both regimes, so a broken bank or shuffled tables trips it
# while embedder-quality noise does not).  The byte bound is structural
# — block-granular rows of (V+1) fp32 — so any layout regression
# (over-allocation, leaked blocks) trips it.
CL_MIN_FINAL_ACC = 0.03    # absolute floor, full 250-way run
CL_SMOKE_MIN_FINAL_ACC = 0.18  # absolute floor, 20-way smoke run
CL_MAX_WAY_BYTES = 512.0   # device bytes per enrolled way (paged bank)
CL_REHEARSAL_DROP_MAX = 0.15  # rehearsal replay vs exact bank, absolute
CL_FULL_MIN_WAYS = 250     # the silicon demo's way count (full runs)
CL_ACC_BASE_MARGIN = 0.05  # vs committed baseline, like-for-like
# chaos replay (--chaos, the "chaos" section of BENCH_serve_load.json).
# Zero-lost / bit-identity / recoveries==crashes are exact invariants of
# the per-op spill journal; MTTR and goodput are absolute guards.  MTTR
# is adopt-from-journal work (host dict moves, no recompilation), so even
# a shared-runner hiccup sits far under the 2s bound.
CHAOS_MTTR_P99_MAX_US = 2_000_000.0
CHAOS_GOODPUT_FLOOR_TOK_S = 10.0  # faults throttle; catastrophic floor only


def _load(path):
    with open(path) as f:
        data = json.load(f)
    if "tcn" not in data and "lm" not in data:  # pre-split schema
        data = {"tcn": data}
    return data


def _tick_us(section, chunk="1"):
    sweep = section.get("chunk_sweep", {}).get(chunk, {})
    return sweep.get("us_per_tick") or sweep.get("us_per_dispatch")


def _norm_cost(section):
    """(park + resume) in units of one T=1 dispatch on the same machine."""
    park, resume = section.get("park_us"), section.get("resume_us")
    tick = _tick_us(section)
    if park is None or resume is None or not tick:
        return None
    return (park + resume) / tick


def check_latency(name: str, section: dict) -> list[str]:
    """Validate a section's ``dispatch_latency`` telemetry summary and
    gate its tail.  Schema first (a malformed summary means the obs plane
    broke, which this gate exists to catch), then
    p99 <= max(TAIL_RATIO_MAX * p50, p50 + TAIL_SLACK_US)."""
    errors = []
    lat = section.get("dispatch_latency")
    if lat is None:
        errors.append(f"{name}: dispatch_latency summary missing "
                      f"(obs histograms not wired into the bench?)")
        return errors
    for key in ("count", "p50_us", "p99_us", "mean_us", "by_shape"):
        if key not in lat:
            errors.append(f"{name}: dispatch_latency missing field {key!r}")
    if errors:
        return errors
    count, p50, p99 = lat["count"], lat["p50_us"], lat["p99_us"]
    if not (isinstance(count, int) and count > 0):
        errors.append(f"{name}: dispatch_latency count={count!r} "
                      f"(expected > 0 post-warmup samples)")
        return errors
    if not (0 < p50 <= p99):
        errors.append(f"{name}: dispatch_latency quantiles inconsistent "
                      f"(p50={p50}, p99={p99})")
        return errors
    shapes = lat["by_shape"]
    if not isinstance(shapes, dict) or not shapes:
        errors.append(f"{name}: dispatch_latency.by_shape empty")
    else:
        for shape, row in shapes.items():
            if not all(k in row for k in ("count", "p50_us", "p99_us")):
                errors.append(f"{name}: by_shape[{shape!r}] malformed: "
                              f"{sorted(row)}")
        total = sum(row.get("count", 0) for row in shapes.values())
        if total != count:
            errors.append(f"{name}: by_shape counts sum to {total}, "
                          f"summary says {count}")
    limit = max(TAIL_RATIO_MAX * p50, p50 + TAIL_SLACK_US)
    if p99 > limit:
        errors.append(f"{name}: dispatch latency tail p99={p99:.0f}us > "
                      f"max({TAIL_RATIO_MAX}x p50, p50 + "
                      f"{TAIL_SLACK_US:.0f}us) = {limit:.0f}us "
                      f"(p50={p50:.0f}us, n={count})")
    print(f"[gate] {name} dispatch latency: p50={p50:.0f}us "
          f"p99={p99:.0f}us n={count} limit={limit:.0f}us")
    return errors


def check(fresh: dict, base: dict) -> list[str]:
    errors, skipped = [], []

    def gate(ok, msg):
        if not ok:
            errors.append(msg)

    tcn, lm = fresh.get("tcn"), fresh.get("lm")
    gate(tcn is not None, "fresh results have no 'tcn' section")
    gate(lm is not None, "fresh results have no 'lm' section")

    if tcn:
        s = tcn.get("speedup_160_vs_1", 0.0)
        gate(
            s >= TCN_MIN_SPEEDUP,
            f"tcn chunk speedup {s:.2f}x < {TCN_MIN_SPEEDUP}x (160 vs 1)",
        )
        errors += check_latency("tcn", tcn)
    if lm:
        s = lm.get("speedup_16_vs_1", 0.0)
        gate(
            s >= LM_MIN_SPEEDUP,
            f"lm chunk speedup {s:.2f}x < {LM_MIN_SPEEDUP}x (16 vs 1)",
        )
        errors += check_latency("lm", lm)
        cap = lm.get("capacity")
        if not cap:
            # hard error, not a skip: the paged-capacity contract is part
            # of the lm bench schema — a missing section means the sweep
            # silently didn't run (or the artifact is stale)
            errors.append("lm: capacity section missing from fresh run "
                          "(paged slot-memory sweep did not run?)")
        else:
            r = cap.get("capacity_ratio", 0.0)
            gate(
                r >= CAP_MIN_RATIO,
                f"lm paged capacity {r:.1f}x < {CAP_MIN_RATIO}x resident "
                f"sessions vs dense at equal bytes "
                f"(paged={cap.get('paged', {}).get('resident_sessions')}, "
                f"dense={cap.get('dense', {}).get('resident_sessions')})",
            )
            a = cap.get("admission_p99_ratio", 0.0)
            gate(
                a >= ADMIT_P99_MIN_RATIO,
                f"lm paged admission p99 only {a:.1f}x lower than dense "
                f"(< {ADMIT_P99_MIN_RATIO}x; O(1) admission regressed?)",
            )
            gate(
                bool(cap.get("bit_identical")),
                "lm paged capacity bench: paged decode not bit-identical "
                "to dense under churn",
            )
        spec = lm.get("speculative")
        if not spec:
            skipped.append("lm: speculative sweep missing from fresh run")
        else:
            s = spec.get("speedup_vs_plain", 0.0)
            gate(
                s >= SPEC_MIN_SPEEDUP,
                f"lm speculative speedup {s:.2f}x < {SPEC_MIN_SPEEDUP}x "
                f"(K={spec.get('k')}, "
                f"acceptance={spec.get('acceptance_rate', 0):.2f})",
            )

    for name in ("tcn", "lm"):
        f, b = fresh.get(name), base.get(name)
        if not f or not b:
            skipped.append(f"{name}: no baseline section")
            continue
        fn, bn = _norm_cost(f), _norm_cost(b)
        if fn is None or bn is None:
            skipped.append(f"{name}: park/resume cost missing")
        else:
            limit = max(COST_RATIO_MAX * bn, NOISE_FLOOR)
            gate(
                fn <= limit,
                f"{name} park+resume cost {fn:.2f} dispatches > "
                f"{limit:.2f} (baseline {bn:.2f}, max {COST_RATIO_MAX}x)",
            )
        key = "parked_state_bytes" if name == "tcn" else "parked_blob_bytes"
        fb, bb = f.get(key), b.get(key)
        if fb is None or bb is None:
            skipped.append(f"{name}: {key} missing")
        else:
            gate(
                fb <= BYTES_RATIO_MAX * bb,
                f"{name} {key} {fb} > {BYTES_RATIO_MAX}x baseline {bb}",
            )

    for msg in skipped:
        print(f"[gate] SKIP {msg}")
    return errors


def check_kernels(fresh: dict, base: dict | None) -> list[str]:
    """Gate the fused-kernel fast path (BENCH_kernels.json schema).

    The absolute >= 1.2x floor and the bit-identity flag always apply;
    the degradation guard vs baseline only applies like-for-like (same
    smoke flag), since a smoke sweep's speedup is not comparable to a
    full run's."""
    errors = []
    comparable = base is not None and base.get("smoke") == fresh.get("smoke")
    for key in ("fp32", "quantized"):
        sec = fresh.get(key)
        if sec is None:
            errors.append(f"kernels: fresh results have no {key!r} sweep")
            continue
        s = sec.get("speedup_fused", 0.0)
        if s < KERNEL_MIN_SPEEDUP:
            errors.append(
                f"kernels {key}: fused speedup {s:.2f}x < "
                f"{KERNEL_MIN_SPEEDUP}x (unfused {sec.get('us_unfused')}us"
                f" vs fused {sec.get('us_fused')}us)",
            )
        if not sec.get("bit_identical"):
            errors.append(
                f"kernels {key}: fused path not bit-identical to the scan path",
            )
        bs = (base or {}).get(key, {}).get("speedup_fused")
        if bs is None or not comparable:
            print(f"[gate] SKIP kernels {key}: no comparable baseline")
            bs = None
        elif s < bs / KERNEL_RATIO_MAX:
            errors.append(
                f"kernels {key}: fused speedup {s:.2f}x < baseline "
                f"{bs:.2f}x / {KERNEL_RATIO_MAX} (regression)",
            )
        print(
            f"[gate] kernels {key}: speedup={round(s, 2)} "
            f"baseline={None if bs is None else round(bs, 2)}",
        )
    return errors


def check_serve(fresh: dict, base: dict | None) -> list[str]:
    """Gate the async serving plane load bench (BENCH_serve_load.json).

    Matching the PR 7 convention, a missing section is a hard fail — it
    means the load replay silently didn't run or the artifact is stale.
    Absolute gates (bit-identity, completion, TTFR schema + tail,
    goodput floor) always apply; the p99-TTFR and goodput gates vs the
    committed baseline apply like-for-like (same smoke flag) only."""
    errors = []
    sec = fresh.get("serve_load")
    if sec is None:
        return ["serve: fresh results have no 'serve_load' section "
                "(load replay did not run?)"]
    if not sec.get("bit_identical"):
        errors.append("serve: plane token streams not bit-identical to the "
                      "synchronous control replay")
    n, done = sec.get("sessions", 0), sec.get("completed", -1)
    if done != n:
        errors.append(f"serve: {done}/{n} sessions completed (churn must "
                      f"lose no sessions — retries, not drops)")
    ttfr = sec.get("ttfr")
    if not ttfr or not all(k in ttfr for k in ("count", "p50_us", "p99_us")):
        errors.append(f"serve: ttfr summary malformed: {ttfr!r}")
        return errors
    count, p50, p99 = ttfr["count"], ttfr["p50_us"], ttfr["p99_us"]
    if not (count > 0 and 0 < p50 <= p99):
        errors.append(f"serve: ttfr quantiles inconsistent "
                      f"(n={count}, p50={p50}, p99={p99})")
        return errors
    limit = max(TTFR_TAIL_RATIO * p50, p50 + TTFR_SLACK_US)
    if p99 > limit:
        errors.append(f"serve: TTFR tail p99={p99:.0f}us > "
                      f"max({TTFR_TAIL_RATIO}x p50, p50 + "
                      f"{TTFR_SLACK_US:.0f}us) = {limit:.0f}us "
                      f"(p50={p50:.0f}us)")
    goodput = sec.get("goodput_tok_s", 0.0)
    if goodput < GOODPUT_FLOOR_TOK_S:
        errors.append(f"serve: goodput {goodput:.1f} tok/s < absolute "
                      f"floor {GOODPUT_FLOOR_TOK_S} tok/s")
    bsec = (base or {}).get("serve_load")
    comparable = bsec is not None and bsec.get("smoke") == sec.get("smoke")
    if not comparable:
        print("[gate] SKIP serve relative gates: no comparable baseline "
              "(smoke flags differ or baseline missing)")
    else:
        bp99 = bsec.get("ttfr", {}).get("p99_us")
        if bp99 and p99 > TTFR_P99_RATIO_MAX * bp99:
            errors.append(f"serve: TTFR p99 {p99:.0f}us > "
                          f"{TTFR_P99_RATIO_MAX}x baseline {bp99:.0f}us")
        bgood = bsec.get("goodput_tok_s")
        if bgood and goodput < bgood / GOODPUT_RATIO_MIN:
            errors.append(f"serve: goodput {goodput:.1f} tok/s < baseline "
                          f"{bgood:.1f} / {GOODPUT_RATIO_MIN} (regression)")
    print(f"[gate] serve: {done}/{n} sessions, goodput={goodput} tok/s, "
          f"TTFR p50={p50:.0f}us p99={p99:.0f}us limit={limit:.0f}us, "
          f"retries={sec.get('open_retries')}, "
          f"bit_identical={sec.get('bit_identical')}")
    return errors


def check_chaos(fresh: dict) -> list[str]:
    """Gate the fault-injected serving replay (--chaos, the "chaos"
    section of BENCH_serve_load.json).

    Section-missing is a hard fail — it means serve_load ran without
    ``--chaos`` (or the artifact is stale), and a robustness PR's whole
    point is that the fault path is exercised every merge.  All gates are
    absolute properties of the fresh run (determinism makes them
    reproducible from the recorded plan spec alone, no baseline needed):

      * crashes >= 1 — the seeded plan actually fired (a horizon/rate
        drift that schedules zero crashes silently guts the gate);
      * recoveries == crashes — every crash was repaired;
      * lost_sessions == 0 — the per-op spill journal missed nothing;
      * completed == sessions — clients retried through to completion;
      * bit_identical — survivor token streams match the fault-free
        synchronous control exactly;
      * MTTR p99 bounded — recovery stays adopt-from-journal cheap, not
        rebuild-the-world expensive.
    """
    errors = []
    sec = fresh.get("chaos")
    if sec is None:
        return ["chaos: fresh results have no 'chaos' section "
                "(serve_load ran without --chaos, or stale artifact)"]
    crashes = sec.get("crashes", 0)
    recoveries = sec.get("recoveries", 0)
    if crashes < 1:
        errors.append(f"chaos: plan injected {crashes} crashes (< 1): the "
                      f"fault schedule never fired")
    if recoveries != crashes:
        errors.append(f"chaos: {recoveries} recoveries != {crashes} crashes "
                      f"(a crashed worker was never rebuilt)")
    lost = sec.get("lost_sessions", -1)
    if lost != 0:
        errors.append(f"chaos: {lost} sessions lost (spill journal must "
                      f"cover every acknowledged op)")
    n, done = sec.get("sessions", 0), sec.get("completed", -1)
    if done != n:
        errors.append(f"chaos: {done}/{n} sessions completed under faults "
                      f"(retries must carry every client to completion)")
    if not sec.get("bit_identical"):
        errors.append("chaos: survivor token streams diverged from the "
                      "fault-free synchronous control")
    mttr = sec.get("mttr", {})
    p99 = mttr.get("p99_us")
    if not p99 or p99 <= 0:
        errors.append(f"chaos: mttr summary malformed: {mttr!r}")
    elif p99 > CHAOS_MTTR_P99_MAX_US:
        errors.append(f"chaos: MTTR p99={p99:.0f}us > "
                      f"{CHAOS_MTTR_P99_MAX_US:.0f}us (recovery no longer "
                      f"adopt-from-journal cheap)")
    goodput = sec.get("goodput_tok_s", 0.0)
    if goodput < CHAOS_GOODPUT_FLOOR_TOK_S:
        errors.append(f"chaos: goodput under faults {goodput:.1f} tok/s < "
                      f"floor {CHAOS_GOODPUT_FLOOR_TOK_S} tok/s")
    print(f"[gate] chaos: {done}/{n} sessions, {crashes} crashes / "
          f"{recoveries} recoveries, lost={lost}, "
          f"MTTR p99={p99}us, goodput={goodput} tok/s, "
          f"bit_identical={sec.get('bit_identical')}")
    return errors


def check_cl(fresh: dict, base: dict | None) -> list[str]:
    """Gate the served continual-learning curve (BENCH_cl_serve.json).

    Section-missing is a hard fail (the streaming-enrollment bench
    silently didn't run or the artifact is stale).  Absolute gates
    (bit-identity, accuracy floor, bytes/way, enroll tail, rehearsal
    margin, full-run way count) always apply; the accuracy gate vs the
    committed baseline applies like-for-like (same smoke flag) only."""
    errors = []
    sec = fresh.get("cl_serve")
    if sec is None:
        return ["cl: fresh results have no 'cl_serve' section "
                "(served CL curve did not run?)"]
    served, reh = sec.get("served"), sec.get("rehearsal")
    if not served or not reh:
        return [f"cl: cl_serve malformed (served={bool(served)}, "
                f"rehearsal={bool(reh)})"]
    if not served.get("bit_identical"):
        errors.append("cl: paged tenant bank not bit-identical to the "
                      "dense enroll-once control at equal class counts")
    smoke = bool(sec.get("smoke"))
    floor = CL_SMOKE_MIN_FINAL_ACC if smoke else CL_MIN_FINAL_ACC
    acc = served.get("final_acc", 0.0)
    if acc < floor:
        errors.append(f"cl: final accuracy {acc:.3f} < floor {floor} "
                      f"({sec.get('n_classes')} ways, "
                      f"{sec.get('shots')} shots)")
    if not smoke and sec.get("n_classes", 0) < CL_FULL_MIN_WAYS:
        errors.append(f"cl: full run reached only {sec.get('n_classes')} "
                      f"ways < {CL_FULL_MIN_WAYS} (silicon-demo contract)")
    bpw = served.get("bytes_per_way", float("inf"))
    if bpw > CL_MAX_WAY_BYTES:
        errors.append(f"cl: {bpw:.0f} device bytes/way > "
                      f"{CL_MAX_WAY_BYTES:.0f} (paged bank over-allocating?)")
    lat = served.get("enroll_latency")
    if not lat or not all(k in lat for k in ("count", "p50_us", "p99_us")):
        errors.append(f"cl: enroll_latency summary malformed: {lat!r}")
        return errors
    count, p50, p99 = lat["count"], lat["p50_us"], lat["p99_us"]
    if not (count > 0 and 0 < p50 <= p99):
        errors.append(f"cl: enroll latency quantiles inconsistent "
                      f"(n={count}, p50={p50}, p99={p99})")
        return errors
    limit = max(TAIL_RATIO_MAX * p50, p50 + TAIL_SLACK_US)
    if p99 > limit:
        errors.append(f"cl: enroll latency tail p99={p99:.0f}us > "
                      f"max({TAIL_RATIO_MAX}x p50, p50 + "
                      f"{TAIL_SLACK_US:.0f}us) = {limit:.0f}us "
                      f"(p50={p50:.0f}us, n={count})")
    drop = reh.get("acc_drop")
    if drop is None or drop > CL_REHEARSAL_DROP_MAX:
        errors.append(f"cl: rehearsal replay accuracy drop {drop} > "
                      f"{CL_REHEARSAL_DROP_MAX} (u4 log2 latent replay "
                      f"degraded?)")
    bsec = (base or {}).get("cl_serve")
    comparable = bsec is not None and bool(bsec.get("smoke")) == smoke
    if not comparable:
        print("[gate] SKIP cl relative gates: no comparable baseline "
              "(smoke flags differ or baseline missing)")
    else:
        bacc = bsec.get("served", {}).get("final_acc")
        if bacc and acc < bacc - CL_ACC_BASE_MARGIN:
            errors.append(f"cl: final accuracy {acc:.3f} < baseline "
                          f"{bacc:.3f} - {CL_ACC_BASE_MARGIN} (regression)")
    print(f"[gate] cl: {sec.get('n_classes')} ways final_acc={acc} "
          f"enroll p50={p50:.0f}us p99={p99:.0f}us limit={limit:.0f}us "
          f"bytes/way={bpw} rehearsal_drop={drop} "
          f"bit_identical={served.get('bit_identical')}")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="BENCH_session_throughput.json")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--kernels", default=None, help="BENCH_kernels.json to gate")
    ap.add_argument("--kernels-baseline", default=None)
    ap.add_argument("--serve", default=None,
                    help="BENCH_serve_load.json to gate")
    ap.add_argument("--serve-baseline", default=None)
    ap.add_argument("--chaos", default=None,
                    help="BENCH_serve_load.json whose 'chaos' section to "
                         "gate (fault-injected replay; absolute gates)")
    ap.add_argument("--cl", default=None,
                    help="BENCH_cl_serve.json to gate")
    ap.add_argument("--cl-baseline", default=None)
    args = ap.parse_args()
    fresh, base = _load(args.fresh), _load(args.baseline)
    errors = check(fresh, base)
    if args.kernels:
        with open(args.kernels) as f:
            kfresh = json.load(f)
        kbase = None
        if args.kernels_baseline:
            with open(args.kernels_baseline) as f:
                kbase = json.load(f)
        errors += check_kernels(kfresh, kbase)
    if args.serve:
        with open(args.serve) as f:
            sfresh = json.load(f)
        sbase = None
        if args.serve_baseline:
            with open(args.serve_baseline) as f:
                sbase = json.load(f)
        errors += check_serve(sfresh, sbase)
    if args.chaos:
        with open(args.chaos) as f:
            errors += check_chaos(json.load(f))
    if args.cl:
        with open(args.cl) as f:
            clfresh = json.load(f)
        clbase = None
        if args.cl_baseline:
            with open(args.cl_baseline) as f:
                clbase = json.load(f)
        errors += check_cl(clfresh, clbase)
    for name in ("tcn", "lm"):
        f = fresh.get(name, {})
        speedup = f.get("speedup_160_vs_1") or f.get("speedup_16_vs_1")
        nc = _norm_cost(f)
        cost = nc if nc is None else round(nc, 2)
        print(f"[gate] {name}: speedup={speedup} norm_park_resume={cost}")
    cap = fresh.get("lm", {}).get("capacity")
    if cap:
        print(
            f"[gate] lm capacity: {round(cap.get('capacity_ratio', 0), 1)}x "
            f"resident, admission p99 "
            f"{round(cap.get('admission_p99_ratio', 0), 1)}x lower, "
            f"bit_identical={cap.get('bit_identical')}",
        )
    spec = fresh.get("lm", {}).get("speculative")
    if spec:
        print(
            f"[gate] lm speculative: K={spec.get('k')} "
            f"speedup={round(spec.get('speedup_vs_plain', 0), 2)} "
            f"acceptance={round(spec.get('acceptance_rate', 0), 2)}",
        )
    if errors:
        for e in errors:
            print(f"[gate] FAIL {e}", file=sys.stderr)
        sys.exit(1)
    print("[gate] OK: no bench regression vs baseline")


if __name__ == "__main__":
    main()
