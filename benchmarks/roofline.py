"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run
records (deliverable g).  Sources: analytic FLOP/byte counters (primary; XLA
cost_analysis undercounts scanned programs — see utils/hlo.py docstring) and
HLO-parsed collective bytes.  Writes experiments/roofline.md + emits CSV.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.core.costmodel import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, roofline


def load_records(out_dir="experiments/dryrun", mesh_tag="pod16x16",
                 exp="baseline"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"{mesh_tag}__*__{exp}.json"))):
        recs.append(json.load(open(f)))
    return recs


def analyse(rec):
    n = rec["n_chips"]
    terms = roofline(
        rec["flops_global_analytic"],
        rec["bytes_global_analytic"],
        rec["collective_bytes_per_device"] * n,  # global collective bytes
        n,
    )
    useful = rec["model_flops"] / max(rec["flops_global_analytic"], 1.0)
    frac = terms.compute_s / max(terms.bound_s, 1e-12)
    return terms, useful, frac


def _what_would_help(rec, terms):
    d = terms.dominant
    if d == "compute":
        return "at compute roofline; reduce remat recompute or quantize"
    if d == "memory":
        if rec["kind"] == "decode":
            return "cut weight/cache bytes: log2-4bit weights, MLA/quantized cache"
        return "fuse/reuse: bigger microbatch, activation recompute over reload"
    return "reduce comm: drop SP all-gathers, shard_map a2a MoE, overlap"


def run(write_md=True):
    recs = load_records()
    lines = ["| arch | shape | compute_s | memory_s | collective_s | bound | "
             "MODEL/HLO | roofline frac | bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        terms, useful, frac = analyse(rec)
        name = f"{rec['arch']}__{rec['shape']}"
        emit(f"roofline_{name}", 0.0,
             f"compute_s={terms.compute_s:.4g};memory_s={terms.memory_s:.4g};"
             f"collective_s={terms.collective_s:.4g};dom={terms.dominant};"
             f"useful={useful:.2f};frac={frac:.2f}")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {terms.compute_s:.4g} | "
            f"{terms.memory_s:.4g} | {terms.collective_s:.4g} | "
            f"{terms.dominant} | {useful:.2f} | {frac:.2f} | "
            f"{_what_would_help(rec, terms)} |")
    if write_md and recs:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline.md", "w") as f:
            f.write(f"# Roofline (16x16 pod, v5e: {PEAK_FLOPS_BF16/1e12:.0f} "
                    f"bf16 TFLOP/s, {HBM_BW/1e9:.0f} GB/s HBM, "
                    f"{ICI_BW/1e9:.0f} GB/s/link ICI)\n\n")
            f.write("\n".join(lines) + "\n")
    return recs


if __name__ == "__main__":
    run()
