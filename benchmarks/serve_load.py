"""Trace-driven load bench for the async serving plane (serving/plane.py).

Generates a deterministic request trace — Poisson arrivals, heavy-tailed
(Pareto) session lengths, diurnal tenant skew (tenant popularity rotates
sinusoidally over the virtual day, so load concentrates on different
tenants in different phases of the trace) — and replays it as fast as
possible through a ``ServingPlane`` over bounded paged LM slot grids:
100k+ sessions (``--smoke``: 3k) churning through ``workers x n_slots``
compiled lanes with ``max_sessions`` bounding the live set, so admission
back-pressure (``Rejected``, retried with backoff) is part of steady
state, not an error path.

Reported through the ``repro.obs`` registry and gated by
``check_regression.py --serve``:

  * **TTFR** — time-to-first-result per session, from the client's first
    open attempt (admission retries included: back-pressure IS latency)
    to its first batched push result; p50/p99 from a registry histogram;
  * **goodput-under-churn** — completed tokens/s of wall time over the
    whole replay, retry stalls and all;
  * **bit-identity** — a deterministic sample of sessions is re-decoded
    alone on a synchronous one-slot control service; the plane's token
    streams must match exactly (continuous batching only changes when
    work is grouped, never what a lane computes).

``--chaos`` appends a second, smaller replay under a seeded fault plan
(serving/faults.py): every worker is wrapped in a ``FaultInjector``
drawing crashes, admission storms, and transient flakes from
``FaultPlan.seeded``, the plane journals every touched session
(``checkpoint_every=1``), and clients retry every rejected verb through
``RetryPolicy``.  The report's ``"chaos"`` section carries MTTR
percentiles, goodput-under-faults, ``lost_sessions`` (must be 0), and a
bit-identity verdict against the same synchronous control — gated by
``check_regression.py --chaos``.

Emits ``BENCH_serve_load.json`` + ``BENCH_serve_metrics.json`` (registry
snapshot); ``--trace out.json`` additionally exports a Perfetto span
trace of the replay.

    PYTHONPATH=src python -m benchmarks.serve_load [--smoke] [--chaos] \\
        [--sessions N] [--workers W] [--trace out.json]
"""

import argparse
import asyncio
import json
import time

import jax
import numpy as np

from repro.configs import RuntimeConfig, get_config
from repro.models import build_bundle
from repro.obs import Tracer
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.serving import (FaultInjector, FaultPlan, Rejected, RetryPolicy,
                           ServingPlane)
from repro.sessions import LMSessionService

OUT_PATH = "BENCH_serve_load.json"
METRICS_PATH = "BENCH_serve_metrics.json"

N_SESSIONS = 100_000
N_TENANTS = 64
SEQ_CAP = 64
T_CHUNK = 4        # decode chunk per dispatch AND per-push token budget
MAX_LEN = 40       # session length cap (< seq_cap - 1 with 1-token prompts)
WINDOW = 256       # concurrent client coroutines (the arrival window)
BIT_SAMPLE = 32    # sessions re-decoded on the synchronous control
DAY = 1000.0       # virtual-seconds per diurnal period


def gen_trace(n_sessions: int, seed: int = 0) -> list[dict]:
    """The deterministic request trace.  Arrival times are a Poisson
    process in virtual time; lengths are 1 + Pareto (mostly a few tokens,
    a long tail up to MAX_LEN); each arrival picks its tenant from a
    diurnal popularity profile (each tenant's weight peaks at its own
    phase of the virtual day)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=DAY / max(n_sessions / 4, 1),
                           size=n_sessions)
    at = np.cumsum(gaps)
    lengths = 1 + np.minimum(rng.pareto(1.5, n_sessions) * 3,
                             MAX_LEN - 1).astype(np.int64)
    phase = 2 * np.pi * (at[:, None] / DAY
                         + np.arange(N_TENANTS)[None, :] / N_TENANTS)
    w = 1.0 + 0.9 * np.sin(phase)  # (n_sessions, N_TENANTS) diurnal skew
    w /= w.sum(axis=1, keepdims=True)
    u = rng.random(n_sessions)
    tenants = (w.cumsum(axis=1) < u[:, None]).sum(axis=1)
    prompts = rng.integers(1, 32, size=n_sessions)
    return [{"t": float(at[i]), "tenant": int(tenants[i]),
             "len": int(lengths[i]), "prompt": int(prompts[i])}
            for i in range(n_sessions)]


def _make_worker(bundle, params, n_slots: int, runtime: RuntimeConfig,
                 registry):
    return LMSessionService(
        bundle, params, n_slots=n_slots, seq_cap=SEQ_CAP, t_chunk=T_CHUNK,
        max_sessions=8 * n_slots,  # the bounded live set: churn source
        runtime=runtime, metrics=registry)


async def _retrying(op, policy: RetryPolicy, counters: dict):
    """Run ``op()`` (an awaitable factory) to completion through the shared
    retry discipline: retryable ``Rejected`` sleeps per ``RetryPolicy`` —
    honoring the plane's ``retry_after`` congestion hint as the floor —
    and retries; anything else propagates."""
    attempt = 0
    while True:
        try:
            return await op()
        except Rejected as e:
            if not e.retryable:
                raise
            counters["retries"] += 1
            await policy.sleep(attempt, e.retry_after)
            attempt += 1


async def _replay(plane: ServingPlane, trace: list[dict], registry,
                  sample_every: int, policy: RetryPolicy,
                  retry_all: bool = False) -> dict:
    """Replay the trace through the plane with a bounded arrival window.
    Returns per-session token streams for the bit-identity sample plus
    churn counters.  ``retry_all`` extends the retry discipline from
    opens (admission back-pressure, part of fault-free steady state) to
    every verb — required under chaos, where pushes and closes also fail
    retryably (crash / transient / storm)."""
    h_ttfr = registry.histogram("serve_ttfr_us")
    sem = asyncio.Semaphore(WINDOW)
    sampled: dict[int, list[int]] = {}
    counters = {"retries": 0, "completed": 0, "tokens": 0}

    async def client(i: int, req: dict):
        try:
            t0 = time.perf_counter()
            psid = await _retrying(
                lambda: plane.open_session(np.array([req["prompt"]],
                                                    np.int32),
                                           tenant=req["tenant"]),
                policy, counters)
            toks: list[int] = []
            first = True
            left = req["len"]
            while left > 0:
                n = min(left, T_CHUNK)
                if retry_all:
                    toks += await _retrying(lambda: plane.push(psid, n),
                                            policy, counters)
                else:
                    toks += await plane.push(psid, n)
                if first:
                    h_ttfr.record((time.perf_counter() - t0) * 1e6)
                    first = False
                left -= n
            if retry_all:
                await _retrying(lambda: plane.close(psid), policy, counters)
            else:
                await plane.close(psid)
            counters["completed"] += 1
            counters["tokens"] += len(toks)
            if i % sample_every == 0:
                sampled[i] = toks
        finally:
            sem.release()

    # acquire BEFORE spawning so only ~WINDOW coroutines exist at once
    # (100k pre-built coroutine objects would dominate memory, not serving)
    tasks = []
    for i, req in enumerate(trace):
        await sem.acquire()
        tasks.append(asyncio.ensure_future(client(i, req)))
    await asyncio.gather(*tasks)
    return {"sampled": sampled, **counters}


def _sync_control(bundle, params, trace, sampled, runtime) -> bool:
    """Re-decode every sampled session ALONE on a one-slot synchronous
    service: the strictest control — no plane, no batching, no churn."""
    for i, got in sorted(sampled.items()):
        req = trace[i]
        svc = LMSessionService(bundle, params, n_slots=1, seq_cap=SEQ_CAP,
                               t_chunk=T_CHUNK, max_sessions=1,
                               runtime=runtime)
        sid = svc.open_session(np.array([req["prompt"]], np.int32))
        want = svc.decode({sid: req["len"]})[sid]
        svc.close(sid)
        if got != want:
            print(f"# BIT-IDENTITY FAIL session {i}: plane={got} "
                  f"sync={want}", flush=True)
            return False
    return True


def run(n_sessions: int, n_workers: int, n_slots: int, smoke: bool,
        trace_path: str | None, seed: int = 0) -> dict:
    registry = default_registry()
    runtime = RuntimeConfig(paged=True)  # paged admission is the O(1) path
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    trace = gen_trace(n_sessions, seed=seed)
    sample_every = max(1, n_sessions // BIT_SAMPLE)

    workers = [_make_worker(bundle, params, n_slots, runtime, registry)
               for _ in range(n_workers)]
    tracer = Tracer(enabled=bool(trace_path))
    plane = ServingPlane(workers, max_queue=4 * WINDOW, runtime=runtime,
                         metrics=registry, tracer=tracer)

    # warm the compile caches so the replay measures serving, not XLA
    warm = _make_worker(bundle, params, n_slots, runtime, registry)
    wsid = warm.open_session(np.array([1], np.int32))
    warm.decode({wsid: T_CHUNK})
    registry.histogram("serve_ttfr_us").reset()

    async def main():
        async with plane:
            return await _replay(plane, trace, registry, sample_every,
                                 RetryPolicy(seed=seed))

    t0 = time.perf_counter()
    res = asyncio.run(main())
    wall = time.perf_counter() - t0
    if trace_path:
        tracer.export(trace_path)
        print(f"# wrote {trace_path}", flush=True)

    identical = _sync_control(bundle, params, trace, res["sampled"], runtime)
    h = registry.histogram("serve_ttfr_us")
    snap = registry.snapshot()
    batches = sum(e["value"] for e in snap.get("plane_batches_total", []))
    lanes = snap.get("plane_batch_lanes", [{}])[0]
    rejected = {e["labels"]["reason"]: e["value"]
                for e in snap.get("plane_rejected_total", [])}
    out = {
        "smoke": smoke, "config": cfg.name, "sessions": n_sessions,
        "tenants": N_TENANTS, "workers": n_workers, "n_slots": n_slots,
        "max_live_sessions": n_workers * 8 * n_slots,
        "wall_s": round(wall, 3),
        "completed": res["completed"],
        "tokens_total": res["tokens"],
        "goodput_tok_s": round(res["tokens"] / wall, 1),
        "open_retries": res["retries"],
        "rejected_total": rejected,
        "batches_total": int(batches),
        "mean_batch_lanes": round(lanes.get("sum", 0)
                                  / max(lanes.get("count", 1), 1), 2),
        "ttfr": {"count": h.count, "p50_us": round(h.percentile(50), 1),
                 "p99_us": round(h.percentile(99), 1),
                 "mean_us": round(h.mean, 1)},
        "bit_identical": identical,
        "bit_sample": len(res["sampled"]),
    }
    print(f"# serve_load: {res['completed']}/{n_sessions} sessions, "
          f"{out['goodput_tok_s']} tok/s, TTFR p50={out['ttfr']['p50_us']}us "
          f"p99={out['ttfr']['p99_us']}us, {res['retries']} admission "
          f"retries, {out['batches_total']} batches "
          f"(mean {out['mean_batch_lanes']} lanes), "
          f"bit_identical={identical}", flush=True)
    return out


CHAOS_SESSIONS = 400   # smoke chaos trace (full: 4x)


def run_chaos(n_workers: int, n_slots: int, smoke: bool,
              seed: int = 0) -> dict:
    """The chaos replay: the same trace machinery under a seeded fault
    plan.  Every worker is a ``FaultInjector`` over a fresh paged LM grid,
    the plane journals every touched session (``checkpoint_every=1`` —
    exact recovery), and clients retry EVERY verb through ``RetryPolicy``.
    The ratchet: zero lost sessions, and every surviving stream
    bit-identical to the fault-free synchronous control."""
    registry = MetricsRegistry()   # isolated from the fault-free run
    runtime = RuntimeConfig(paged=True)
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    n_sessions = CHAOS_SESSIONS if smoke else 4 * CHAOS_SESSIONS
    trace = gen_trace(n_sessions, seed=seed + 1)
    sample_every = max(1, n_sessions // BIT_SAMPLE)

    # plan horizon ~ per-worker verb count (open + pushes + close); each
    # worker gets its own seeded plan so crashes do not synchronize
    pushes = sum(-(-r["len"] // T_CHUNK) for r in trace)
    horizon = int((2 * n_sessions + pushes) / n_workers)
    plans = [FaultPlan.seeded(seed + 17 * (i + 1), horizon,
                              crash_every=max(40, horizon // 4),
                              storm_every=max(50, horizon // 5),
                              flake_every=max(30, horizon // 6))
             for i in range(n_workers)]

    def factory():
        return _make_worker(bundle, params, n_slots, runtime, registry)

    injectors = [FaultInjector(factory(), plans[i], factory=factory)
                 for i in range(n_workers)]
    plane = ServingPlane(injectors, max_queue=4 * WINDOW, runtime=runtime,
                         metrics=registry, checkpoint_every=1)

    async def main():
        async with plane:
            res = await _replay(plane, trace, registry, sample_every,
                                RetryPolicy(seed=seed), retry_all=True)
            return res, plane.stats()

    t0 = time.perf_counter()
    (res, stats) = asyncio.run(main())
    wall = time.perf_counter() - t0
    identical = _sync_control(bundle, params, trace, res["sampled"], runtime)

    snap = registry.snapshot()

    def _total(name):
        return int(sum(e["value"] for e in snap.get(name, [])))

    h = registry.histogram("plane_mttr_us")
    out = {
        "smoke": smoke, "sessions": n_sessions, "workers": n_workers,
        "n_slots": n_slots,
        "plan": [p.spec() for p in plans],
        "wall_s": round(wall, 3),
        "completed": res["completed"],
        "tokens_total": res["tokens"],
        "goodput_tok_s": round(res["tokens"] / wall, 1),
        "retries": res["retries"],
        "crashes": _total("plane_crashes_total"),
        "recoveries": _total("plane_recoveries_total"),
        "handoffs": _total("plane_handoffs_total"),
        "lost_sessions": stats["lost_sessions"],
        "mttr": {"count": h.count, "p50_us": round(h.percentile(50), 1),
                 "p99_us": round(h.percentile(99), 1)},
        "bit_identical": identical,
        "bit_sample": len(res["sampled"]),
    }
    print(f"# chaos: {res['completed']}/{n_sessions} sessions through "
          f"{out['crashes']} crashes ({out['recoveries']} recoveries, "
          f"{out['lost_sessions']} lost), {out['goodput_tok_s']} tok/s, "
          f"MTTR p99={out['mttr']['p99_us']}us, {res['retries']} retries, "
          f"bit_identical={identical}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3k sessions on a smaller grid (CI)")
    ap.add_argument("--chaos", action="store_true",
                    help="append a fault-injected replay (chaos section)")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--trace", default=None,
                    help="export a Perfetto span trace of the replay")
    args = ap.parse_args()
    n_sessions = args.sessions if args.sessions is not None else \
        (3_000 if args.smoke else N_SESSIONS)
    n_slots = args.slots if args.slots is not None else \
        (8 if args.smoke else 16)
    out = run(n_sessions, args.workers, n_slots, args.smoke, args.trace)
    report = {"serve_load": out}
    if args.chaos:
        report["chaos"] = run_chaos(args.workers, n_slots, args.smoke)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)
    with open(METRICS_PATH, "w") as f:
        json.dump(default_registry().snapshot(), f, indent=2)
    print(f"# wrote {METRICS_PATH}", flush=True)
    if not out["bit_identical"]:
        raise SystemExit("serve_load: plane output diverged from the "
                         "synchronous control")
    if args.chaos and (report["chaos"]["lost_sessions"]
                       or not report["chaos"]["bit_identical"]):
        raise SystemExit("serve_load --chaos: sessions lost or diverged "
                         "under faults")


if __name__ == "__main__":
    main()
