"""Trace-driven load bench for the async serving plane (serving/plane.py).

Generates a deterministic request trace — Poisson arrivals, heavy-tailed
(Pareto) session lengths, diurnal tenant skew (tenant popularity rotates
sinusoidally over the virtual day, so load concentrates on different
tenants in different phases of the trace) — and replays it as fast as
possible through a ``ServingPlane`` over bounded paged LM slot grids:
100k+ sessions (``--smoke``: 3k) churning through ``workers x n_slots``
compiled lanes with ``max_sessions`` bounding the live set, so admission
back-pressure (``Rejected``, retried with backoff) is part of steady
state, not an error path.

Reported through the ``repro.obs`` registry and gated by
``check_regression.py --serve``:

  * **TTFR** — time-to-first-result per session, from the client's first
    open attempt (admission retries included: back-pressure IS latency)
    to its first batched push result; p50/p99 from a registry histogram;
  * **goodput-under-churn** — completed tokens/s of wall time over the
    whole replay, retry stalls and all;
  * **bit-identity** — a deterministic sample of sessions is re-decoded
    alone on a synchronous one-slot control service; the plane's token
    streams must match exactly (continuous batching only changes when
    work is grouped, never what a lane computes).

Emits ``BENCH_serve_load.json`` + ``BENCH_serve_metrics.json`` (registry
snapshot); ``--trace out.json`` additionally exports a Perfetto span
trace of the replay.

    PYTHONPATH=src python -m benchmarks.serve_load [--smoke] \\
        [--sessions N] [--workers W] [--trace out.json]
"""

import argparse
import asyncio
import json
import time

import jax
import numpy as np

from repro.configs import RuntimeConfig, get_config
from repro.models import build_bundle
from repro.obs import Tracer
from repro.obs.metrics import default_registry
from repro.serving import Rejected, ServingPlane
from repro.sessions import LMSessionService

OUT_PATH = "BENCH_serve_load.json"
METRICS_PATH = "BENCH_serve_metrics.json"

N_SESSIONS = 100_000
N_TENANTS = 64
SEQ_CAP = 64
T_CHUNK = 4        # decode chunk per dispatch AND per-push token budget
MAX_LEN = 40       # session length cap (< seq_cap - 1 with 1-token prompts)
WINDOW = 256       # concurrent client coroutines (the arrival window)
BIT_SAMPLE = 32    # sessions re-decoded on the synchronous control
DAY = 1000.0       # virtual-seconds per diurnal period


def gen_trace(n_sessions: int, seed: int = 0) -> list[dict]:
    """The deterministic request trace.  Arrival times are a Poisson
    process in virtual time; lengths are 1 + Pareto (mostly a few tokens,
    a long tail up to MAX_LEN); each arrival picks its tenant from a
    diurnal popularity profile (each tenant's weight peaks at its own
    phase of the virtual day)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=DAY / max(n_sessions / 4, 1),
                           size=n_sessions)
    at = np.cumsum(gaps)
    lengths = 1 + np.minimum(rng.pareto(1.5, n_sessions) * 3,
                             MAX_LEN - 1).astype(np.int64)
    phase = 2 * np.pi * (at[:, None] / DAY
                         + np.arange(N_TENANTS)[None, :] / N_TENANTS)
    w = 1.0 + 0.9 * np.sin(phase)  # (n_sessions, N_TENANTS) diurnal skew
    w /= w.sum(axis=1, keepdims=True)
    u = rng.random(n_sessions)
    tenants = (w.cumsum(axis=1) < u[:, None]).sum(axis=1)
    prompts = rng.integers(1, 32, size=n_sessions)
    return [{"t": float(at[i]), "tenant": int(tenants[i]),
             "len": int(lengths[i]), "prompt": int(prompts[i])}
            for i in range(n_sessions)]


def _make_worker(bundle, params, n_slots: int, runtime: RuntimeConfig,
                 registry):
    return LMSessionService(
        bundle, params, n_slots=n_slots, seq_cap=SEQ_CAP, t_chunk=T_CHUNK,
        max_sessions=8 * n_slots,  # the bounded live set: churn source
        runtime=runtime, metrics=registry)


async def _replay(plane: ServingPlane, trace: list[dict], registry,
                  sample_every: int) -> dict:
    """Replay the trace through the plane with a bounded arrival window.
    Returns per-session token streams for the bit-identity sample plus
    churn counters."""
    h_ttfr = registry.histogram("serve_ttfr_us")
    sem = asyncio.Semaphore(WINDOW)
    sampled: dict[int, list[int]] = {}
    counters = {"retries": 0, "completed": 0, "tokens": 0}

    async def client(i: int, req: dict):
        try:
            t0 = time.perf_counter()
            attempt = 0
            while True:  # admission back-pressure: retry with backoff
                try:
                    psid = await plane.open_session(
                        np.array([req["prompt"]], np.int32),
                        tenant=req["tenant"])
                    break
                except Rejected as e:
                    if not e.retryable:
                        raise
                    counters["retries"] += 1
                    attempt += 1
                    await asyncio.sleep(min(0.0002 * attempt, 0.01))
            toks: list[int] = []
            first = True
            left = req["len"]
            while left > 0:
                toks += await plane.push(psid, min(left, T_CHUNK))
                if first:
                    h_ttfr.record((time.perf_counter() - t0) * 1e6)
                    first = False
                left -= min(left, T_CHUNK)
            await plane.close(psid)
            counters["completed"] += 1
            counters["tokens"] += len(toks)
            if i % sample_every == 0:
                sampled[i] = toks
        finally:
            sem.release()

    # acquire BEFORE spawning so only ~WINDOW coroutines exist at once
    # (100k pre-built coroutine objects would dominate memory, not serving)
    tasks = []
    for i, req in enumerate(trace):
        await sem.acquire()
        tasks.append(asyncio.ensure_future(client(i, req)))
    await asyncio.gather(*tasks)
    return {"sampled": sampled, **counters}


def _sync_control(bundle, params, trace, sampled, runtime) -> bool:
    """Re-decode every sampled session ALONE on a one-slot synchronous
    service: the strictest control — no plane, no batching, no churn."""
    for i, got in sorted(sampled.items()):
        req = trace[i]
        svc = LMSessionService(bundle, params, n_slots=1, seq_cap=SEQ_CAP,
                               t_chunk=T_CHUNK, max_sessions=1,
                               runtime=runtime)
        sid = svc.open_session(np.array([req["prompt"]], np.int32))
        want = svc.decode({sid: req["len"]})[sid]
        svc.close(sid)
        if got != want:
            print(f"# BIT-IDENTITY FAIL session {i}: plane={got} "
                  f"sync={want}", flush=True)
            return False
    return True


def run(n_sessions: int, n_workers: int, n_slots: int, smoke: bool,
        trace_path: str | None, seed: int = 0) -> dict:
    registry = default_registry()
    runtime = RuntimeConfig(paged=True)  # paged admission is the O(1) path
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    trace = gen_trace(n_sessions, seed=seed)
    sample_every = max(1, n_sessions // BIT_SAMPLE)

    workers = [_make_worker(bundle, params, n_slots, runtime, registry)
               for _ in range(n_workers)]
    tracer = Tracer(enabled=bool(trace_path))
    plane = ServingPlane(workers, max_queue=4 * WINDOW, runtime=runtime,
                         metrics=registry, tracer=tracer)

    # warm the compile caches so the replay measures serving, not XLA
    warm = _make_worker(bundle, params, n_slots, runtime, registry)
    wsid = warm.open_session(np.array([1], np.int32))
    warm.decode({wsid: T_CHUNK})
    registry.histogram("serve_ttfr_us").reset()

    async def main():
        async with plane:
            return await _replay(plane, trace, registry, sample_every)

    t0 = time.perf_counter()
    res = asyncio.run(main())
    wall = time.perf_counter() - t0
    if trace_path:
        tracer.export(trace_path)
        print(f"# wrote {trace_path}", flush=True)

    identical = _sync_control(bundle, params, trace, res["sampled"], runtime)
    h = registry.histogram("serve_ttfr_us")
    snap = registry.snapshot()
    batches = sum(e["value"] for e in snap.get("plane_batches_total", []))
    lanes = snap.get("plane_batch_lanes", [{}])[0]
    rejected = {e["labels"]["reason"]: e["value"]
                for e in snap.get("plane_rejected_total", [])}
    out = {
        "smoke": smoke, "config": cfg.name, "sessions": n_sessions,
        "tenants": N_TENANTS, "workers": n_workers, "n_slots": n_slots,
        "max_live_sessions": n_workers * 8 * n_slots,
        "wall_s": round(wall, 3),
        "completed": res["completed"],
        "tokens_total": res["tokens"],
        "goodput_tok_s": round(res["tokens"] / wall, 1),
        "open_retries": res["retries"],
        "rejected_total": rejected,
        "batches_total": int(batches),
        "mean_batch_lanes": round(lanes.get("sum", 0)
                                  / max(lanes.get("count", 1), 1), 2),
        "ttfr": {"count": h.count, "p50_us": round(h.percentile(50), 1),
                 "p99_us": round(h.percentile(99), 1),
                 "mean_us": round(h.mean, 1)},
        "bit_identical": identical,
        "bit_sample": len(res["sampled"]),
    }
    print(f"# serve_load: {res['completed']}/{n_sessions} sessions, "
          f"{out['goodput_tok_s']} tok/s, TTFR p50={out['ttfr']['p50_us']}us "
          f"p99={out['ttfr']['p99_us']}us, {res['retries']} admission "
          f"retries, {out['batches_total']} batches "
          f"(mean {out['mean_batch_lanes']} lanes), "
          f"bit_identical={identical}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3k sessions on a smaller grid (CI)")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--trace", default=None,
                    help="export a Perfetto span trace of the replay")
    args = ap.parse_args()
    n_sessions = args.sessions if args.sessions is not None else \
        (3_000 if args.smoke else N_SESSIONS)
    n_slots = args.slots if args.slots is not None else \
        (8 if args.smoke else 16)
    out = run(n_sessions, args.workers, n_slots, args.smoke, args.trace)
    with open(OUT_PATH, "w") as f:
        json.dump({"serve_load": out}, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)
    with open(METRICS_PATH, "w") as f:
        json.dump(default_registry().snapshot(), f, indent=2)
    print(f"# wrote {METRICS_PATH}", flush=True)
    if not out["bit_identical"]:
        raise SystemExit("serve_load: plane output diverged from the "
                         "synchronous control")


if __name__ == "__main__":
    main()
