"""Multi-tenant streaming session subsystem: throughput, tail latency, and
park/resume cost over one fixed compiled slot grid.

Demonstrates the subsystem's contract at serving scale:
  * >=64 concurrent sessions advance through ONE jitted batched call/tick;
  * p50/p99 per-tick step latency and aggregate sessions x samples/s;
  * evicting a session to the host parking lot and resuming it later is
    bit-identical to an uninterrupted run (asserted, not just reported);
  * pack/unpack cost and per-session parked-state bytes (the O(R) claim).
"""

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import StreamSessionService

N_SLOTS = 64
TICKS = 40


def _service(bundle, params, bn, **kw):
    return StreamSessionService(bundle, params, bn, n_slots=N_SLOTS,
                                max_tenants=8, max_ways=4, **kw)


def run():
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N_SLOTS, TICKS + 8, cfg.tcn_in_channels)).astype(np.float32)

    # -- steady-state: 64 sessions, one batched call per tick ---------------
    svc = _service(bundle, params, bn)
    # 60 anonymous streams + 4 personalized tenants (the FSL/CL path)
    sids = [svc.open_session() for _ in range(N_SLOTS - 4)]
    sids += [svc.open_session(tenant=None) for _ in range(4)]
    shots = rng.normal(size=(3, 12, cfg.tcn_in_channels)).astype(np.float32)
    svc.push_audio({sid: x[i, 0] for i, sid in enumerate(sids)})  # compile
    lat = []
    for t in range(1, TICKS + 1):
        if t == 5:  # tenants enroll keywords mid-stream, streams stay live
            for sid in sids[-4:]:
                svc.enroll_shots(sid, shots)
        t0 = time.perf_counter()
        svc.push_audio({sid: x[i, t] for i, sid in enumerate(sids)})
        lat.append((time.perf_counter() - t0) * 1e6)
    lat = np.sort(np.asarray(lat))
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    rate = N_SLOTS / (lat.mean() * 1e-6)
    emit("sessions/steady_64", lat.mean(),
         f"{rate:.0f} sessions*samples/s p50={p50:.0f}us p99={p99:.0f}us")

    # -- park / resume cost -------------------------------------------------
    st = svc.stats()
    victim = sids[0]
    t0 = time.perf_counter()
    svc.park(victim)
    park_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    svc.push_audio({victim: x[0, TICKS + 1]})
    resume_us = (time.perf_counter() - t0) * 1e6
    emit("sessions/park", park_us, f"parked_state={st['slot_state_bytes']}B")
    emit("sessions/resume_push", resume_us, "unpack+step")

    # -- evict -> park -> resume is bit-identical ---------------------------
    xa = x[0]
    control = _service(bundle, params, bn)
    c = control.open_session()
    control_out = [control.push_audio({c: xa[t]})[c] for t in range(30)]

    svc2 = _service(bundle, params, bn, max_sessions=N_SLOTS + 8)
    others = [svc2.open_session() for _ in range(N_SLOTS - 1)]
    a = svc2.open_session()
    out = [svc2.push_audio({a: xa[t], **{s: x[j + 1, t] for j, s in
                                         enumerate(others)}})[a]
           for t in range(15)]
    # opening one more session must evict the LRU idle session == a
    for t in range(3):
        svc2.push_audio({s: x[j + 1, 15 + t] for j, s in enumerate(others)})
    extra = svc2.open_session()
    assert svc2.poll(a)["state"] == "parked", "expected LRU eviction of idle session"
    svc2.push_audio({extra: x[0, TICKS]})
    svc2.close(extra)
    for t in range(15, 30):  # resume mid-stream (different slot is fine)
        out.append(svc2.push_audio({a: xa[t]})[a])
    exact = all(
        np.array_equal(out[t]["emb"], control_out[t]["emb"])
        and np.array_equal(out[t]["logits"], control_out[t]["logits"])
        for t in range(30))
    assert exact, "park/resume must be bit-identical to the uninterrupted run"
    emit("sessions/park_resume_exact", 0.0,
         f"bit_identical=True evictions={svc2.stats()['evictions']}")
