"""Session subsystem benchmarks: throughput, tail latency, chunked-dispatch
amortization, and park/resume cost over fixed compiled slot grids — for
BOTH services (``--service tcn|lm|both``).

TCN streaming (--service tcn):
  * >=64 concurrent sessions advance through ONE jitted batched call/tick;
  * chunk sweep (T_chunk in {1, 16, 160}): samples/sec/session as the
    host<->device dispatch cost is amortized over lax.scan time chunks —
    the per-sample baseline pays one dispatch per sample, T_chunk=160 pays
    one per 160 (the 16 kHz raw-audio serving wall is dispatch, not math);
  * grid_scan at T_chunk=160 is asserted bit-exact vs 160 sequential
    grid_step calls (not just reported);
  * p50/p99 per-tick step latency and aggregate sessions x samples/s;
  * evicting a session to the host parking lot and resuming it later is
    bit-identical to an uninterrupted run (asserted, not just reported);
  * pack/unpack cost and per-session parked-state bytes (the O(R) claim).

LM sessions (--service lm):
  * token-chunk sweep (T_chunk in {1, 16}): decoded tokens/s/session as
    dispatch is amortized over ``decode_scan`` token chunks (KV-cache
    chunk ≙ time chunk) — >=3x at 16 vs 1 is asserted, not just reported;
  * evict -> KV park -> resume emits a token stream bit-identical to an
    uninterrupted run (asserted);
  * park/resume wall time and O(pos) parked-blob bytes;
  * speculative decode (``--speculative K``, default 4): tokens/s and
    acceptance rate of the drafter/verifier layer (sessions/spec.py,
    parallel verify + the n-gram self-draft drafter) vs plain chunked
    decode of the same requests on the same grid.  This sweep uses a
    BIGGER model than the dispatch sweep on purpose: speculation
    amortizes the per-step MATH (K+1 positions per weight pass), so the
    model must be large enough that per-step math — not dispatch — is
    the wall being attacked.  check_regression gates the speedup >=1.3x.
  * paged capacity (``--capacity`` reruns just this section): a paged
    grid (sessions/paging.py block pool) vs a dense grid holding the
    SAME device cache bytes, fed heavy-tailed session lengths — resident
    sessions admitted before back-pressure (gated >= 8x dense), and
    admission p50/p99 over open/close cycles (dense admission scrubs a
    seq_cap column on device, paged admission is a host table write;
    p99 gated >= 5x lower).  The paged grid's token streams are asserted
    bit-identical to the dense grid's under slot churn in the same run,
    so the capacity win can never come from a decode divergence.

Emits ``BENCH_session_throughput.json`` ({"tcn": ..., "lm": ...}) next to
the cwd; CI compares it against the committed baseline with
``benchmarks.check_regression`` and fails on regression.  ``--smoke``
shrinks the grids for CI runtime; the asserted properties are identical.

Telemetry (repro.obs): every bench service reports into the process
default registry, so the run also emits ``BENCH_metrics_snapshot.json``
(the full registry snapshot — counters, gauges, per-shape latency
histograms, kernel-build provenance) and each section carries a
``dispatch_latency`` summary (merged p50/p99 of the service's OWN
per-dispatch log2 histograms, reset after warmup so compile time never
pollutes the tail).  ``check_regression`` validates the schema and gates
the p99/p50 tail ratio.  Set ``REPRO_TRACE=trace.json`` to additionally
capture a Perfetto-loadable span trace of the whole run.

    PYTHONPATH=src python -m benchmarks.session_throughput \\
        [--smoke] [--service {tcn,lm,both}] [--speculative K] [--capacity]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.obs.metrics import default_registry, latency_summary
from repro.sessions import (
    AdmissionError,
    LMSessionService,
    SpeculativeDecoder,
    StreamSessionService,
    grid_init,
    grid_scan,
    grid_step,
    lengths_to_valid,
    ngram_drafter,
    parked_bytes,
)

N_SLOTS = 64
TICKS = 40
CHUNK_SWEEP = (1, 16, 160)
SWEEP_SAMPLES = 320  # samples/session per sweep point (divisible by all)
LM_CHUNK_SWEEP = (1, 16)
LM_TOKENS = 48       # tokens/session per timed LM sweep pass
LM_REPS = 7          # best-of-N passes (container timing jitter)
OUT_PATH = "BENCH_session_throughput.json"
METRICS_PATH = "BENCH_metrics_snapshot.json"


def _service(bundle, params, bn, *, n_slots, **kw):
    # every bench service reports into the process-default registry so ONE
    # snapshot (BENCH_metrics_snapshot.json) carries the whole run —
    # services run sequentially, so shared counters never race
    return StreamSessionService(bundle, params, bn, n_slots=n_slots,
                                max_tenants=8, max_ways=4,
                                metrics=default_registry(), **kw)


def _latency_summary(svc) -> dict:
    """p50/p99 of the service's per-shape dispatch-latency histograms,
    merged into one distribution (obs.metrics.latency_summary — log2
    buckets add exactly), plus the per-shape breakdown.  Callers reset
    the registry after warmup so compile-time outliers never pollute the
    steady-state tail."""
    rows = svc.metrics().get("dispatch_latency_us", [])
    rows = [r for r in rows
            if r["labels"].get("service") == svc._service_name]
    return latency_summary(rows, by="shape")


def _chunk_sweep(cfg, bundle, params, bn, *, n_slots, n_samples):
    """samples/sec/session at each compiled chunk size (same total work)."""
    rng = np.random.default_rng(1)
    out = {}
    for t_chunk in CHUNK_SWEEP:
        svc = _service(bundle, params, bn, n_slots=n_slots, t_chunk=t_chunk)
        sids = [svc.open_session() for _ in range(n_slots)]
        x = rng.normal(size=(n_slots, t_chunk, cfg.tcn_in_channels)
                       ).astype(np.float32)
        chunk = {sid: x[i] if t_chunk > 1 else x[i, 0]
                 for i, sid in enumerate(sids)}
        svc.push_audio(chunk)  # compile
        svc.metrics_registry.reset()  # drop compile-time latency outliers
        ticks = max(n_samples // t_chunk, 1)
        t0 = time.perf_counter()
        for _ in range(ticks):
            svc.push_audio(chunk)
        dt = time.perf_counter() - t0
        rate = ticks * t_chunk / dt  # samples/sec/session
        out[t_chunk] = {"samples_per_sec_per_session": rate,
                        "dispatches": svc.dispatches,
                        "us_per_tick": dt / ticks * 1e6,
                        "dispatch_latency": _latency_summary(svc)}
        emit(f"sessions/chunk_T{t_chunk}", dt / ticks * 1e6,
             f"{rate:.0f} samples/s/session over {n_slots} sessions")
    speedup = (out[160]["samples_per_sec_per_session"]
               / out[1]["samples_per_sec_per_session"])
    emit("sessions/chunk_speedup_160v1", 0.0, f"{speedup:.1f}x")
    assert speedup >= 5.0, (
        f"chunked dispatch amortization regressed: T_chunk=160 is only "
        f"{speedup:.1f}x the per-sample baseline (contract: >=5x)")
    return out, speedup


def _assert_scan_matches_steps(cfg, bundle, params, bn, *, n_slots):
    """grid_scan over a 160-sample chunk == 160 sequential grid_step calls,
    bit for bit (ragged: half the slots stop at 87 samples)."""
    T = 160
    rng = np.random.default_rng(2)
    x = rng.normal(size=(n_slots, T, cfg.tcn_in_channels)).astype(np.float32)
    lens = np.where(np.arange(n_slots) % 2 == 0, T, 87)
    states_a = grid_init(cfg, n_slots)
    # params/bn as jit ARGUMENTS: the cross-program exactness discipline
    states_a, emb_a, _ = jax.jit(
        lambda p, b, s, xx, v: grid_scan(p, b, cfg, s, xx, v))(
            params, bn, states_a, jnp.asarray(x), lengths_to_valid(lens, T))
    states_b = grid_init(cfg, n_slots)
    gstep = jax.jit(lambda p, b, s, xx, a: grid_step(p, b, cfg, s, xx, a))
    emb_b = np.zeros((n_slots, T, cfg.embed_dim), np.float32)
    for t in range(T):
        states_b, e, _ = gstep(params, bn, states_b, jnp.asarray(x[:, t]),
                               jnp.asarray(t < lens))
        emb_b[:, t] = np.asarray(e)
    emb_a = np.asarray(emb_a)
    for i in range(n_slots):
        assert np.array_equal(emb_a[i, :lens[i]], emb_b[i, :lens[i]]), \
            f"grid_scan diverged from sequential grid_step at slot {i}"
    for a, b in zip(jax.tree.leaves(states_a), jax.tree.leaves(states_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "grid_scan end state diverged from sequential grid_step"
    emit("sessions/scan_bit_exact_T160", 0.0,
         f"ragged {n_slots}-slot scan == 160 sequential steps")


def run_tcn(smoke: bool = False):
    n_slots = 16 if smoke else N_SLOTS
    ticks = 10 if smoke else TICKS
    n_samples = 160 if smoke else SWEEP_SAMPLES
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n_slots, ticks + 8, cfg.tcn_in_channels)
                   ).astype(np.float32)

    # -- steady-state: one batched per-sample call per tick (T=1 path) ------
    svc = _service(bundle, params, bn, n_slots=n_slots)
    # anonymous streams + 4 personalized tenants (the FSL/CL path)
    sids = [svc.open_session() for _ in range(n_slots - 4)]
    sids += [svc.open_session(tenant=None) for _ in range(4)]
    shots = rng.normal(size=(3, 12, cfg.tcn_in_channels)).astype(np.float32)
    svc.push_audio({sid: x[i, 0] for i, sid in enumerate(sids)})  # compile
    svc.metrics_registry.reset()  # steady-state tails only (no compile)
    lat = []
    for t in range(1, ticks + 1):
        if t == 5:  # tenants enroll keywords mid-stream, streams stay live
            for sid in sids[-4:]:
                svc.enroll_shots(sid, shots)
        t0 = time.perf_counter()
        svc.push_audio({sid: x[i, t] for i, sid in enumerate(sids)})
        lat.append((time.perf_counter() - t0) * 1e6)
    lat = np.sort(np.asarray(lat))
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    rate = n_slots / (lat.mean() * 1e-6)
    emit(f"sessions/steady_{n_slots}", lat.mean(),
         f"{rate:.0f} sessions*samples/s p50={p50:.0f}us p99={p99:.0f}us")
    # the service's OWN histogram view of the same ticks (the telemetry
    # plane the regression gate reads) — captured before the chunk sweep
    # resets the shared registry
    steady_latency = _latency_summary(svc)
    emit("sessions/dispatch_latency", steady_latency["p50_us"],
         f"hist p50={steady_latency['p50_us']:.0f}us "
         f"p99={steady_latency['p99_us']:.0f}us "
         f"n={steady_latency['count']}")

    # -- chunked dispatch amortization (the tentpole metric) ----------------
    sweep, speedup = _chunk_sweep(cfg, bundle, params, bn,
                                  n_slots=n_slots, n_samples=n_samples)
    _assert_scan_matches_steps(cfg, bundle, params, bn,
                               n_slots=4 if smoke else 8)

    # -- park / resume cost -------------------------------------------------
    st = svc.stats()
    victim = sids[0]
    t0 = time.perf_counter()
    svc.park(victim)
    park_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    svc.push_audio({victim: x[0, ticks + 1]})
    resume_us = (time.perf_counter() - t0) * 1e6
    emit("sessions/park", park_us, f"parked_state={st['slot_state_bytes']}B")
    emit("sessions/resume_push", resume_us, "unpack+step")

    # -- evict -> park -> resume is bit-identical (chunked pushes) ----------
    xa = x[0]
    control = _service(bundle, params, bn, n_slots=n_slots)
    c = control.open_session()
    control_out = control.push_audio({c: xa[:30]})[c]

    svc2 = _service(bundle, params, bn, n_slots=n_slots,
                    max_sessions=n_slots + 8)
    others = [svc2.open_session() for _ in range(n_slots - 1)]
    a = svc2.open_session()
    out = svc2.push_audio({a: xa[:15], **{s: x[j + 1, :15] for j, s in
                                          enumerate(others)}})[a]
    # opening one more session must evict the LRU idle session == a
    svc2.push_audio({s: x[j + 1, 15:18] for j, s in enumerate(others)})
    extra = svc2.open_session()
    assert svc2.poll(a)["state"] == "parked", "expected LRU eviction of idle session"
    svc2.push_audio({extra: x[0, ticks]})
    svc2.close(extra)
    tail = svc2.push_audio({a: xa[15:30]})[a]  # resume mid-stream, new slot ok
    emb = np.concatenate([out["emb"], tail["emb"]])
    logits = np.concatenate([out["logits"], tail["logits"]])
    exact = (np.array_equal(emb, control_out["emb"])
             and np.array_equal(logits, control_out["logits"]))
    assert exact, "park/resume must be bit-identical to the uninterrupted run"
    emit("sessions/park_resume_exact", 0.0,
         f"bit_identical=True evictions={svc2.stats()['evictions']}")

    return {
        "config": cfg.name, "smoke": smoke, "n_slots": n_slots,
        "steady_p50_us": p50, "steady_p99_us": p99,
        "dispatch_latency": steady_latency,
        "chunk_sweep": {str(k): v for k, v in sweep.items()},
        "speedup_160_vs_1": speedup,
        "parked_state_bytes": st["slot_state_bytes"],
        "park_us": park_us, "resume_us": resume_us,
    }


# ---------------------------------------------------------------------------
# LM sessions: chunked multi-token decode + KV park/resume
# ---------------------------------------------------------------------------

def _lm_service(bundle, params, *, n_slots, t_chunk, **kw):
    kw.setdefault("seq_cap", 16 + (2 + LM_REPS) * LM_TOKENS)
    kw.setdefault("metrics", default_registry())
    return LMSessionService(bundle, params, n_slots=n_slots, t_chunk=t_chunk,
                            **kw)


def run_lm(smoke: bool = False, speculative_k: int = 4):
    n_slots = 4 if smoke else 8
    n_tokens = 24 if smoke else LM_TOKENS
    # deliberately tiny model: the metric is DISPATCH amortization (the
    # serving wall this subsystem attacks), so per-step math must not
    # drown the per-dispatch cost being amortized — same philosophy as the
    # TCN sweep's smoke config
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
               for _ in range(n_slots)]

    # -- token-chunk sweep: dispatch amortization (the tentpole metric) -----
    sweep, streams = {}, {}
    for t_chunk in LM_CHUNK_SWEEP:
        svc = _lm_service(bundle, params, n_slots=n_slots, t_chunk=t_chunk)
        sids = [svc.open_session(p) for p in prompts]
        # warm run: compiles every bucket the timed runs use (prefill rides
        # along); then best-of-N steady-state passes (container timing
        # jitter dwarfs the single-pass signal)
        out = svc.decode({sid: n_tokens for sid in sids})
        svc.metrics_registry.reset()  # drop compile-time latency outliers
        best, nd = 0.0, 0
        for _ in range(LM_REPS):
            d0 = svc.dispatches
            t0 = time.perf_counter()
            out2 = svc.decode({sid: n_tokens for sid in sids})
            dt = time.perf_counter() - t0
            for sid in sids:
                out[sid] += out2[sid]
            if n_tokens / dt > best:
                best, nd = n_tokens / dt, svc.dispatches - d0
        sweep[t_chunk] = {"tokens_per_sec_per_session": best,
                          "dispatches": nd,
                          "us_per_dispatch": n_tokens / best / nd * 1e6,
                          "dispatch_latency": _latency_summary(svc)}
        streams[t_chunk] = [out[sid] for sid in sids]
        emit(f"lm/chunk_T{t_chunk}", n_tokens / best / nd * 1e6,
             f"{best:.0f} tokens/s/session over {n_slots} sessions")
    for a, b in zip(*[streams[t] for t in LM_CHUNK_SWEEP]):
        assert a == b, "chunked decode diverged from per-token decode"
    speedup = (sweep[16]["tokens_per_sec_per_session"]
               / sweep[1]["tokens_per_sec_per_session"])
    emit("lm/chunk_speedup_16v1", 0.0, f"{speedup:.1f}x")
    assert speedup >= 3.0, (
        f"chunked decode amortization regressed: T_chunk=16 is only "
        f"{speedup:.1f}x the per-token baseline (contract: >=3x)")

    # -- evict -> KV park -> resume is bit-identical ------------------------
    ctl = _lm_service(bundle, params, n_slots=2, t_chunk=8, max_sessions=8)
    c = ctl.open_session(prompts[0])
    want = ctl.decode({c: n_tokens})[c]
    svc = _lm_service(bundle, params, n_slots=2, t_chunk=8, max_sessions=8)
    a = svc.open_session(prompts[0])
    got = svc.decode({a: n_tokens // 3})[a]
    b1 = svc.open_session(prompts[1])   # slot pressure: a is LRU
    b2 = svc.open_session(prompts[2])
    assert svc.poll(a)["state"] == "parked", "expected LRU eviction"
    svc.decode({b1: 4, b2: 4})
    got += svc.decode({a: n_tokens - n_tokens // 3})[a]  # resume, new slot ok
    assert got == want, "KV park/resume must be bit-identical"
    emit("lm/park_resume_exact", 0.0,
         f"bit_identical=True evictions={svc.stats()['evictions']}")

    # -- park / resume cost (O(pos) blob) -----------------------------------
    svc = _lm_service(bundle, params, n_slots=2, t_chunk=8, max_sessions=4)
    s = svc.open_session(prompts[0])
    svc.decode({s: n_tokens // 2})
    svc.decode({s: 1})  # warm the T=1 bucket: time the dispatch, not XLA
    t0 = time.perf_counter()
    svc.park(s)
    park_us = (time.perf_counter() - t0) * 1e6
    blob = parked_bytes(svc.parking[s])
    t0 = time.perf_counter()
    svc.decode({s: 1})
    resume_us = (time.perf_counter() - t0) * 1e6
    emit("lm/park", park_us, f"parked_blob={blob}B at pos="
         f"{svc.sessions[s].steps - 1}")
    emit("lm/resume_decode", resume_us, "unpack+decode")

    return {
        "config": cfg.name, "smoke": smoke, "n_slots": n_slots,
        "dispatch_latency": sweep[16]["dispatch_latency"],
        "chunk_sweep": {str(k): v for k, v in sweep.items()},
        "speedup_16_vs_1": speedup,
        "parked_blob_bytes": blob,
        "park_us": park_us, "resume_us": resume_us,
        "speculative": run_lm_speculative(smoke=smoke, k=speculative_k),
        "capacity": run_lm_capacity(smoke=smoke),
    }


def run_lm_speculative(smoke: bool = False, k: int = 4):
    """Speculative (parallel-verify, n-gram self-draft) vs plain chunked
    decode: same requests, same grid, same t_chunk.  The model here is
    deliberately LARGER than the dispatch-sweep's (d256 vs d16): the
    speculative win is K+1 verify positions per weight pass, so per-step
    math must dominate, which is exactly the regime real decode serving
    sits in (weight-bandwidth bound).  Acceptance is deterministic (fixed
    seed -> fixed streams); only wall time varies, so best-of-N passes."""
    n_slots = 2 if smoke else 4
    n_tokens = 48 if smoke else 96
    reps = 5 if smoke else LM_REPS
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=256, d_ff=512, vocab_size=128, head_dim=64)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(n_slots)]
    seq_cap = 16 + (2 + reps) * n_tokens

    def best_of(decode_fn, sids):
        decode_fn({sid: n_tokens for sid in sids})  # warm: compile + cycle
        best = 0.0
        for _ in range(reps):
            t0 = time.perf_counter()
            decode_fn({sid: n_tokens for sid in sids})
            best = max(best, n_tokens / (time.perf_counter() - t0))
        return best

    plain = LMSessionService(bundle, params, n_slots=n_slots,
                             seq_cap=seq_cap, t_chunk=16,
                             metrics=default_registry())
    base = best_of(plain.decode, [plain.open_session(p) for p in prompts])

    svc = LMSessionService(bundle, params, n_slots=n_slots,
                           seq_cap=seq_cap, t_chunk=16,
                           metrics=default_registry())
    sp = SpeculativeDecoder(svc, ngram_drafter(), k=k, verify="parallel")
    spec = best_of(sp.decode, [svc.open_session(p) for p in prompts])

    speedup = spec / base
    emit(f"lm/speculative_k{k}", 0.0,
         f"{spec:.0f} vs {base:.0f} tokens/s/session "
         f"({speedup:.2f}x, acceptance={sp.acceptance_rate:.2f})")
    return {
        "k": k, "verify": "parallel", "drafter": "ngram",
        "acceptance_rate": sp.acceptance_rate,
        "tokens_per_sec_per_session": spec,
        "baseline_tokens_per_sec_per_session": base,
        "speedup_vs_plain": speedup,
    }


def run_lm_capacity(smoke: bool = False):
    """Paged vs dense resident capacity at EQUAL device cache bytes, plus
    admission latency (the O(1) admission claim) and in-bench bit-identity.

    The dense control reserves a full seq_cap column per slot, so its
    resident ceiling is its slot count.  The paged grid backs 16x the
    slots with a block pool holding the SAME bytes (dense_slots *
    seq_cap positions + one NULL block); heavy-tailed session lengths —
    most prompts fit one block, a long tail takes several — let it bind
    many more live sessions before the pool pushes back.  Admission is
    measured over open/close cycles of 1-token prompts (no prefill on
    either path): dense admission scrubs the slot's cache column with
    per-leaf device writes, paged admission zeroes a host int32 table
    row.  Both ratios are gated by check_regression; the bit-identity
    flag is asserted here (paged streams == dense streams under slot
    churn), so a capacity win can never ride on a decode divergence."""
    block_len, seq_cap, dense_slots = 8, 128, 4
    n_blocks = dense_slots * (seq_cap // block_len)   # equal cache bytes
    paged_slots = dense_slots * 16
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    # heavy-tailed session lengths: mostly one-block prompts, every 9th
    # is a 5-block long-form session (the mix paging exists to serve)
    n_cand = 2 * paged_slots
    lens = rng.integers(3, 8, size=n_cand)
    lens[::9] = 41
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]

    def svc_pair():
        dense = LMSessionService(
            bundle, params, n_slots=dense_slots, seq_cap=seq_cap,
            t_chunk=8, max_sessions=dense_slots, metrics=default_registry())
        paged = LMSessionService(
            bundle, params, n_slots=paged_slots, seq_cap=seq_cap,
            t_chunk=8, max_sessions=paged_slots, paged=True,
            block_len=block_len, n_blocks=n_blocks, prefix_cache=False,
            metrics=default_registry())
        return dense, paged

    def cache_bytes(svc):
        return int(sum(np.asarray(a).nbytes
                       for a in jax.tree.leaves(svc.cache)))

    def admit_until_backpressure(svc):
        opened = []
        try:
            for p in prompts:
                opened.append(svc.open_session(p))
        except AdmissionError:
            pass
        else:
            raise AssertionError("candidate pool never hit back-pressure")
        return opened

    def admission_cycles(svc, reps=100):
        tok = np.array([1], np.int32)
        for _ in range(3):  # warm the eager scrub ops / host paths
            svc.close(svc.open_session(tok))
        lat = []
        for _ in range(reps):
            t0 = time.perf_counter()
            sid = svc.open_session(tok)
            jax.block_until_ready(jax.tree.leaves(svc.cache))
            lat.append((time.perf_counter() - t0) * 1e6)
            svc.close(sid)
        lat = np.asarray(lat)
        return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))

    dense, paged = svc_pair()
    out = {}
    for name, svc in (("dense", dense), ("paged", paged)):
        opened = admit_until_backpressure(svc)
        resident = len(opened)
        for sid in opened:
            svc.close(sid)
        p50, p99 = admission_cycles(svc)
        out[name] = {"n_slots": svc.n_slots, "seq_cap": seq_cap,
                     "resident_sessions": resident,
                     "cache_bytes": cache_bytes(svc),
                     "admit_p50_us": p50, "admit_p99_us": p99}
        emit(f"lm/capacity_{name}", p50,
             f"{resident} resident sessions in {cache_bytes(svc)}B "
             f"admit p50={p50:.0f}us p99={p99:.0f}us")
    out["paged"].update(block_len=block_len, n_blocks=n_blocks)
    if paged.paged:
        paged.pool.check()  # nothing leaked across the admission storm

    # -- paged == dense bit-identity under slot churn (same run) ------------
    bi_prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
                  for _ in range(4)]

    def churn_streams(**kw):
        svc = LMSessionService(bundle, params, n_slots=2, seq_cap=seq_cap,
                               t_chunk=8, max_sessions=8,
                               metrics=default_registry(), **kw)
        sids = [svc.open_session(p) for p in bi_prompts]
        streams = {s: [] for s in sids}
        for _ in range(3):  # pairs alternate slots: every round churns
            for i in (0, 2):
                got = svc.decode({sids[i]: 5, sids[i + 1]: 5})
                for s, toks in got.items():
                    streams[s] += toks
        return list(streams.values())

    identical = churn_streams() == churn_streams(paged=True,
                                                 block_len=block_len)
    assert identical, "paged decode diverged from dense under churn"

    ratio = out["paged"]["resident_sessions"] / out["dense"]["resident_sessions"]
    p99_ratio = out["dense"]["admit_p99_us"] / out["paged"]["admit_p99_us"]
    out.update(capacity_ratio=ratio, admission_p99_ratio=p99_ratio,
               admission_p50_ratio=(out["dense"]["admit_p50_us"]
                                    / out["paged"]["admit_p50_us"]),
               bit_identical=identical, smoke=smoke)
    emit("lm/capacity_ratio", 0.0,
         f"{ratio:.1f}x resident at equal bytes, admission p99 "
         f"{p99_ratio:.1f}x lower, bit_identical={identical}")
    return out


def run(smoke: bool = False):
    """benchmarks/run.py harness entry: both services + the JSON artifact."""
    _write_out({"tcn": run_tcn(smoke=smoke), "lm": run_lm(smoke=smoke)})


def _write_out(sections: dict):
    """Merge new sections into BENCH_session_throughput.json (so
    --service lm refreshes only the lm subtree)."""
    out = {}
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                prev = json.load(f)
            if "tcn" in prev or "lm" in prev:  # ignore pre-split schema
                out = prev
        except (json.JSONDecodeError, OSError):
            pass
    out.update(sections)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {OUT_PATH} ({', '.join(sections)})", flush=True)
    # the full telemetry snapshot of the run (every bench service reports
    # into the process-default registry, kernel builds included) — the CI
    # artifact a failed gate is debugged from
    with open(METRICS_PATH, "w") as f:
        json.dump(default_registry().snapshot(), f, indent=2)
    print(f"# wrote {METRICS_PATH}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids for CI (same asserted properties)")
    ap.add_argument("--service", choices=("tcn", "lm", "both"),
                    default="both")
    ap.add_argument("--speculative", type=int, default=4, metavar="K",
                    help="draft length for the lm speculative sweep")
    ap.add_argument("--capacity", action="store_true",
                    help="rerun ONLY the paged-capacity section and merge "
                         "it into the existing lm subtree")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    sections = {}
    if args.capacity:
        prev = {}
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                prev = json.load(f).get("lm", {})
        sections["lm"] = {**prev,
                          "capacity": run_lm_capacity(smoke=args.smoke)}
    else:
        if args.service in ("tcn", "both"):
            sections["tcn"] = run_tcn(smoke=args.smoke)
        if args.service in ("lm", "both"):
            sections["lm"] = run_lm(smoke=args.smoke,
                                    speculative_k=args.speculative)
    _write_out(sections)


if __name__ == "__main__":
    main()
