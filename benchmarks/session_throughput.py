"""Multi-tenant streaming session subsystem: throughput, tail latency,
chunked-dispatch amortization, and park/resume cost over one fixed
compiled slot grid.

Demonstrates the subsystem's contract at serving scale:
  * >=64 concurrent sessions advance through ONE jitted batched call/tick;
  * chunk sweep (T_chunk in {1, 16, 160}): samples/sec/session as the
    host<->device dispatch cost is amortized over lax.scan time chunks —
    the per-sample baseline pays one dispatch per sample, T_chunk=160 pays
    one per 160 (the 16 kHz raw-audio serving wall is dispatch, not math);
  * grid_scan at T_chunk=160 is asserted bit-exact vs 160 sequential
    grid_step calls (not just reported);
  * p50/p99 per-tick step latency and aggregate sessions x samples/s;
  * evicting a session to the host parking lot and resuming it later is
    bit-identical to an uninterrupted run (asserted, not just reported);
  * pack/unpack cost and per-session parked-state bytes (the O(R) claim).

Emits ``BENCH_session_throughput.json`` next to the cwd so CI can track
the samples/sec trajectory per chunk size.  ``--smoke`` shrinks the grid
for CI runtime; the asserted properties are identical.

    PYTHONPATH=src python -m benchmarks.session_throughput [--smoke]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import (
    StreamSessionService,
    grid_init,
    grid_scan,
    grid_step,
    lengths_to_valid,
)

N_SLOTS = 64
TICKS = 40
CHUNK_SWEEP = (1, 16, 160)
SWEEP_SAMPLES = 320  # samples/session per sweep point (divisible by all)


def _service(bundle, params, bn, *, n_slots, **kw):
    return StreamSessionService(bundle, params, bn, n_slots=n_slots,
                                max_tenants=8, max_ways=4, **kw)


def _chunk_sweep(cfg, bundle, params, bn, *, n_slots, n_samples):
    """samples/sec/session at each compiled chunk size (same total work)."""
    rng = np.random.default_rng(1)
    out = {}
    for t_chunk in CHUNK_SWEEP:
        svc = _service(bundle, params, bn, n_slots=n_slots, t_chunk=t_chunk)
        sids = [svc.open_session() for _ in range(n_slots)]
        x = rng.normal(size=(n_slots, t_chunk, cfg.tcn_in_channels)
                       ).astype(np.float32)
        chunk = {sid: x[i] if t_chunk > 1 else x[i, 0]
                 for i, sid in enumerate(sids)}
        svc.push_audio(chunk)  # compile
        ticks = max(n_samples // t_chunk, 1)
        t0 = time.perf_counter()
        for _ in range(ticks):
            svc.push_audio(chunk)
        dt = time.perf_counter() - t0
        rate = ticks * t_chunk / dt  # samples/sec/session
        out[t_chunk] = {"samples_per_sec_per_session": rate,
                        "dispatches": svc.dispatches - 1,
                        "us_per_tick": dt / ticks * 1e6}
        emit(f"sessions/chunk_T{t_chunk}", dt / ticks * 1e6,
             f"{rate:.0f} samples/s/session over {n_slots} sessions")
    speedup = (out[160]["samples_per_sec_per_session"]
               / out[1]["samples_per_sec_per_session"])
    emit("sessions/chunk_speedup_160v1", 0.0, f"{speedup:.1f}x")
    assert speedup >= 5.0, (
        f"chunked dispatch amortization regressed: T_chunk=160 is only "
        f"{speedup:.1f}x the per-sample baseline (contract: >=5x)")
    return out, speedup


def _assert_scan_matches_steps(cfg, bundle, params, bn, *, n_slots):
    """grid_scan over a 160-sample chunk == 160 sequential grid_step calls,
    bit for bit (ragged: half the slots stop at 87 samples)."""
    T = 160
    rng = np.random.default_rng(2)
    x = rng.normal(size=(n_slots, T, cfg.tcn_in_channels)).astype(np.float32)
    lens = np.where(np.arange(n_slots) % 2 == 0, T, 87)
    states_a = grid_init(cfg, n_slots)
    # params/bn as jit ARGUMENTS: the cross-program exactness discipline
    states_a, emb_a, _ = jax.jit(
        lambda p, b, s, xx, v: grid_scan(p, b, cfg, s, xx, v))(
            params, bn, states_a, jnp.asarray(x), lengths_to_valid(lens, T))
    states_b = grid_init(cfg, n_slots)
    gstep = jax.jit(lambda p, b, s, xx, a: grid_step(p, b, cfg, s, xx, a))
    emb_b = np.zeros((n_slots, T, cfg.embed_dim), np.float32)
    for t in range(T):
        states_b, e, _ = gstep(params, bn, states_b, jnp.asarray(x[:, t]),
                               jnp.asarray(t < lens))
        emb_b[:, t] = np.asarray(e)
    emb_a = np.asarray(emb_a)
    for i in range(n_slots):
        assert np.array_equal(emb_a[i, :lens[i]], emb_b[i, :lens[i]]), \
            f"grid_scan diverged from sequential grid_step at slot {i}"
    for a, b in zip(jax.tree.leaves(states_a), jax.tree.leaves(states_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "grid_scan end state diverged from sequential grid_step"
    emit("sessions/scan_bit_exact_T160", 0.0,
         f"ragged {n_slots}-slot scan == 160 sequential steps")


def run(smoke: bool = False):
    n_slots = 16 if smoke else N_SLOTS
    ticks = 10 if smoke else TICKS
    n_samples = 160 if smoke else SWEEP_SAMPLES
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n_slots, ticks + 8, cfg.tcn_in_channels)
                   ).astype(np.float32)

    # -- steady-state: one batched per-sample call per tick (T=1 path) ------
    svc = _service(bundle, params, bn, n_slots=n_slots)
    # anonymous streams + 4 personalized tenants (the FSL/CL path)
    sids = [svc.open_session() for _ in range(n_slots - 4)]
    sids += [svc.open_session(tenant=None) for _ in range(4)]
    shots = rng.normal(size=(3, 12, cfg.tcn_in_channels)).astype(np.float32)
    svc.push_audio({sid: x[i, 0] for i, sid in enumerate(sids)})  # compile
    lat = []
    for t in range(1, ticks + 1):
        if t == 5:  # tenants enroll keywords mid-stream, streams stay live
            for sid in sids[-4:]:
                svc.enroll_shots(sid, shots)
        t0 = time.perf_counter()
        svc.push_audio({sid: x[i, t] for i, sid in enumerate(sids)})
        lat.append((time.perf_counter() - t0) * 1e6)
    lat = np.sort(np.asarray(lat))
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    rate = n_slots / (lat.mean() * 1e-6)
    emit(f"sessions/steady_{n_slots}", lat.mean(),
         f"{rate:.0f} sessions*samples/s p50={p50:.0f}us p99={p99:.0f}us")

    # -- chunked dispatch amortization (the tentpole metric) ----------------
    sweep, speedup = _chunk_sweep(cfg, bundle, params, bn,
                                  n_slots=n_slots, n_samples=n_samples)
    _assert_scan_matches_steps(cfg, bundle, params, bn,
                               n_slots=4 if smoke else 8)

    # -- park / resume cost -------------------------------------------------
    st = svc.stats()
    victim = sids[0]
    t0 = time.perf_counter()
    svc.park(victim)
    park_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    svc.push_audio({victim: x[0, ticks + 1]})
    resume_us = (time.perf_counter() - t0) * 1e6
    emit("sessions/park", park_us, f"parked_state={st['slot_state_bytes']}B")
    emit("sessions/resume_push", resume_us, "unpack+step")

    # -- evict -> park -> resume is bit-identical (chunked pushes) ----------
    xa = x[0]
    control = _service(bundle, params, bn, n_slots=n_slots)
    c = control.open_session()
    control_out = control.push_audio({c: xa[:30]})[c]

    svc2 = _service(bundle, params, bn, n_slots=n_slots,
                    max_sessions=n_slots + 8)
    others = [svc2.open_session() for _ in range(n_slots - 1)]
    a = svc2.open_session()
    out = svc2.push_audio({a: xa[:15], **{s: x[j + 1, :15] for j, s in
                                          enumerate(others)}})[a]
    # opening one more session must evict the LRU idle session == a
    svc2.push_audio({s: x[j + 1, 15:18] for j, s in enumerate(others)})
    extra = svc2.open_session()
    assert svc2.poll(a)["state"] == "parked", "expected LRU eviction of idle session"
    svc2.push_audio({extra: x[0, ticks]})
    svc2.close(extra)
    tail = svc2.push_audio({a: xa[15:30]})[a]  # resume mid-stream, new slot ok
    emb = np.concatenate([out["emb"], tail["emb"]])
    logits = np.concatenate([out["logits"], tail["logits"]])
    exact = (np.array_equal(emb, control_out["emb"])
             and np.array_equal(logits, control_out["logits"]))
    assert exact, "park/resume must be bit-identical to the uninterrupted run"
    emit("sessions/park_resume_exact", 0.0,
         f"bit_identical=True evictions={svc2.stats()['evictions']}")

    with open("BENCH_session_throughput.json", "w") as f:
        json.dump({
            "config": cfg.name, "smoke": smoke, "n_slots": n_slots,
            "steady_p50_us": p50, "steady_p99_us": p99,
            "chunk_sweep": {str(k): v for k, v in sweep.items()},
            "speedup_160_vs_1": speedup,
            "parked_state_bytes": st["slot_state_bytes"],
        }, f, indent=2)
    print("# wrote BENCH_session_throughput.json", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI (same asserted properties)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
