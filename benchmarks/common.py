"""Shared benchmark utilities: timing, CSV rows, and one cached meta-trained
TCN embedder reused by the FSL/CL benchmarks (Table I / Fig. 15)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


_CACHE = {}


def get_meta_trained_tcn(episodes: int = 260, img: int = 12, n_classes: int = 40,
                         seed: int = 0):
    """Meta-train the paper's TCN PN embedder (reduced for CPU) once."""
    key = (episodes, img, n_classes, seed)
    if key in _CACHE:
        return _CACHE[key]
    from repro.configs import get_config
    from repro.core import protonet as pn
    from repro.data import EpisodicSampler, GlyphClasses, split_classes
    from repro.models import build_bundle
    from repro.models.tcn import tcn_empty_state, tcn_forward
    from repro.training.optim import adamw, apply_updates

    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(16, 16, 16), tcn_kernel=5, embed_dim=32, n_classes=5)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    state = tcn_empty_state(cfg)
    ds = GlyphClasses(n_classes, seed=seed, size=img)
    train_cls, test_cls = split_classes(n_classes, 0.5, seed=seed)
    sampler = EpisodicSampler(ds, train_cls, seed=seed + 1)
    opt_init, opt_update = adamw(2e-3)
    opt_state = opt_init(params)

    def episode_loss(params, state, sx, sy, qx, qy):
        emb_s, _, new_state = tcn_forward(params, state, cfg, sx, train=True)
        emb_q, _, _ = tcn_forward(params, new_state, cfg, qx, train=True)
        s = pn.support_sums(emb_s, sy, 5)
        w, b = pn.pn_fc_from_sums(s, sx.shape[0] // 5)
        logits = pn.pn_logits(emb_q, w, b)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, qy[:, None], 1)[:, 0]
        return jnp.mean(lse - gold), new_state

    @jax.jit
    def step(params, state, opt_state, sx, sy, qx, qy):
        (loss, new_state), grads = jax.value_and_grad(
            episode_loss, has_aux=True)(params, state, sx, sy, qx, qy)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), new_state, opt_state, loss

    for ep in range(episodes):
        sx, sy, qx, qy = sampler.episode(ep, n_ways=5, k_shots=3, n_query=3)
        params, state, opt_state, _ = step(
            params, state, opt_state, jnp.asarray(sx), jnp.asarray(sy),
            jnp.asarray(qx), jnp.asarray(qy))

    out = (cfg, bundle, params, state, ds, test_cls)
    _CACHE[key] = out
    return out


def fsl_accuracy(cfg, params, state, ds, classes, n_ways, k, n_ep=10,
                 log2=False, seed=97):
    from repro.core import protonet as pn
    from repro.data import EpisodicSampler
    from repro.models.tcn import tcn_forward
    sampler = EpisodicSampler(ds, classes, seed=seed)
    accs = []
    for ep in range(n_ep):
        sx, sy, qx, qy = sampler.episode(ep, n_ways, k, n_query=4)
        emb_s, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx),
                                  train=False, quantize=log2)
        emb_q, _, _ = tcn_forward(params, state, cfg, jnp.asarray(qx),
                                  train=False, quantize=log2)
        s = pn.support_sums(emb_s, jnp.asarray(sy), n_ways)
        if log2:
            w, b, _, _ = pn.pn_fc_from_sums_log2(s, k)
        else:
            w, b = pn.pn_fc_from_sums(s, k)
        pred = jnp.argmax(pn.pn_logits(emb_q, w, b), axis=-1)
        accs.append(float(jnp.mean(pred == jnp.asarray(qy))))
    return float(np.mean(accs)), float(np.std(accs) / max(len(accs), 1) ** 0.5)
