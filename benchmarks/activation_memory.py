"""Fig. 9(b) reproduction: activation-memory accounting across TCN-accelerator
buffering strategies — ping-pong [11,19], triple-buffer [13], and Chameleon's
single dual-port FIFO — for the paper's three deployed models."""

import time

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.streaming import cone_stats


def _strategies(cfg, seq_len):
    cmax = max(cfg.tcn_channels)
    fifo = cone_stats(cfg, seq_len)["act_entries"]
    return {
        # ping-pong: two full-layer activation buffers (seq x channels)
        "pingpong": 2 * seq_len * cmax,
        # triple buffer for residuals (UltraTrail)
        "triple": 3 * seq_len * cmax,
        # Chameleon: greedy dilation-aware layer FIFOs (seq-length-free)
        "chameleon_fifo": fifo,
    }


def run():
    cases = [("chameleon-tcn-kws", 63), ("chameleon-tcn", 784),
             ("chameleon-tcn-audio", 16000)]
    for name, T in cases:
        cfg = get_config(name)
        t0 = time.perf_counter()
        strat = _strategies(cfg, T)
        dt = (time.perf_counter() - t0) * 1e6
        kb = {k: v * 0.5 / 1024 for k, v in strat.items()}  # 4-bit acts
        emit(f"actmem_{name}", dt,
             f"pingpong_kB={kb['pingpong']:.1f};triple_kB={kb['triple']:.1f};"
             f"fifo_kB={kb['chameleon_fifo']:.2f};"
             f"reduction={strat['triple'] / strat['chameleon_fifo']:.0f}x")


if __name__ == "__main__":
    run()
