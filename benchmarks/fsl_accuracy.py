"""Table I reproduction: FSL accuracy across ways/shots on sequential
(synthetic-)Omniglot, fp32 and the MatMul-free log2 path.

Omniglot itself is not available offline (DESIGN §1); the benchmark runs the
identical pipeline on procedural glyph classes, so the *mechanism* numbers
(FC-vs-prototype agreement, log2 delta, way/shot scaling) are the
reproducible claims; absolute accuracies are dataset-dependent.
"""

import time

from benchmarks.common import emit, fsl_accuracy, get_meta_trained_tcn


def run():
    cfg, bundle, params, state, ds, test_cls = get_meta_trained_tcn()
    scenarios = [(5, 1), (5, 5), (10, 1), (10, 5), (15, 1)]
    for n_ways, k in scenarios:
        if n_ways > len(test_cls):
            continue
        t0 = time.perf_counter()
        acc, sem = fsl_accuracy(cfg, params, state, ds, test_cls, n_ways, k)
        dt = (time.perf_counter() - t0) * 1e6 / 10
        emit(f"fsl_{n_ways}way_{k}shot_fp32", dt, f"acc={acc:.3f}+-{sem:.3f}")
        acc_q, _ = fsl_accuracy(cfg, params, state, ds, test_cls, n_ways, k,
                                log2=True)
        emit(f"fsl_{n_ways}way_{k}shot_log2", dt,
             f"acc={acc_q:.3f};delta={acc_q - acc:+.3f}")


if __name__ == "__main__":
    run()
