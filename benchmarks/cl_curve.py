"""Fig. 15 reproduction + the served continual-learning curve.

Two benches in one module:

  * ``run()`` — the original enroll-once CL sweep (curve-shape claims of
    Fig. 15: final/average accuracy vs ways for 1/2/5/10 shots).  Per-class
    support and query embeddings are computed ONCE and cached — the
    previous version re-embedded every enrolled class's query clips at
    every step, an O(n^2) stack of TCN forward passes for what is an O(n)
    measurement (the classifier itself is one tiny matmul per checkpoint).

  * ``run_served()`` — the paper's 250-ways-per-tenant silicon demo as a
    SERVED measurement: a paged-bank ``StreamSessionService`` behind the
    async ``ServingPlane``, enrolling one class at a time through the
    plane's ``enroll`` verb (label-keyed, tenant-affine).  Reports
    accuracy checkpoints along the curve, enroll p50/p99 latency from the
    ``repro.obs`` histogram (post-warmup), device bytes per way of the
    block-granular bank, and a bounded-rehearsal replay leg (u4 log2
    latent replay, ``rehearse_tenant``).  A dense enroll-once control
    (``store_add_class`` into a pre-sized ``PrototypeStore``) is built
    from the SAME shot embeddings and the paged bank must stay
    bit-identical to it — FC rows and query logits — at every checkpoint.

Emits ``BENCH_cl_serve.json``, gated by ``check_regression.py --cl``
(accuracy floor, bytes/way bound, enroll-latency tail, bit-identity).

    PYTHONPATH=src python -m benchmarks.cl_curve [--smoke] \\
        [--classes N] [--shots K]
"""

import argparse
import asyncio
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_meta_trained_tcn
from repro.core import protonet as pn
from repro.models.tcn import tcn_forward
from repro.obs.metrics import default_registry, latency_summary
from repro.serving import ServingPlane
from repro.sessions import StreamSessionService, paged_bank_fc

OUT_PATH = "BENCH_cl_serve.json"

N_CLASSES = 250   # the silicon demo's way count (--smoke: 20)
SHOTS = 10        # shots per class in the served curve (--smoke: 5)
N_QUERY = 4       # held-out query clips per class
N_CKPTS = 12      # accuracy checkpoints along the curve
BLOCK_WAYS = 16   # paged-bank block granularity (--smoke: 4)
REHEARSAL_CAP = 8  # rehearsal shots kept per way (--smoke: 4)


def _embed_queries(cfg, params, state, ds, classes, n_query=N_QUERY):
    """Embed each class's held-out query clips ONCE (the O(n^2) fix)."""
    out = []
    for j, c in enumerate(classes):
        q = ds.sample(int(c), n_query, seed=900 + j)
        embq, _, _ = tcn_forward(params, state, cfg, jnp.asarray(q),
                                 train=False)
        out.append(np.asarray(embq))
    return out


def run(max_ways: int = 16):
    """Enroll-once CL sweep (Fig. 15 curve shape)."""
    cfg, bundle, params, state, ds, test_cls = get_meta_trained_tcn()
    n_total = min(max_ways, len(test_cls))
    qry = _embed_queries(cfg, params, state, ds, test_cls[:n_total])
    for shots in (1, 2, 5, 10):
        t0 = time.perf_counter()
        store = pn.store_init(n_total, cfg.embed_dim)
        accs = []
        for j in range(n_total):
            sx = ds.sample(int(test_cls[j]), shots, seed=500 + j)
            emb, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx),
                                    train=False)
            store = pn.store_add_class(store, emb)
            q = jnp.asarray(np.concatenate(qry[:j + 1]))
            gold = np.repeat(np.arange(j + 1), N_QUERY)
            pred = np.asarray(pn.store_classify(store, q))
            accs.append(float(np.mean(pred == gold)))
        dt = (time.perf_counter() - t0) * 1e6 / n_total
        emit(f"cl_{n_total}way_{shots}shot", dt,
             f"final={accs[-1]:.3f};avg={np.mean(accs):.3f}")


# -- the served curve --------------------------------------------------------

def _paged_fc(svc, tenant):
    """The tenant's live FC rows read through its block table — the same
    ``paged_bank_fc`` the service dispatches with."""
    tables, ways = svc.bankpool.slot_tables(np.array([tenant], np.int32))
    w, b = paged_bank_fc(svc.bankpool.s_sums, svc.bankpool.counts,
                         jnp.asarray(tables), jnp.asarray(ways))
    return w[0], b[0]


def _acc(w, b, queries, n_query):
    q = jnp.asarray(np.concatenate(queries))
    logits = np.asarray(pn.pn_logits(q, w, b))
    gold = np.repeat(np.arange(len(queries)), n_query)
    return float(np.mean(logits.argmax(-1) == gold)), logits


def run_served(n_classes: int = N_CLASSES, shots: int = SHOTS,
               block_ways: int = BLOCK_WAYS,
               rehearsal_cap: int = REHEARSAL_CAP,
               smoke: bool = False, seed: int = 0) -> dict:
    registry = default_registry()
    # a 0.5 train/test split feeds the meta-trainer and leaves n_classes
    # unseen classes for the CL curve (the smoke sizing reuses run()'s
    # cached embedder so CI meta-trains once)
    cfg, bundle, params, state, ds, test_cls = get_meta_trained_tcn(
        n_classes=2 * n_classes, seed=seed)
    n = min(n_classes, len(test_cls))
    svc = StreamSessionService(
        bundle, params, bn_state=state, n_slots=2, max_tenants=2,
        max_ways=n, t_chunk=16, paged_bank=True, bank_block_ways=block_ways,
        rehearsal_cap=rehearsal_cap, metrics=registry)

    # warm the enroll path (embed + block-alloc + refine compiles), then
    # reset the latency histogram so tails measure steady state
    warm = ds.sample(int(test_cls[0]), shots, seed=123)
    wsid = svc.open_session(tenant=None)
    svc.enroll_shots(wsid, warm)
    svc.enroll_shots(wsid, warm, way=0)
    svc.close(wsid)
    registry.histogram("enroll_latency_us", service="tcn").reset()

    qry = _embed_queries(cfg, params, state, ds, test_cls[:n])
    ckpts = sorted(set(np.linspace(1, n, min(N_CKPTS, n), dtype=int)))
    store = pn.store_init(n, cfg.embed_dim)  # dense enroll-once control
    curve, identical = [], True
    plane = ServingPlane(svc, metrics=registry)

    async def drive():
        nonlocal store, identical
        async with plane:
            # an explicit tenant id both routes (affinity hash) and claims
            # the tenant's bank row on the tenant-aware TCN service
            psid = await plane.open_session(tenant=0)
            tenant = (await plane.poll(psid))["tenant"]
            assert tenant == 0, tenant
            for j in range(n):
                sx = ds.sample(int(test_cls[j]), shots, seed=500 + j)
                await plane.enroll(psid, sx, label=int(test_cls[j]))
                # dense control folds the SAME embeddings (the service's
                # own jitted embedder on the same clips) enroll-once style
                store = pn.store_add_class(store, svc._embed(jnp.asarray(sx)))
                if j + 1 in ckpts:
                    wp, bp = _paged_fc(svc, tenant)
                    acc, lp = _acc(wp[:n], bp[:n], qry[:j + 1], N_QUERY)
                    wd, bd = pn.store_fc(store)
                    _, ld = _acc(wd, bd, qry[:j + 1], N_QUERY)
                    same = (np.array_equal(np.asarray(wp[:n]), np.asarray(wd))
                            and np.array_equal(np.asarray(bp[:n]),
                                               np.asarray(bd))
                            and np.array_equal(lp, ld))
                    identical = identical and same
                    curve.append([j + 1, round(acc, 4)])
                    print(f"# cl_serve: {j + 1}/{n} ways acc={acc:.3f} "
                          f"bit_identical={same}", flush=True)
            # one probe classification through the serving path proper
            probe = ds.sample(int(test_cls[0]), 1, seed=777)[0]
            res = await plane.push(psid, probe)
            return tenant, int(res["pred"])

    t0 = time.perf_counter()
    tenant, probe_pred = asyncio.run(drive())
    wall = time.perf_counter() - t0

    accs = [a for _, a in curve]
    row = next(r for r in svc.metrics()["enroll_latency_us"]
               if r["labels"].get("service") == "tcn")
    lat = latency_summary([row])
    device_bytes = svc.bankpool.row_bytes(tenant)
    pool = svc.bankpool.stats()
    plane_enrolls = sum(e["value"] for e in
                        svc.metrics().get("plane_enrolls_total", []))

    # bounded-rehearsal leg: replace the exact running sums with the u4
    # log2 latent-replay reconstruction and re-measure the final point
    buffer_bytes = svc.rehearsal.nbytes(tenant)
    svc.rehearse_tenant(tenant)
    wr, br = _paged_fc(svc, tenant)
    racc, _ = _acc(wr[:n], br[:n], qry, N_QUERY)

    out = {
        "smoke": smoke, "n_classes": n, "shots": shots, "n_query": N_QUERY,
        "block_ways": block_ways, "wall_s": round(wall, 3),
        "served": {
            "final_acc": round(accs[-1], 4),
            "avg_acc": round(float(np.mean(accs)), 4),
            "curve": curve,
            "enroll_latency": lat,
            "bit_identical": bool(identical),
            "device_bytes_tenant": int(device_bytes),
            "bytes_per_way": round(device_bytes / n, 1),
            "pool": pool,
            "plane_enrolls": int(plane_enrolls),
            "probe_pred": probe_pred,
        },
        "rehearsal": {
            "cap_per_class": rehearsal_cap,
            "buffer_bytes": int(buffer_bytes),
            "bytes_per_way": round(buffer_bytes / n, 1),
            "final_acc": round(racc, 4),
            "acc_drop": round(accs[-1] - racc, 4),
        },
    }
    print(f"# cl_serve: {n} ways final_acc={accs[-1]:.3f} "
          f"avg_acc={out['served']['avg_acc']:.3f} "
          f"enroll p50={lat['p50_us']:.0f}us p99={lat['p99_us']:.0f}us "
          f"bytes/way={out['served']['bytes_per_way']} "
          f"rehearsal_acc={racc:.3f} bit_identical={identical}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="20-way served curve on the shared CI embedder")
    ap.add_argument("--classes", type=int, default=None)
    ap.add_argument("--shots", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        run(max_ways=8)
        out = run_served(n_classes=args.classes or 20,
                         shots=args.shots or 5,
                         block_ways=4, rehearsal_cap=4, smoke=True)
    else:
        run()
        out = run_served(n_classes=args.classes or N_CLASSES,
                         shots=args.shots or SHOTS)
    with open(OUT_PATH, "w") as f:
        json.dump({"cl_serve": out}, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)


if __name__ == "__main__":
    main()
