"""Fig. 15 reproduction: continual learning — one class at a time via the
prototype store, final & average accuracy vs number of ways for 1/2/5/10
shots.  (The silicon demo reaches 250 ways; the CPU benchmark sweeps to the
synthetic test split's size and reproduces the *curve shape* claims: shots
help at high way-counts with diminishing returns beyond 5.)
"""

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_meta_trained_tcn
from repro.core import protonet as pn
from repro.models.tcn import tcn_forward


def run(max_ways: int = 16):
    cfg, bundle, params, state, ds, test_cls = get_meta_trained_tcn()
    n_total = min(max_ways, len(test_cls))
    for shots in (1, 2, 5, 10):
        t0 = time.perf_counter()
        store = pn.store_init(n_total, cfg.embed_dim)
        accs = []
        for j in range(n_total):
            sx = ds.sample(int(test_cls[j]), shots, seed=500 + j)
            emb, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx),
                                    train=False)
            store = pn.store_add_class(store, emb)
            correct = total = 0
            for jj in range(j + 1):
                q = ds.sample(int(test_cls[jj]), 4, seed=900 + jj)
                embq, _, _ = tcn_forward(params, state, cfg, jnp.asarray(q),
                                         train=False)
                correct += int(jnp.sum(pn.store_classify(store, embq) == jj))
                total += 4
            accs.append(correct / total)
        dt = (time.perf_counter() - t0) * 1e6 / n_total
        emit(f"cl_{n_total}way_{shots}shot", dt,
             f"final={accs[-1]:.3f};avg={np.mean(accs):.3f}")


if __name__ == "__main__":
    run()
