"""Benchmark harness — one module per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""

import argparse
import time

SUITES = [
    ("greedy_tcn", "Fig 8c: greedy dilation-aware vs weight-stationary"),
    ("activation_memory", "Fig 9b: FIFO vs ping-pong/triple buffers"),
    ("kws_efficiency", "Fig 11/12 + Table II: dual-mode PE array model"),
    ("kernel_bench", "kernels: packed-log2 byte savings"),
    ("session_throughput", "multi-tenant sessions: chunked scan sweep "
                           "(audio T_chunk 1/16/160 + LM token chunks), "
                           "p50/p99 latency, park/resume both services"),
    ("fsl_accuracy", "Table I: FSL accuracy (synthetic-Omniglot)"),
    ("cl_curve", "Fig 15: continual-learning curve"),
    ("roofline", "dry-run roofline terms (EXPERIMENTS §Roofline)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    for mod_name, desc in SUITES:
        if args.only and args.only != mod_name:
            continue
        print(f"# --- {mod_name}: {desc}", flush=True)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{mod_name},0,FAILED:{e!r}", flush=True)
            raise
    print(f"# total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
