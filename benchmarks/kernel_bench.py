"""Kernel fast-path benchmark: fused vs unfused chunk scan on real
``chameleon_tcn`` shapes, plus the packed-log2 HBM-byte accounting.

The headline metric is the tentpole contract: advancing a slot grid over a
T_chunk=160 time chunk through the fused block kernels
(core/streaming.make_fused_chunk over kernels/tcn_block.py) vs the
pre-existing per-sample ``lax.scan`` body (``grid_scan``) — same shapes,
same slots, best-of-N wall time.  The fused path must be >= 1.2x on CPU
(benchmarks/check_regression.py gates it against the committed
``BENCH_kernels.json``), and its outputs/end state are ASSERTED
bit-identical to the scan path on the baked params, not just reported.

The quantized sweep is the paper's deployment mode: the unfused path pays
per-STEP weight fake-quantization (160x per chunk); the fused path bakes
it once at session open and expands nibble-packed codes per dispatch.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.streaming import make_fused_chunk
from repro.kernels.ref import log2_matmul_ref
from repro.models import build_bundle
from repro.models.tcn import bake_stream_params, tcn_empty_state
from repro.quant.log2 import compute_scale, pack_nibbles, quantize_log2
from repro.sessions import grid_init, grid_scan, lengths_to_valid

OUT_PATH = "BENCH_kernels.json"
REPS = 5  # best-of-N (container timing jitter)
T_CHUNK = 160
N_SLOTS = 8


def _best_of(f, *args):
    jax.block_until_ready(f(*args))  # compile
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _fused_vs_unfused(cfg, params, bn, *, quantize, n_slots, t_chunk):
    """One sweep point: wall time of both executors + bit-parity assert."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n_slots, t_chunk, cfg.tcn_in_channels))
                    .astype(np.float32))
    lens = jnp.full((n_slots,), t_chunk, jnp.int32)
    valid = lengths_to_valid(np.full(n_slots, t_chunk), t_chunk)
    states = grid_init(cfg, n_slots)

    # the unfused baseline is the deployed path as-is: RAW params, live BN
    # math, per-step fake-quant when quantized
    unfused = jax.jit(lambda p, b, s, xx, v: grid_scan(
        p, b, cfg, s, xx, v, quantize=quantize))
    us_unfused = _best_of(unfused, params, bn, states, x, valid)

    scan_p, scan_bn, fused_p = bake_stream_params(params, bn, cfg,
                                                  quantize=quantize)
    fused = jax.jit(make_fused_chunk(cfg, quantize=quantize))
    us_fused = _best_of(fused, fused_p, states, x, lens)

    # bit-parity on the baked params (the fused service's actual anchor)
    sa, ea, la = jax.jit(lambda p, b, s, xx, v: grid_scan(
        p, b, cfg, s, xx, v, quantize=quantize))(
            scan_p, scan_bn, states, x, valid)
    sb, eb, lb = fused(fused_p, states, x, lens)
    exact = np.array_equal(np.asarray(ea), np.asarray(eb)) and np.array_equal(
        np.asarray(la), np.asarray(lb))
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        exact = exact and np.array_equal(np.asarray(a), np.asarray(b))
    # asserted here (a divergence fails the bench run itself) AND recorded
    # as the computed value, so check_regression's bit_identical gate also
    # catches a stale or hand-edited BENCH_kernels.json
    assert exact, "fused chunk diverged from grid_scan on baked params"

    name = "quantized" if quantize else "fp32"
    emit(f"kernels/fused_chunk_{name}", us_fused,
         f"unfused={us_unfused:.0f}us speedup={us_unfused / us_fused:.2f}x "
         f"bit_identical={bool(exact)}")
    return {"us_unfused": us_unfused, "us_fused": us_fused,
            "speedup_fused": us_unfused / us_fused,
            "bit_identical": bool(exact)}


def _log2_bytes(smoke: bool):
    """Packed-log2 matmul byte accounting (the HBM->VMEM 4x story)."""
    M, K, N = (64, 256, 256) if smoke else (256, 2048, 2048)
    w = jax.random.normal(jax.random.key(0), (K, N)) * 0.05
    s = compute_scale(w)
    packed = pack_nibbles(quantize_log2(w, s))
    x = jax.random.normal(jax.random.key(1), (M, K), jnp.bfloat16)
    f = jax.jit(lambda x, p: log2_matmul_ref(x, p, s))
    us = _best_of(f, x, packed)
    bytes_bf16 = K * N * 2
    bytes_packed = K * N // 2
    emit(f"kernels/log2mm_{M}x{K}x{N}", us,
         f"weight_bytes_saved={1 - bytes_packed / bytes_bf16:.0%};"
         f"packed_MB={bytes_packed / 2 ** 20:.1f}")
    return {"m": M, "k": K, "n": N, "us": us,
            "weight_bytes_saved_pct": 100 * (1 - bytes_packed / bytes_bf16)}


def run(smoke: bool = False):
    cfg = get_config("chameleon-tcn")
    if smoke:
        cfg = cfg.smoke()
    n_slots = 4 if smoke else N_SLOTS
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(jax.random.normal(jax.random.key(7),
                                                       a.shape)),
        tcn_empty_state(cfg))  # non-trivial running stats: folding is real

    out = {"config": cfg.name, "smoke": smoke, "n_slots": n_slots,
           "t_chunk": T_CHUNK}
    for quantize in (False, True):
        key = "quantized" if quantize else "fp32"
        out[key] = _fused_vs_unfused(cfg, params, bn, quantize=quantize,
                                     n_slots=n_slots, t_chunk=T_CHUNK)
    out["log2_matmul"] = _log2_bytes(smoke)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CI (same asserted parity)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
