"""Kernel-level accounting: packed-log2 matmul HBM-byte savings (the
transferable 'MatMul-free' win on TPU) + wall-time of the jnp oracle path on
CPU (Pallas interpret-mode timing is not meaningful; TPU timing needs HW)."""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.ref import log2_matmul_ref
from repro.quant.log2 import compute_scale, pack_nibbles, quantize_log2


def run():
    for (M, K, N) in [(256, 2048, 2048), (1024, 2048, 8192)]:
        w = jax.random.normal(jax.random.key(0), (K, N)) * 0.05
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        x = jax.random.normal(jax.random.key(1), (M, K), jnp.bfloat16)
        f = jax.jit(lambda x, p: log2_matmul_ref(x, p, s))
        us, _ = time_fn(f, x, packed)
        bytes_bf16 = K * N * 2
        bytes_packed = K * N // 2
        # arithmetic intensity gain for the weight-bound decode regime
        emit(f"log2mm_{M}x{K}x{N}", us,
             f"weight_bytes_saved={1 - bytes_packed / bytes_bf16:.0%};"
             f"packed_MB={bytes_packed / 2 ** 20:.1f}")


if __name__ == "__main__":
    run()
