"""Fig. 11/12 + Table II (KWS columns) reproduction from the calibrated
dual-mode PE-array/SRAM cost model: array-size sweep, real-time KWS power in
both modes, peak GOPS/TOPS/W, and the comparison against published
accelerators (constants from the paper's Table II)."""

import time

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.costmodel import PEArrayMode, kws_ops_per_s
from repro.core.streaming import greedy_inference_stats
from repro.launch.analytic import param_count
from repro.models.build import build_bundle

# published comparison points (paper Fig. 12 / Table II)
SOTA = {
    "vocell": {"power_uw": 10.6, "gops": 0.13},
    "tinyvers": {"power_uw": 193.0, "gops": 17.6},
    "ultratrail": {"power_uw": 8.2, "gops": 3.8},
}


def run():
    # the paper's MFCC KWS model: 16.5k params, 63-frame windows
    cfg = get_config("chameleon-tcn-kws")
    macs_per_window = greedy_inference_stats(cfg, 63)["macs"] / 2
    ops_rate = kws_ops_per_s(macs_per_window)

    t0 = time.perf_counter()
    # Fig. 11(a): array-size sweep (leakage/throughput trade)
    best = []
    for n in (2, 4, 8, 16, 32):
        mode = PEArrayMode(n)
        p = mode.realtime_power_w(ops_rate)
        best.append((n, p))
        emit(f"pe_sweep_n{n}", 0.0,
             f"rt_kws_uW={p * 1e6:.2f};peak_gops={mode.peak_gops():.1f};"
             f"clock_kHz={mode.clock_for(ops_rate) / 1e3:.1f}")

    m4, m16 = PEArrayMode(4), PEArrayMode(16)
    p4 = m4.realtime_power_w(ops_rate) * 1e6
    p16 = (m16.realtime_power_w(ops_rate)) * 1e6
    dt = (time.perf_counter() - t0) * 1e6
    emit("dualmode_kws", dt,
         f"mode4_uW={p4:.2f};mode16_uW={p16:.2f};gating_saves={1 - p4 / p16:.0%}")
    # Fig. 12 headline: peak GOPS vs best SotA
    ratio = m16.peak_gops() / max(v["gops"] for v in SOTA.values())
    emit("peak_throughput", 0.0,
         f"peak_gops={m16.peak_gops():.1f};vs_sota={ratio:.1f}x")
    for name, v in SOTA.items():
        emit(f"vs_{name}", 0.0,
             f"power_ratio={v['power_uw'] / p4:.1f}x;"
             f"gops_ratio={m16.peak_gops() / v['gops']:.0f}x")
    # model footprint (Table II: smallest model size among KWS accelerators)
    bundle = build_bundle(cfg)
    n_params = param_count(bundle.param_defs)
    emit("kws_model", 0.0,
         f"params={n_params};kB_log2={n_params * 0.5 / 1024:.1f}")


if __name__ == "__main__":
    run()
