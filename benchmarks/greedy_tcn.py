"""Fig. 8(c) reproduction: activation-memory and compute of Chameleon's
greedy dilation-aware streaming vs a weight-stationary, non-dilation-
optimized baseline, as a function of sequence length (paper: ~90x memory and
~10x compute reduction at 16k steps with the 130k-param budget)."""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.streaming import cone_eval, cone_stats, ws_inference_stats
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state, tcn_forward


def run():
    cfg = get_config("chameleon-tcn-audio")  # raw-audio 16 kHz preset
    for T in (1_000, 4_000, 16_000, 64_000):
        t0 = time.perf_counter()
        ws = ws_inference_stats(cfg, T)
        gr = cone_stats(cfg, T)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"greedy_tcn_seq{T}", dt,
             f"mem_ratio={ws['act_entries'] / gr['act_entries']:.1f}x;"
             f"compute_ratio={ws['macs'] / gr['macs']:.1f}x;"
             f"fifo_kB={gr['act_entries'] * 0.5 / 1024:.2f}")

    # "identical outputs" (Fig. 8c footnote): cone evaluation == dense conv
    small = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8, 8), tcn_kernel=3, embed_dim=12, n_classes=4)
    bundle = build_bundle(small)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(small)
    x = jax.random.normal(jax.random.key(1), (2, 64, 1))
    t0 = time.perf_counter()
    emb_d, _, _ = tcn_forward(params, bn, small, x, train=False)
    emb_c, _, evals = cone_eval(params, bn, small, x)
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(emb_c - emb_d)))
    emit("greedy_identical_outputs", dt,
         f"max_err={err:.2e};cone_evals={evals};dense_evals={64 * 6}")


if __name__ == "__main__":
    run()
