"""LM sessions: many more requests than compiled slots, on one fixed grid.

The LM analog of examples/serve_multitenant.py — the slot grid is a KV
cache, a "time chunk" is a token chunk:

  * true chunked prefill: ``open_session`` feeds the prompt through
    multi-token cached steps in pow2 chunks (causal attention over each
    whole chunk — a 256-token prompt is 8 dispatches, not 256 steps);
  * chunked multi-token decode: one jitted ``decode_scan`` dispatch
    advances every pushed session by up to t_chunk greedy tokens;
  * speculative decode: a pluggable drafter proposes K tokens per lane
    and one dispatch verifies them (sessions/spec.py) — the exact scan
    mode is bit-identical to plain decode for ANY drafter;
  * oversubscription: opening more sessions than slots LRU-evicts an idle
    one — its KV-cache column is packed to a host blob truncated to its
    position (O(pos) bytes, the cost-aware eviction signal);
  * bit-identical resume: an evicted session continues in ANY free slot
    with exactly the token stream of an uninterrupted run;
  * spill/restore: the parking lot survives process restarts through
    checkpoint/store;
  * async serving plane: concurrent clients push through ``ServingPlane``
    (serving/plane.py) and the continuous batcher groups them into shared
    dispatches, bit-identically to pushing alone.

The service is driven through the unified ``SessionService`` protocol
surface — ``push({sid: n_tokens})`` is the LM spelling of the protocol's
hot path (README "Serving plane").

    PYTHONPATH=src python examples/serve_lm_sessions.py
"""

import asyncio
import tempfile

import numpy as np

import jax

from repro.configs import get_config
from repro.models import build_bundle
from repro.serving import ServingPlane
from repro.sessions import (
    LMSessionService,
    SpeculativeDecoder,
    ngram_drafter,
    parked_bytes,
)


def main():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))

    # 2 compiled slots, up to 6 live sessions: churn by construction
    svc = LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                           t_chunk=16, max_sessions=6)

    print("== chunked prefill + chunked decode ==")
    rng = np.random.default_rng(0)
    d0 = svc.dispatches
    a = svc.open_session(rng.integers(0, 64, size=33).astype(np.int32))
    print(f"   33-token prompt chunk-prefilled in {svc.dispatches - d0} "
          f"dispatches (pow2 chunks; was 33 scan steps)")
    b = svc.open_session(rng.integers(0, 64, size=3).astype(np.int32))
    d0 = svc.dispatches
    out = svc.push({a: 24, b: 24})  # protocol verb; decode() is the alias
    print(f"   2 sessions x 24 tokens in {svc.dispatches - d0} dispatches "
          f"(vs 24 per-token)")
    print(f"   a: {out[a][:8]}...  b: {out[b][:8]}...")

    print("== speculative decode: draft K, verify in one dispatch ==")
    spec = SpeculativeDecoder(svc, ngram_drafter(), k=4)  # exact scan mode
    d0 = svc.dispatches
    more = spec.decode({a: 16, b: 16})
    print(f"   16 more tokens each in {svc.dispatches - d0} dispatches, "
          f"acceptance={spec.acceptance_rate:.2f} (bit-identical to plain "
          f"decode by contract)")
    assert more[a] == svc.outputs[a][24:]  # the same stream, continued

    print("== oversubscription: the grid evicts, sessions never notice ==")
    c = svc.open_session(rng.integers(0, 64, size=4).astype(np.int32))
    parked = [s for s in (a, b) if svc.poll(s)["state"] == "parked"]
    blob = parked_bytes(svc.parking[parked[0]])
    print(f"   opened 3rd session on a 2-slot grid -> session {parked[0]} "
          f"parked ({blob} host bytes, O(pos) truncated KV column)")
    svc.decode({c: 8})
    resumed = svc.decode({parked[0]: 8})[parked[0]]  # resumes in a new slot
    print(f"   resumed {parked[0]} bit-identically: {resumed[:8]}")

    print("== spill to disk, restore into a fresh service ==")
    with tempfile.TemporaryDirectory() as d:
        path = svc.spill_parking(f"{d}/lm_lot.npz", include_bound=True)
        fresh = LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                                 t_chunk=16, max_sessions=6)
        restored = fresh.restore_parking(path)
        tail = fresh.decode({restored[0]: 4})[restored[0]]
        print(f"   restored sessions {restored} from {path.split('/')[-1]}; "
              f"session {restored[0]} continued with {tail}")
    print(f"   stats: {svc.stats()['evictions']} evictions, "
          f"{svc.stats()['dispatches']} dispatches total")

    print("== async serving plane: concurrent clients, batched dispatches ==")

    async def plane_demo():
        worker = LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                                  t_chunk=16, max_sessions=6)

        async def client(tenant, n):
            psid = await plane.open_session(
                rng.integers(0, 64, size=2).astype(np.int32), tenant=tenant)
            toks = await plane.push(psid, n)   # grouped with other clients
            await plane.close(psid)
            return toks

        async with ServingPlane(worker) as plane:
            d0 = worker.dispatches
            streams = await asyncio.gather(client("alice", 8),
                                           client("bob", 8))
            print(f"   2 concurrent clients x 8 tokens in "
                  f"{worker.dispatches - d0} shared dispatches: "
                  f"{[s[:4] for s in streams]}...")
            lanes = plane.metrics()["plane_batch_lanes"][0]
            print(f"   continuous batches of up to {int(lanes['max'])} "
                  f"lanes, bit-identical to solo runs by contract")

    asyncio.run(plane_demo())
    print("done.")


if __name__ == "__main__":
    main()
