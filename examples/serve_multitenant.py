"""Multi-tenant streaming KWS: the paper's per-user deployment story as a
service.  Two tenants enroll *different* personalized keyword sets (FSL
through the shared TCN embedder) while their audio streams are live; a
burst of extra sessions then overflows the slot grid, forcing LRU eviction
to the host parking lot and a bit-exact resume.

Runs on the fused kernel fast path (``RuntimeConfig(fused=True)``: BN
folded at construction, one fused block op per TCN block per tick —
README "Kernel fast path"); pass ``fused=False`` below for the
per-sample scan body.  The service is driven through the unified
``SessionService`` protocol surface (``push`` — README "Serving plane").

    PYTHONPATH=src python examples/serve_multitenant.py
"""

import numpy as np

import jax

from repro.configs import RuntimeConfig, get_config
from repro.data import KeywordAudio
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import StreamSessionService

# one resolved view of the process switches (explicit > env > default)
RUNTIME = RuntimeConfig.resolve(fused=True)


def stream_clip(svc, sid, frames):
    """Push a whole (T, C_in) clip as ONE ragged chunk (ceil(T / t_chunk)
    jitted dispatches) and return the end-of-chunk view of the result."""
    res = svc.push({sid: frames})[sid]
    tl = res["tenant_logits"]
    return {"pred": res["pred"], "step": res["step"],
            "emb": res["emb"][-1], "logits": res["logits"][-1],
            "tenant_logits": None if tl is None else tl[-1]}


def main():
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    svc = StreamSessionService(bundle, params, tcn_empty_state(cfg),
                               n_slots=4, max_tenants=4, max_ways=4,
                               max_sessions=12, runtime=RUNTIME)
    audio = KeywordAudio(n_classes=6, seed=0)

    print("== two tenants enroll different keyword sets, streams live ==")
    alice = svc.open_session(tenant=None)
    bob = svc.open_session(tenant=None)
    for cls in (0, 1):   # alice's keywords: classes 0, 1
        svc.enroll_shots(alice, audio.mfcc(audio.sample(cls, 3, seed=cls)))
    for cls in (2, 3):   # bob's keywords: classes 2, 3
        svc.enroll_shots(bob, audio.mfcc(audio.sample(cls, 3, seed=cls)))
    qa = audio.mfcc(audio.sample(0, 1, seed=50))[0]
    qb = audio.mfcc(audio.sample(3, 1, seed=51))[0]
    ra = stream_clip(svc, alice, qa)
    rb = stream_clip(svc, bob, qb)
    print(f"   alice heard class 0 -> way {ra['pred']} of {svc.poll(alice)['n_ways']}"
          f" (tenant logits {np.round(ra['tenant_logits'][:2], 1)})")
    print(f"   bob   heard class 3 -> way {rb['pred']} of {svc.poll(bob)['n_ways']}"
          f" (tenant logits {np.round(rb['tenant_logits'][:2], 1)})")

    print("== continual learning: bob appends a way mid-stream ==")
    svc.enroll_shots(bob, audio.mfcc(audio.sample(4, 3, seed=4)))
    rb2 = stream_clip(svc, bob, audio.mfcc(audio.sample(4, 1, seed=52))[0])
    print(f"   bob now has {svc.poll(bob)['n_ways']} ways; "
          f"class 4 query -> way {rb2['pred']}")

    print("== slot pressure: 6 more sessions on a 4-slot grid ==")
    burst = [svc.open_session() for _ in range(6)]
    svc.push({sid: qa[:10] for sid in burst[:4]})  # one chunked tick
    print(f"   stats: {svc.stats()}")
    print(f"   alice is {svc.poll(alice)['state']} (evicted to the parking lot)")
    ra2 = svc.push({alice: qa[0]})[alice]  # resumes bit-exactly
    print(f"   alice resumed at step {ra2['step']}, state "
          f"{svc.poll(alice)['state']}, pred way {ra2['pred']}")
    for sid in burst:
        svc.close(sid)
    print("done.")


if __name__ == "__main__":
    main()
