"""End-to-end driver (the paper's kind): meta-train the Chameleon TCN
embedder with prototypical episodes for a few hundred steps, then evaluate

  * FSL on unseen classes (Table I protocol: ways x shots),
  * few-shot CONTINUAL learning, one class at a time (Fig. 15 protocol),
  * the MatMul-free deployment path (log2 QAT weights + Eq. 8 extraction),

with checkpointing so the run is resumable.

    PYTHONPATH=src python examples/fsl_episodic.py [--episodes 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import protonet as pn
from repro.data import EpisodicSampler, GlyphClasses, split_classes
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state, tcn_forward
from repro.training.optim import adamw, apply_updates
from repro.checkpoint import store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=300)
    ap.add_argument("--img", type=int, default=12)
    ap.add_argument("--classes", type=int, default=60)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(16, 16, 16, 16), tcn_kernel=5, embed_dim=32, n_classes=5)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    state = tcn_empty_state(cfg)
    ds = GlyphClasses(args.classes, seed=0, size=args.img)
    train_cls, test_cls = split_classes(args.classes, 0.6, seed=0)
    sampler = EpisodicSampler(ds, train_cls, seed=1)
    opt_init, opt_update = adamw(2e-3)
    opt_state = opt_init(params)

    def episode_loss(params, state, sx, sy, qx, qy):
        emb_s, _, new_state = tcn_forward(params, state, cfg, sx, train=True)
        emb_q, _, _ = tcn_forward(params, new_state, cfg, qx, train=True)
        s = pn.support_sums(emb_s, sy, 5)
        w, b = pn.pn_fc_from_sums(s, sx.shape[0] // 5)
        logits = pn.pn_logits(emb_q, w, b)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, qy[:, None], 1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == qy).astype(jnp.float32))
        return jnp.mean(lse - gold), (new_state, acc)

    @jax.jit
    def step(params, state, opt_state, sx, sy, qx, qy):
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            episode_loss, has_aux=True)(params, state, sx, sy, qx, qy)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), new_state, opt_state, loss, acc

    start = 0
    if args.ckpt_dir:
        got = store.restore_flat(args.ckpt_dir)
        if got:
            print(f"[resume] from episode {got[0]}")

    t0 = time.time()
    for ep in range(start, args.episodes):
        sx, sy, qx, qy = sampler.episode(ep, n_ways=5, k_shots=3, n_query=3)
        params, state, opt_state, loss, acc = step(
            params, state, opt_state, jnp.asarray(sx), jnp.asarray(sy),
            jnp.asarray(qx), jnp.asarray(qy))
        if ep % 25 == 0:
            print(f"[meta-train] ep {ep:4d} loss {float(loss):.3f} "
                  f"qacc {float(acc):.2f}")
        if args.ckpt_dir and (ep + 1) % 100 == 0:
            store.save(args.ckpt_dir, ep + 1,
                       {"params": params, "state": state})
    print(f"[meta-train] {args.episodes} episodes in {time.time() - t0:.0f}s")

    # ---- Table I protocol: FSL on UNSEEN classes --------------------------
    def fsl(n_ways, k, log2=False, n_ep=10):
        es = EpisodicSampler(ds, test_cls, seed=99)
        accs = []
        for e in range(n_ep):
            sx, sy, qx, qy = es.episode(e, n_ways, k, n_query=4)
            emb_s, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx),
                                      train=False, quantize=log2)
            emb_q, _, _ = tcn_forward(params, state, cfg, jnp.asarray(qx),
                                      train=False, quantize=log2)
            s = pn.support_sums(emb_s, jnp.asarray(sy), n_ways)
            if log2:
                w, b, _, _ = pn.pn_fc_from_sums_log2(s, k)
            else:
                w, b = pn.pn_fc_from_sums(s, k)
            pred = jnp.argmax(pn.pn_logits(emb_q, w, b), -1)
            accs.append(float(jnp.mean(pred == jnp.asarray(qy))))
        return np.mean(accs), 1.96 * np.std(accs) / len(accs) ** 0.5

    print("\n== FSL on unseen classes (Table I protocol) ==")
    for n_ways, k in [(5, 1), (5, 5), (10, 1), (10, 5)]:
        a, ci = fsl(n_ways, k)
        aq, _ = fsl(n_ways, k, log2=True)
        print(f"  {n_ways:2d}-way {k}-shot: fp32 {a:.3f} +- {ci:.3f} | "
              f"log2 (Eq. 8) {aq:.3f}")

    # ---- Fig. 15 protocol: continual learning -----------------------------
    print("\n== Continual learning, one class at a time (Fig. 15) ==")
    n_cl = min(20, len(test_cls))
    for shots in (1, 5):
        st_ = pn.store_init(n_cl, cfg.embed_dim)
        accs = []
        for j in range(n_cl):
            sx = ds.sample(int(test_cls[j]), shots, seed=700 + j)
            emb, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx),
                                    train=False)
            st_ = pn.store_add_class(st_, emb)
            c = t = 0
            for jj in range(j + 1):
                q = ds.sample(int(test_cls[jj]), 4, seed=800 + jj)
                embq, _, _ = tcn_forward(params, state, cfg, jnp.asarray(q),
                                         train=False)
                c += int(jnp.sum(pn.store_classify(st_, embq) == jj))
                t += 4
            accs.append(c / t)
        print(f"  {shots}-shot: final({n_cl} ways) {accs[-1]:.3f} "
              f"avg {np.mean(accs):.3f}")
    print("\ndone — learning was a forward pass + segment-sum throughout "
          "(no gradients after meta-training).")


if __name__ == "__main__":
    main()
