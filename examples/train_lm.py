"""LM training example with fault tolerance: train a reduced assigned arch on
the synthetic seekable stream, kill mid-run, and resume exactly.

    PYTHONPATH=src python examples/train_lm.py [--arch olmo-1b] [--steps 60]

(On hardware, drop --smoke sizing and point --mesh at the pod; see
repro/launch/train.py for the production entrypoint.)
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import lm_batch
from repro.models import build_bundle
from repro.training import TrainConfig, Trainer
from repro.training.optim import adamw, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[setup] {cfg.name} (reduced): {n/1e6:.2f}M params")

    data = lambda s: {k: jnp.asarray(v) for k, v in
                      lm_batch(s, args.batch, args.seq, cfg.vocab_size).items()}
    opt = adamw(warmup_cosine(3e-3, args.steps // 10, args.steps))

    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainConfig(steps=args.steps, ckpt_dir=ckpt, ckpt_every=20,
                         log_every=10)
        print("[run 1] training, will 'crash' at 60% ...")
        t1 = Trainer(bundle.loss_fn, params, tc, data, optimizer=opt)
        t1.run(steps=int(args.steps * 0.6))
        t1.ckpt.wait()

        print("[run 2] relaunch -> auto-resume from latest valid checkpoint")
        t2 = Trainer(bundle.loss_fn, params, tc, data, optimizer=opt)
        resumed = t2.maybe_resume()
        print(f"[run 2] resumed at step {resumed}")
        state, hist = t2.run()
        for h in hist:
            print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
                  f"acc {h.get('acc', 0):.3f}")
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"[done] loss {first:.3f} -> {last:.3f} "
              f"(copy-task structure learned: {last < first})")


if __name__ == "__main__":
    main()
