"""Serving example: (a) real-time streaming KWS through the session-service
façade (the blessed entry point — sessions/service.py), and (b) batched LM
serving with slot reuse — now chunk-native: LMServer rides
sessions/lm.decode_scan, so each step() is ONE jitted dispatch for every
live request and prefill is folded into the first decode chunk.  For
multi-tenant personalization, eviction, and park/resume see
examples/serve_multitenant.py (TCN) and examples/serve_lm_sessions.py (LM
KV-cache park/resume + oversubscription).

    PYTHONPATH=src python examples/serve_stream.py
"""

import numpy as np

import jax

from repro.configs import get_config
from repro.data import KeywordAudio
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.serving import LMServer, ServeConfig
from repro.sessions import StreamSessionService


def main():
    print("== streaming KWS (ring-buffer TCN, MFCC frontend, chunked) ==")
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    svc = StreamSessionService(bundle, params, tcn_empty_state(cfg),
                               n_slots=2, max_tenants=1, t_chunk=16)
    audio = KeywordAudio(n_classes=4, seed=0)
    clips = np.concatenate([audio.sample(0, 1, seed=1),
                            audio.sample(2, 1, seed=2)])
    frames = audio.mfcc(clips)  # (2, 63, 28)
    streams = [svc.open_session() for _ in range(2)]
    # one ragged-chunk push streams the whole clip: ceil(63/16)=4 jitted
    # dispatches instead of 63, per-sample logits still come back
    res = svc.push_audio({sid: frames[i] for i, sid in enumerate(streams)})
    logits = np.stack([res[sid]["logits"][-1] for sid in streams])
    print(f"   streamed {frames.shape[1]} frames x2 sessions in "
          f"{svc.dispatches} dispatches -> end-of-clip logits {logits.shape}, "
          f"argmax {logits.argmax(-1)}")

    print("== batched LM serving (slot reuse) ==")
    lcfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    lbundle = build_bundle(lcfg)
    lparams = lbundle.init(jax.random.key(1))
    lm = LMServer(lbundle, lparams, ServeConfig(max_batch=4, seq_cap=48))
    r1 = lm.add_request(np.array([1, 2, 3], np.int32))
    r2 = lm.add_request(np.array([9, 8], np.int32))
    for _ in range(8):
        lm.step()
    print(f"   req {r1}: {lm.outputs[r1]}")
    print(f"   req {r2}: {lm.outputs[r2]}")
    lm.finish(r1)
    r3 = lm.add_request(np.array([5], np.int32))
    lm.step()
    print(f"   slot reused for req {r3}: {lm.outputs[r3]}")
    print("done.")


if __name__ == "__main__":
    main()
