"""Quickstart: the paper's loop in two minutes on CPU.

1. meta-train a tiny Chameleon TCN embedder on synthetic sequential glyphs,
2. learn a NEW 5-way task gradient-free via the PN-as-FC head (Eq. 6),
3. stream one query through the ring-buffer executor.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from benchmarks.common import get_meta_trained_tcn
from repro.core import protonet as pn
from repro.core.streaming import stream_init, stream_step
from repro.data import EpisodicSampler
from repro.models.tcn import tcn_forward


def main():
    print("== meta-training a 3-block TCN PN embedder (synthetic Omniglot) ==")
    cfg, bundle, params, state, ds, test_cls = get_meta_trained_tcn(episodes=80)

    print("== gradient-free FSL on unseen classes (PN-as-FC, Eq. 6) ==")
    sampler = EpisodicSampler(ds, test_cls, seed=5)
    sx, sy, qx, qy = sampler.episode(0, n_ways=5, k_shots=3, n_query=4)
    emb_s, _, _ = tcn_forward(params, state, cfg, jnp.asarray(sx), train=False)
    w, b = pn.pn_fc_from_sums(
        pn.support_sums(emb_s, jnp.asarray(sy), 5), k=3)
    emb_q, _, _ = tcn_forward(params, state, cfg, jnp.asarray(qx), train=False)
    pred = jnp.argmax(pn.pn_logits(emb_q, w, b), axis=-1)
    acc = float(jnp.mean(pred == jnp.asarray(qy)))
    print(f"   learned 5 new classes from 15 examples -> query acc {acc:.2f} "
          f"(chance 0.20)")

    print("== streaming one query through the ring-buffer executor ==")
    sstate = stream_init(cfg, 1)
    x = jnp.asarray(qx[:1])
    step = jax.jit(lambda s, xt: stream_step(params, state, cfg, s, xt))
    for t in range(x.shape[1]):
        sstate, emb, _ = step(sstate, x[:, t])
    full, _, _ = tcn_forward(params, state, cfg, x, train=False)
    err = float(jnp.max(jnp.abs(emb - full)))
    print(f"   streaming output == full conv (max err {err:.1e})")
    print("done.")


if __name__ == "__main__":
    main()
