"""Analytic FLOP / HBM-byte counters per (arch x shape) — the primary
roofline source.

Why not cost_analysis(): XLA's HloCostAnalysis counts a while-loop body ONCE,
so any scanned program (we scan over layers, loss chunks, KV chunks — by
design, for O(1)-in-depth compile time) is undercounted by the trip count.
The dry-run records BOTH: the raw cost_analysis numbers and these analytic
counts; collectives come from the HLO text with trip-count correction
(utils/hlo.py).  All formulas below are standard dense-algebra op counts
(2 flops per MAC), auditable per family.

Conventions: counts are GLOBAL (whole step, all chips); causal attention
scores average S/2 keys per query; the train multiplier is
fwd + bwd (2x) + full-remat recompute (1x) = 4x forward flops
(remat_policy="dots" saves the recompute on matmuls: 3x + attention extras).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.shapes import ENCDEC_ENC_LEN, SHAPES
from repro.models.config import ArchConfig
from repro.sharding.rules import ParamDef
import jax

# ---------------------------------------------------------------------------
# Parameter counts (exact, from the ParamDef tree)
# ---------------------------------------------------------------------------

def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    total = 0
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def active_param_count(cfg: ArchConfig, defs) -> int:
    """MoE: only topk/E of routed-expert params are active per token."""
    total = param_count(defs)
    if not cfg.n_experts:
        return total
    n_moe_layers = cfg.n_layers - cfg.n_dense_layers
    routed = n_moe_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff_expert
    active_routed = routed * cfg.moe_topk / cfg.n_experts
    return int(total - routed + active_routed)


# ---------------------------------------------------------------------------
# Per-layer forward FLOPs (per token unless stated)
# ---------------------------------------------------------------------------

def _attn_flops_tok(cfg: ArchConfig, kv_len: float) -> float:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    if cfg.use_mla:
        dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
        proj = 2 * D * (H * (dn + dr)) + 2 * D * (r + dr) \
            + 2 * r * H * (dn + dv) * (kv_len and 1)  # ukv recompute: see below
        # NOTE decode recomputes k/v from the latent for the whole context:
        # that term is kv_len-dependent and added in decode accounting.
        scores = 2 * kv_len * H * (dn + dr) + 2 * kv_len * H * dv
        out = 2 * (H * dv) * D
        return proj + scores + out
    proj = 2 * D * H * Dh + 2 * 2 * D * Hkv * Dh
    scores = 2 * kv_len * H * Dh * 2
    out = 2 * H * Dh * D
    return proj + scores + out


def _mlp_flops_tok(cfg: ArchConfig) -> float:
    mats = 3 if cfg.mlp_type == "swiglu" else 2
    return mats * 2 * cfg.d_model * cfg.d_ff


def _moe_flops_tok(cfg: ArchConfig) -> float:
    f = 2 * cfg.d_model * cfg.n_experts  # router
    f += cfg.moe_topk * 3 * 2 * cfg.d_model * cfg.d_ff_expert
    f += cfg.n_shared_experts * 3 * 2 * cfg.d_model * cfg.d_ff_expert
    return f


def _rwkv_flops_tok(cfg: ArchConfig) -> float:
    D, F = cfg.d_model, cfg.d_ff
    Dh = cfg.rwkv_head_dim
    H = D // Dh
    R = cfg.rwkv_decay_lora
    from repro.models.rwkv import CHUNK
    C = CHUNK
    proj = 5 * 2 * D * D + 2 * D * R + 2 * R * D   # r,k,v,g,o + decay LoRA
    wkv = H * (4 * C * Dh + 6 * Dh * Dh)           # chunked intra + state
    chan = 2 * 2 * D * F + 2 * D * D
    return proj + wkv + chan


def _mamba_flops_tok(cfg: ArchConfig) -> float:
    D = cfg.d_model
    di = cfg.ssm_expand * D
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    H = di // P
    from repro.models.ssm import CHUNK
    C = CHUNK
    conv_dim = di + 2 * N
    proj = 2 * D * (2 * di + 2 * N + H)
    conv = 2 * conv_dim * cfg.ssm_conv_k
    ssd = H * (2 * C * (N + P) + 4 * N * P)
    out = 2 * di * D
    return proj + conv + ssd + out


def _tcn_flops_tok(cfg: ArchConfig) -> float:
    k = cfg.tcn_kernel
    f = 0.0
    c_in = cfg.tcn_in_channels
    for c in cfg.tcn_channels:
        f += 2 * k * (c_in * c + c * c)
        if c_in != c:
            f += 2 * c_in * c
        c_in = c
    return f


def layer_fwd_flops_tok(cfg: ArchConfig, kv_len: float, moe_layer: bool) -> float:
    if cfg.family == "rwkv":
        return _rwkv_flops_tok(cfg)
    if cfg.family == "hybrid":
        return _mamba_flops_tok(cfg)
    f = _attn_flops_tok(cfg, kv_len)
    f += _moe_flops_tok(cfg) if moe_layer else _mlp_flops_tok(cfg)
    return f


# ---------------------------------------------------------------------------
# Whole-step global FLOPs per (cfg, shape)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Counts:
    flops_global: float      # whole step, all chips
    bytes_global: float      # HBM traffic estimate, all chips
    model_flops: float       # 6 * N_active * tokens (train) or 2*N*tokens
    n_params: int
    n_params_active: int


def _train_mult(cfg: ArchConfig) -> float:
    return 4.0 if cfg.remat_policy == "nothing" else 3.2


def count_cell(cfg: ArchConfig, defs, shape_name: str,
               param_bytes: int = 4) -> Counts:
    s = SHAPES[shape_name]
    B, S = s.global_batch, s.seq_len
    N = param_count(defs)
    Na = active_param_count(cfg, defs)
    V, D, L = cfg.vocab_size, cfg.d_model, cfg.n_layers

    if cfg.family == "tcn":
        T = B * S
        fwd = T * _tcn_flops_tok(cfg)
        mult = _train_mult(cfg) if s.kind == "train" else 1.0
        return Counts(fwd * mult, N * 4 * 10, 6.0 * N * T, N, N)

    if s.kind == "train":
        if cfg.family == "audio":
            T_dec = B * (S // 2)
            T_enc = B * (S // 2)
            fwd = T_enc * cfg.n_enc_layers * layer_fwd_flops_tok(cfg, S // 4, False)
            fwd += T_dec * L * (layer_fwd_flops_tok(cfg, S // 4, False)
                                + _attn_flops_tok(cfg, S // 2))  # + cross
            T_loss = T_dec
        else:
            T = B * S
            n_moe = L - cfg.n_dense_layers if cfg.n_experts else 0
            fwd = T * (L - n_moe) * layer_fwd_flops_tok(cfg, S / 2, False)
            fwd += T * n_moe * layer_fwd_flops_tok(cfg, S / 2, True)
            if cfg.family == "hybrid":
                from repro.models.build import _zamba_n_apps
                fwd += T * _zamba_n_apps(cfg) * (
                    _attn_flops_tok(cfg, S / 2) + _mlp_flops_tok(cfg))
            T_loss = T
        fwd += 2.0 * T_loss * D * V  # lm head
        flops = fwd * _train_mult(cfg)
        # HBM bytes: params fp32 {read fwd+bwd+remat, grad w+r, adam m/v r+w,
        # param w} ~ 10x + saved activations 2x r/w + logits chunks
        act_bytes = L * B * S * D * 2 * 2
        bytes_ = N * 4 * 10 + act_bytes + 2 * T_loss * V * 4 / 16  # V sharded
        model_flops = 6.0 * Na * T_loss
        return Counts(flops, bytes_, model_flops, N, Na)

    if s.kind == "prefill":
        if cfg.family == "audio":
            T = B * S
            fwd = B * ENCDEC_ENC_LEN * cfg.n_enc_layers * \
                layer_fwd_flops_tok(cfg, ENCDEC_ENC_LEN / 2, False)
            fwd += T * L * (layer_fwd_flops_tok(cfg, S / 2, False)
                            + _attn_flops_tok(cfg, ENCDEC_ENC_LEN))
        else:
            T = B * S
            n_moe = L - cfg.n_dense_layers if cfg.n_experts else 0
            fwd = T * (L - n_moe) * layer_fwd_flops_tok(cfg, S / 2, False)
            fwd += T * n_moe * layer_fwd_flops_tok(cfg, S / 2, True)
            if cfg.family == "hybrid":
                from repro.models.build import _zamba_n_apps
                fwd += T * _zamba_n_apps(cfg) * (
                    _attn_flops_tok(cfg, S / 2) + _mlp_flops_tok(cfg))
        fwd += 2.0 * B * D * V  # last-position logits
        cache_bytes = _cache_bytes(cfg, B, S)
        bytes_ = N * param_bytes + cache_bytes + B * S * D * 2 * 2 * L
        return Counts(fwd, bytes_, 2.0 * Na * B * S, N, Na)

    # decode: one token, kv_len = S context
    T = B
    if cfg.family == "rwkv":
        f_tok = _rwkv_flops_tok(cfg) - 0  # state update is O(1) in S
        fwd = T * L * f_tok
    elif cfg.family == "hybrid":
        from repro.models.build import _zamba_n_apps
        fwd = T * L * _mamba_flops_tok(cfg)
        fwd += T * _zamba_n_apps(cfg) * (
            _attn_flops_tok(cfg, S) + _mlp_flops_tok(cfg))
    else:
        n_moe = L - cfg.n_dense_layers if cfg.n_experts else 0
        fwd = T * (L - n_moe) * layer_fwd_flops_tok(cfg, S, False)
        fwd += T * n_moe * layer_fwd_flops_tok(cfg, S, True)
        if cfg.use_mla:
            if cfg.mla_absorb:
                # absorbed decode: scores+context directly in latent space
                fwd += T * L * (2 * 2 * S * cfg.kv_lora_rank * cfg.n_heads
                                + 2 * cfg.n_heads * cfg.kv_lora_rank
                                * (cfg.qk_nope_dim + cfg.v_head_dim))
            else:
                # baseline decode up-projects the latent for the full context
                fwd += T * L * 2 * S * cfg.kv_lora_rank * cfg.n_heads * \
                    (cfg.qk_nope_dim + cfg.v_head_dim)
        if cfg.family == "audio":
            fwd += T * L * _attn_flops_tok(cfg, ENCDEC_ENC_LEN)
    fwd += 2.0 * T * D * V
    cache_bytes = _cache_bytes(cfg, B, S)
    bytes_ = N * param_bytes + cache_bytes  # read params + read cache
    return Counts(fwd, bytes_, 2.0 * Na * T, N, Na)


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    if cfg.family == "rwkv":
        D = cfg.d_model
        H = D // cfg.rwkv_head_dim
        return cfg.n_layers * B * (2 * D * 2 + H * cfg.rwkv_head_dim ** 2 * 4)
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        H = di // cfg.ssm_head_dim
        ssm = cfg.n_layers * B * (H * cfg.ssm_state * cfg.ssm_head_dim * 4
                                  + (cfg.ssm_conv_k - 1) * (di + 2 * cfg.ssm_state) * 2)
        from repro.models.build import _zamba_n_apps
        attn = _zamba_n_apps(cfg) * B * S * 2 * cfg.n_kv_heads * cfg.dh * 2
        return ssm + attn
    if cfg.use_mla:
        per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.dh * 2
    cache = cfg.n_layers * B * S * per_tok
    if cfg.family == "audio":
        cache += cfg.n_layers * B * ENCDEC_ENC_LEN * 2 * cfg.n_kv_heads * cfg.dh * 2
    return cache
