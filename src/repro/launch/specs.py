"""Sharding specs for batches, caches, and optimizer state per (arch, mesh).

Parameters get their specs from the ParamDef logical axes (sharding/rules);
this module covers the *runtime* trees: input batches, KV/state caches, and
optimizer state (which mirrors the param specs leaf-for-leaf).
Dims are only sharded when divisible by the mesh axis size (e.g. 8 KV heads
on a 16-way model axis stay replicated — Megatron's GQA duplication rule).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models.config import ArchConfig
from repro.training.trainer import TrainState


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh, dim: int, axes):
    """Shard dim over axes only if divisible."""
    return axes if dim % max(_axis_size(mesh, axes), 1) == 0 and dim > 1 else None


def batch_pspecs(cfg: ArchConfig, specs: dict, mesh) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for name, s in specs.items():
        if name == "pos" or s.ndim == 0:
            out[name] = P()
            continue
        b = _maybe(mesh, s.shape[0], dp)
        if s.ndim == 3:  # (B, S/T, D/C) real-valued frontend stubs
            out[name] = P(b, None, None)
        else:            # (B, S) tokens / labels; (B,) labels
            out[name] = P(b, *([None] * (s.ndim - 1)))
    return out


def cache_pspecs(cfg: ArchConfig, cache_tree, mesh) -> dict:
    """KV caches (L,B,S,H,D), MLA latents (L,B,S,r), SSM states, rings."""
    dp = dp_axes(mesh)

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        shape = leaf.shape
        b = _maybe(mesh, shape[1], dp) if len(shape) >= 2 else None
        if any(n in ("k", "v") for n in names) and len(shape) == 5:
            L, B, S, H, Dh = shape
            h = _maybe(mesh, H, "model")
            # kv_heads < TP degree (e.g. 8 on 16): shard the SEQUENCE over
            # the model axis instead; GSPMD turns the softmax/AV reductions
            # into tiny per-step all-reduces (context-parallel decode).
            seq_axes = [a for a in ("data", "model")
                        if (a == "data" and b is None) or (a == "model" and h is None)]
            seq = tuple(seq_axes) if seq_axes else None
            if seq is not None and S % _axis_size(mesh, seq) != 0:
                seq = None
            return P(None, b, seq, h, None)
        if any(n == "c_kv" for n in names):  # (L,B,S,r) MLA latent
            L, B, S, r = shape
            seq = _maybe(mesh, S, "model")
            return P(None, b, seq, None)
        if any(n == "k_rope" for n in names):  # (L,B,S,1,dr)
            seq = _maybe(mesh, shape[2], "model")
            return P(None, b, seq, None, None)
        if any(n == "ssm" for n in names):  # (L,B,H,N,P)
            return P(None, b, _maybe(mesh, shape[2], "model"), None, None)
        if any(n == "state" for n in names):  # rwkv (L,B,H,Dh,Dh)
            return P(None, b, _maybe(mesh, shape[2], "model"), None, None)
        if any(n == "conv" for n in names):  # (L,B,K-1,C)
            return P(None, b, None, _maybe(mesh, shape[3], "model"))
        if len(shape) == 3:  # rwkv x_prev (L,B,D)
            return P(None, b, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def named(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def train_state_pspecs(param_pspecs_tree, opt_state_abstract) -> TrainState:
    """Optimizer state mirrors the param specs (mu/nu per-leaf)."""
    return TrainState(
        params=param_pspecs_tree,
        opt_state=type(opt_state_abstract)(
            step=P(),
            mu=param_pspecs_tree,
            nu=param_pspecs_tree,
        ),
        model_state={},
        err_state={},
        step=P(),
    )
