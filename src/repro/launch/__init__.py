from repro.launch.mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
