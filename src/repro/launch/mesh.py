"""Production mesh definitions.

A v5e pod slice is 16x16 = 256 chips; the multi-pod mesh adds a leading
"pod" axis (2 pods = 512 chips) whose collectives ride DCN — the gradient
all-reduce over (pod, data) is the multi-pod proof.  Functions, not
module-level constants: importing this module never touches jax device
state (device count is locked at first backend init).
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; Auto is the pre-0.5 default anyway
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary test meshes (e.g. (2, 2) on 4 host devices)."""
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    return mesh.devices.size
