import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first backend init).  This module is the ONLY place the
# 512-device host platform is requested; tests and benches see 1 device.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED, LONG_CONTEXT_ARCHS, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh, n_chips     # noqa: E402
from repro.launch.specs import (                                            # noqa: E402
    batch_pspecs, cache_pspecs, named, train_state_pspecs)
from repro.models import build_bundle                                       # noqa: E402
from repro.sharding.ctx import shard_ctx                                    # noqa: E402
from repro.sharding.rules import DEFAULT_RULES, param_pspecs                # noqa: E402
from repro.training import TrainState, make_train_step                      # noqa: E402
from repro.training.optim import adamw                                      # noqa: E402
from repro.utils.hlo import collective_bytes, parse_cost_analysis           # noqa: E402

# --- named experiments: the §Perf hillclimb levers -------------------------
# Each experiment = (rules overrides, config overrides).  "baseline" is the
# paper-faithful configuration recorded in §Roofline.

EXPERIMENTS = {
    "baseline": ({}, {}),
    # no sequence-parallel activations (ablation: SP off)
    "no_sp": ({"seq_act": None}, {}),
    # save matmul outputs instead of full remat
    "remat_dots": ({}, {"remat_policy": "dots"}),
    # larger loss chunk (fewer scan iterations, bigger live logits)
    "logit_chunk_2k": ({}, {"logit_chunk": 2048}),
    # both remat_dots and no_sp
    "remat_dots_no_sp": ({"seq_act": None}, {"remat_policy": "dots"}),
    # ablation: materialized-scores attention instead of flash-chunked
    "dense_attn": ({}, {"attn_chunk_threshold": 1 << 30}),
    # no FSDP: params sharded over model (TP) only
    "no_fsdp": ({"embed": None}, {}),
    # serving: bf16 params (2x fewer weight bytes; decode is weight-bound)
    "serve_bf16": ({}, {}),
    # serving: bf16 + no FSDP (no per-step param all-gather at decode)
    "serve_bf16_no_fsdp": ({"embed": None}, {}),
    # hybrid/mamba: replicate the (small) mamba projections and shard the
    # sequence over `model` instead of TP — kills the per-layer row-parallel
    # all-reduce of (B,S,D) activations that dominates zamba prefill comm
    "mamba_seqshard": ({"ffn": None, "heads": None, "attn_out": None}, {}),
    # MLA decode with weight absorption (attend in the latent space) +
    # serving dtype/layout — the deepseek decode compute-term lever
    "mla_absorb_serve": ({"embed": None}, {"mla_absorb": True}),
}
# (4-bit log2-packed serving — the paper's technique — is analysed
# analytically in EXPERIMENTS §Perf on top of serve_bf16_no_fsdp, backed by
# the kernel validated in tests/test_kernels.py.)

_SERVE_DTYPE = {"serve_bf16": 2, "serve_bf16_no_fsdp": 2,
                "mla_absorb_serve": 2}


def _cast_param_defs(defs, dtype):
    from repro.sharding.rules import ParamDef
    import jax.numpy as jnp_

    def cast(d):
        if d.dtype == jnp_.float32:
            return ParamDef(d.shape, d.axes, d.init, jnp_.bfloat16, d.scale)
        return d

    return jax.tree.map(cast, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _mem_analysis_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch: str, shape_name: str, mesh, *, exp: str = "baseline",
               verbose: bool = True, smoke: bool = False):
    """Lower + compile one (arch x shape) cell on the given mesh; return the
    roofline record."""
    rules_over, cfg_over = EXPERIMENTS[exp]
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    bundle = build_bundle(cfg)
    param_bytes = _SERVE_DTYPE.get(exp, 4)
    if param_bytes != 4:
        bundle.param_defs = _cast_param_defs(bundle.param_defs, param_bytes)
    s = SHAPES[shape_name]
    rules = dict(DEFAULT_RULES)
    rules.update(rules_over)
    t0 = time.time()
    with shard_ctx(mesh, rules) as resolved:
        pspecs = param_pspecs(bundle.param_defs, resolved, mesh)
        aparams = bundle.abstract_params()
        ispecs = bundle.input_specs(shape_name)
        bspecs = batch_pspecs(cfg, ispecs, mesh)
        if s.kind == "train":
            opt = adamw(1e-4)
            accum = max(1, cfg.train_microbatch)
            step_fn = make_train_step(bundle.loss_fn, opt, grad_accum=accum)
            if accum > 1:  # batch leaves become (accum, B/accum, ...)
                ispecs = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (accum, x.shape[0] // accum) + x.shape[1:], x.dtype),
                    ispecs)
                bspecs = jax.tree.map(
                    lambda p: jax.sharding.PartitionSpec(None, *p), bspecs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            aopt = jax.eval_shape(opt[0], aparams)
            astate = TrainState(aparams, aopt, {}, {},
                                jax.ShapeDtypeStruct((), jnp.int32))
            st_specs = train_state_pspecs(pspecs, aopt)
            jf = jax.jit(
                step_fn,
                in_shardings=(named(mesh, st_specs), named(mesh, bspecs)),
                out_shardings=(named(mesh, st_specs), None),
                donate_argnums=(0,))
            lowered = jf.lower(astate, ispecs)
        elif s.kind == "prefill":
            # constrain the produced cache to its serving layout (decode's
            # in_shardings) so the cache never materializes replicated
            acache = bundle.cache_specs(s.global_batch, s.seq_len)
            cspecs = cache_pspecs(cfg, acache, mesh)
            jf = jax.jit(bundle.prefill_fn,
                         in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
                         out_shardings=(None, named(mesh, cspecs)))
            lowered = jf.lower(aparams, ispecs)
        else:  # decode
            acache = bundle.cache_specs(s.global_batch, s.seq_len)
            cspecs = cache_pspecs(cfg, acache, mesh)
            jf = jax.jit(bundle.decode_fn,
                         in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                                       named(mesh, bspecs)),
                         out_shardings=(None, named(mesh, cspecs)),
                         donate_argnums=(1,))
            lowered = jf.lower(aparams, acache, ispecs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = parse_cost_analysis(compiled.cost_analysis())
    text = compiled.as_text()
    coll = collective_bytes(text)
    from repro.launch.analytic import count_cell
    ana = count_cell(cfg, bundle.param_defs, shape_name, param_bytes=param_bytes)
    rec = {
        "arch": arch, "shape": shape_name, "exp": exp,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_chips": n_chips(mesh),
        "kind": s.kind, "seq_len": s.seq_len, "global_batch": s.global_batch,
        "cost_analysis_flops_raw": cost.get("flops", 0.0),
        "cost_analysis_bytes_raw": cost.get("bytes accessed", 0.0),
        "flops_global_analytic": ana.flops_global,
        "bytes_global_analytic": ana.bytes_global,
        "model_flops": ana.model_flops,
        "n_params": ana.n_params,
        "n_params_active": ana.n_params_active,
        "collective_bytes_per_device": coll["total"],
        "collective_by_type": coll["by_type"],
        "collective_count": coll["count"],
        "memory_analysis": _mem_analysis_dict(compiled),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_chars": len(text),
    }
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[dryrun] {arch} x {shape_name} ({exp}) on {rec['mesh']}: "
              f"flops(analytic,global)={rec['flops_global_analytic']:.3e} "
              f"coll/dev={rec['collective_bytes_per_device']:.3e} "
              f"args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return rec


def cell_path(out_dir, arch, shape, mesh_tag, exp):
    return os.path.join(out_dir, f"{mesh_tag}__{arch}__{shape}__{exp}.json")


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run launcher")
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 2x2 (axes data,model) for tests")
    ap.add_argument("--exp", default="baseline", choices=sorted(EXPERIMENTS))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sharding test, not the real dry-run)")
    args = ap.parse_args()

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
        mesh_tag = "x".join(map(str, dims))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_tag = "pod2x16x16" if args.multi_pod else "pod16x16"

    archs = [args.arch] if args.arch else [c.name for c in ASSIGNED]
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                print(f"[dryrun] SKIP {arch} x long_500k "
                      f"(full-attention arch; see DESIGN.md §3)", flush=True)
                continue
            path = cell_path(args.out, arch, shape, mesh_tag, args.exp)
            if os.path.exists(path) and not args.force:
                print(f"[dryrun] cached {path}", flush=True)
                continue
            try:
                rec = lower_cell(arch, shape, mesh, exp=args.exp, smoke=args.smoke)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}", flush=True)
        raise SystemExit(1)
    print("[dryrun] all cells OK", flush=True)


if __name__ == "__main__":
    main()
