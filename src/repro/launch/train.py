"""Production training launcher.

Single-host usage (CPU smoke / demo):
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 50 --batch 8 --seq 128

On a real pod each host runs the same script under its jax.distributed
initialization; the mesh below covers all devices, the data stream is
seekable by step (exact resume), and checkpoints are written/validated
atomically — kill any host and relaunch to resume.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.mesh import make_mesh
from repro.models import build_bundle
from repro.sharding.ctx import shard_ctx
from repro.sharding.rules import DEFAULT_RULES
from repro.training import TrainConfig, Trainer
from repro.training.optim import adamw, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 (data x model)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params")

    def data_fn(step):
        b = lm_batch(step, args.batch, args.seq, cfg.vocab_size)
        if args.grad_accum > 1:
            b = {k: v.reshape(args.grad_accum, -1, *v.shape[1:])
                 for k, v in b.items()}
        return {k: jnp.asarray(v) for k, v in b.items()}

    tcfg = TrainConfig(
        steps=args.steps, grad_accum=args.grad_accum, log_every=10,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        grad_compression="int8_ef" if args.compress_grads else None)
    optimizer = adamw(warmup_cosine(args.lr, args.steps // 10, args.steps))

    def run():
        trainer = Trainer(bundle.loss_fn, params, tcfg, data_fn,
                          optimizer=optimizer)
        resumed = trainer.maybe_resume()
        if resumed:
            print(f"[train] resumed from step {resumed}")
        state, hist = trainer.run()
        for h in hist:
            print(f"[train] step {h['step']:5d} loss {h['loss']:.4f} "
                  f"acc {h.get('acc', 0):.3f} gnorm {h.get('grad_norm', 0):.2f}")
        if trainer.straggler_events:
            print(f"[train] straggler events: {trainer.straggler_events}")
        return state

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(dims, ("data", "model")[-len(dims):])
        with shard_ctx(mesh, dict(DEFAULT_RULES)):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
