"""Chameleon-JAX: MatMul-free TCN + prototypical-learning framework (pod scale)."""

__version__ = "1.0.0"
