"""Ring-buffer streaming TCN execution — the paper's §III-B contribution.

Chameleon's "greedy dilation-aware execution with layer-wise FIFO activation
storage" (Fig. 8) keeps, per conv layer, only the last (k-1)·d activations and
overwrites the oldest slot each step.  That is precisely a ring buffer; total
streaming state is O(receptive field), *independent of sequence length* —
two orders of magnitude below a same-length KV cache, which is what makes
16 kHz raw-audio KWS feasible on 2 kB of activation memory.

This module is the JAX equivalent: per-layer ring buffers in a pytree,
indexed with a shared step counter mod buffer length; one jitted ``step``
advances all layers for one timestep.  Output is bit-exact vs. the
full-sequence convolution (tests/test_tcn_stream.py), reproducing the
"identical outputs" claim of Fig. 8(c).

The residual path needs no extra buffer at all (the paper's dual-port
register file, Fig. 9): the block input of the current step is still live
when the residual add happens.

The params-as-jit-ARGUMENTS discipline documented on ``stream_scan_single``
is load-bearing well beyond this module: any chunked scan whose per-step
outputs must be bit-identical across separately compiled chunk sizes needs
it.  sessions/lm.decode_scan applies the same rule to LM serving, where the
KV-cache token chunk is the exact analog of the time chunk here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.tcn import BN_EPS
from repro.quant.log2 import fake_quant_act_u4, fake_quant_log2


def ring_sizes(cfg: ArchConfig) -> dict:
    """Per-layer FIFO depths: (k-1)*d for each of the two convs per block."""
    k = cfg.tcn_kernel
    out = {}
    c_in = cfg.tcn_in_channels
    for i, c in enumerate(cfg.tcn_channels):
        d = 2 ** i
        out[f"b{i}"] = {"ring1": ((k - 1) * d, c_in), "ring2": ((k - 1) * d, c)}
        c_in = c
    return out


def stream_state_bytes(cfg: ArchConfig, bytes_per_act: float = 0.5) -> float:
    """Total streaming activation memory (the paper counts 4-bit = 0.5 B)."""
    return sum(n * c * bytes_per_act
               for b in ring_sizes(cfg).values() for (n, c) in b.values())


def stream_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    state = {"t": jnp.zeros((), jnp.int32), "blocks": {}}
    for name, rs in ring_sizes(cfg).items():
        (n1, c1), (n2, c2) = rs["ring1"], rs["ring2"]
        state["blocks"][name] = {
            "ring1": jnp.zeros((batch, n1, c1), dtype),
            "ring2": jnp.zeros((batch, n2, c2), dtype),
        }
    return state


def stream_init_single(cfg: ArchConfig, dtype=jnp.float32) -> dict:
    """Single-session streaming state with NO batch axis: rings (n, c), t ().

    This is the vmappable pytree unit the sessions subsystem stacks into a
    structure-of-arrays slot grid — one leaf set per session, so a session's
    entire stream position is capturable/restorable as one small pytree."""
    state = {"t": jnp.zeros((), jnp.int32), "blocks": {}}
    for name, rs in ring_sizes(cfg).items():
        (n1, c1), (n2, c2) = rs["ring1"], rs["ring2"]
        state["blocks"][name] = {"ring1": jnp.zeros((n1, c1), dtype),
                                 "ring2": jnp.zeros((n2, c2), dtype)}
    return state


def stream_step_single(params, bn_state, cfg: ArchConfig, state: dict,
                       x_t: jax.Array, *, quantize: bool = False):
    """``stream_step`` for one session: x_t (C_in,), rings (n, c).

    Designed to sit under ``jax.vmap`` (sessions/state.py): vmapping this
    over a stacked state recovers exactly the batched math of
    ``stream_step``, but with an *independent* step counter per session —
    streams admitted at different times stay phase-correct."""
    st = {"t": state["t"],
          "blocks": jax.tree.map(lambda a: a[None], state["blocks"])}
    new, emb, logits = stream_step(params, bn_state, cfg, st, x_t[None],
                                   quantize=quantize)
    return ({"t": new["t"],
             "blocks": jax.tree.map(lambda a: a[0], new["blocks"])},
            emb[0], logits[0])


def stream_scan_single(params, bn_state, cfg: ArchConfig, state: dict,
                       x_chunk: jax.Array, valid: jax.Array, *,
                       quantize: bool = False):
    """Advance one session over a whole time chunk INSIDE jit.

    x_chunk: (T, C_in); valid: (T,) bool.  Runs ``jax.lax.scan`` over the T
    samples so a chunk costs ONE dispatch instead of T — the host<->device
    round trip per 16 kHz sample is the serving wall, not the compute
    (ReckOn makes the same amortization argument in hardware).

    ``valid`` handles ragged per-session chunk lengths: steps with
    valid=False leave the state bit-frozen (the same ``jnp.where``
    discipline grid_step uses for inactive slots), so padding a short
    chunk to the compiled T never perturbs the stream.  Outputs at invalid
    steps are computed but meaningless — callers mask them.

    Returns (new_state, embs (T, V), logits (T, n_classes)); step t of the
    outputs is bit-exact vs a ``stream_step_single`` call at that step
    (T=1 is exactly that special case; tests/test_streaming_chunk.py).

    Bit-exactness across SEPARATELY JITTED programs (e.g. a T=160 scan vs
    160 single steps) additionally requires params/bn_state to enter jit
    as arguments, not closure constants — XLA constant-folds a captured
    BN chain differently per program, reassociating the multiplies by one
    ULP.  Runtime data is never reassociated.
    """
    def body(st, inp):
        x_t, v = inp
        stepped, emb, logits = stream_step_single(
            params, bn_state, cfg, st, x_t, quantize=quantize)
        st2 = jax.tree.map(lambda n, o: jnp.where(v, n, o), stepped, st)
        return st2, (emb, logits)

    new_state, (embs, logits) = jax.lax.scan(body, state, (x_chunk, valid))
    return new_state, embs, logits


# ---------------------------------------------------------------------------
# Fused kernel fast path: whole-chunk block evaluation over ring-buffer taps
# ---------------------------------------------------------------------------

def _ordered_history(ring, t):
    """Time-order one ring's circular layout.  ring: (S, n, c); t: (S,)
    step counters.  Row i of the result is the sample at time t-n+i —
    slots not yet written (stream younger than n) read their zero init,
    which is exactly causal left-padding."""
    n = ring.shape[1]
    idx = (t[:, None] + jnp.arange(n)[None, :]) % n
    return jnp.take_along_axis(ring, idx[:, :, None], axis=1)


def _ring_advance(strip, t, lengths, n):
    """New circular ring contents after consuming ``lengths`` chunk samples.

    strip: (S, n+T, c) time-ordered [history | chunk-values]; the window
    ``strip[L : L+n]`` holds times t+L-n .. t+L-1, re-laid so slot s holds
    the sample at time ≡ s (mod n).  L=0 reproduces the old ring bit-for-
    bit (the inactive-slot freeze), with no branch."""
    ar = jnp.arange(n)[None, :]
    window = jnp.take_along_axis(strip, (lengths[:, None] + ar)[:, :, None],
                                 axis=1)
    perm = (ar - (t + lengths)[:, None]) % n
    return jnp.take_along_axis(window, perm[:, :, None], axis=1)


def make_fused_chunk(cfg: ArchConfig, *, quantize: bool = False,
                     backend: str | None = None):
    """Build the fused chunk executor (kernel backend resolved ONCE).

    Returns ``fused_chunk(fused_params, states, x, lengths)`` advancing a
    whole slot grid over a time chunk through kernels/tcn_block.py:
    ``states`` is the SoA grid (rings (S, n, c), t (S,)); x: (S, T, C_in);
    lengths: (S,) valid-prefix lengths (the service's ragged chunks are
    always prefixes of the padded tick).  Returns (new_states, embs
    (S, T, V), logits (S, T, n_classes)); outputs at positions >= lengths
    are computed-but-meaningless (callers slice), state bit-freezes there.

    Vs ``grid_scan`` this pays k tap-shifted batched matmuls per conv for
    the WHOLE chunk instead of a T-step lax.scan of per-sample ops, and
    the conv history is the ring taps themselves — no per-chunk re-pad.
    On baked params (models/tcn.bake_stream_params) it is bit-identical
    to ``grid_scan``; params must enter jit as arguments (same discipline
    as stream_scan_single).  ``backend=None`` defers to
    ``cfg.kernel_backend``."""
    from repro.kernels.tcn_block import expand_weight, make_block_fn

    block_fn = make_block_fn(backend or cfg.kernel_backend)
    k = cfg.tcn_kernel
    qa = (lambda a: fake_quant_act_u4(a, jnp.float32(cfg.act_scale))) \
        if quantize else (lambda a: a)

    def fused_chunk(fused_params, states, x, lengths):
        t = states["t"]
        lengths = jnp.asarray(lengths, t.dtype)
        new_blocks = {}
        h = x
        for i in range(len(cfg.tcn_channels)):
            name = f"b{i}"
            d = 2 ** i
            rings = states["blocks"][name]
            hist1 = _ordered_history(rings["ring1"], t)
            hist2 = _ordered_history(rings["ring2"], t)
            strip1 = jnp.concatenate([hist1, h], axis=1)
            h, mid = block_fn(strip1, hist2, fused_params["blocks"][name],
                              dilation=d, k=k, act_scale=cfg.act_scale,
                              quantize=quantize)
            strip2 = jnp.concatenate([hist2, mid], axis=1)
            new_blocks[name] = {
                "ring1": _ring_advance(strip1, t, lengths,
                                       rings["ring1"].shape[1]),
                "ring2": _ring_advance(strip2, t, lengths,
                                       rings["ring2"].shape[1]),
            }
        emb = h @ expand_weight(fused_params["head_w"]) + fused_params["head_b"]
        emb = qa(jax.nn.relu(emb))
        logits = emb @ fused_params["fc"]["w"] + fused_params["fc"]["b"]
        return {"t": t + lengths, "blocks": new_blocks}, emb, logits

    return fused_chunk


def _taps(ring, x_t, t, dilation: int, k: int):
    """Collect the k conv taps for the current step: x_{t-(k-1-j)d}, j=0..k-1.

    The newest tap is x_t itself (passed in registers, not the buffer) —
    taps older than the start of the stream read zero-initialized slots,
    matching causal left-padding."""
    n = ring.shape[1]  # (k-1)*d
    taps = []
    for j in range(k - 1):
        off = (k - 1 - j) * dilation  # steps back
        idx = jnp.mod(t - off, n)
        taps.append(jax.lax.dynamic_index_in_dim(ring, idx, axis=1, keepdims=False))
    taps.append(x_t)
    return taps  # list of (B, C), ordered oldest tap (w[0]) .. newest (w[k-1])


def _write(ring, x_t, t):
    n = ring.shape[1]
    return jax.lax.dynamic_update_index_in_dim(ring, x_t, jnp.mod(t, n), axis=1)


def _bn_inf(x, p, st, which):
    inv = jax.lax.rsqrt(st[f"{which}_var"] + BN_EPS)
    return (x - st[f"{which}_mean"]) * inv * p[which]["scale"] + p[which]["bias"]


def stream_step(params, bn_state, cfg: ArchConfig, state: dict, x_t: jax.Array,
                *, quantize: bool = False):
    """Advance the TCN one timestep.  x_t: (B, C_in).

    Returns (new_state, embedding (B, V), logits (B, n_classes)).
    Matches ``tcn_forward(...)[:, t]`` exactly for every t (tested).
    """
    qw = (lambda w: fake_quant_log2(w)) if quantize else (lambda w: w)
    qa = (lambda a: fake_quant_act_u4(a, jnp.float32(cfg.act_scale))) \
        if quantize else (lambda a: a)
    t = state["t"]
    new_blocks = {}
    h = x_t
    for i in range(len(cfg.tcn_channels)):
        name = f"b{i}"
        p = params["blocks"][name]
        st = bn_state[name]
        rings = state["blocks"][name]
        d = 2 ** i
        k = cfg.tcn_kernel
        w1 = qw(p["conv1_w"])  # (k, Cin, Cout)
        taps = _taps(rings["ring1"], h, t, d, k)
        y = sum(tp @ w1[j] for j, tp in enumerate(taps)) + p["conv1_b"]
        y = qa(jax.nn.relu(_bn_inf(y, p, st, "bn1")))
        w2 = qw(p["conv2_w"])
        taps2 = _taps(rings["ring2"], y, t, d, k)
        y2 = sum(tp @ w2[j] for j, tp in enumerate(taps2)) + p["conv2_b"]
        y2 = _bn_inf(y2, p, st, "bn2")
        if "down_w" in p:
            res = h @ qw(p["down_w"])[0] + p["down_b"]
        else:
            res = h
        new_blocks[name] = {"ring1": _write(rings["ring1"], h, t),
                            "ring2": _write(rings["ring2"], y, t)}
        h = qa(jax.nn.relu(y2 + res))
    emb = h @ qw(params["head_w"]) + params["head_b"]
    emb = qa(jax.nn.relu(emb))
    logits = emb @ params["fc"]["w"] + params["fc"]["b"]
    return {"t": t + 1, "blocks": new_blocks}, emb, logits


# ---------------------------------------------------------------------------
# Greedy dilation-aware (cone-sparse) evaluation — Fig. 7(b)/8(a).
#
# For end-of-window classification (KWS on a 1 s window) only the final
# timestep's class is needed, so layer l need only be evaluated at positions
# in the backward dependency cone — the dilation grid {T-1 - j*d_l}.  Deeper
# layers are evaluated exponentially more sparsely (the paper's "zero-valued
# activations introduced by dilation" skip), the steady-state FIFO per conv
# is k-1 entries *independent of dilation*, and the total activation state is
# sum_l (k-1)*C ~ 2 kB for the raw-audio model — the paper's headline.
# Dense per-step streaming (stream_step above) is the other serving mode
# (per-step outputs); both produce outputs identical to the full conv.
# ---------------------------------------------------------------------------

def _cone_positions(cfg: ArchConfig, T: int):
    """Needed positions per block, top down. Returns list[np-like sorted
    arrays], index 0 = input positions."""
    import numpy as np
    k = cfg.tcn_kernel
    nb = len(cfg.tcn_channels)
    need = {nb: np.array([T - 1])}
    for b in range(nb - 1, -1, -1):
        d = 2 ** b
        ps = need[b + 1]
        # two stacked convs with the same dilation: offsets 0..2(k-1)d
        offs = np.arange(0, 2 * (k - 1) * d + 1, d)
        prev = (ps[:, None] - offs[None, :]).reshape(-1)
        need[b] = np.unique(prev[prev >= 0])
    return [need[b] for b in range(nb + 1)]


def cone_eval(params, bn_state, cfg: ArchConfig, x, *, quantize: bool = False):
    """Greedy dilation-aware evaluation of the FINAL timestep's embedding:
    computes only the backward cone (paper Fig. 8a).  x: (B, T, Cin).
    Returns (embedding (B, V), logits, positions_evaluated)."""
    import numpy as np
    from repro.quant.log2 import fake_quant_act_u4, fake_quant_log2

    qw = (lambda w: fake_quant_log2(w)) if quantize else (lambda w: w)
    qa = (lambda a: fake_quant_act_u4(a, jnp.float32(cfg.act_scale))) \
        if quantize else (lambda a: a)
    B, T, _ = x.shape
    k = cfg.tcn_kernel
    need = _cone_positions(cfg, T)
    total_evals = 0
    # h holds block-(b) input values at positions need[b]
    h = x[:, jnp.asarray(need[0]), :]
    for b in range(len(cfg.tcn_channels)):
        d = 2 ** b
        p = params["blocks"][f"b{b}"]
        st = bn_state[f"b{b}"]
        pos_in = need[b]
        pos_out = need[b + 1]
        idx_of = {int(v): i for i, v in enumerate(pos_in)}
        # conv1 at the mid grid: positions needed by conv2 of this block
        mid = np.unique((pos_out[:, None]
                         - np.arange(0, (k - 1) * d + 1, d)[None]).reshape(-1))
        mid = mid[mid >= 0]

        def taps(pos_set, source_pos, source_vals, dd):
            cols = []
            src = {int(v): i for i, v in enumerate(source_pos)}
            for j in range(k):
                idx = [src.get(int(q - (k - 1 - j) * dd), -1) for q in pos_set]
                gathered = source_vals[:, jnp.asarray(np.maximum(idx, 0)), :]
                mask = (np.asarray(idx) >= 0).astype(np.float32)[None, :, None]
                cols.append(gathered * mask)  # causal zero-pad
            return cols

        c1 = taps(mid, pos_in, h, d)
        w1 = qw(p["conv1_w"])
        y1 = sum(c @ w1[j] for j, c in enumerate(c1)) + p["conv1_b"]
        y1 = qa(jax.nn.relu(_bn_inf(y1, p, st, "bn1")))
        total_evals += len(mid)
        c2 = taps(pos_out, mid, y1, d)
        w2 = qw(p["conv2_w"])
        y2 = sum(c @ w2[j] for j, c in enumerate(c2)) + p["conv2_b"]
        y2 = _bn_inf(y2, p, st, "bn2")
        total_evals += len(pos_out)
        # residual: block input at pos_out (subset of pos_in)
        ridx = jnp.asarray([idx_of[int(q)] for q in pos_out])
        res_src = h[:, ridx, :]
        if "down_w" in p:
            res = res_src @ qw(p["down_w"])[0] + p["down_b"]
        else:
            res = res_src
        h = qa(jax.nn.relu(y2 + res))
    feat = h[:, -1, :]
    emb = qa(jax.nn.relu(feat @ qw(params["head_w"]) + params["head_b"]))
    logits = emb @ params["fc"]["w"] + params["fc"]["b"]
    return emb, logits, total_evals


def cone_stats(cfg: ArchConfig, seq_len: int):
    """Steady-state greedy-execution accounting for a length-T window:
    per-conv FIFO depth k-1 (dilation-independent!), per-layer evaluations
    = T / dilation."""
    k = cfg.tcn_kernel
    acts = 0
    macs = 0
    c_in = cfg.tcn_in_channels
    for i, c in enumerate(cfg.tcn_channels):
        d = 2 ** i
        evals = max(seq_len // d, 1)
        macs += evals * k * (c_in * c + c * c)
        acts += (k - 1) * (c_in + c)  # two FIFOs per block
        c_in = c
    return {"act_entries": acts, "macs": macs}


def ws_inference_stats(cfg: ArchConfig, seq_len: int):
    """Weight-stationary baseline accounting for the Fig. 8(c) comparison
    (paper: ~90x memory / ~10x compute at 16k steps): activation memory is a
    full-sequence buffer (WS requires pre-loading the sequence), and compute
    evaluates every layer densely at every timestep (no dilation-aware
    skipping of the unused cone complement)."""
    k = cfg.tcn_kernel
    cmax = max(max(cfg.tcn_channels), cfg.tcn_in_channels)
    acts = seq_len * cmax
    macs = 0
    c_in = cfg.tcn_in_channels
    for c in cfg.tcn_channels:
        macs += seq_len * k * (c_in * c + c * c)
        c_in = c
    return {"act_entries": acts, "macs": macs}


def greedy_inference_stats(cfg: ArchConfig, seq_len: int):
    """Chameleon-style streaming accounting: FIFO state + dilation-aware
    compute (only real taps, no zero-padding work)."""
    k = cfg.tcn_kernel
    acts = sum(n * c for b in ring_sizes(cfg).values() for (n, c) in b.values())
    macs = 0
    c_in = cfg.tcn_in_channels
    for i, c in enumerate(cfg.tcn_channels):
        macs += seq_len * k * (c_in * c + c * c)
        c_in = c
    return {"act_entries": acts, "macs": macs}
