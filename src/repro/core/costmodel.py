"""Analytical cost models.

1. The paper's dual-mode PE array / SRAM power model (§III-C, Fig. 11/12/16):
   silicon power cannot be measured here, so the *analysis* that produced the
   paper's Fig. 11 trade-off (optimal array sizes 4 and 16 under an
   SRAM-dominated power assumption) is reproduced from first principles,
   calibrated against the paper's own measured points.

2. TPU v5e roofline constants + the three-term roofline evaluator used by
   benchmarks/roofline.py and EXPERIMENTS.md (§Roofline).
"""

from __future__ import annotations

from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Chameleon ASIC model (calibrated to the paper's measured points)
# ---------------------------------------------------------------------------

# measured anchors (paper §IV):   16x16 @150 MHz -> 76.8 GOPS peak
PEAK_GOPS_16 = 76.8              # = 2 * 256 MACs * 150 MHz
F_MAX_HZ = 150e6
# Fig. 16 @0.73 V: 4x4 real-time KWS 3.1 uW total; 16x16 variant 7.4 uW
P_LEAK_CORE_AON_W = 1.5e-6       # core + always-on mem leakage (4x4 mode)
P_LEAK_MSB_W = 3.3e-6            # gateable MSB memory leakage (16x16 adds it)
E_DYN_PER_OP_J = 16e-15          # dynamic energy per (shift+add) op, 0.73 V


@dataclass(frozen=True)
class PEArrayMode:
    n: int  # array side (4 or 16)

    @property
    def macs(self) -> int:
        return self.n * self.n

    def peak_gops(self, f_hz: float = F_MAX_HZ) -> float:
        return 2 * self.macs * f_hz / 1e9

    def realtime_power_w(self, ops_per_s: float) -> float:
        """Leakage + dynamic power to sustain ops_per_s in real time."""
        leak = P_LEAK_CORE_AON_W + (P_LEAK_MSB_W if self.n > 4 else 0.0)
        return leak + E_DYN_PER_OP_J * ops_per_s

    def clock_for(self, ops_per_s: float) -> float:
        return ops_per_s / (2 * self.macs)


def kws_ops_per_s(macs_per_window: float, windows_per_s: float = 62.5) -> float:
    """Real-time KWS op rate (16 ms MFCC hop => 62.5 inferences/s)."""
    return 2.0 * macs_per_window * windows_per_s


# ---------------------------------------------------------------------------
# TPU v5e roofline
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link (brief's constant)


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             n_chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * PEAK_FLOPS_BF16),
        memory_s=hlo_bytes / (n_chips * HBM_BW),
        collective_s=collective_bytes / (n_chips * ICI_BW),
    )


def model_flops(n_params_active: float, n_tokens: float) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE)."""
    return 6.0 * n_params_active * n_tokens
