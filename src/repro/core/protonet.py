"""Prototypical-network learning as an equivalent FC layer — the paper's
central contribution (§III-A, Eq. 3–6 and the log2 form Eq. 8).

The reformulation: with prototypes P_j = s^j / k  (s^j = sum of the k support
embeddings of way j), the squared L2 distance to a query x satisfies

    D_j^2  ∝  (1/2k) ||s^j||^2  -  s^j · x        (after scaling by k/2)

so classification (argmin D_j) is an FC layer with W_j = s^j and
b_j = -(1/2k)||s^j||^2 followed by argmax — *learning is just a forward pass
plus a segment-sum*.  This module provides:

  * exact fp32 extraction (Eq. 6) and the MatMul-free log2 form (Eq. 8),
    where the squared sum-embedding is computed by doubling the log2
    exponent — the ASIC's bit-shift, here exp2(2e) — never a multiply;
  * a class-incremental ``PrototypeStore`` (CL = appending rows, 26 B/way
    on the ASIC; here: one (V,) row + one scalar per way);
  * distributed adaptation: shot embeddings computed data-parallel, the
    segment-sum is a psum over the dp axes, the FC row store is sharded
    over `model` — so on-device learning scales to pods unchanged.

Works against *any* Bundle's ``embed_fn`` (TCN or LM backbones).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.quant.log2 import compute_scale, dequantize_log2, quantize_log2


# ---------------------------------------------------------------------------
# Eq. 3–6: exact PN -> FC extraction
# ---------------------------------------------------------------------------

def support_sums(embeddings: jax.Array, labels: jax.Array, n_ways: int):
    """s^j = sum over the k shots of way j. embeddings: (N*k, V); labels (N*k,)."""
    return jax.ops.segment_sum(embeddings, labels, num_segments=n_ways)


def pn_fc_from_sums(s: jax.Array, k: int):
    """Eq. 6: W_j = s^j, b_j = -(1/2k)||s^j||^2. Returns (W (N,V), b (N,))."""
    w = s
    b = -jnp.sum(jnp.square(s), axis=-1) / (2.0 * k)
    return w, b


def pn_fc_from_sums_log2(s: jax.Array, k: int):
    """Eq. 8: the MatMul-free variant.  s is quantized to 4-bit signed log2
    codes; the square inside the bias becomes an exponent *doubling*
    (left shift on the ASIC; exp2(2e) here), and the 1/2k scale a right
    shift by ceil(log2(k)) + 1.  Returns (W_deq, b, codes, scale)."""
    scale = compute_scale(s)
    q = quantize_log2(s, scale)                      # nibble codes
    w = dequantize_log2(q, scale)                    # FC weights (log2 grid)
    # |value| = 2^(1-|q|) * scale  =>  value^2 = 2^(2*(1-|q|)) * scale^2
    e2 = 2.0 * (1.0 - jnp.abs(q.astype(jnp.float32)))  # doubled exponent
    sq = jnp.where(q == 0, 0.0, jnp.exp2(e2)) * (scale ** 2)
    k_shift = 2.0 ** jnp.ceil(jnp.log2(jnp.asarray(float(k))))  # 2^ceil(log2 k)
    b = -jnp.sum(sq, axis=-1) / (2.0 * k_shift)
    return w, b, q, scale


def pn_logits(x: jax.Array, w: jax.Array, b: jax.Array):
    """Forward pass through the equivalent FC layer: (B,V) -> (B,N).
    argmax equals argmin of the squared L2 distance to the prototypes."""
    return jnp.einsum("bv,nv->bn", x, w) + b[None, :]


def pn_logits_banked(x: jax.Array, w: jax.Array, b: jax.Array,
                     bank_ids: jax.Array):
    """Batched multi-store distance: each query row classifies against ITS
    OWN stacked FC rows.  x: (S, V); w: (T, N, V); b: (T, N); bank_ids: (S,)
    int32 selecting the bank row per query (negative ids clamp to 0 — callers
    mask those rows out).  Returns (S, N) logits.

    This is the multi-tenant form of Eq. 6: the gather + einsum stay one
    fused batched contraction, so S concurrent personalized classifiers cost
    one matmul — the software analogue of the ASIC swapping FC rows per
    user (26 B/way) without touching the shared embedder."""
    ids = jnp.clip(bank_ids, 0, w.shape[0] - 1)
    return jnp.einsum("sv,snv->sn", x, w[ids]) + b[ids]


def l2_classify(x: jax.Array, prototypes: jax.Array):
    """Oracle: argmin_j ||P_j - x||^2 (used by tests/benchmarks only)."""
    d2 = jnp.sum(jnp.square(x[:, None, :] - prototypes[None]), axis=-1)
    return jnp.argmin(d2, axis=-1), d2


# ---------------------------------------------------------------------------
# Few-shot adaptation (the "learning controller" + "parameter extractor")
# ---------------------------------------------------------------------------

def adapt(embed_fn, params, support_batch, labels, n_ways: int, k: int,
          *, log2: bool = False, backend: str | None = None):
    """End-to-end FSL (Fig. 6): embed the N*k support samples (step 1),
    segment-sum into prototypes (step 2), extract FC params (step 3).
    Returns (W, b).  Pure function of params+support — jit/pjit-able.

    Steps 2+3 go through the kernel dispatch layer (kernels/dispatch):
    on accelerators the fused ``proto_extract`` kernel produces W and b
    in one pass (the bias' square-and-reduce never round-trips to HBM);
    the CPU/"ref" resolution keeps the exact segment-sum path.  The log2
    form (Eq. 8) stays pure-jnp — its exponent-doubling is already
    MatMul-free.

    Backend resolution happens at CALL time here (trace time under jit)
    — adapt is the cold enrollment path, called eagerly by every current
    caller, so there is no per-dispatch re-probe to amortize; pass
    ``backend=`` explicitly (or pre-build ``make_proto_extract_op``) to
    pin the choice in a hot loop."""
    emb = embed_fn(params, support_batch).astype(jnp.float32)
    if log2:
        s = support_sums(emb, labels, n_ways)
        w, b, _, _ = pn_fc_from_sums_log2(s, k)
        return w, b
    from repro.kernels import dispatch
    from repro.kernels.ops import make_proto_extract_op
    if dispatch.resolve(backend).use_pallas:
        onehot = jax.nn.one_hot(labels, n_ways, dtype=jnp.float32).T
        return make_proto_extract_op(backend)(emb, onehot, k)
    s = support_sums(emb, labels, n_ways)
    return pn_fc_from_sums(s, k)


# ---------------------------------------------------------------------------
# Continual learning: a growable prototype store
# ---------------------------------------------------------------------------

class PrototypeStore(NamedTuple):
    """CL state: FC rows for up to max_ways classes.  s_sums and counts are
    kept so a class can receive additional shots later (prototype refinement
    = just adding to the sum, Eq. 3)."""
    s_sums: jax.Array   # (max_ways, V)
    counts: jax.Array   # (max_ways,)
    n_ways: jax.Array   # scalar int32


def store_init(max_ways: int, dim: int) -> PrototypeStore:
    return PrototypeStore(
        s_sums=jnp.zeros((max_ways, dim), jnp.float32),
        counts=jnp.zeros((max_ways,), jnp.float32),
        n_ways=jnp.zeros((), jnp.int32),
    )


def store_add_class(store: PrototypeStore, shot_embeddings: jax.Array) -> PrototypeStore:
    """Learn one new class from its k shot embeddings (k, V).

    Overflow contract: at ``n_ways == max_ways`` the update is a masked
    no-op — the store is returned unchanged (n_ways does NOT increment,
    no row is overwritten).  dynamic_update_index_in_dim would otherwise
    clamp the write onto the last learned row, silently corrupting it
    while n_ways kept counting.  Traced callers stay jit-safe; host
    callers (the session service) raise before reaching the op."""
    max_ways = store.s_sums.shape[0]
    ok = store.n_ways < max_ways
    idx = jnp.minimum(store.n_ways, max_ways - 1)
    s = shot_embeddings.astype(jnp.float32).sum(axis=0)
    k = jnp.float32(shot_embeddings.shape[0])
    # .set (not .add) on counts: a row re-learned after store reset/clear
    # must not inherit residue from its previous occupant (tenancy.py's
    # bank_add_class already followed this rule)
    return PrototypeStore(
        s_sums=jax.lax.dynamic_update_index_in_dim(
            store.s_sums, jnp.where(ok, s, store.s_sums[idx]), idx, 0),
        counts=store.counts.at[idx].set(
            jnp.where(ok, k, store.counts[idx])),
        n_ways=store.n_ways + ok.astype(jnp.int32),
    )


def store_update_class(store: PrototypeStore, idx, shot_embeddings) -> PrototypeStore:
    """Add more shots to an existing class (prototype refinement)."""
    s = shot_embeddings.astype(jnp.float32).sum(axis=0)
    return PrototypeStore(
        s_sums=store.s_sums.at[idx].add(s),
        counts=store.counts.at[idx].add(shot_embeddings.shape[0]),
        n_ways=store.n_ways,
    )


def store_fc(store: PrototypeStore):
    """FC weights/bias over the currently learned ways.

    Eq. 6's (W=s, b=-||s||^2/2k) form assumes every class has the same shot
    count k (the per-class k/2 rescale must be uniform for argmax to equal
    argmin-distance).  The CL store allows heterogeneous counts, so it uses
    the normalized equivalent W_j = P_j = s_j/k_j, b_j = -||P_j||^2 / 2 —
    identical up to a global scale when counts are uniform (tested).
    Unlearned rows get bias -inf so they never win the argmax."""
    k = jnp.maximum(store.counts, 1.0)[:, None]
    w = store.s_sums / k
    b = -jnp.sum(jnp.square(w), axis=-1) / 2.0
    live = jnp.arange(store.s_sums.shape[0]) < store.n_ways
    b = jnp.where(live, b, -jnp.inf)
    return w, b


def store_classify(store: PrototypeStore, emb: jax.Array):
    w, b = store_fc(store)
    return jnp.argmax(pn_logits(emb.astype(jnp.float32), w, b), axis=-1)
