# The paper's primary contribution, as composable JAX modules:
#   protonet  — PN-as-FC unified learning/inference (Eq. 3-8) + CL store
#   streaming — greedy dilation-aware FIFO (ring-buffer) TCN execution
#   costmodel — dual-mode PE-array/SRAM model + TPU v5e roofline terms
from repro.core import costmodel, protonet, streaming

__all__ = ["costmodel", "protonet", "streaming"]
