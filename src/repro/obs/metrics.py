"""Zero-dependency metrics plane: Counter / Gauge / Histogram + registry.

The serving stack (slot grids, LM sessions, speculative decode, fused
kernels) is a performance artifact — every headline claim of the source
paper is a *measurement* — yet until this module its only counters were
two bare ints on ``SlotGridService``.  This registry is the one surface
every service reports through:

  * ``Counter``   — monotonically increasing float/int (evictions,
    dispatches, drafted/accepted tokens);
  * ``Gauge``     — last-write-wins scalar (bound slots, parked bytes,
    occupancy of the most recent dispatch);
  * ``Histogram`` — log2-bucketed distribution for latency: bucket ``i``
    covers ``(2^(i-1), 2^i]``, so microsecond-scale dispatch times and
    millisecond-scale park/resume costs land in one compact fixed-size
    array with ~41% worst-case quantile error at the bucket edges —
    ``percentile()`` interpolates geometrically inside the winning bucket
    (exact when samples are log-uniform within it), which is plenty for
    a p99/p50 tail-ratio CI gate.

Metrics are keyed by (name, labels) where labels is a small frozen dict
(``service=``, ``tenant=``, ``backend=``, ``shape=`` ...) — the Prometheus
data model, without the dependency.  ``snapshot()`` returns a pure-JSON
tree (what ``service.metrics()`` surfaces and the bench writes to disk);
``prometheus()`` renders the text exposition format so a scrape endpoint
is one ``app.route`` away.

Everything here is host-side and allocation-light: a ``Histogram.record``
is two adds and an int log2 — safe to leave enabled on the hot path.
Device-side (in-jit) counters live in obs/device.py; they FEED this
registry but never depend on it.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Iterable

# log2 buckets: index i holds samples in (2^(i-1), 2^i].  64 buckets cover
# [1, 2^63] — from 1 us to ~292k years when recording microseconds.
N_BUCKETS = 64


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter.  ``inc`` only; resets are a registry operation."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Log2-bucketed histogram (see module docstring for the bucket rule).

    Records non-negative values; values in [0, 1] land in bucket 0.  Keeps
    exact ``count``/``sum``/``min``/``max`` alongside the buckets, so means
    are exact and only quantiles are bucket-approximate."""

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets = [0] * N_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        if v < 0:
            raise ValueError(f"histogram value must be >= 0, got {v}")
        i = 0 if v <= 1 else min(math.ceil(math.log2(v)), N_BUCKETS - 1)
        self.buckets[i] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        """Drop all samples (benches call this after warmup so compile-time
        outliers never pollute steady-state tails)."""
        self.__init__()

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate quantile ``q`` in [0, 100]: find the bucket holding
        the q-th sample, interpolate geometrically between its bounds
        (log-uniform assumption), clamp to the observed min/max."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        if self.count == 0:
            return 0.0
        rank = q / 100 * self.count
        seen = 0
        for i, n in enumerate(self.buckets):
            if n == 0:
                continue
            if seen + n >= rank:
                lo = 1.0 if i == 0 else float(2 ** (i - 1))
                hi = float(2 ** i)
                frac = (rank - seen) / n
                v = lo * (hi / lo) ** frac
                return float(min(max(v, self.min), self.max))
            seen += n
        return float(self.max)

    def to_dict(self) -> dict:
        # sparse bucket encoding: {exponent: count} for non-empty buckets
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "p50": self.percentile(50), "p99": self.percentile(99),
                "buckets": {str(i): n for i, n in enumerate(self.buckets)
                            if n}}


def merge_histogram_rows(rows: Iterable[dict]) -> Histogram:
    """Merge ``snapshot()``-encoded histogram rows into one ``Histogram``.
    Log2 buckets ADD exactly (same fixed bounds everywhere), and count/
    sum/min/max recombine losslessly — only quantiles stay bucket-
    approximate, exactly as in any single histogram."""
    m = Histogram()
    for r in rows:
        if not r.get("count"):
            continue
        for i, n in r["buckets"].items():
            m.buckets[int(i)] += n
        m.count += r["count"]
        m.sum += r["sum"]
        m.min = min(m.min, r["min"])
        m.max = max(m.max, r["max"])
    return m


def latency_summary(rows: Iterable[dict], *, by: str | None = None) -> dict:
    """The bench-side latency schema: count/p50/p99/mean of the merged
    rows, plus (with ``by="shape"`` etc.) a per-label breakdown under
    ``by_<label>``.  Shared by session_throughput, serve_load, and the
    served CL curve so ``check_regression`` gates one shape everywhere."""
    rows = [r for r in rows if r.get("count")]
    m = merge_histogram_rows(rows)
    out = {
        "count": m.count,
        "p50_us": m.percentile(50),
        "p99_us": m.percentile(99),
        "mean_us": m.mean,
    }
    if by is not None:
        out[f"by_{by}"] = {r["labels"].get(by, "?"):
                           {"count": r["count"], "p50_us": r["p50"],
                            "p99_us": r["p99"]} for r in rows}
    return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create store of (name, labels) -> metric.

    Thread-safe on the create path (an asyncio/worker front-end will share
    one registry across slot-grid workers); reads of plain int/float slots
    are atomic under CPython and need no lock."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: dict):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = _KINDS[kind]()
                    self._metrics[key] = m
        if not isinstance(m, _KINDS[kind]):
            raise TypeError(
                f"metric {name!r}{dict(labels)} already registered as "
                f"{type(m).__name__}, requested {kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    def reset(self) -> None:
        """Reset every metric in place (handles stay valid)."""
        with self._lock:
            for m in self._metrics.values():
                m.__init__()

    # -- exposition ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Pure-JSON tree: {name: [{labels: {...}, **metric}]}.  The shape
        ``service.metrics()`` returns and BENCH_metrics_snapshot.json
        persists."""
        out: dict[str, list] = {}
        for (name, lk), m in sorted(self._metrics.items()):
            out.setdefault(name, []).append(
                {"labels": dict(lk), **m.to_dict()})
        return out

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    def prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4).  Histograms render
        cumulative ``le`` buckets at the log2 upper bounds plus the
        conventional ``_sum``/``_count`` series."""
        lines: list[str] = []
        typed: set[str] = set()
        for (name, lk), m in sorted(self._metrics.items()):
            kind = ("counter" if isinstance(m, Counter)
                    else "gauge" if isinstance(m, Gauge) else "histogram")
            if name not in typed:
                lines.append(f"# TYPE {name} {kind}")
                typed.add(name)
            if isinstance(m, Histogram):
                cum = 0
                for i, n in enumerate(m.buckets):
                    if n == 0:
                        continue
                    cum += n
                    lines.append(f"{name}_bucket"
                                 f"{_prom_labels(lk, le=float(2 ** i))} {cum}")
                lines.append(f"{name}_bucket{_prom_labels(lk, le='+Inf')} "
                             f"{m.count}")
                lines.append(f"{name}_sum{_prom_labels(lk)} {m.sum}")
                lines.append(f"{name}_count{_prom_labels(lk)} {m.count}")
            else:
                lines.append(f"{name}{_prom_labels(lk)} {m.value}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_labels(lk: Iterable[tuple], **extra) -> str:
    pairs = list(lk) + [(k, v) for k, v in extra.items()]
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


# The process-default registry: module-level producers that have no service
# to hang a registry on (kernels/dispatch.py op builds) report here, and
# standalone tools (benches) can fold it into their snapshots.
DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT
