"""Serving telemetry plane: metrics, trace spans, in-scan device counters.

Three layers, all zero-dependency:

  * metrics.py — ``Counter``/``Gauge``/``Histogram`` (log2 latency
    buckets) in a labeled ``MetricsRegistry`` with JSON snapshot +
    Prometheus text exposition; every service reports through one;
  * trace.py   — Chrome-trace/Perfetto span tracer (``trace.span(...)``
    context manager, instants, counter tracks), ring-buffered, activated
    process-wide by ``REPRO_TRACE=path``;
  * device.py  — in-jit counters threaded through the scans as extra
    outputs (speculative acceptance per lane, chunk occupancy,
    pow2-padding waste, masked-vs-live ratios) — one small reduce per
    dispatch, off by default, bit-identical on session state.
"""

from repro.obs.device import (
    acceptance_stats,
    decode_occupancy,
    env_device_counters,
    occupancy_stats,
    valid_stats,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    latency_summary,
    merge_histogram_rows,
)
from repro.obs.trace import Tracer, get_tracer, trace

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "latency_summary", "merge_histogram_rows",
    "Tracer", "get_tracer", "trace",
    "acceptance_stats", "decode_occupancy", "env_device_counters",
    "occupancy_stats", "valid_stats",
]
