"""Chrome-trace / Perfetto span tracer for host-side scheduler decisions.

Records the serving plane's *time-structured* story — what the metrics
registry aggregates away: when each dispatch ran and how long (keyed by
compiled-program shape), which bind evicted which victim and what the
parked blob cost, pack/unpack transfers, park/resume, prefill chunks,
speculative verify rounds.  Events are ring-buffered (bounded memory, the
newest ``capacity`` events win) and exported as Trace Event Format JSON —
open the file at https://ui.perfetto.dev or chrome://tracing and the slot
grid's schedule is a flame chart.

Usage:

    from repro.obs import trace
    with trace.span("dispatch", cat="grid", shape="T160", lanes=12):
        ...                       # complete event "X" with measured dur
    trace.instant("evict", sid=3, cost_bytes=1892)
    trace.counter("parking", parked=7, bound=16)
    trace.export("trace.json")

A DISABLED tracer (the default) costs one attribute load and a truthiness
check per call — ``span()`` returns a shared no-op context manager, so the
hot path pays nothing measurable.  Activation:

  * ``REPRO_TRACE=/path/trace.json`` — enables the process-global tracer
    at import time and registers an atexit export to that path (how the CI
    bench job captures its trace artifact);
  * ``Tracer(enabled=True)`` / ``tracer.enable()`` — programmatic.

Services accept a ``tracer=`` argument and default to the global one, so
a test can hand a private enabled tracer to one service without touching
the environment.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from collections import deque

ENV_VAR = "REPRO_TRACE"


class _NullSpan:
    """Shared no-op context manager — the disabled tracer's span()."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._push({
            "name": self.name, "ph": "X", "cat": self.cat or "repro",
            "ts": self._t0 / 1e3, "dur": (t1 - self._t0) / 1e3,
            "pid": self._tracer.pid, "tid": self._tracer._tid(),
            "args": self.args})
        return False


class Tracer:
    """Ring-buffered Trace Event Format recorder.

    ``ts``/``dur`` are microseconds on the ``time.perf_counter_ns`` clock
    (monotonic; one clock for every event, so spans nest correctly in the
    viewer).  Thread identity comes from ``threading.get_native_id`` so a
    future multi-worker front-end traces onto separate rows for free."""

    def __init__(self, *, enabled: bool = False, capacity: int = 65536,
                 pid: int | None = None):
        self.enabled = enabled
        self.pid = os.getpid() if pid is None else pid
        self._events: deque = deque(maxlen=capacity)
        self.dropped = 0  # events that fell off the ring

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def _tid(self) -> int:
        return threading.get_native_id()

    def _push(self, ev: dict) -> None:
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(ev)

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "", **args):
        """Context manager for a complete ("X") event: duration measured
        between __enter__ and __exit__."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Zero-duration marker ("i" event) — scheduler decisions (evict,
        retire, admit) that have a moment, not an extent."""
        if not self.enabled:
            return
        self._push({"name": name, "ph": "i", "s": "t",
                    "cat": cat or "repro", "ts": time.perf_counter_ns() / 1e3,
                    "pid": self.pid, "tid": self._tid(), "args": args})

    def counter(self, name: str, **values) -> None:
        """Chrome "C" counter event — renders as a stacked area track
        (e.g. bound vs parked session counts over the run)."""
        if not self.enabled:
            return
        self._push({"name": name, "ph": "C",
                    "ts": time.perf_counter_ns() / 1e3,
                    "pid": self.pid, "tid": self._tid(), "args": values})

    # -- export -------------------------------------------------------------
    def events(self) -> list[dict]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    def export(self, path: str) -> str:
        """Write Trace Event Format JSON ({"traceEvents": [...]}) —
        loadable by Perfetto and chrome://tracing as-is."""
        doc = {"traceEvents": self.events(),
               "displayTimeUnit": "ms",
               "otherData": {"producer": "repro.obs.trace",
                             "dropped_events": self.dropped}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


# -- the process-global tracer ----------------------------------------------
# REPRO_TRACE=path enables it at import time and exports on process exit;
# services default to this tracer, so the env var alone instruments a whole
# run with zero code changes (the bench jobs use exactly this).

TRACE_PATH = os.environ.get(ENV_VAR, "").strip()
trace = Tracer(enabled=bool(TRACE_PATH))

if TRACE_PATH:  # pragma: no cover - exercised via subprocess in tests
    atexit.register(lambda: trace.export(TRACE_PATH))


def get_tracer() -> Tracer:
    return trace
