"""Device-side counters: hot-loop efficiency measured WITHOUT leaving jit.

The slot-grid scans are deliberately shape-static — masked lanes and
padded steps still compute — so the interesting efficiency numbers
(how much of the compiled grid did real work?) exist only *inside* the
jitted program.  These helpers compute them there, as a few extra scalar
reduces on values the scan already materializes, and return them as one
small extra output per dispatch:

  * ``occupancy_stats(lengths, t_pad)``  -> (4,) i32
        [live_steps, total_steps, live_lanes, n_lanes]
    for a grid/decode dispatch with per-lane valid-prefix lengths: the
    masked-vs-live step ratio, lane occupancy, and pow2-padding waste of
    the tick are all host-derivable from this one vector
    (``decode_occupancy``);
  * ``acceptance_stats(ys, draft, n_draft)`` -> (S,) i32
    per-lane accepted-draft counts of a speculative verify — the length
    of each lane's matching prefix, computed on device from the verify
    outputs (the host does the same comparison for control flow; the
    device counter exists so acceptance is measurable per dispatch even
    when the host loop is elsewhere, and it is the cross-check the
    instrumentation tests pin against the host arithmetic).

Contract (tested in tests/test_obs.py): threading these outputs through a
jitted scan changes NOTHING about the session state or the decoded
outputs — the instrumented program is bit-identical to the uninstrumented
one on every state leaf.  The counters are pure functions of inputs the
program already carries (lengths, masks, argmax outputs); no state math
is touched, no extra sync is added (the stats ride the same host fetch
as the outputs).

Off by default: services compile the instrumented twin only when
constructed with ``device_counters=True`` (or ``REPRO_DEVICE_COUNTERS=1``).
"""

from __future__ import annotations

import os

import jax.numpy as jnp

ENV_VAR = "REPRO_DEVICE_COUNTERS"


def env_device_counters() -> bool:
    return os.environ.get(ENV_VAR, "").strip().lower() in ("1", "true", "yes")


def occupancy_stats(lengths, t_pad: int):
    """(S,) per-lane valid-prefix lengths -> (4,) i32 stats vector
    [live_steps, total_steps, live_lanes, n_lanes].  Call INSIDE the
    jitted dispatch wrapper; one tiny transfer carries the whole tick's
    efficiency story."""
    lengths = jnp.asarray(lengths, jnp.int32)
    s = lengths.shape[0]
    return jnp.stack([
        jnp.sum(lengths),
        jnp.int32(s * t_pad),
        jnp.sum((lengths > 0).astype(jnp.int32)),
        jnp.int32(s),
    ])


def valid_stats(valid):
    """(S, T) bool validity mask -> the same (4,) i32 vector (the mask is
    ``lengths_to_valid`` of the prefix lengths, so the row-sums recover
    them)."""
    valid = jnp.asarray(valid)
    return occupancy_stats(valid.sum(axis=1), valid.shape[1])


def acceptance_stats(ys, draft, n_draft):
    """Per-lane accepted-draft counts of one verify dispatch.

    ys (S, K+1) verify outputs, draft (S, K) proposed tokens, n_draft (S,)
    valid drafts per lane.  Returns (S,) i32 — the length of each lane's
    matching prefix (the ``m`` the host rollback arithmetic computes)."""
    ys, draft = jnp.asarray(ys), jnp.asarray(draft)
    k = draft.shape[1]
    match = (ys[:, :k] == draft) & (jnp.arange(k)[None, :]
                                    < jnp.asarray(n_draft)[:, None])
    # cumprod trick: 1 while the prefix matches, 0 forever after the first
    # mismatch; the row sum IS the matching-prefix length
    return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)


def decode_occupancy(stats) -> dict:
    """Host-side view of an ``occupancy_stats`` vector: ratios derived once
    per dispatch (never on device — the device's job ends at the reduces).

      live_step_ratio  live / total grid steps (masked-vs-live);
      lane_occupancy   lanes doing any work / compiled lanes;
      pad_waste        padded-but-dead steps WITHIN live lanes / their
                       padded extent — the pow2 bucket's overhang."""
    live, total, lanes_live, lanes = (int(x) for x in stats)
    t_pad = total // lanes if lanes else 0
    live_extent = lanes_live * t_pad
    return {
        "live_steps": live,
        "total_steps": total,
        "live_lanes": lanes_live,
        "lanes": lanes,
        "live_step_ratio": live / total if total else 0.0,
        "lane_occupancy": lanes_live / lanes if lanes else 0.0,
        "pad_waste": 1.0 - live / live_extent if live_extent else 0.0,
    }
