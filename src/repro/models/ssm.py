"""Mamba2 (SSD) blocks for the zamba2 hybrid architecture.

Chunked matmul-form execution of the selective-state recurrence
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t x_t^T,   y_t = C_t . S_t + D_h x_t
(scalar decay per head) — within-chunk interactions become a masked (C, C)
score matrix on the MXU; the (N, P) state is carried across chunks by a scan.
The depthwise conv1d in front is exactly the paper's ring-buffer pattern at
decode time: a (k-1)-deep FIFO per channel (see core/streaming.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import rmsnorm
from repro.sharding.rules import ParamDef

CHUNK = 64


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba_param_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    d_inner, H, N, P = _dims(cfg)
    conv_dim = d_inner + 2 * N  # x, B, C get the depthwise conv
    proj_out = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "norm": {"w": ParamDef((D,), ("embed",), init="ones")},
        "in_proj": ParamDef((D, proj_out), ("embed", "ffn")),
        "conv_w": ParamDef((cfg.ssm_conv_k, conv_dim), ("conv_k", "ffn"), scale=0.2),
        "conv_b": ParamDef((conv_dim,), ("ffn",), init="zeros"),
        "a_log": ParamDef((H,), ("heads",), init="zeros"),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "d_skip": ParamDef((H,), ("heads",), init="ones"),
        "out_norm": {"w": ParamDef((d_inner,), ("ffn",), init="ones")},
        "out_proj": ParamDef((d_inner, D), ("ffn", "embed")),
    }


def _causal_conv(x, w, b, ring=None):
    """Depthwise causal conv1d. x: (B,S,C); w: (K,C); ring: (B,K-1,C) or None.

    Returns (y, new_ring).  new_ring carries the last K-1 inputs — the
    Chameleon FIFO for decode.
    """
    K = w.shape[0]
    if ring is None:
        ring = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([ring, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_ring = xp[:, -(K - 1):, :]
    return jax.nn.silu(y), new_ring


def ssd_chunked(x, dt, A, B, C, state):
    """Chunked SSD. x: (B,T,H,P); dt: (B,T,H); A: (H,) (<0);
    B,C: (B,T,N); state: (B,H,N,P). Returns (y, state)."""
    Bb, T, H, P = x.shape
    N = B.shape[-1]
    Cl = min(CHUNK, T)
    n = -(-T // Cl)
    pad = n * Cl - T
    if pad:
        # dt=0 -> decay exp(0)=1 and zero update: state passes through
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xr = x.reshape(Bb, n, Cl, H, P).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(Bb, n, Cl, H).transpose(1, 0, 2, 3)
    Br = B.reshape(Bb, n, Cl, N).transpose(1, 0, 2, 3)
    Cr = C.reshape(Bb, n, Cl, N).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((Cl, Cl), bool))  # s <= t

    def body(S, xs):
        xb, dtb, Bb_, Cb = xs  # (B,C,H,P), (B,C,H), (B,C,N), (B,C,N)
        ldec = dtb.astype(jnp.float32) * A.astype(jnp.float32)  # log decay per step (<=0)
        cum = jnp.cumsum(ldec, axis=1)  # (B,C,H) inclusive
        # scores[t,s] = (C_t . B_s) exp(cum_t - cum_s) dt_s   for s <= t
        cb = jnp.einsum("btn,bsn->bts", Cb.astype(jnp.float32), Bb_.astype(jnp.float32))
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        att = cb[..., None] * dec * dtb[:, None, :, :]
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", att, xb.astype(jnp.float32))
        # inter-chunk: y_t += C_t . (exp(cum_t) * S)
        y = y + jnp.einsum("btn,bth,bhnp->bthp", Cb.astype(jnp.float32),
                           jnp.exp(cum), S)
        # state: S' = exp(cum_C) S + sum_s exp(cum_C - cum_s) dt_s B_s x_s^T
        w_s = jnp.exp(cum[:, -1:, :] - cum) * dtb  # (B,C,H)
        S_new = jnp.exp(cum[:, -1])[..., None, None] * S + \
            jnp.einsum("bsn,bsh,bshp->bhnp", Bb_.astype(jnp.float32), w_s, xb.astype(jnp.float32))
        return S_new, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32), (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, n * Cl, H, P)[:, :T]
    return y.astype(x.dtype), state


def ssd_step(x, dt, A, B, C, state):
    """Single-token decode. x: (B,H,P); dt: (B,H); B,C: (B,N); state: (B,H,N,P)."""
    dec = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", B.astype(jnp.float32), dt.astype(jnp.float32),
                     x.astype(jnp.float32))
    state = dec[..., None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), state)
    return y.astype(x.dtype), state


def mamba_layer(p, cfg: ArchConfig, x, cache):
    """Mamba2 block. x: (B,S,D); cache: {'conv': (B,K-1,convdim), 'ssm': (B,H,N,P)}."""
    B_, S, D = x.shape
    d_inner, H, N, P = _dims(cfg)
    h = rmsnorm(x, p["norm"]["w"])
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(B_, S, H, P)
    if S == 1:
        y, new_ssm = ssd_step(xh[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0], cache["ssm"])
        y = y[:, None]
    else:
        y, new_ssm = ssd_chunked(xh, dt, A, Bmat, Cmat, cache["ssm"])
    y = y + p["d_skip"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"]["w"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return x + out, {"conv": new_conv, "ssm": new_ssm}


def mamba_empty_cache(cfg: ArchConfig, n_layers: int, batch: int, dtype):
    d_inner, H, N, P = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_k - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_layers, batch, H, N, P), jnp.float32),
    }
