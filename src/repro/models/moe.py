"""Mixture-of-Experts FFN with expert parallelism.

Token-choice top-k routing with a static capacity per expert (dropless within
capacity-factor), implemented as scatter -> grouped einsum -> gather so that
every op is GSPMD-partitionable: experts are sharded over the ``model`` axis
(EP) and XLA inserts the dispatch/combine collectives.  A manual shard_map
all-to-all variant is a §Perf hillclimb lever; this is the baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import mlp_swiglu
from repro.sharding.ctx import constrain
from repro.sharding.rules import ParamDef


def moe_param_defs(cfg: ArchConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    defs = {
        "router": ParamDef((D, E), ("embed", "experts"), scale=0.006),
        "wg": ParamDef((E, D, Fe), ("experts", "embed", None)),
        "wi": ParamDef((E, D, Fe), ("experts", "embed", None)),
        "wd": ParamDef((E, Fe, D), ("experts", None, "embed"), scale=Fe ** -0.5),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        defs["shared"] = {
            "wg": ParamDef((D, Fs), ("embed", "ffn")),
            "wi": ParamDef((D, Fs), ("embed", "ffn")),
            "wd": ParamDef((Fs, D), ("ffn", "embed"), scale=Fs ** -0.5),
        }
    return defs


def expert_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    cap = int(n_tokens * cfg.moe_topk / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_ffn(p: dict, cfg: ArchConfig, x: jax.Array):
    """x: (B, S, D) -> (y (B, S, D), aux_metrics dict).

    Dispatch/combine are *batched per sequence* (leading G=B dim): capacity
    is allocated per sequence and every scatter/gather carries the batch dim,
    which GSPMD partitions cleanly over the dp axes (a flat (T*K,) scatter
    into an expert-sharded buffer forces replication — measured 200+ GiB on
    1M-token batches).  Experts stay sharded over `model` (EP).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_topk
    C = expert_capacity(S, cfg)  # per-sequence capacity

    # --- routing (fp32) ---
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (G, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    fe = (jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=2).mean(axis=(0, 1))) / K
    aux_loss = E * jnp.sum(fe * me)

    # --- position-in-expert via batched stable sort.  All scatters go
    # through vmap: advanced indexing with an explicit arange(B) flattens
    # the indices and hides the batch dim from GSPMD's scatter partitioner
    # (measured: full-batch u32 replication, 60 GiB/device); vmapped
    # scatters keep it as an operand batching dim and partition cleanly. ---
    flat_e = top_e.reshape(B, S * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (G, SK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jax.vmap(
        lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(flat_e)  # (G, E)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos_sorted = jnp.arange(S * K, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(starts, sorted_e, axis=-1)
    pos = jax.vmap(
        lambda o, ps: jnp.zeros((S * K,), jnp.int32).at[o].set(ps)
    )(order, pos_sorted)
    keep = pos < C
    dropped = 1.0 - keep.mean()

    # --- dispatch: batched scatter into per-sequence expert buffers ---
    dest = jnp.where(keep, flat_e * C + pos, E * C)  # OOB -> dropped
    x_rep = jnp.repeat(x, K, axis=1).astype(x.dtype)  # (G, SK, D)
    xbuf = jax.vmap(
        lambda d, xr: jnp.zeros((E * C, D), x.dtype).at[d].set(xr, mode="drop")
    )(dest, x_rep)
    xbuf = constrain(xbuf.reshape(B, E, C, D), ("batch", "experts", None, None))

    # --- grouped expert SwiGLU (G x E batched matmuls on the MXU) ---
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xbuf, p["wg"].astype(x.dtype)))
    u = jnp.einsum("gecd,edf->gecf", xbuf, p["wi"].astype(x.dtype))
    h = constrain(g * u, ("batch", "experts", None, None))
    ybuf = jnp.einsum("gecf,efd->gecd", h,
                      p["wd"].astype(x.dtype)).reshape(B, E * C, D)

    # --- combine: batched gather of each token's K outputs, weight, sum ---
    safe = jnp.where(keep, dest, 0)
    y_rep = jnp.where(keep[..., None],
                      jnp.take_along_axis(ybuf, safe[..., None], axis=1), 0.0)
    y = (y_rep.reshape(B, S, K, D) *
         top_p[..., None].astype(x.dtype)).sum(axis=2)

    if cfg.n_shared_experts:
        y = y + mlp_swiglu(p["shared"], x)

    metrics = {"moe_aux": aux_loss, "moe_dropped": dropped}
    return y, metrics
