"""Temporal Convolutional Network — the paper's embedder (§III-B, Fig. 7).

Residual blocks of two causal dilated Conv1d + BN + ReLU; dilation doubles per
block so the receptive field grows exponentially (Eq. 7).  Supports:

  * fp32 training (batch-norm with running stats carried in a state pytree),
  * QAT: 4-bit signed log2 weights + 4-bit unsigned uniform activations with
    BN folded into the preceding conv (the paper's Brevitas flow, §IV-A),
  * full-sequence inference (training/embedding) and O(R)-state streaming
    (core/streaming.py — the greedy dilation-aware FIFO execution).

The final embedding is the last timestep's features projected to V dims; the
classifier is a plain FC layer — exactly the layer the PN-as-FC learning rule
(core/protonet.py) writes into.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.quant.log2 import (
    compute_scale,
    dequantize_log2,
    fake_quant_act_u4,
    fake_quant_log2,
    pack_nibbles,
    quantize_log2,
)
from repro.sharding.rules import ParamDef

BN_EPS = 1e-5


def receptive_field(cfg: ArchConfig) -> int:
    k = cfg.tcn_kernel
    return 1 + sum(2 * (2 ** b) * (k - 1) for b in range(len(cfg.tcn_channels)))


def tcn_param_defs(cfg: ArchConfig) -> dict:
    k = cfg.tcn_kernel
    chans = cfg.tcn_channels
    defs: dict = {"blocks": {}}
    c_in = cfg.tcn_in_channels
    for i, c_out in enumerate(chans):
        b: dict = {
            "conv1_w": ParamDef((k, c_in, c_out), ("conv_k", "channels_in", "channels")),
            "conv1_b": ParamDef((c_out,), ("channels",), init="zeros"),
            "conv2_w": ParamDef((k, c_out, c_out), ("conv_k", "channels_in", "channels")),
            "conv2_b": ParamDef((c_out,), ("channels",), init="zeros"),
            "bn1": {"scale": ParamDef((c_out,), ("channels",), init="ones"),
                    "bias": ParamDef((c_out,), ("channels",), init="zeros")},
            "bn2": {"scale": ParamDef((c_out,), ("channels",), init="ones"),
                    "bias": ParamDef((c_out,), ("channels",), init="zeros")},
        }
        if c_in != c_out:
            b["down_w"] = ParamDef((1, c_in, c_out), ("conv_k", "channels_in", "channels"))
            b["down_b"] = ParamDef((c_out,), ("channels",), init="zeros")
        defs["blocks"][f"b{i}"] = b
        c_in = c_out
    defs["head_w"] = ParamDef((c_in, cfg.embed_dim), ("channels_in", None))
    defs["head_b"] = ParamDef((cfg.embed_dim,), (None,), init="zeros")
    defs["fc"] = {
        "w": ParamDef((cfg.embed_dim, cfg.n_classes), (None, "proto"), init="zeros"),
        "b": ParamDef((cfg.n_classes,), ("proto",), init="zeros"),
    }
    return defs


def tcn_empty_state(cfg: ArchConfig) -> dict:
    st = {}
    for i, c in enumerate(cfg.tcn_channels):
        st[f"b{i}"] = {
            "bn1_mean": jnp.zeros((c,)), "bn1_var": jnp.ones((c,)),
            "bn2_mean": jnp.zeros((c,)), "bn2_var": jnp.ones((c,)),
        }
    return st


def causal_conv1d(x, w, b, dilation: int):
    """x: (B, T, Cin); w: (K, Cin, Cout). Left-padded causal dilated conv."""
    k = w.shape[0]
    pad = (k - 1) * dilation
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype),
        window_strides=(1,), padding=[(pad, 0)], rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    ) + b.astype(x.dtype)


def _bn(x, scale, bias, mean, var):
    inv = jax.lax.rsqrt(var + BN_EPS)
    return (x - mean) * inv * scale + bias


def _bn_train(x, scale, bias, run_mean, run_var, momentum=0.9):
    mean = jnp.mean(x, axis=(0, 1))
    var = jnp.var(x, axis=(0, 1))
    y = _bn(x, scale, bias, mean, var)
    new_mean = momentum * run_mean + (1 - momentum) * mean
    new_var = momentum * run_var + (1 - momentum) * var
    return y, new_mean, new_var


def _maybe_q_w(w, quantize):
    return fake_quant_log2(w) if quantize else w


def _maybe_q_a(x, quantize, scale=0.25):
    # fixed per-tensor scale (the paper's trained requantizer): makes the
    # quantized streaming/cone executors bit-consistent with full-sequence
    # inference (a data-dependent max would differ per execution schedule)
    import jax.numpy as _jnp
    return fake_quant_act_u4(x, _jnp.float32(scale)) if quantize else x


def tcn_forward(params, state, cfg: ArchConfig, x, *, train: bool = False,
                quantize: bool = False):
    """x: (B, T, C_in) -> (embedding (B, V), logits (B, n_classes), new_state).

    quantize=True runs the QAT fake-quant path (log2 weights, u4 activations);
    when quantize is set with train=False, BN uses running stats — matching
    the paper's deployment flow where BN is folded into the conv weights.
    """
    new_state = {}
    h = x
    for i in range(len(cfg.tcn_channels)):
        p = params["blocks"][f"b{i}"]
        st = state[f"b{i}"]
        d = 2 ** i
        ns = dict(st)
        y = causal_conv1d(h, _maybe_q_w(p["conv1_w"], quantize), p["conv1_b"], d)
        if train:
            y, ns["bn1_mean"], ns["bn1_var"] = _bn_train(
                y, p["bn1"]["scale"], p["bn1"]["bias"], st["bn1_mean"], st["bn1_var"])
        else:
            y = _bn(y, p["bn1"]["scale"], p["bn1"]["bias"], st["bn1_mean"], st["bn1_var"])
        y = _maybe_q_a(jax.nn.relu(y), quantize, cfg.act_scale)
        y = causal_conv1d(y, _maybe_q_w(p["conv2_w"], quantize), p["conv2_b"], d)
        if train:
            y, ns["bn2_mean"], ns["bn2_var"] = _bn_train(
                y, p["bn2"]["scale"], p["bn2"]["bias"], st["bn2_mean"], st["bn2_var"])
        else:
            y = _bn(y, p["bn2"]["scale"], p["bn2"]["bias"], st["bn2_mean"], st["bn2_var"])
        if "down_w" in p:
            res = causal_conv1d(h, _maybe_q_w(p["down_w"], quantize), p["down_b"], 1)
        else:
            res = h
        h = _maybe_q_a(jax.nn.relu(y + res), quantize, cfg.act_scale)
        new_state[f"b{i}"] = ns
    feat = h[:, -1, :]  # causal: last timestep sees the full receptive field
    emb = feat @ _maybe_q_w(params["head_w"], quantize) + params["head_b"]
    emb = _maybe_q_a(jax.nn.relu(emb), quantize, cfg.act_scale)  # u4 embeddings (§IV-A)
    logits = emb @ params["fc"]["w"] + params["fc"]["b"]
    return emb, logits, new_state


def fold_bn(params, state, cfg: ArchConfig):
    """Fold BN into conv weights/biases (deployment, paper §IV-A).

    Returns params' such that conv+bias reproduces conv+BN with running stats;
    BN params become identity.  Enables the pure conv streaming executor and
    the packed log2 deployment pipeline.
    """
    out = jax.tree.map(lambda x: x, params)  # shallow-ish copy of the tree
    for i in range(len(cfg.tcn_channels)):
        p = dict(out["blocks"][f"b{i}"])
        st = state[f"b{i}"]
        for conv, bn in (("conv1", "bn1"), ("conv2", "bn2")):
            scale = p[bn]["scale"] / jnp.sqrt(st[f"{bn}_var"] + BN_EPS)
            p[f"{conv}_w"] = p[f"{conv}_w"] * scale[None, None, :]
            p[f"{conv}_b"] = (p[f"{conv}_b"] - st[f"{bn}_mean"]) * scale + p[bn]["bias"]
            p[bn] = {"scale": jnp.ones_like(scale), "bias": jnp.zeros_like(scale)}
        out["blocks"][f"b{i}"] = p
    new_state = jax.tree.map(
        lambda x: jnp.zeros_like(x), tcn_empty_state(cfg))
    for b in new_state.values():  # var must fold to 1, mean to 0
        b["bn1_var"] = jnp.ones_like(b["bn1_var"]) * (1.0 - BN_EPS)
        b["bn2_var"] = jnp.ones_like(b["bn2_var"]) * (1.0 - BN_EPS)
    return out, new_state


# ---------------------------------------------------------------------------
# Session-open baking for the fused kernel fast path (kernels/tcn_block.py)
# ---------------------------------------------------------------------------

def _bake_weight(w, quantize: bool):
    """One weight's (scan_value, fused_value) pair.

    quantize=True replaces the weight by its log2 fake-quant VALUE wq (so
    the per-step scan path, whose ``fake_quant_log2`` is exactly idempotent
    on the log2 grid, reproduces wq bit-for-bit every step without doing
    the quantization work 160x per chunk), and hands the fused path the
    nibble-PACKED codes — 2/byte at rest, expanded in-kernel."""
    if not quantize:
        return w, w
    s = compute_scale(w)
    q = quantize_log2(w, s)
    wq = dequantize_log2(q, s)
    if w.shape[-1] % 2 == 0:
        return wq, {"codes": pack_nibbles(q), "scale": s}
    return wq, wq  # odd last axis can't nibble-pack; keep fp32


def bake_stream_params(params, state, cfg: ArchConfig, *, quantize: bool = False):
    """One-time session-open transform behind the fused fast path.

    Folds BN into conv weight/bias (``fold_bn``) and, for quantized
    services, pre-bakes the log2 weight fake-quant.  Returns
    ``(scan_params, scan_bn, fused_params)``:

      * scan_params/scan_bn — drop-in for the EXISTING per-step scan path
        (stream_step / grid_scan / tcn_forward).  On these the BN chain is
        the exact identity and re-fake-quantization is an exact fixpoint,
        so the scan path computes pure conv+bias bit-for-bit — the anchor
        the fused kernels are held bit-identical to.
      * fused_params — the kernel-layout tree kernels/tcn_block.py
        consumes (packed codes for quantized weights, no BN leaves).

    Inference-mode only: BN folding uses running stats, so baked params
    must never be trained (README "Kernel fast path" caveats).
    """
    folded, fbn = fold_bn(params, state, cfg)
    fused: dict = {"blocks": {}}
    for i in range(len(cfg.tcn_channels)):
        name = f"b{i}"
        p = dict(folded["blocks"][name])
        fp = {}
        for cv in ("conv1", "conv2"):
            p[f"{cv}_w"], fp[f"{cv}_w"] = _bake_weight(p[f"{cv}_w"], quantize)
            fp[f"{cv}_b"] = p[f"{cv}_b"]
        if "down_w" in p:
            p["down_w"], fp["down_w"] = _bake_weight(p["down_w"], quantize)
            fp["down_b"] = p["down_b"]
        folded["blocks"][name] = p
        fused["blocks"][name] = fp
    hw, fused["head_w"] = _bake_weight(folded["head_w"], quantize)
    folded["head_w"] = hw
    fused["head_b"] = folded["head_b"]
    fused["fc"] = folded["fc"]  # the PN head is never quantized
    return folded, fbn, fused


def make_fused_forward(cfg: ArchConfig, *, quantize: bool = False,
                       backend: str | None = None):
    """Batch-forward twin of the fused streaming executor (backend resolved
    ONCE).  Returns ``forward(fused_params, x) -> (emb (B, V), logits)``:
    inference on baked params via the fused block kernels, with zero
    history strips — bit-identical to the fused chunk executor run from a
    fresh stream state, and allclose (not bitwise: BN folding reassociates
    by design) to ``tcn_forward(train=False)`` on the raw params.
    ``backend=None`` defers to ``cfg.kernel_backend``."""
    from repro.kernels.tcn_block import expand_weight, make_block_fn

    block_fn = make_block_fn(backend or cfg.kernel_backend)
    k = cfg.tcn_kernel

    def forward(fused_params, x):
        B, _, _ = x.shape
        qa = (lambda a: fake_quant_act_u4(a, jnp.float32(cfg.act_scale))) \
            if quantize else (lambda a: a)
        h = x
        for i, c in enumerate(cfg.tcn_channels):
            d = 2 ** i
            n = (k - 1) * d
            strip1 = jnp.pad(h, ((0, 0), (n, 0), (0, 0)))
            hist2 = jnp.zeros((B, n, c), h.dtype)
            h, _ = block_fn(strip1, hist2, fused_params["blocks"][f"b{i}"],
                            dilation=d, k=k, act_scale=cfg.act_scale,
                            quantize=quantize)
        feat = h[:, -1, :]
        emb = feat @ expand_weight(fused_params["head_w"]) + fused_params["head_b"]
        emb = qa(jax.nn.relu(emb))
        logits = emb @ fused_params["fc"]["w"] + fused_params["fc"]["b"]
        return emb, logits

    return forward
