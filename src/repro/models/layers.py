"""Shared transformer building blocks: norms, rotary, attention (GQA + MLA),
MLPs.  Pure functions of (params, x); parameter trees are declared with
ParamDef (see sharding/rules.py) by the per-arch builders in transformer.py.

Compute dtype is bf16 by default (params fp32, norms/softmax in fp32) —
matching TPU v5e MXU-native precision.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

# Norms use custom VJPs with dtype-controlled backward passes.  Rationale
# (measured, see EXPERIMENTS §Perf): (i) an x->f32 convert as the first op of
# a checkpointed scan body makes XLA store the *converted* f32 tensor as the
# per-layer residual, doubling the dominant activation-save memory; (ii) the
# auto-derived transpose of a mixed-precision stats reduction promotes
# x-shaped cotangents to f32.  Hand-writing the VJP keeps every x-shaped
# tensor in the activation dtype while stats/param-grads accumulate in f32.

def _f32_dot(a, b, sub):
    return jnp.einsum(sub, a, b, preferred_element_type=jnp.float32)


@jax.custom_vjp
def _rms_core(x, w, eps):
    D = x.shape[-1]
    ms = _f32_dot(x, x, "...d,...d->...") / D
    inv = jax.lax.rsqrt(ms + eps)
    return x * inv[..., None].astype(x.dtype) * w.astype(x.dtype)


def _rms_fwd(x, w, eps):
    D = x.shape[-1]
    ms = _f32_dot(x, x, "...d,...d->...") / D
    inv = jax.lax.rsqrt(ms + eps)
    y = x * inv[..., None].astype(x.dtype) * w.astype(x.dtype)
    return y, (x, w, inv)


def _rms_bwd(res, dy):
    x, w, inv = res
    D = x.shape[-1]
    wb = w.astype(x.dtype)
    invb = inv[..., None].astype(x.dtype)
    # dw accumulates in f32 (param grad); dx stays in the activation dtype
    dw = _f32_dot(dy * invb, x, "...d,...d->d" if x.ndim > 1 else "d,d->d")
    s = _f32_dot(dy * wb, x, "...d,...d->...") / D  # (B,S) f32
    coef = (inv ** 3 * s)[..., None].astype(x.dtype)
    dx = dy * wb * invb - x * coef
    return dx, dw.astype(w.dtype), None


_rms_core.defvjp(_rms_fwd, _rms_bwd)


def rmsnorm(x, w, eps=1e-6):
    return _rms_core(x, w, eps)


@jax.custom_vjp
def _ln_core(x, w, b, eps):
    return _ln_fwd(x, w, b, eps)[0]


def _ln_fwd(x, w, b, eps):
    D = x.shape[-1]
    mu = _f32_dot(x, jnp.ones((D,), x.dtype), "...d,d->...") / D
    ms = _f32_dot(x, x, "...d,...d->...") / D
    var = jnp.maximum(ms - jnp.square(mu), 0.0)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mu[..., None].astype(x.dtype)) * inv[..., None].astype(x.dtype)
    y = xhat * w.astype(x.dtype) + b.astype(x.dtype)
    return y, (xhat, w, inv)


def _ln_bwd(res, dy):
    xhat, w, inv = res
    D = xhat.shape[-1]
    wb = w.astype(xhat.dtype)
    dyw = dy * wb
    db = _f32_dot(dy, jnp.ones(dy.shape[:-1], dy.dtype), "...d,...->d")
    dw = _f32_dot(dy, xhat, "...d,...d->d")
    m1 = (_f32_dot(dyw, jnp.ones((D,), xhat.dtype), "...d,d->...") / D)
    m2 = (_f32_dot(dyw, xhat, "...d,...d->...") / D)
    dx = (dyw - m1[..., None].astype(xhat.dtype)
          - xhat * m2[..., None].astype(xhat.dtype))
    dx = dx * inv[..., None].astype(xhat.dtype)
    return dx, dw.astype(w.dtype), db.astype(w.dtype), None


_ln_core.defvjp(_ln_fwd, _ln_bwd)


def layernorm(x, w, b, eps=1e-5):
    D = x.shape[-1]
    if w is None:
        w = jnp.ones((D,), jnp.float32)
    if b is None:
        b = jnp.zeros((D,), jnp.float32)
    return _ln_core(x, w, b, eps)


def layernorm_np(x, eps=1e-5):
    """Non-parametric LayerNorm (OLMo): no learnable scale/bias."""
    return layernorm(x, None, None, eps)


def apply_norm(norm_type: str, p: dict, name: str, x):
    if norm_type == "rmsnorm":
        return rmsnorm(x, p[name]["w"])
    if norm_type == "layernorm":
        return layernorm(x, p[name]["w"], p[name]["b"])
    if norm_type == "layernorm_np":
        return layernorm_np(x)
    raise ValueError(norm_type)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions, dim: int, theta: float):
    """positions (...,) int -> (cos, sin) of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rotary_frac: float = 1.0):
    """x: (B, S, H, Dh); cos/sin: (B?, S, Dr/2). Rotates the first Dr dims."""
    dr = cos.shape[-1] * 2
    xr, xp = x[..., :dr], x[..., dr:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[..., None, :].astype(x.dtype) if cos.ndim == x.ndim - 2 else cos.astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype) if sin.ndim == x.ndim - 2 else sin.astype(x.dtype)
    # broadcast over the head axis: cos (B,S,1,Dr/2)
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


# ---------------------------------------------------------------------------
# Attention cores.  q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh); GQA via
# grouped einsum (never materializes repeated KV heads).
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _group_q(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attention_dense(q, k, v, *, causal: bool, q_offset=0, kv_len=None, softmax_scale=None):
    """Materialized-scores attention (fp32 softmax). For short/medium seqs."""
    b, sq, hq, dh = q.shape
    n_kv = k.shape[2]
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    qg = _group_q(q, n_kv)  # (B,Sq,Hkv,G,Dh)
    # f32 via dot accumulation (MXU-native): an .astype(f32) on the output
    # makes XLA materialize convert(k) — hoisted out of the layer scan, that
    # is a full f32 copy of the KV cache (measured 5 GiB/device at 32k).
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    skv = k.shape[1]
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    if kv_len is not None:  # mask out cache positions beyond current length
        scores = jnp.where(jnp.arange(skv)[None, :] < kv_len, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, v.shape[-1])  # v dim may differ (MLA)


def attention_chunked(q, k, v, *, causal: bool, kv_chunk: int = 1024, softmax_scale=None):
    """Flash-style online-softmax attention, scanning over KV chunks.

    O(Sq * kv_chunk) live memory instead of O(Sq * Skv): required to lower
    32k-token prefill within HBM.  Fully-masked (future) chunks still execute
    (scan has a static trip count) but are numerically inert; the causal skip
    is a hillclimb lever (see EXPERIMENTS §Perf).
    """
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    n_kv = k.shape[2]
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, n_kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, n_kv, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    qg = _group_q(q, n_kv)
    qpos = jnp.arange(sq)[:, None]

    # remat per KV chunk: without this the scan stacks every chunk's (Sq,
    # kv_chunk) prob tensor as a backward residual — 4 GiB/layer at 4k x 1k
    # chunks on d8192 models.  Recomputing scores in the bwd pass is the
    # flash-attention backward by construction.
    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        idx, kb, vb = xs
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = kpos < skv
        if causal:
            mask = mask & (kpos <= qpos)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    g = hq // n_kv
    dv = v.shape[-1]
    # constrain the online-softmax carries: they are fresh zeros, and
    # without a constraint GSPMD replicates the head dim (GiBs of f32 acc)
    m0 = constrain(jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32),
                   ("batch", "heads_act", None, None))
    l0 = constrain(jnp.zeros((b, n_kv, g, sq), jnp.float32),
                   ("batch", "heads_act", None, None))
    a0 = constrain(jnp.zeros((b, n_kv, g, sq, dv), jnp.float32),
                   ("batch", "heads_act", None, None, None))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dv).astype(q.dtype)


def attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
              softmax_scale=None, chunked_threshold: int = 8192):
    # Prefill (q_offset==0, kv_len==Sq) needs no cache-length mask: the
    # causal mask subsumes it, so the flash-chunked path applies.  Without
    # this, 32k prefill materializes S x S f32 scores (32 GiB/head-group).
    prefill_like = (isinstance(q_offset, int) and q_offset == 0
                    and isinstance(kv_len, int) and kv_len == q.shape[1])
    if q.shape[1] > 1 and k.shape[1] >= chunked_threshold and causal \
            and (kv_len is None or prefill_like):
        return attention_chunked(q, k, v, causal=True, softmax_scale=softmax_scale)
    if q.shape[1] > 1 and k.shape[1] >= chunked_threshold and kv_len is None:
        return attention_chunked(q, k, v, causal=causal, softmax_scale=softmax_scale)
    return attention_dense(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
        softmax_scale=softmax_scale,
    )


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_swiglu(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = constrain(h, ("batch", None, "ffn"))
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))


def mlp_gelu(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if "bi" in p:
        h = h + p["bi"].astype(x.dtype)
    h = jax.nn.gelu(h)
    h = constrain(h, ("batch", None, "ffn"))
    h = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))
    if "bd" in p:
        h = h + p["bd"].astype(x.dtype)
    return h


def mlp_relu(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if "bi" in p:
        h = h + p["bi"].astype(x.dtype)
    h = jax.nn.relu(h)
    h = constrain(h, ("batch", None, "ffn"))
    h = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))
    if "bd" in p:
        h = h + p["bd"].astype(x.dtype)
    return h


MLP_FNS = {"swiglu": mlp_swiglu, "gelu": mlp_gelu, "relu": mlp_relu}
