"""Architecture configuration shared by every model family."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | rwkv | hybrid | vlm | audio | tcn
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"  # swiglu | gelu | relu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_np
    qkv_bias: bool = False
    mlp_bias: bool = False
    parallel_block: bool = False  # command-r style fused attn+FFN residual
    # GQA with n_kv_heads < TP degree: repeat KV to full heads so the head
    # dim shards cleanly (Megatron's duplication rule, lifted to activations)
    attn_kv_repeat: bool = False
    rope_theta: float = 1e6
    rotary_frac: float = 1.0
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # dense FFN in first layer(s) (deepseek-v2 uses dense layer 0)
    n_dense_layers: int = 0

    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # decode-time weight absorption: attend in the latent space instead of
    # up-projecting K/V for the whole context every step (§Perf lever)
    mla_absorb: bool = False

    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # --- Mamba2 / hybrid (zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_k: int = 4
    attn_every: int = 0  # hybrid: shared attention block period (0 = none)

    # --- enc-dec (seamless) ---
    n_enc_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = "none"  # none | patch | frames
    n_patches: int = 1024   # vlm: patches prepended to the text sequence

    # --- TCN (the paper's arch) ---
    tcn_kernel: int = 0
    tcn_channels: tuple = ()
    tcn_in_channels: int = 1
    embed_dim: int = 64       # PN embedding size V
    act_scale: float = 0.25   # fixed u4 activation scale (QAT + streaming)
    n_classes: int = 12       # inference FC head (rewritten by PN learning)

    # --- numerics / execution ---
    # kernel backend for the fused fast path (kernels/dispatch.py):
    # auto | mosaic | triton | interpret | ref — resolved once at op
    # construction; REPRO_KERNEL_BACKEND overrides "auto"
    kernel_backend: str = "auto"
    act_dtype: str = "bfloat16"
    logit_chunk: int = 512      # chunked cross-entropy seq chunk
    attn_chunk_threshold: int = 4096  # flash-chunked attention above this
    # microbatch gradient accumulation for train_4k (memory roofline knob;
    # also the compute/comm overlap unit — see trainer.py)
    train_microbatch: int = 1
    remat_policy: str = "nothing"  # nothing | dots
    scan_layers: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 2),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 128),
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.dh >= 32 else self.dh,
            logit_chunk=64,
        )
        if self.n_experts:
            kw.update(
                n_experts=min(self.n_experts, 4),
                moe_topk=min(self.moe_topk, 2),
                d_ff_expert=min(self.d_ff_expert, 64),
                n_shared_experts=min(self.n_shared_experts, 1),
                # drop-free in smoke tests: capacity drops are position-
                # dependent, which would confound cache-consistency checks
                capacity_factor=64.0,
            )
        if self.use_mla:
            kw.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, attn_every=self.attn_every and 2)
        if self.n_enc_layers:
            kw.update(n_enc_layers=2)
        if self.frontend == "patch":
            kw.update(n_patches=8)
        if self.tcn_channels:
            kw.update(tcn_channels=tuple(min(c, 16) for c in self.tcn_channels[:3]),
                      tcn_kernel=min(self.tcn_kernel, 3), embed_dim=16)
        return self.replace(**kw)
