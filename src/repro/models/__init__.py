from repro.models.config import ArchConfig
from repro.models.build import Bundle, build_bundle, input_specs, make_empty_cache

__all__ = ["ArchConfig", "Bundle", "build_bundle", "input_specs", "make_empty_cache"]
