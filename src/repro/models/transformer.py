"""Decoder-only and encoder-decoder transformer assembly.

Covers the dense / moe / vlm / audio families (GQA + MLA attention, SwiGLU /
GELU MLPs, MoE FFNs, parallel residual blocks).  Layers are *scanned* over
stacked parameters — HLO size and SPMD-partitioning time are O(1) in depth,
which is what makes compiling 40 (arch x shape) cells on one core (and pod-
scale compile caches) tractable.

Three execution modes share one layer body:
  train   — no cache, remat per layer, chunked cross-entropy loss
  prefill — builds the KV cache (chunked flash attention for 32k inputs)
  decode  — single-token step against the cache
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (
    MLP_FNS,
    apply_norm,
    apply_rope,
    attention,
    rope_angles,
)
from repro.models.moe import moe_ffn, moe_param_defs
from repro.sharding.ctx import constrain
from repro.sharding.rules import ParamDef


def _adt(cfg):
    return jnp.bfloat16 if cfg.act_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def norm_param_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"w": ParamDef((D,), ("embed",), init="ones")}
    if cfg.norm_type == "layernorm":
        return {"w": ParamDef((D,), ("embed",), init="ones"),
                "b": ParamDef((D,), ("embed",), init="zeros")}
    return {}  # layernorm_np: non-parametric


def attn_param_defs(cfg: ArchConfig) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    if cfg.use_mla:
        dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
        return {
            "wq": ParamDef((D, H * (dn + dr)), ("embed", "heads")),
            "w_dkv": ParamDef((D, r + dr), ("embed", None)),
            "kv_norm": ParamDef((r,), (None,), init="ones"),
            "w_ukv": ParamDef((r, H * (dn + dv)), (None, "heads")),
            "wo": ParamDef((H * dv, D), ("heads", "embed"), scale=(H * dv) ** -0.5),
        }
    defs = {
        "wq": ParamDef((D, H * Dh), ("embed", "heads")),
        "wk": ParamDef((D, Hkv * Dh), ("embed", "kv_heads")),
        "wv": ParamDef((D, Hkv * Dh), ("embed", "kv_heads")),
        "wo": ParamDef((H * Dh, D), ("heads", "embed"), scale=(H * Dh) ** -0.5),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H * Dh,), ("heads",), init="zeros")
        defs["bk"] = ParamDef((Hkv * Dh,), ("kv_heads",), init="zeros")
        defs["bv"] = ParamDef((Hkv * Dh,), ("kv_heads",), init="zeros")
    return defs


def mlp_param_defs(cfg: ArchConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {
            "wg": ParamDef((D, F), ("embed", "ffn")),
            "wi": ParamDef((D, F), ("embed", "ffn")),
            "wd": ParamDef((F, D), ("ffn", "embed"), scale=F ** -0.5),
        }
    defs = {
        "wi": ParamDef((D, F), ("embed", "ffn")),
        "wd": ParamDef((F, D), ("ffn", "embed"), scale=F ** -0.5),
    }
    if cfg.mlp_bias:
        defs["bi"] = ParamDef((F,), ("ffn",), init="zeros")
        defs["bd"] = ParamDef((D,), ("embed",), init="zeros")
    return defs


def layer_param_defs(cfg: ArchConfig, *, moe: bool, cross: bool = False) -> dict:
    defs = {"attn_norm": norm_param_defs(cfg), "attn": attn_param_defs(cfg)}
    if not cfg.parallel_block:
        defs["mlp_norm"] = norm_param_defs(cfg)
    defs["mlp"] = moe_param_defs(cfg) if moe else mlp_param_defs(cfg)
    if cross:
        defs["cross_norm"] = norm_param_defs(cfg)
        defs["cross"] = attn_param_defs(cfg.replace(use_mla=False))
    return defs


def stack_defs(defs, n: int):
    """Prepend a scanned 'layers' dimension to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.dtype, d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def model_param_defs(cfg: ArchConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), init="embed"),
        "final_norm": norm_param_defs(cfg),
        "lm_head": ParamDef((D, V), ("embed", "vocab")),
    }
    if cfg.is_encdec:
        n_dec = cfg.n_layers
        defs["enc_layers"] = stack_defs(layer_param_defs(cfg, moe=False), cfg.n_enc_layers)
        defs["enc_norm"] = norm_param_defs(cfg)
        defs["layers"] = stack_defs(layer_param_defs(cfg, moe=False, cross=True), n_dec)
        return defs
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        defs["layers"] = stack_defs(layer_param_defs(cfg, moe=False), n_dense)
    if n_moe:
        defs["moe_layers"] = stack_defs(layer_param_defs(cfg, moe=True), n_moe)
    return defs


# ---------------------------------------------------------------------------
# Attention blocks (GQA and MLA) with optional cache
# ---------------------------------------------------------------------------

def gqa_attn(p, cfg: ArchConfig, x, rope_cs, cache, pos, *, causal=True,
             kv_x=None, cross_cached=False):
    """Self- or cross-attention with optional KV cache.

    Modes (selected statically by the caller):
      self, no cache          — training / encoder
      self, cache + pos       — prefill (pos=0) or decode (pos=t): updates cache
      cross, kv_x             — compute cross K/V from encoder output
      cross, cross_cached     — decode: reuse cached cross K/V (never updated)
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, H, Dh)
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin)

    q = constrain(q, ("batch", None, "heads_act", None))
    kv_len = None
    q_offset = 0
    new_cache = cache
    if cross_cached:
        k, v = cache["k"], cache["v"]
    else:
        src = x if kv_x is None else kv_x
        k = jnp.einsum("bsd,de->bse", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,de->bse", src, p["wv"].astype(x.dtype))
        if "bk" in p:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        k = k.reshape(B, -1, Hkv, Dh)
        v = v.reshape(B, -1, Hkv, Dh)
        if rope_cs is not None and kv_x is None:
            k = apply_rope(k, cos, sin)
        if kv_x is not None and cache is not None:
            # prefill of the cross K/V cache
            new_cache = {"k": k, "v": v}
        elif cache is not None:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = {"k": k, "v": v}
            kv_len = pos + S
            q_offset = pos
    if cfg.attn_kv_repeat and Hkv < H and S > 1:
        # TRAIN/PREFILL with n_kv < TP degree: the head dim must shard, so
        # gather the sequence dim FIRST (needed for attention anyway), then
        # repeat + slice heads locally — avoids a seq->heads reshard that
        # GSPMD can only do via full rematerialization.  DECODE (S==1) skips
        # the repeat: its cache is sequence-sharded and grouped attention
        # with replicated KV heads is already parallel.
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
        k = constrain(k, ("batch", None, "heads_act", None))
        v = constrain(v, ("batch", None, "heads_act", None))
    out = attention(
        q, k, v, causal=causal and kv_x is None and not cross_cached,
        q_offset=q_offset, kv_len=kv_len,
        chunked_threshold=cfg.attn_chunk_threshold,
    )
    out = out.reshape(B, S, H * Dh)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def mla_attn(p, cfg: ArchConfig, x, rope_full, cache, pos):
    """DeepSeek-V2 multi-head latent attention.

    Caches only the compressed latent (B, Smax, r) + shared rope key
    (B, Smax, dr) — 576 B/token vs 4 KiB for equivalent GQA: the paper's
    "shrink the decode state" goal achieved by low-rank projection.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    cos, sin = rope_full
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)

    dkv = jnp.einsum("bsd,de->bse", x, p["w_dkv"].astype(x.dtype))
    c_kv, k_rope = dkv[..., :r], dkv[..., r:]
    from repro.models.layers import rmsnorm
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,dr) shared head

    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, pos, 0, 0))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        kv_len, q_offset = pos + S, pos
    else:
        new_cache, kv_len, q_offset = None, None, 0

    if cfg.mla_absorb and S == 1 and cache is not None:
        # DECODE with weight absorption: fold W_uk into the query and W_uv
        # into the output so attention runs directly against the compressed
        # latent cache — O(r) per cached token instead of re-up-projecting
        # K/V for the whole context every step (H*(dn+dv)/2r ~ 4x fewer
        # context-length FLOPs for the v2-lite dims; measured in §Perf).
        w_ukv = p["w_ukv"].astype(x.dtype).reshape(r, H, dn + dv)
        w_uk, w_uv = w_ukv[..., :dn], w_ukv[..., dn:]
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (B,1,H,r)
        scores = jnp.einsum("bshr,bkr->bhsk", q_eff, c_kv.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        scores = scores + jnp.einsum(
            "bshd,bkzd->bhsk", q_rope, k_rope.astype(x.dtype),
            preferred_element_type=jnp.float32)
        scores = scores * (dn + dr) ** -0.5
        skv = c_kv.shape[1]
        scores = jnp.where(jnp.arange(skv)[None, None, None] < kv_len,
                           scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhsk,bkr->bshr", probs, c_kv.astype(x.dtype))
        out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv)
    else:
        # up-project latents to per-head K_nope and V (baseline path)
        ukv = jnp.einsum("bsr,re->bse", c_kv.astype(x.dtype), p["w_ukv"].astype(x.dtype))
        ukv = ukv.reshape(B, -1, H, dn + dv)
        k_nope, v = ukv[..., :dn], ukv[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope.astype(x.dtype), (B, k_nope.shape[1], H, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention(
            qq, k, v, causal=True, q_offset=q_offset, kv_len=kv_len,
            softmax_scale=(dn + dr) ** -0.5,
            chunked_threshold=cfg.attn_chunk_threshold,
        )
    out = out.reshape(B, S, H * dv)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# Layer body + scan driver
# ---------------------------------------------------------------------------

def layer_fwd(p, cfg: ArchConfig, x, rope_cs, cache, pos, *, moe: bool,
              causal=True, enc_h=None):
    """One transformer layer. Returns (x, new_cache, metrics)."""
    metrics = {}
    x = constrain(x, ("batch", "seq_act", None))
    h = apply_norm(cfg.norm_type, p, "attn_norm", x)
    attn_fn = mla_attn if cfg.use_mla else gqa_attn
    if cfg.use_mla:
        a, new_cache = mla_attn(p["attn"], cfg, h, rope_cs, cache, pos)
    else:
        a, new_cache = gqa_attn(p["attn"], cfg, h, rope_cs, cache, pos, causal=causal)
    if cfg.parallel_block:
        if moe:
            m, metrics = moe_ffn(p["mlp"], cfg, h)
        else:
            m = MLP_FNS[cfg.mlp_type](p["mlp"], h)
        x = x + a + m
    else:
        x = x + a
        x = constrain(x, ("batch", "seq_act", None))
        h = apply_norm(cfg.norm_type, p, "mlp_norm", x)
        if moe:
            m, metrics = moe_ffn(p["mlp"], cfg, h)
        else:
            m = MLP_FNS[cfg.mlp_type](p["mlp"], h)
        x = x + m
    if enc_h is not None or (cache is not None and "cross" in cache):
        x = constrain(x, ("batch", "seq_act", None))
        h = apply_norm(cfg.norm_type, p, "cross_norm", x)
        ca, cross_cache = gqa_attn(
            p["cross"], cfg, h, None,
            cache.get("cross") if cache else None, None, causal=False,
            kv_x=enc_h, cross_cached=(enc_h is None and cache is not None))
        x = x + ca
        if cache is not None:
            new_cache = {**(new_cache or {}), "cross": cross_cache}
    return x, new_cache, metrics


def _remat_policy(cfg: ArchConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _pick_group(L: int, max_group: int = 8) -> int:
    """Largest divisor of L that is <= max_group (two-level remat grouping)."""
    for g in range(min(max_group, L), 0, -1):
        if L % g == 0:
            return g
    return 1


def scan_layers(stacked, cfg: ArchConfig, x, rope_cs, cache, pos, *, moe: bool,
                remat: bool, causal=True, enc_h=None):
    """Scan one homogeneous layer stack. cache: stacked cache pytree or None.

    Training uses TWO-LEVEL (grouped) remat: an outer scan over L/G groups
    whose bodies are checkpointed, each re-scanning its G layers (also
    checkpointed) on the backward pass.  Saved residuals drop from L
    x-shaped slices to L/G + G transient — the classic sqrt-depth schedule —
    which is what fits d8192x80L training in 16 GiB/chip (EXPERIMENTS §Perf).
    """

    def body(x, p, c):
        return layer_fwd(p, cfg, x, rope_cs, c, pos, moe=moe, causal=causal, enc_h=enc_h)

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))

    if cache is None:
        L = jax.tree.leaves(stacked)[0].shape[0]
        G = _pick_group(L) if (remat and cfg.scan_layers) else 1

        def f(carry, pl):
            y, _, m = body(carry, pl, None)
            return y, m

        if G > 1:
            grouped = jax.tree.map(
                lambda a: a.reshape(L // G, G, *a.shape[1:]), stacked)

            @jax.checkpoint
            def group_body(carry, pg):
                return jax.lax.scan(f, carry, pg)

            x, ms = jax.lax.scan(group_body, x, grouped)
            ms = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), ms)
        else:
            x, ms = jax.lax.scan(f, x, stacked)
        new_cache = None
    else:
        def f(carry, xs):
            pl, cl = xs
            y, nc, m = body(carry, pl, cl)
            return y, (nc, m)
        x, (new_cache, ms) = jax.lax.scan(f, x, (stacked, cache))
    metrics = jax.tree.map(jnp.mean, ms) if ms else {}
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# Full model: embed -> stacks -> norm -> (loss | logits)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ArchConfig, tokens):
    e = params["embed"][tokens]
    return e.astype(_adt(cfg))


def backbone(params, cfg: ArchConfig, x, pos, cache, *, remat, enc_h=None):
    """Run the decoder stack(s). x: (B,S,D) embedded input."""
    S = x.shape[1]
    positions = pos + jnp.arange(S)
    rope_dim = int((cfg.qk_rope_dim if cfg.use_mla else cfg.dh) * cfg.rotary_frac)
    cos, sin = rope_angles(positions, rope_dim, cfg.rope_theta)
    metrics = {}
    new_cache = {}
    if cfg.is_encdec:
        x, nc, _ = scan_layers(params["layers"], cfg, x, (cos, sin),
                               cache.get("self") if cache else None, pos,
                               moe=False, remat=remat, enc_h=enc_h)
        if cache is not None:
            new_cache["self"] = nc
    else:
        if "layers" in params:
            x, nc, m = scan_layers(params["layers"], cfg, x, (cos, sin),
                                   cache.get("dense") if cache else None, pos,
                                   moe=False, remat=remat)
            metrics.update(m)
            if cache is not None:
                new_cache["dense"] = nc
        if "moe_layers" in params:
            x, nc, m = scan_layers(params["moe_layers"], cfg, x, (cos, sin),
                                   cache.get("moe") if cache else None, pos,
                                   moe=True, remat=remat)
            metrics.update(m)
            if cache is not None:
                new_cache["moe"] = nc
    x = apply_norm(cfg.norm_type, params, "final_norm", x)
    return x, (new_cache if cache is not None else None), metrics


def run_encoder(params, cfg: ArchConfig, frames, remat):
    """Non-causal encoder over stub frame embeddings (B, S_enc, D)."""
    S = frames.shape[1]
    cos, sin = rope_angles(jnp.arange(S), int(cfg.dh * cfg.rotary_frac), cfg.rope_theta)
    x = frames.astype(_adt(cfg))
    x, _, _ = scan_layers(params["enc_layers"], cfg, x, (cos, sin), None, 0,
                          moe=False, remat=remat, causal=False)
    return apply_norm(cfg.norm_type, params, "enc_norm", x)


def chunked_cross_entropy(hidden, w_head, labels, chunk: int):
    """Memory-bounded LM loss: scan over *sequence* chunks so the (B, S, V)
    logits tensor never materializes.  The batch dim stays intact so its
    data-parallel sharding survives the scan (merging (B,S)->(T,) would let
    GSPMD drop the sharding and replicate multi-GiB logit chunks).
    labels < 0 are masked."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    h, y = hidden, labels
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        y = jnp.pad(y, ((0, 0), (0, pad)), constant_values=-1)
    h = h.reshape(B, n, chunk, D).swapaxes(0, 1)   # (n, B, chunk, D)
    y = y.reshape(B, n, chunk).swapaxes(0, 1)

    # remat: without this, every chunk's (B, chunk, V) logits are stacked as
    # backward residuals — ~4 GiB/device at 256k vocab.  Recomputing the
    # chunk matmul in bwd is the standard fused-CE trade.
    @jax.checkpoint
    def body(acc, xs):
        hc, yc = xs
        hc = constrain(hc, ("batch", None, None))
        logits = jnp.einsum("bcd,dv->bcv", hc,
                            w_head.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (yc >= 0).astype(jnp.float32)
        loss_sum, cnt, correct = acc
        pred = jnp.argmax(logits, axis=-1)
        return (loss_sum + jnp.sum((lse - gold) * mask),
                cnt + mask.sum(),
                correct + jnp.sum((pred == yc) * mask)), None

    (loss_sum, cnt, correct), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), (h, y))
    cnt = jnp.maximum(cnt, 1.0)
    return loss_sum / cnt, {"acc": correct / cnt, "tokens": cnt}


def logits_last(params, cfg: ArchConfig, hidden):
    """LM-head logits for the final position only (decode/prefill output)."""
    h = hidden[:, -1, :]
    return jnp.einsum("bd,dv->bv", h, params["lm_head"].astype(h.dtype)).astype(jnp.float32)


def logits_all(params, cfg: ArchConfig, hidden):
    """LM-head logits at EVERY position of a cached multi-token step.

    The chunked-prefill / speculative-verify output: a (B, S) forward at
    position ``pos`` against the cache needs the greedy continuation at
    each of its S positions (draft token j is judged by the argmax after
    feeding token j), not just the last.  Kept separate from the training
    path's chunked_cross_entropy — S here is a small token chunk, so the
    (B, S, V) logits tensor is fine to materialize."""
    return jnp.einsum("bsd,dv->bsv", hidden,
                      params["lm_head"].astype(hidden.dtype)).astype(jnp.float32)
