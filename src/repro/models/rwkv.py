"""RWKV6 ("Finch") — attention-free time mixing with data-dependent decay.

TPU adaptation: the per-token recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T
is executed in *chunked matmul form* (like flash-linear-attention) so the MXU
does the work: within a chunk of length C the token-token interaction is a
(C, C) masked score matrix with per-channel decay factors; across chunks only
the (Dh, Dh) state is carried by a lax.scan.  All exp() arguments are <= 0 by
construction, so the chunking is numerically safe.  This mirrors the paper's
theme of restructuring a sequential dataflow for the available compute array.

Decode is a single O(1)-state update — the Chameleon FIFO idea degenerating
to one slot (see DESIGN §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import layernorm
from repro.sharding.rules import ParamDef

CHUNK = 32


def rwkv_layer_param_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    F = cfg.d_ff
    R = cfg.rwkv_decay_lora
    mix = lambda: ParamDef((D,), ("embed",), init="zeros")
    return {
        "ln1": {"w": ParamDef((D,), ("embed",), init="ones"),
                "b": ParamDef((D,), ("embed",), init="zeros")},
        "ln2": {"w": ParamDef((D,), ("embed",), init="ones"),
                "b": ParamDef((D,), ("embed",), init="zeros")},
        "time": {
            "mix_r": mix(), "mix_k": mix(), "mix_v": mix(), "mix_g": mix(), "mix_w": mix(),
            "wr": ParamDef((D, D), ("embed", "heads")),
            "wk": ParamDef((D, D), ("embed", "heads")),
            "wv": ParamDef((D, D), ("embed", "heads")),
            "wg": ParamDef((D, D), ("embed", "heads")),
            "w0": ParamDef((D,), ("embed",), init="zeros"),
            "wa": ParamDef((D, R), ("embed", None)),
            "wb": ParamDef((R, D), (None, "heads")),
            "u": ParamDef((D,), ("embed",), init="zeros"),
            "wo": ParamDef((D, D), ("heads", "embed")),
            "gn_w": ParamDef((D,), ("embed",), init="ones"),
            "gn_b": ParamDef((D,), ("embed",), init="zeros"),
        },
        "channel": {
            "mix_k": mix(), "mix_r": mix(),
            "wk": ParamDef((D, F), ("embed", "ffn")),
            "wv": ParamDef((F, D), ("ffn", "embed")),
            "wr": ParamDef((D, D), ("embed", "heads")),
        },
    }


def _token_shift(x, x_prev):
    """x: (B,S,D); x_prev: (B,D) carry from the previous step/chunk."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def _decay(p, xw):
    """Data-dependent decay w_t in (0,1): exp(-exp(w0 + tanh(x@A)@B))."""
    lora = jnp.einsum("bsd,dr->bsr", xw, p["wa"].astype(xw.dtype))
    logw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(lora), p["wb"].astype(xw.dtype)
    ).astype(jnp.float32)
    return -jnp.exp(logw)  # log-decay (<= 0); w = exp(log_w)


def wkv_chunked(r, k, v, log_w, u, state):
    """Chunked WKV6 recurrence.

    r,k,v: (B, T, H, Dh); log_w: (B, T, H, Dh) (<=0); u: (H, Dh);
    state: (B, H, Dh, Dh) [k-dim x v-dim].  T must be a multiple of CHUNK.
    Returns (y (B,T,H,Dh), final state).
    """
    B, T, H, Dh = r.shape
    C = min(CHUNK, T)
    n = -(-T // C)
    pad = n * C - T
    if pad:
        # zero r/k/v and log_w=0 (decay 1) leave the state untouched
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, log_w = map(zpad, (r, k, v, log_w))
    resh = lambda x: x.reshape(B, n, C, H, Dh).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = map(resh, (r, k, v, log_w))

    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)  # strictly lower: s < t

    def body(S, xs):
        rb, kb, vb, wb = xs  # (B, C, H, Dh)
        cum = jnp.cumsum(wb.astype(jnp.float32), axis=1)  # L_t inclusive
        cum_prev = cum - wb.astype(jnp.float32)           # L_{t-1}
        # intra-chunk scores: att[t,s] = sum_i r[t,i] k[s,i] exp(L_{t-1,i}-L_{s,i})
        att = jnp.einsum(
            "bthi,bshi,btshi->bhts",
            rb.astype(jnp.float32), kb.astype(jnp.float32),
            jnp.exp(cum_prev[:, :, None] - cum[:, None, :]),
        )
        att = jnp.where(mask[None, None], att, 0.0)
        # diagonal bonus term: (r_t * u * k_t) -> weight for v_t
        diag = jnp.einsum("bthi,hi,bthi->bth", rb.astype(jnp.float32),
                          u.astype(jnp.float32), kb.astype(jnp.float32))
        y = jnp.einsum("bhts,bshj->bthj", att, vb.astype(jnp.float32))
        y = y + diag[..., None] * vb.astype(jnp.float32)
        # contribution from carried state: r~_t = r_t * exp(L_{t-1})
        rt = rb.astype(jnp.float32) * jnp.exp(cum_prev)
        y = y + jnp.einsum("bthi,bhij->bthj", rt, S)
        # state update: S' = exp(L_C) (.) S + sum_s exp(L_C - L_s) k_s v_s^T
        kt = kb.astype(jnp.float32) * jnp.exp(cum[:, -1:, :, :] - cum)
        S_new = jnp.exp(cum[:, -1])[..., None] * S + jnp.einsum("bshi,bshj->bhij", kt, vb.astype(jnp.float32))
        return S_new, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n * C, H, Dh)[:, :T]
    return y.astype(r.dtype), state


def wkv_step(r, k, v, log_w, u, state):
    """Single-token decode update. r,k,v,log_w: (B,H,Dh); state: (B,H,Dh,Dh)."""
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    y = jnp.einsum("bhi,bhij->bhj", rf, state) + \
        jnp.einsum("bhi,hi,bhi,bhj->bhj", rf, u.astype(jnp.float32), kf, vf)
    state = jnp.exp(log_w.astype(jnp.float32))[..., None] * state + \
        jnp.einsum("bhi,bhj->bhij", kf, vf)
    return y.astype(r.dtype), state


def _group_norm(y, w, b, H):
    """Per-head LayerNorm on (B, T, H, Dh) flattened output (RWKV ln_x)."""
    B, T, _, Dh = y.shape
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(B, T, H * Dh) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return yn


def time_mix(p, cfg: ArchConfig, x, x_prev, state):
    """RWKV6 time-mixing. x: (B,S,D). Returns (out, (new_x_prev, new_state))."""
    B, S, D = x.shape
    H = D // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    xs = _token_shift(x, x_prev)
    lerp = lambda m: x + (xs - x) * p[m].astype(x.dtype)
    xr, xk, xv, xg, xw = (lerp(m) for m in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w"))
    proj = lambda h, w: jnp.einsum("bsd,de->bse", h, p[w].astype(x.dtype))
    r = proj(xr, "wr").reshape(B, S, H, Dh)
    k = proj(xk, "wk").reshape(B, S, H, Dh)
    v = proj(xv, "wv").reshape(B, S, H, Dh)
    g = jax.nn.silu(proj(xg, "wg"))
    log_w = _decay(p, xw).reshape(B, S, H, Dh)
    u = p["u"].reshape(H, Dh)
    if S == 1:
        y, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], u, state)
        y = y[:, None]
    else:
        y, state = wkv_chunked(r, k, v, log_w, u, state)
    y = _group_norm(y, p["gn_w"], p["gn_b"], H).astype(x.dtype) * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    return out, (x[:, -1, :], state)


def channel_mix(p, cfg: ArchConfig, x, x_prev):
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mix_k"].astype(x.dtype)
    xr = x + (xs - x) * p["mix_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return r * kv, x[:, -1, :]


def rwkv_layer(p, cfg: ArchConfig, x, cache):
    """cache: {'x_prev_t','x_prev_c': (B,D), 'state': (B,H,Dh,Dh)}."""
    h = layernorm(x, p["ln1"]["w"], p["ln1"]["b"])
    dt, (xp_t, state) = time_mix(p["time"], cfg, h, cache["x_prev_t"], cache["state"])
    x = x + dt
    h = layernorm(x, p["ln2"]["w"], p["ln2"]["b"])
    dc, xp_c = channel_mix(p["channel"], cfg, h, cache["x_prev_c"])
    x = x + dc
    return x, {"x_prev_t": xp_t, "x_prev_c": xp_c, "state": state}


def rwkv_empty_cache(cfg: ArchConfig, batch: int, dtype):
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    L = cfg.n_layers
    return {
        "x_prev_t": jnp.zeros((L, batch, D), dtype),
        "x_prev_c": jnp.zeros((L, batch, D), dtype),
        "state": jnp.zeros((L, batch, H, Dh, Dh), jnp.float32),
    }
