"""Model bundles: one uniform functional API per architecture.

``build_bundle(cfg)`` returns a ``Bundle`` whose members are pure jittable
functions — the trainer, serving engine, dry-run launcher, and the PN-as-FC
learning head (core/protonet.py) all consume this interface, so the paper's
technique composes with every architecture in the zoo.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.shapes import ENCDEC_ENC_LEN, SHAPES
from repro.models import tcn as tcn_mod
from repro.models.config import ArchConfig
from repro.models.rwkv import rwkv_empty_cache, rwkv_layer, rwkv_layer_param_defs
from repro.models.ssm import mamba_empty_cache, mamba_layer, mamba_param_defs
from repro.models.transformer import (
    backbone,
    chunked_cross_entropy,
    embed_tokens,
    layer_fwd,
    layer_param_defs,
    logits_all,
    logits_last,
    model_param_defs,
    norm_param_defs,
    run_encoder,
    stack_defs,
)
from repro.sharding.rules import ParamDef, abstract_params, init_params


def _adt(cfg):
    return jnp.bfloat16 if cfg.act_dtype == "bfloat16" else jnp.float32


@dataclass
class Bundle:
    cfg: ArchConfig
    param_defs: dict
    loss_fn: Callable      # (params, batch) -> (loss, metrics)
    prefill_fn: Callable   # (params, batch) -> (logits_last, cache)
    decode_fn: Callable    # (params, cache, batch{tokens,pos}) -> (logits, cache)
    embed_fn: Callable     # (params, batch) -> (B, E) embeddings for protonet
    empty_cache: Callable  # (batch, seq_len) -> concrete cache pytree
    cache_specs: Callable  # (batch, seq_len) -> ShapeDtypeStruct cache pytree
    # multi-token cached step: (params, cache, batch{tokens (B,S), pos}) ->
    # (logits (B,S,V) at EVERY position, cache).  The chunked-prefill /
    # speculative-verify workhorse — causal attention over the whole chunk
    # at once amortizes the math, not just the dispatch.  decode_fn is its
    # S=1, last-position special case.
    step_fn: Callable | None = None

    def init(self, key):
        return init_params(self.param_defs, key)

    def abstract_params(self):
        return abstract_params(self.param_defs)

    def input_specs(self, shape_name: str) -> dict:
        return input_specs(self.cfg, shape_name)


# ---------------------------------------------------------------------------
# Input specs per (arch family, shape) — ShapeDtypeStruct stand-ins only.
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    s = SHAPES[shape_name]
    B, S = s.global_batch, s.seq_len
    i32 = jnp.int32
    adt = _adt(cfg)
    D = cfg.d_model
    if cfg.family == "tcn":
        if s.kind == "train":
            return {"x": jax.ShapeDtypeStruct((B, S, cfg.tcn_in_channels), jnp.float32),
                    "labels": jax.ShapeDtypeStruct((B,), i32)}
        return {"x": jax.ShapeDtypeStruct((B, 1, cfg.tcn_in_channels), jnp.float32)}
    if s.kind == "train":
        if cfg.family == "vlm":
            P = cfg.n_patches
            return {"patches": jax.ShapeDtypeStruct((B, P, D), adt),
                    "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
                    "labels": jax.ShapeDtypeStruct((B, S - P), i32)}
        if cfg.family == "audio":
            half = S // 2
            return {"frames": jax.ShapeDtypeStruct((B, half, D), adt),
                    "tokens": jax.ShapeDtypeStruct((B, half), i32),
                    "labels": jax.ShapeDtypeStruct((B, half), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if s.kind == "prefill":
        if cfg.family == "vlm":
            P = cfg.n_patches
            return {"patches": jax.ShapeDtypeStruct((B, P, D), adt),
                    "tokens": jax.ShapeDtypeStruct((B, S - P), i32)}
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, ENCDEC_ENC_LEN, D), adt),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _kv_cache(cfg, L, B, S, dtype):
    Hkv, Dh = cfg.n_kv_heads, cfg.dh
    return {"k": jnp.zeros((L, B, S, Hkv, Dh), dtype),
            "v": jnp.zeros((L, B, S, Hkv, Dh), dtype)}


def _mla_cache(cfg, L, B, S, dtype):
    return {"c_kv": jnp.zeros((L, B, S, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((L, B, S, 1, cfg.qk_rope_dim), dtype)}


def make_empty_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16):
    if cfg.family == "rwkv":
        return rwkv_empty_cache(cfg, B, dtype)
    if cfg.family == "hybrid":
        n_apps = _zamba_n_apps(cfg)
        return {"mamba": mamba_empty_cache(cfg, cfg.n_layers, B, dtype),
                "attn": _kv_cache(cfg, n_apps, B, S, dtype)}
    if cfg.family == "audio":
        c = _kv_cache(cfg, cfg.n_layers, B, S, dtype)
        c["cross"] = _kv_cache(cfg, cfg.n_layers, B, ENCDEC_ENC_LEN, dtype)
        return {"self": c}
    per = _mla_cache if cfg.use_mla else _kv_cache
    out = {}
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        out["dense"] = per(cfg, n_dense, B, S, dtype)
    if n_moe:
        out["moe"] = per(cfg, n_moe, B, S, dtype)
    return out


def make_cache_specs(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16):
    concrete = jax.eval_shape(lambda: make_empty_cache(cfg, B, S, dtype))
    return concrete


# ---------------------------------------------------------------------------
# Decoder-only LM / MoE / VLM / enc-dec bundles
# ---------------------------------------------------------------------------

def _lm_inputs_train(params, cfg, batch):
    """Embed the batch -> (x (B,S,D), labels (B,S), enc_h or None)."""
    enc_h = None
    if cfg.family == "vlm":
        text = embed_tokens(params, cfg, batch["tokens"])
        x = jnp.concatenate([batch["patches"].astype(text.dtype), text], axis=1)
        P = batch["patches"].shape[1]
        pad = jnp.full((x.shape[0], P), -1, jnp.int32)
        labels = jnp.concatenate([pad, batch["labels"]], axis=1)
    elif cfg.family == "audio":
        enc_h = run_encoder(params, cfg, batch["frames"], remat=True)
        x = embed_tokens(params, cfg, batch["tokens"])
        labels = batch["labels"]
    else:
        x = embed_tokens(params, cfg, batch["tokens"])
        labels = batch["labels"]
    return x, labels, enc_h


def build_lm_bundle(cfg: ArchConfig) -> Bundle:
    defs = model_param_defs(cfg)

    def loss_fn(params, batch):
        x, labels, enc_h = _lm_inputs_train(params, cfg, batch)
        h, _, metrics = backbone(params, cfg, x, 0, None, remat=True, enc_h=enc_h)
        loss, lm_m = chunked_cross_entropy(h, params["lm_head"], labels, cfg.logit_chunk)
        metrics = {**metrics, **lm_m}
        if "moe_aux" in metrics:
            loss = loss + cfg.router_aux_coef * metrics["moe_aux"]
        return loss, metrics

    def prefill_fn(params, batch):
        enc_h = None
        if cfg.family == "vlm":
            text = embed_tokens(params, cfg, batch["tokens"])
            x = jnp.concatenate([batch["patches"].astype(text.dtype), text], axis=1)
        elif cfg.family == "audio":
            enc_h = run_encoder(params, cfg, batch["frames"], remat=False)
            x = embed_tokens(params, cfg, batch["tokens"])
        else:
            x = embed_tokens(params, cfg, batch["tokens"])
        B, S = x.shape[0], x.shape[1]
        cache = make_empty_cache(cfg, B, S, _adt(cfg))
        h, cache, _ = backbone(params, cfg, x, 0, cache, remat=False, enc_h=enc_h)
        return logits_last(params, cfg, h), cache

    def step_fn(params, cache, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache, _ = backbone(params, cfg, x, batch["pos"], cache, remat=False)
        return logits_all(params, cfg, h), cache

    def decode_fn(params, cache, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache, _ = backbone(params, cfg, x, batch["pos"], cache, remat=False)
        return logits_last(params, cfg, h), cache

    def embed_fn(params, batch):
        if "labels" not in batch:
            batch = {**batch, "labels": batch["tokens"]}
        x, _, enc_h = _lm_inputs_train(params, cfg, batch)
        h, _, _ = backbone(params, cfg, x, 0, None, remat=False, enc_h=enc_h)
        return h.mean(axis=1)

    return Bundle(
        cfg=cfg, param_defs=defs, loss_fn=loss_fn, prefill_fn=prefill_fn,
        decode_fn=decode_fn, embed_fn=embed_fn, step_fn=step_fn,
        empty_cache=lambda B, S: make_empty_cache(cfg, B, S, _adt(cfg)),
        cache_specs=lambda B, S: make_cache_specs(cfg, B, S, _adt(cfg)),
    )


# ---------------------------------------------------------------------------
# RWKV6 bundle
# ---------------------------------------------------------------------------

def rwkv_model_param_defs(cfg: ArchConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), init="embed"),
        "ln_in": {"w": ParamDef((D,), ("embed",), init="ones"),
                  "b": ParamDef((D,), ("embed",), init="zeros")},
        "layers": stack_defs(rwkv_layer_param_defs(cfg), cfg.n_layers),
        "final_norm": {"w": ParamDef((D,), ("embed",), init="ones"),
                       "b": ParamDef((D,), ("embed",), init="zeros")},
        "lm_head": ParamDef((D, V), ("embed", "vocab")),
    }


def _rwkv_forward(params, cfg, x, cache, *, remat: bool):
    from repro.models.layers import layernorm

    x = layernorm(x, params["ln_in"]["w"], params["ln_in"]["b"])

    def body(x, p, c):
        return rwkv_layer(p, cfg, x, c)

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def f(carry, xs):
        p, c = xs
        y, nc = body(carry, p, c)
        return y, nc

    x, new_cache = jax.lax.scan(f, x, (params["layers"], cache))
    x = layernorm(x, params["final_norm"]["w"], params["final_norm"]["b"])
    return x, new_cache


def build_rwkv_bundle(cfg: ArchConfig) -> Bundle:
    defs = rwkv_model_param_defs(cfg)

    def loss_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        cache = rwkv_empty_cache(cfg, x.shape[0], x.dtype)
        h, _ = _rwkv_forward(params, cfg, x, cache, remat=True)
        return chunked_cross_entropy(h, params["lm_head"], batch["labels"], cfg.logit_chunk)

    def prefill_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        cache = rwkv_empty_cache(cfg, x.shape[0], x.dtype)
        h, cache = _rwkv_forward(params, cfg, x, cache, remat=False)
        return logits_last(params, cfg, h), cache

    def step_fn(params, cache, batch):
        # multi-token cached step: the chunked-matmul WKV form.  NOT
        # bitwise-equal to S sequential decode steps (the recurrence is
        # reassociated), so exactness-contracted callers (chunked prefill,
        # scan verify) must use the per-token path for this family.
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache = _rwkv_forward(params, cfg, x, cache, remat=False)
        return logits_all(params, cfg, h), cache

    def decode_fn(params, cache, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache = _rwkv_forward(params, cfg, x, cache, remat=False)
        return logits_last(params, cfg, h), cache

    def embed_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        cache = rwkv_empty_cache(cfg, x.shape[0], x.dtype)
        h, _ = _rwkv_forward(params, cfg, x, cache, remat=False)
        return h.mean(axis=1)

    return Bundle(
        cfg=cfg, param_defs=defs, loss_fn=loss_fn, prefill_fn=prefill_fn,
        decode_fn=decode_fn, embed_fn=embed_fn, step_fn=step_fn,
        empty_cache=lambda B, S: rwkv_empty_cache(cfg, B, _adt(cfg)),
        cache_specs=lambda B, S: jax.eval_shape(
            lambda: rwkv_empty_cache(cfg, B, _adt(cfg))),
    )


# ---------------------------------------------------------------------------
# Zamba2 hybrid bundle (Mamba2 stack + one shared attention block)
# ---------------------------------------------------------------------------

def _zamba_n_apps(cfg: ArchConfig) -> int:
    return -(-cfg.n_layers // cfg.attn_every)


def zamba_model_param_defs(cfg: ArchConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), init="embed"),
        "layers": stack_defs(mamba_param_defs(cfg), cfg.n_layers),
        "shared_attn": layer_param_defs(cfg, moe=False),  # ONE shared block
        "final_norm": norm_param_defs(cfg),
        "lm_head": ParamDef((D, V), ("embed", "vocab")),
    }


def _zamba_forward(params, cfg, x, cache, pos, *, remat: bool):
    """Mamba stack with the shared attention block applied every attn_every
    layers (Zamba's parameter-sharing trick: same weights, distinct KV
    caches per application site).  cache=None means training: mamba states
    start at zero per sequence and no KV cache is threaded."""
    from repro.models.layers import rope_angles
    from repro.models.transformer import apply_norm

    B, S, _ = x.shape
    train = cache is None
    positions = pos + jnp.arange(S)
    cos, sin = rope_angles(positions, int(cfg.dh * cfg.rotary_frac), cfg.rope_theta)

    mbody = lambda p, x, c: mamba_layer(p, cfg, x, c)
    abody = lambda x, c: layer_fwd(params["shared_attn"], cfg, x, (cos, sin),
                                   c, pos, moe=False)
    if remat:
        mbody = jax.checkpoint(mbody, policy=jax.checkpoint_policies.nothing_saveable)
        abody = jax.checkpoint(abody, policy=jax.checkpoint_policies.nothing_saveable)

    L, E = cfg.n_layers, cfg.attn_every
    n_apps = _zamba_n_apps(cfg)
    mcache = cache["mamba"] if not train else mamba_empty_cache(cfg, L, B, x.dtype)
    new_attn_caches = []
    new_mamba = []
    sl = lambda t, i0, i1: jax.tree.map(lambda a: a[i0:i1], t)
    for app in range(n_apps):
        ac1 = None if train else jax.tree.map(lambda a: a[app], cache["attn"])
        x, nc, _ = abody(x, ac1)
        new_attn_caches.append(nc)
        i0, i1 = app * E, min((app + 1) * E, L)
        seg_params = sl(params["layers"], i0, i1)
        seg_cache = sl(mcache, i0, i1)

        def f(carry, xs):
            p, c = xs
            y, nc2 = mbody(p, carry, c)
            return y, nc2

        x, seg_new = jax.lax.scan(f, x, (seg_params, seg_cache))
        new_mamba.append(seg_new)
    x = apply_norm(cfg.norm_type, params, "final_norm", x)
    if train:
        return x, None
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_attn_caches),
    }
    return x, new_cache


def build_zamba_bundle(cfg: ArchConfig) -> Bundle:
    defs = zamba_model_param_defs(cfg)
    empty = lambda B, S: make_empty_cache(cfg, B, S, _adt(cfg))

    def loss_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, _ = _zamba_forward(params, cfg, x, None, 0, remat=True)
        return chunked_cross_entropy(h, params["lm_head"], batch["labels"], cfg.logit_chunk)

    def prefill_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        cache = empty(x.shape[0], x.shape[1])
        h, cache = _zamba_forward(params, cfg, x, cache, 0, remat=False)
        return logits_last(params, cfg, h), cache

    def step_fn(params, cache, batch):
        # chunked-matmul SSD form: reassociated vs sequential ssd_step, so
        # the same per-token-exactness caveat as the RWKV bundle applies
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache = _zamba_forward(params, cfg, x, cache, batch["pos"], remat=False)
        return logits_all(params, cfg, h), cache

    def decode_fn(params, cache, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, cache = _zamba_forward(params, cfg, x, cache, batch["pos"], remat=False)
        return logits_last(params, cfg, h), cache

    def embed_fn(params, batch):
        x = embed_tokens(params, cfg, batch["tokens"])
        h, _ = _zamba_forward(params, cfg, x, None, 0, remat=False)
        return h.mean(axis=1)

    return Bundle(
        cfg=cfg, param_defs=defs, loss_fn=loss_fn, prefill_fn=prefill_fn,
        decode_fn=decode_fn, embed_fn=embed_fn, step_fn=step_fn,
        empty_cache=empty,
        cache_specs=lambda B, S: jax.eval_shape(lambda: empty(B, S)),
    )


# ---------------------------------------------------------------------------
# TCN bundle (the paper's architecture)
# ---------------------------------------------------------------------------

def build_tcn_bundle(cfg: ArchConfig) -> Bundle:
    defs = tcn_mod.tcn_param_defs(cfg)

    def loss_fn(params, batch, state=None, quantize=False):
        state = state if state is not None else tcn_mod.tcn_empty_state(cfg)
        emb, logits, new_state = tcn_mod.tcn_forward(
            params, state, cfg, batch["x"], train=True, quantize=quantize)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
        loss = jnp.mean(lse - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, ({"acc": acc}, new_state)

    def embed_fn(params, batch, state=None, quantize=False):
        state = state if state is not None else tcn_mod.tcn_empty_state(cfg)
        emb, _, _ = tcn_mod.tcn_forward(params, state, cfg, batch["x"],
                                        train=False, quantize=quantize)
        return emb

    def prefill_fn(params, batch):
        state = tcn_mod.tcn_empty_state(cfg)
        emb, logits, _ = tcn_mod.tcn_forward(params, state, cfg, batch["x"])
        return logits, {}

    def decode_fn(params, cache, batch):  # streaming lives in core/streaming
        raise NotImplementedError("use core.streaming for TCN decode")

    return Bundle(
        cfg=cfg, param_defs=defs, loss_fn=loss_fn, prefill_fn=prefill_fn,
        decode_fn=decode_fn, embed_fn=embed_fn,
        empty_cache=lambda B, S: {}, cache_specs=lambda B, S: {},
    )


BUILDERS = {
    "dense": build_lm_bundle,
    "moe": build_lm_bundle,
    "vlm": build_lm_bundle,
    "audio": build_lm_bundle,
    "rwkv": build_rwkv_bundle,
    "hybrid": build_zamba_bundle,
    "tcn": build_tcn_bundle,
}


def build_bundle(cfg: ArchConfig) -> Bundle:
    return BUILDERS[cfg.family](cfg)
