from repro.training import optim
from repro.training.trainer import TrainConfig, Trainer, TrainState, make_train_step

__all__ = ["optim", "TrainConfig", "Trainer", "TrainState", "make_train_step"]
