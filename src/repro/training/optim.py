"""Pure-JAX optimizers (optax is not available in this environment).

Functional API mirroring optax: an optimizer is ``(init_fn, update_fn)`` where
``update_fn(grads, state, params) -> (updates, new_state)`` and updates are
*added* to params.  All state lives in pytrees so it shards/checkpoints like
params.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


class SGDState(NamedTuple):
    step: jax.Array
    momentum: object


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw(
    lr: float | Callable,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float | None = 1.0,
):
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init_fn(params):
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def update_fn(grads, state: AdamState, params):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def _upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(_upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu), {"grad_norm": gnorm}

    return init_fn, update_fn


def sgd(lr: float | Callable, momentum: float = 0.9, grad_clip: float | None = None):
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init_fn(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update_fn(grads, state: SGDState, params):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads
        )
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype), mom, params)
        return updates, SGDState(step=step, momentum=mom), {"grad_norm": gnorm}

    return init_fn, update_fn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
