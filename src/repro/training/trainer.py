"""Training loop: grad accumulation, checkpoint/restart, straggler hooks.

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * checkpoints are atomic + validated (checkpoint/store.py);
  * the data stream is seekable by step (data/synthetic.lm_batch), so
    kill-and-resume reproduces the uninterrupted run bitwise;
  * saves are async (device->host snapshot on the loop thread only);
  * a per-step wall-clock EMA flags stragglers (on real clusters this is the
    signal that triggers hot-spare promotion / elastic re-mesh; here the hook
    records and logs).

Distributed optimization levers (wired via TrainConfig):
  * microbatch gradient accumulation (lax.scan over microbatches) — also the
    compute/comm overlap lever: with async collectives the reduce of
    microbatch i overlaps the fwd/bwd of i+1;
  * optional int8 error-feedback gradient compression for the DP all-reduce
    (quant/compress.py), demonstrated end-to-end on data-parallel meshes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.quant import compress
from repro.training import optim


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    model_state: Any   # e.g. TCN batch-norm running stats ({} for LMs)
    err_state: Any     # error-feedback residuals ({} when compression off)
    step: jax.Array


@dataclass
class TrainConfig:
    steps: int = 100
    grad_accum: int = 1
    log_every: int = 10
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    straggler_factor: float = 3.0
    grad_compression: str | None = None  # None | "int8_ef"
    dp_axis: str | None = None           # shard_map axis for compressed DP


def make_train_step(loss_fn, optimizer, *, grad_accum: int = 1,
                    has_model_state: bool = False,
                    grad_compression: str | None = None):
    """loss_fn(params, batch [, model_state]) -> (loss, metrics[, new_state])."""
    opt_init, opt_update = optimizer

    def compute_grads(params, model_state, batch):
        if has_model_state:
            def lf(p):
                loss, (m, ns) = loss_fn(p, batch, model_state)
                return loss, (m, ns)
            (loss, (metrics, new_ms)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            new_ms = model_state
        return loss, metrics, new_ms, grads

    def train_step(state: TrainState, batch):
        params = state.params
        if grad_accum > 1:
            def micro(carry, mb):
                g_acc, l_acc, ms = carry
                loss, metrics, ms, grads = compute_grads(params, ms, mb)
                g_acc = jax.tree.map(lambda a, g: a + g / grad_accum, g_acc, grads)
                return (g_acc, l_acc + loss / grad_accum, ms), metrics
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, new_ms), metrics = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32), state.model_state), batch)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            loss, metrics, new_ms, grads = compute_grads(params, state.model_state, batch)

        err_state = state.err_state
        if grad_compression == "int8_ef":
            codes, scales, err_state = compress.compress_tree(grads, err_state)
            grads = compress.decompress_tree(codes, scales)

        updates, opt_state, opt_metrics = opt_update(grads, state.opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(params, opt_state, new_ms, err_state, state.step + 1), metrics

    return train_step


class Trainer:
    def __init__(self, loss_fn, params, cfg: TrainConfig,
                 data_fn: Callable[[int], Any], *, optimizer=None,
                 model_state=None, donate: bool = True):
        self.cfg = cfg
        optimizer = optimizer or optim.adamw(3e-4)
        self.opt_init, _ = optimizer
        # copy params: the jitted step donates its input state, so the
        # caller's arrays must not be aliased into it
        params = jax.tree.map(jnp.array, params) if donate else params
        has_ms = model_state is not None
        self.data_fn = data_fn
        step_fn = make_train_step(
            loss_fn, optimizer, grad_accum=cfg.grad_accum,
            has_model_state=has_ms,
            grad_compression=cfg.grad_compression)
        self.train_step = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
        err = compress.init_error_state(params) if cfg.grad_compression else {}
        self.state = TrainState(
            params=params, opt_state=self.opt_init(params),
            model_state=model_state if has_ms else {},
            err_state=err, step=jnp.zeros((), jnp.int32))
        self.ckpt = store.AsyncCheckpointer(cfg.ckpt_dir, cfg.ckpt_keep) \
            if cfg.ckpt_dir else None
        self.straggler_events: list = []
        self.history: list = []

    def maybe_resume(self) -> int:
        if not self.cfg.ckpt_dir:
            return 0
        got = store.restore_into(self.cfg.ckpt_dir, self.state)
        if got is None:
            return 0
        step, tree = got
        self.state = jax.tree.map(jnp.asarray, tree)
        self.state = self.state._replace(step=jnp.asarray(step, jnp.int32))
        return step

    def run(self, steps: int | None = None):
        steps = steps if steps is not None else self.cfg.steps
        start = int(self.state.step)
        ema = None
        for step in range(start, steps):
            batch = self.data_fn(step)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler detection: step latency vs EMA.  The first steps
            # include jit compilation and must not seed the EMA, or a real
            # straggler later hides under the inflated baseline.
            if ema is not None and dt > self.cfg.straggler_factor * ema:
                self.straggler_events.append((step, dt, ema))
            if step >= start + 2:
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if step % self.cfg.log_every == 0 or step == steps - 1:
                self.history.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}})
            if self.ckpt and (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1, self.state)
        if self.ckpt:
            self.ckpt.save_async(int(self.state.step), self.state)
            self.ckpt.wait()
        return self.state, self.history
