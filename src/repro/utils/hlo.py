"""HLO-text analysis helpers.

``cost_analysis()`` does not report collective traffic, so the roofline's
collective term is derived by parsing the post-SPMD HLO text and summing the
bytes of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).

Post-optimization HLO does not annotate operand types inline (operands are
``%name`` references), so we account bytes from the *result* shape, which
equals the operand size for all-reduce / all-to-all / collective-permute and
the per-device wire traffic for ring all-gather; for reduce-scatter the
operand is result x group_size, parsed from ``replica_groups=[g,n]<=[...]``.

KNOWN LIMITATION (documented in EXPERIMENTS.md §Dry-run): XLA's
HloCostAnalysis counts a ``while`` body ONCE, so flops/bytes from
``cost_analysis()`` under-count scanned programs; the roofline uses the
analytic counters in benchmarks/analytic.py as the primary source and
records the raw cost_analysis numbers alongside.  The same applies to
collectives inside scanned layer bodies: ``collective_bytes`` therefore
reports both raw sums and a corrected total using while-loop trip counts
parsed from the HLO.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ``%x = f32[256,4096]{1,0} all-reduce(...)`` (also -start async forms)
_OP_LINE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")

# tuple-result form: ``%x = (f32[..], f32[..]) all-to-all(...)``
_TUPLE_OP_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")(-start)?\(")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r"trip_count=(\d+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_bytes(line: str):
    """Returns (opcode, bytes) for a collective op line, else None."""
    m = _OP_LINE_RE.search(line)
    if m:
        dtype, dims, opcode = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(dtype, dims)
    else:
        m = _TUPLE_OP_RE.search(line)
        if not m:
            return None
        opcode = m.group(2)
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
    if opcode == "reduce-scatter":
        g = _GROUPS_RE.search(line)
        if g:
            nbytes *= int(g.group(2))  # operand = result x group size
    return opcode, nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective bytes in an HLO module, with while-loop correction.

    HLO while bodies are separate computations; ops inside them execute
    trip_count times.  We attribute each op line to its enclosing computation
    and scale computations that are while bodies with a known trip_count
    (XLA records ``trip_count=N`` in while-loop backend configs when it can
    prove it; jax lax.scan always produces a provable trip count).
    """
    by_type: dict = defaultdict(int)
    count = 0
    # map computation name -> trip multiplier
    multipliers = _while_multipliers(hlo_text)
    current_comp = ""
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") and ("{" in s) and ("=" not in s.split("{")[0]):
            current_comp = s.split()[0].lstrip("%")
        elif s.startswith("ENTRY"):
            current_comp = "__entry__"
        got = _line_bytes(line)
        if got is None:
            continue
        opcode, nbytes = got
        if nbytes == 0:
            continue
        mult = multipliers.get(current_comp, 1)
        by_type[opcode] += nbytes * mult
        count += 1
    return {"total": sum(by_type.values()), "by_type": dict(by_type),
            "count": count}


_WHILE_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)")
_KNOWN_TRIP_RE = re.compile(
    r'known_trip_count[^0-9]*"?n"?\s*[:=]\s*"?(\d+)"?')


def _while_multipliers(hlo_text: str) -> dict:
    """body-computation name -> trip count (1 if unknown)."""
    mult: dict = {}
    for line in hlo_text.splitlines():
        if " while(" not in line:
            continue
        m = _WHILE_RE.search(line)
        if not m:
            continue
        body = m.group(1)
        t = _KNOWN_TRIP_RE.search(line) or _TRIP_RE.search(line)
        mult[body] = int(t.group(1)) if t else 1
    return mult


def parse_cost_analysis(cost) -> dict:
    """Normalize ``compiled.cost_analysis()`` output across jax versions."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for k, v in dict(cost).items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            pass
    return out
