"""Pytree utilities used throughout the framework (no flax/optax available)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (honours per-leaf dtype)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def map_with_path(fn, tree):
    """jax.tree.map with a '/'-joined string path as the first argument."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def flatten_dict(d: dict, prefix: str = "") -> dict:
    """Flatten a nested dict into {'a/b/c': leaf}."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out


def unflatten_dict(flat: dict) -> dict:
    """Inverse of flatten_dict."""
    out: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
