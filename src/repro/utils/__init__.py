from repro.utils.trees import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    map_with_path,
    flatten_dict,
    unflatten_dict,
)
from repro.utils.hlo import collective_bytes, parse_cost_analysis

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_zeros_like",
    "map_with_path",
    "flatten_dict",
    "unflatten_dict",
    "collective_bytes",
    "parse_cost_analysis",
]
