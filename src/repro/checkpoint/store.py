"""Fault-tolerant checkpointing.

Guarantees:
  * atomic: write to a temp dir + os.replace — a crash mid-save can never
    corrupt the latest valid checkpoint;
  * self-validating: per-array CRC32s + a manifest; load skips (and reports)
    corrupt checkpoints and falls back to the previous valid one;
  * exact resume: together with the seekable data stream, kill -9 at any
    step resumes bitwise-identically (tests/test_checkpoint.py);
  * elastic: arrays are stored unsharded (np.load memory-maps lazily) with
    the pytree structure flattened to stable "a/b/c" path keys, so a reload
    under ANY mesh shape re-shards via device_put — mesh-size-independent
    by construction.  (At 1000-node scale the same format shards per host:
    each host writes its addressable slices keyed by global offset; the
    manifest unions them.  See DESIGN.md §4.)
  * async: ``save_async`` snapshots to host memory on the caller's thread,
    then serializes on a background thread — the train loop never blocks on
    the filesystem.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _to_host(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


def _flatten(tree) -> dict:
    """Flatten ANY pytree (dicts, NamedTuples, lists) to stable path keys."""
    kv, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in kv}


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(_to_host(tree))
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "arrays": {}}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["arrays"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _validate(path: str, verify_crc: bool = False):
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    try:
        with open(mf) as f:
            manifest = json.load(f)
        for key, meta in manifest["arrays"].items():
            ap = os.path.join(path, meta["file"])
            if not os.path.exists(ap):
                return None
            if verify_crc:
                arr = np.load(ap)
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in sorted(os.listdir(ckpt_dir)):
        if name.startswith("step_"):
            steps.append((int(name.split("_")[1]), os.path.join(ckpt_dir, name)))
    return sorted(steps)


def restore_flat(ckpt_dir: str, *, verify_crc: bool = True):
    """Returns (step, {path_key: np.ndarray}) from the newest VALID
    checkpoint, or None.  Corrupt/partial checkpoints are skipped
    (node-failure tolerance)."""
    for step, path in reversed(list_checkpoints(ckpt_dir)):
        manifest = _validate(path, verify_crc=verify_crc)
        if manifest is None:
            continue
        flat = {}
        for key, meta in manifest["arrays"].items():
            flat[key] = np.load(os.path.join(path, meta["file"]))
        return step, flat
    return None


def restore_into(ckpt_dir: str, target_tree, *, shardings=None, verify_crc: bool = True):
    """Restore into the structure of target_tree (mesh-elastic: the optional
    shardings tree re-shards every array under the current mesh)."""
    got = restore_flat(ckpt_dir, verify_crc=verify_crc)
    if got is None:
        return None
    step, flat_loaded = got
    kv, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for path, leaf in kv:
        key = jax.tree_util.keystr(path)
        if key not in flat_loaded:
            raise KeyError(f"checkpoint missing array '{key}'")
        leaves.append(np.asarray(flat_loaded[key], dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return step, tree


def gc_checkpoints(ckpt_dir: str, keep: int = 3):
    ck = list_checkpoints(ckpt_dir)
    for step, path in ck[:-keep]:
        shutil.rmtree(path, ignore_errors=True)


# ---------------------------------------------------------------------------
# Session parking-lot persistence (sessions/service.StreamSessionService)
# ---------------------------------------------------------------------------
#
# A parking lot is {sid: parked pytree of np arrays} — nested dicts whose
# leaves may be raw fp32 rings, nibble-packed {"u4c": uint8, "scale": f32}
# records (sessions/state.pack_slot), or truncated KV-cache columns
# (sessions/state.pack_column; any dtype, including bfloat16).  One .npz
# with "/"-joined path keys holds the whole lot; a "__meta__" JSON blob
# carries the service-side session/tenant bookkeeping.  Written atomically
# (tmp + os.replace), same crash guarantee as the model checkpoints above.
#
# Exotic dtypes: np.savez writes ml_dtypes arrays (bfloat16, fp8) with a
# raw void descr, so np.load would hand back "|V2" bytes.  save_sessions
# therefore records a {key: dtype_name} sidecar in the meta blob and
# load_sessions re-views those buffers — the round trip is bit-identical
# AND dtype-identical.

_META_KEY = "__meta__"
_DTYPES_KEY = "__dtypes__"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; registers bfloat16/fp8 for numpy
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_parking(parking: dict) -> dict:
    flat = {}

    def rec(prefix: str, obj):
        if isinstance(obj, dict):
            for k, v in obj.items():
                rec(f"{prefix}/{k}", v)
        else:
            flat[prefix] = np.asarray(obj)

    for sid, tree in parking.items():
        rec(str(int(sid)), tree)
    return flat


def save_sessions(path: str, parking: dict, meta: dict | None = None) -> str:
    """Atomically spill a session parking lot (+ optional metadata) to disk.

    Every sid must contribute at least one array — a blob that flattens to
    nothing would silently vanish from the npz and the restore would drop
    the session instead of refusing.  (Paged LM blobs always carry their
    "pv" geometry marker, so even a zero-block session round-trips.)"""
    flat = _flatten_parking(parking)
    seen = {key.split("/", 1)[0] for key in flat}
    empty = [sid for sid in parking if str(int(sid)) not in seen]
    if empty:
        raise ValueError(f"session blobs with no arrays cannot round-trip "
                         f"through npz: sids {sorted(empty)}")
    def needs_sidecar(dt: np.dtype) -> bool:
        try:  # native dtypes round-trip by name; ml_dtypes ones do not
            return np.dtype(dt.name) != dt
        except TypeError:
            return True

    dtypes = {k: a.dtype.name for k, a in flat.items()
              if needs_sidecar(a.dtype)}
    if dtypes:
        meta = {**(meta or {}), _DTYPES_KEY: dtypes}
    if meta is not None:
        flat[_META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def load_sessions(path: str):
    """Restore (parking, meta) written by ``save_sessions``.

    Leaves come back as np arrays (0-d for scalars); nibble-packed leaves
    keep their {"u4c", "scale"} record shape — sessions/state.unpack_slot
    decodes either form, so the round trip is bit-identical."""
    parking: dict[int, dict] = {}
    meta = None
    with np.load(path) as z:
        dtypes = {}
        if _META_KEY in z.files:
            meta = json.loads(bytes(z[_META_KEY]).decode())
            dtypes = meta.pop(_DTYPES_KEY, {})
        for key in z.files:
            if key == _META_KEY:
                continue
            arr = z[key]
            if key in dtypes:
                arr = arr.view(_np_dtype(dtypes[key]))
            parts = key.split("/")
            node = parking.setdefault(int(parts[0]), {})
            for p in parts[1:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
    return parking, meta


class AsyncCheckpointer:
    """Snapshot-on-call, serialize-in-background checkpoint writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread = None

    def save_async(self, step: int, tree):
        snapshot = _to_host(tree)  # device->host copy on caller's thread
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, snapshot), daemon=True)
        self._thread.start()

    def _write(self, step, snapshot):
        save(self.ckpt_dir, step, snapshot)
        gc_checkpoints(self.ckpt_dir, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
