"""Assigned architecture config: seamless-m4t-large-v2 (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

SEAMLESS_M4T_V2 = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",  # [arXiv:2308.11596; hf]
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=8192, vocab_size=256206, norm_type="layernorm",
    mlp_type="relu", frontend="frames", train_microbatch=2,  # speech frontend stub: frame embeds
)

CONFIG = SEAMLESS_M4T_V2
