"""Assigned architecture config: command-r-35b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

COMMAND_R_35B = ArchConfig(
    name="command-r-35b", family="dense",  # [hf:CohereForAI/c4ai-command-r-v01]
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128, attn_kv_repeat=True, train_microbatch=2,
    d_ff=22528, vocab_size=256000, norm_type="layernorm",
    parallel_block=True, mlp_type="swiglu", tie_embeddings=True,
    rope_theta=8e6,
)

CONFIG = COMMAND_R_35B
