"""Architecture registry: the 10 assigned configs + the paper's own TCN.

Each assigned architecture lives in its own ``configs/<arch>.py`` (sources and
verification tier documented there); deviations are noted in DESIGN.md §3.
"""

from __future__ import annotations

from repro.models.config import ArchConfig

from repro.configs.rwkv6_1p6b import RWKV6_1B6
from repro.configs.deepseek_v2_lite_16b import DEEPSEEK_V2_LITE
from repro.configs.moonshot_v1_16b_a3b import MOONSHOT_16B_A3B
from repro.configs.olmo_1b import OLMO_1B
from repro.configs.stablelm_1p6b import STABLELM_1B6
from repro.configs.command_r_35b import COMMAND_R_35B
from repro.configs.qwen25_32b import QWEN25_32B
from repro.configs.zamba2_1p2b import ZAMBA2_1B2
from repro.configs.internvl2_76b import INTERNVL2_76B
from repro.configs.seamless_m4t_large_v2 import SEAMLESS_M4T_V2
from repro.configs.chameleon_tcn import (
    CHAMELEON_TCN,
    CHAMELEON_TCN_AUDIO,
    CHAMELEON_TCN_KWS,
)

ASSIGNED = [
    RWKV6_1B6, DEEPSEEK_V2_LITE, MOONSHOT_16B_A3B, OLMO_1B, STABLELM_1B6,
    COMMAND_R_35B, QWEN25_32B, ZAMBA2_1B2, INTERNVL2_76B, SEAMLESS_M4T_V2,
]

REGISTRY = {c.name: c for c in ASSIGNED + [
    CHAMELEON_TCN, CHAMELEON_TCN_AUDIO, CHAMELEON_TCN_KWS]}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
