"""Assigned input shapes (one set, shared by all 10 LM-family archs)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic decode state; only constant/log-state archs
# run it (see DESIGN.md §3). Full-attention archs are recorded as SKIP.
LONG_CONTEXT_ARCHS = {"rwkv6-1.6b", "zamba2-1.2b", "chameleon-tcn"}

# Encoder-decoder: fixed encoder length for serve shapes (see DESIGN.md §6).
ENCDEC_ENC_LEN = 4096


def cells(arch_names):
    """All 40 (arch x shape) cells, with skip annotations."""
    out = []
    for a in arch_names:
        for s in SHAPES.values():
            skip = s.name == "long_500k" and a not in LONG_CONTEXT_ARCHS
            out.append((a, s.name, skip))
    return out
