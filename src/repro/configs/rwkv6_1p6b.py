"""Assigned architecture config: rwkv6-1.6b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

RWKV6_1B6 = ArchConfig(
    name="rwkv6-1.6b", family="rwkv",  # [arXiv:2404.05892]
    n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536,
    n_heads=32, n_kv_heads=32, head_dim=64,  # 2048/64 WKV heads
    norm_type="layernorm", rwkv_head_dim=64,
)

CONFIG = RWKV6_1B6
