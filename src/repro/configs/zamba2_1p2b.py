"""Assigned architecture config: zamba2-1.2b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

ZAMBA2_1B2 = ArchConfig(
    name="zamba2-1.2b", family="hybrid",  # [arXiv:2411.15242; hf]
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, norm_type="rmsnorm",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_k=4,
    attn_every=6, train_microbatch=2,  # one *shared* attention+MLP block applied every 6 layers
)

CONFIG = ZAMBA2_1B2
