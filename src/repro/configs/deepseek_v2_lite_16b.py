"""Assigned architecture config: deepseek-v2-lite-16b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

DEEPSEEK_V2_LITE = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",  # [arXiv:2405.04434; hf]
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    d_ff=10944,  # dense FFN (layer 0), per hf config
    n_dense_layers=1, n_experts=64, moe_topk=6, n_shared_experts=2,
    d_ff_expert=1408, norm_type="rmsnorm", train_microbatch=2,
    # NOTE: assignment line also mentions "160 routed" — that is full V2;
    # V2-*Lite* is 64 routed + 2 shared top-6 (matches the primary spec).
)

CONFIG = DEEPSEEK_V2_LITE
