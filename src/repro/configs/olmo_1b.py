"""Assigned architecture config: olmo-1b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

OLMO_1B = ArchConfig(
    name="olmo-1b", family="dense",  # [arXiv:2402.00838; hf]
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304, norm_type="layernorm_np",  # non-parametric LN
    mlp_type="swiglu", rope_theta=10000.0,
)

CONFIG = OLMO_1B
