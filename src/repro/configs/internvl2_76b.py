"""Assigned architecture config: internvl2-76b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

INTERNVL2_76B = ArchConfig(
    name="internvl2-76b", family="vlm",  # [arXiv:2404.16821]
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128, attn_kv_repeat=True, train_microbatch=4,
    d_ff=28672, vocab_size=128256, norm_type="rmsnorm", mlp_type="swiglu",
    frontend="patch", n_patches=1024,  # InternViT stub: precomputed patch embeds
)

CONFIG = INTERNVL2_76B
