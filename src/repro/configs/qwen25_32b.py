"""Assigned architecture config: qwen2.5-32b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

QWEN25_32B = ArchConfig(
    name="qwen2.5-32b", family="dense",  # [hf:Qwen/Qwen2.5-32B]
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, attn_kv_repeat=True, train_microbatch=2,
    d_ff=27648, vocab_size=152064, norm_type="rmsnorm",
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6,
)

CONFIG = QWEN25_32B
