"""Runtime configuration: ONE resolved view of the process-level switches.

The subsystems historically each read their own environment variable at
construction time — ``REPRO_PAGED`` (sessions/lm.py), ``REPRO_TCN_FUSED``
(sessions/service.py), ``REPRO_KERNEL_BACKEND`` (kernels/dispatch.py),
``REPRO_TRACE`` (obs/trace.py), ``REPRO_DEVICE_COUNTERS`` (obs/device.py).
Five ad-hoc switches with five parsers is how a fleet config drifts, so
they are consolidated here into one frozen dataclass with ONE documented
precedence, applied field by field:

    explicit kwarg  >  environment variable  >  default

``RuntimeConfig.resolve(**overrides)`` implements the middle level: any
field passed as a non-None override wins outright; the rest fall back to
the environment and then to the dataclass default.  A directly
constructed ``RuntimeConfig(...)`` is *fully explicit* — it never
consults the environment — which is what a test or a multi-worker
front-end wants when it must pin behavior regardless of the shell.

Both session services and the async serving plane accept ``runtime=``;
their historical per-field kwargs (``fused=``, ``paged=``, ...) keep
working and sit at the top of the precedence (explicit kwarg beats the
RuntimeConfig, which beats env, which beats the default).

Truthiness matches the historical parsers exactly: the strings "1",
"true", "yes" (case-insensitive, stripped) are True, everything else —
including unset — is False.  ``tests/test_service_protocol.py`` holds the
variable names here equal to the owning modules' ``ENV_VAR`` constants so
the consolidation can never drift from the subsystems it describes.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, fields

# canonical variable names; asserted == the owning modules' ENV_VAR
# constants in tests/test_service_protocol.py (runtime.py stays importable
# without jax, so the heavy modules are not imported here)
ENV_PAGED = "REPRO_PAGED"                      # sessions/lm.py
ENV_FUSED = "REPRO_TCN_FUSED"                  # sessions/service.py
ENV_KERNEL_BACKEND = "REPRO_KERNEL_BACKEND"    # kernels/dispatch.py
ENV_TRACE = "REPRO_TRACE"                      # obs/trace.py
ENV_DEVICE_COUNTERS = "REPRO_DEVICE_COUNTERS"  # obs/device.py
ENV_CHAOS = "REPRO_CHAOS"                      # serving/faults.py

_TRUE = ("1", "true", "yes")


def _env_bool(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in _TRUE


def _env_str(name: str) -> str | None:
    v = os.environ.get(name, "").strip()
    return v or None


@dataclass(frozen=True)
class RuntimeConfig:
    """Resolved process-level switches (see module docstring for the
    precedence contract).

    paged            LM KV caches use the paged block-pool layout
    fused            TCN streaming runs the fused kernel fast path
    kernel_backend   force a kernels/dispatch backend (None = auto)
    trace_path       Perfetto trace output path (None = tracing off);
                     informational unless the process-global tracer was
                     env-activated — benches/the plane export explicitly
    device_counters  compile the instrumented scan twins (in-jit stats)
    chaos            fault-injection plan spec (serving/faults.FaultPlan
                     format, e.g. "crash@40,flake@25"); None = faults off
                     and the production paths are byte-for-byte untouched
    """

    paged: bool = False
    fused: bool = False
    kernel_backend: str | None = None
    trace_path: str | None = None
    device_counters: bool = False
    chaos: str | None = None

    @classmethod
    def resolve(cls, **overrides) -> "RuntimeConfig":
        """Build a config honouring ``explicit kwarg > env > default``.
        Overrides passed as ``None`` mean "not specified" and fall
        through to the environment level."""
        unknown = set(overrides) - {f.name for f in fields(cls)}
        if unknown:
            raise TypeError(f"unknown RuntimeConfig fields: {sorted(unknown)}")
        env = cls(
            paged=_env_bool(ENV_PAGED),
            fused=_env_bool(ENV_FUSED),
            kernel_backend=_env_str(ENV_KERNEL_BACKEND),
            trace_path=_env_str(ENV_TRACE),
            device_counters=_env_bool(ENV_DEVICE_COUNTERS),
            chaos=_env_str(ENV_CHAOS),
        )
        picked = {k: (getattr(env, k) if v is None else v)
                  for k, v in overrides.items()}
        return cls(**{f.name: picked.get(f.name, getattr(env, f.name))
                      for f in fields(cls)})

    def pick(self, field: str, explicit):
        """One field through the full precedence: the caller's explicit
        kwarg (non-None) beats this config's value.  The one-liner every
        service constructor uses, so the rule cannot be re-implemented
        five slightly different ways again."""
        return getattr(self, field) if explicit is None else explicit
