"""Assigned architecture config: moonshot-v1-16b-a3b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

MOONSHOT_16B_A3B = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",  # [hf:moonshotai/Moonlight-16B-A3B]
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    vocab_size=163840, d_ff=11264, n_dense_layers=1,
    n_experts=64, moe_topk=6, n_shared_experts=2, d_ff_expert=1408,
    norm_type="rmsnorm", train_microbatch=2,  # GQA variant (MLA coverage comes from deepseek)
)

CONFIG = MOONSHOT_16B_A3B
