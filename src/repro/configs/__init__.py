from repro.configs.registry import ASSIGNED, REGISTRY, get_config
from repro.configs.runtime import RuntimeConfig
from repro.configs.shapes import SHAPES, LONG_CONTEXT_ARCHS, ENCDEC_ENC_LEN, cells

__all__ = [
    "ASSIGNED", "REGISTRY", "get_config", "RuntimeConfig",
    "SHAPES", "LONG_CONTEXT_ARCHS", "ENCDEC_ENC_LEN", "cells",
]
