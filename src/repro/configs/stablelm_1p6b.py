"""Assigned architecture config: stablelm-1.6b (see DESIGN.md section 3)."""

from repro.models.config import ArchConfig

STABLELM_1B6 = ArchConfig(
    name="stablelm-1.6b", family="dense",  # [hf:stabilityai/stablelm-2-1_6b]
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352, norm_type="layernorm",
    mlp_type="swiglu", rotary_frac=0.25, rope_theta=10000.0,
)

CONFIG = STABLELM_1B6
