"""The paper's own architecture: Chameleon TCN presets (§IV).

Three published presets:
  * FSL embedder — 14 layers / ~116k params (Omniglot, Table I)
  * raw-audio KWS — 24 layers / ~118k params, 16k-step inputs (§IV-C)
  * MFCC KWS — 8 layers / ~16.5k params (the 4x4 "always-on" mode model)
"""

from repro.models.config import ArchConfig

CHAMELEON_TCN = ArchConfig(
    name="chameleon-tcn", family="tcn",
    # 14-layer FSL embedder: 7 residual blocks, receptive field 1525 >= 784
    tcn_kernel=7, tcn_channels=(32, 32, 32, 32, 32, 32, 32),
    tcn_in_channels=1, embed_dim=64, n_classes=5,
    n_layers=14, d_model=32, vocab_size=0, n_heads=1, n_kv_heads=1, d_ff=0,
)

CHAMELEON_TCN_AUDIO = CHAMELEON_TCN.replace(
    name="chameleon-tcn-audio",
    tcn_kernel=7, tcn_channels=(24,) * 12, n_layers=24, n_classes=12,
)

CHAMELEON_TCN_KWS = CHAMELEON_TCN.replace(
    name="chameleon-tcn-kws",
    tcn_kernel=3, tcn_channels=(24, 24, 24, 24), n_layers=8,
    tcn_in_channels=28, n_classes=12,
)

CONFIG = CHAMELEON_TCN
