"""Episodic (meta-learning) samplers: N-way k-shot tasks (paper §II-A).

Meta-train / meta-test splits partition *classes* (Fig. 2c).  Episodes are
deterministic in (seed, episode index) so runs are reproducible and
resumable.
"""

from __future__ import annotations

import numpy as np


class EpisodicSampler:
    def __init__(self, dataset, class_ids, seed: int = 0):
        self.ds = dataset
        self.class_ids = np.asarray(class_ids)
        self.seed = seed

    def episode(self, ep: int, n_ways: int, k_shots: int, n_query: int):
        """Returns (support_x, support_y, query_x, query_y); y in [0, n_ways)."""
        rng = np.random.default_rng((self.seed, ep))
        ways = rng.choice(self.class_ids, size=n_ways, replace=False)
        sx, sy, qx, qy = [], [], [], []
        for j, cls in enumerate(ways):
            samples = self.ds.sample(int(cls), k_shots + n_query, seed=ep * 131 + j)
            sx.append(samples[:k_shots])
            qx.append(samples[k_shots:])
            sy.append(np.full(k_shots, j, np.int32))
            qy.append(np.full(n_query, j, np.int32))
        return (np.concatenate(sx), np.concatenate(sy),
                np.concatenate(qx), np.concatenate(qy))


def split_classes(n_classes: int, train_frac: float = 0.7, seed: int = 0):
    """Meta-train / meta-test class split (disjoint classes, Fig. 2c)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_classes)
    cut = int(n_classes * train_frac)
    return perm[:cut], perm[cut:]
