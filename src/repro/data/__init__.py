from repro.data.episodic import EpisodicSampler, split_classes
from repro.data.synthetic import GlyphClasses, KeywordAudio, lm_batch

__all__ = ["EpisodicSampler", "split_classes", "GlyphClasses", "KeywordAudio", "lm_batch"]
