"""Procedural datasets (Omniglot/GSC are not available offline — DESIGN §1).

* ``GlyphClasses`` — Omniglot-like handwritten-character classes: each class
  is a fixed set of random strokes; each sample redraws them with jitter,
  rendered to 28x28 and flattened pixelwise to a length-784 sequence
  ("sequential Omniglot", paper Fig. 14).
* ``KeywordAudio`` — GSC-like keyword classes: class-specific formant
  trajectories + noise at 16 kHz; raw 1 s clips (16k samples) or 28-dim
  log-mel "MFCC" frames with the paper's 32 ms / 16 ms framing (63 frames).
* ``lm_batch`` — deterministic, *seekable* synthetic token stream (mixture of
  hash noise and copy/repeat structure so an LM can reduce loss); stateless
  in the step index, which is what makes checkpoint-resume exact.
"""

from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Omniglot-like glyphs
# ---------------------------------------------------------------------------

class GlyphClasses:
    def __init__(self, n_classes: int, seed: int = 0, size: int = 28):
        self.n_classes = n_classes
        self.size = size
        self.rng = np.random.default_rng(seed)
        self.protos = [self._make_proto() for _ in range(n_classes)]

    def _make_proto(self):
        n_strokes = int(self.rng.integers(2, 5))
        strokes = []
        for _ in range(n_strokes):
            n_pts = int(self.rng.integers(3, 6))
            pts = self.rng.uniform(3, self.size - 3, (n_pts, 2))
            strokes.append(pts)
        return strokes

    def _render(self, strokes, jitter_rng):
        img = np.zeros((self.size, self.size), np.float32)
        yy, xx = np.mgrid[0:self.size, 0:self.size]
        for pts in strokes:
            p = pts + jitter_rng.normal(0, 0.8, pts.shape)
            for a, b in zip(p[:-1], p[1:]):
                for t in np.linspace(0, 1, 12):
                    c = a * (1 - t) + b * t
                    img += np.exp(-((yy - c[1]) ** 2 + (xx - c[0]) ** 2) / 1.6)
        img = np.clip(img, 0, 1.5) / 1.5
        return img

    def sample(self, cls: int, n: int, seed: int):
        """n samples of class cls -> (n, 784, 1) pixel sequences in [0,1]."""
        rng = np.random.default_rng((seed, cls))
        out = np.stack([self._render(self.protos[cls], rng) for _ in range(n)])
        return out.reshape(n, self.size * self.size, 1)


# ---------------------------------------------------------------------------
# GSC-like keyword audio
# ---------------------------------------------------------------------------

class KeywordAudio:
    SR = 16000

    def __init__(self, n_classes: int = 12, seed: int = 0, duration_s: float = 1.0):
        self.n_classes = n_classes
        self.n_samples = int(self.SR * duration_s)
        rng = np.random.default_rng(seed)
        # class-specific formant trajectories (2-3 "phonemes")
        self.classes = []
        for _ in range(n_classes):
            segs = []
            for _ in range(int(rng.integers(2, 4))):
                f0 = rng.uniform(100, 300)
                f1 = rng.uniform(400, 2500)
                slope = rng.uniform(-400, 400)
                segs.append((f0, f1, slope))
            self.classes.append(segs)

    def sample(self, cls: int, n: int, seed: int, snr: float = 6.0):
        """(n, n_samples, 1) raw audio in [-1, 1]."""
        rng = np.random.default_rng((seed, cls, 7))
        t = np.arange(self.n_samples) / self.SR
        out = np.zeros((n, self.n_samples), np.float32)
        segs = self.classes[cls]
        seg_len = self.n_samples // len(segs)
        for i in range(n):
            x = np.zeros(self.n_samples, np.float32)
            for j, (f0, f1, slope) in enumerate(segs):
                s, e = j * seg_len, (j + 1) * seg_len
                tt = t[s:e] - t[s]
                jf = rng.normal(0, 20)
                x[s:e] = (np.sin(2 * np.pi * ((f0 + jf) * tt))
                          + 0.6 * np.sin(2 * np.pi * ((f1 + jf + slope * tt) * tt)))
            env = np.hanning(self.n_samples)
            noise = rng.normal(0, 10 ** (-snr / 20), self.n_samples)
            out[i] = np.clip(x * env * 0.5 + noise, -1, 1)
        return out[..., None]

    def mfcc(self, audio: np.ndarray, n_mels: int = 28, win_ms: float = 32.0,
             hop_ms: float = 16.0):
        """Log-mel features (the paper's 28-D 'MFCC' map, 63 frames/s)."""
        x = audio[..., 0]
        win = int(self.SR * win_ms / 1000)
        hop = int(self.SR * hop_ms / 1000)
        # pad like the paper's framing (63 frames for 1 s @ 32/16 ms)
        x = np.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, win)])
        n_frames = 1 + (x.shape[-1] - win) // hop
        frames = np.stack([x[..., i * hop:i * hop + win] for i in range(n_frames)], -2)
        spec = np.abs(np.fft.rfft(frames * np.hanning(win), axis=-1)) ** 2
        n_bins = spec.shape[-1]
        # triangular mel-ish filterbank
        centers = np.linspace(2, n_bins - 2, n_mels + 2)
        fb = np.zeros((n_mels, n_bins), np.float32)
        for m in range(n_mels):
            l, c, r = centers[m], centers[m + 1], centers[m + 2]
            bins = np.arange(n_bins)
            fb[m] = np.clip(np.minimum((bins - l) / (c - l + 1e-9),
                                       (r - bins) / (r - c + 1e-9)), 0, 1)
        mel = spec @ fb.T
        return np.log1p(mel).astype(np.float32)


# ---------------------------------------------------------------------------
# Seekable synthetic LM stream
# ---------------------------------------------------------------------------

def _hash_tokens(step: int, idx: np.ndarray, vocab: int, salt: int) -> np.ndarray:
    mix = (step * 1442695040888963407 + salt) % (1 << 64)
    h = (idx.astype(np.uint64) * np.uint64(6364136223846793005)
         + np.uint64(mix))
    h ^= h >> np.uint64(33)
    h *= np.uint64(0xFF51AFD7ED558CCD)
    h ^= h >> np.uint64(33)
    return (h % np.uint64(vocab)).astype(np.int32)


def lm_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    """Deterministic (step -> batch) token stream with learnable structure:
    the second half of each row repeats the first half (copy task), so
    cross-entropy can fall well below ln(vocab).  Returns {tokens, labels}."""
    idx = np.arange(batch * (seq + 1), dtype=np.uint64).reshape(batch, seq + 1)
    toks = _hash_tokens(step, idx, vocab, seed)
    half = (seq + 1) // 2
    toks[:, half:half * 2] = toks[:, :half]
    return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
