"""Paged per-tenant prototype banks over the block-pool allocator.

The dense ``TenantBank`` (tenancy.py) pre-allocates max_tenants x max_ways
FC rows — fine for 8 keyword ways, wrong for the paper's CL headline
(§III-A / Fig. 15: 250 classes learned one at a time).  This module pages
the bank the same way sessions/paging.py pages KV slots: way rows live in
a shared device pool of ``(n_blocks + 1, block_ways, V)`` and each tenant
reads it through a host-side block table, so

  * a tenant's bank GROWS one block at a time as it enrolls past each
    ``block_ways`` boundary — capacity is pooled, not per-tenant;
  * a PARKED tenant holds ZERO device rows: ``park`` copies its blocks to
    host and frees the ids, ``unpark`` re-allocates and scatters the same
    fp32 bytes back (bit-identical — prototype rows are content, not
    layout);
  * exhaustion is ``PoolExhausted`` (an ``AdmissionError``), the same
    back-pressure contract as paged session admission.

Block id 0 is the reserved NULL block (never written by a live tenant):
slot tables are NULL-padded, and ``paged_bank_fc`` masks every row index
>= the tenant's way count to bias -inf, so NULL garbage can never win an
argmax.  The FC math is ``store_fc`` verbatim (W = s/k, b = -||W||^2/2),
so at equal class counts the paged gather is bit-identical to the dense
``bank_fc`` path — asserted by tests and the served CL bench.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sessions.paging import NULL_BLOCK, BlockPool


class PagedBankPool:
    """Block-granular tenant prototype storage + host block tables.

    Device state: ``s_sums (extent, block_ways, V)`` and ``counts
    (extent, block_ways)``, both fp32 (extent = n_blocks + 1, row 0 is
    NULL).  Host state: per-tenant block-id tables, way counts, and the
    parked-blob store.  All mutation is host-driven ``.at[]`` updates —
    the enroll path is cold relative to the scan, so clarity wins.
    """

    def __init__(self, n_blocks: int, block_ways: int, dim: int,
                 max_tenant_blocks: int):
        if block_ways < 1:
            raise ValueError(f"block_ways must be >= 1, got {block_ways}")
        if max_tenant_blocks < 1:
            raise ValueError(
                f"max_tenant_blocks must be >= 1, got {max_tenant_blocks}")
        self.block_ways = int(block_ways)
        self.dim = int(dim)
        self.max_tenant_blocks = int(max_tenant_blocks)
        self.pool = BlockPool(n_blocks)
        self.s_sums = jnp.zeros((self.pool.extent, block_ways, dim),
                                jnp.float32)
        self.counts = jnp.zeros((self.pool.extent, block_ways), jnp.float32)
        self.tables: dict[int, list[int]] = {}   # tenant -> block ids
        self.n_ways: dict[int, int] = {}         # tenant -> enrolled ways
        self._parked: dict[int, dict] = {}       # tenant -> host blob

    # -- capacity -----------------------------------------------------------
    @property
    def max_ways(self) -> int:
        """Per-tenant way cap (table width x block granularity)."""
        return self.max_tenant_blocks * self.block_ways

    def row_bytes(self, tenant: int) -> int:
        """Device bytes currently held by ``tenant`` (block-granular; a
        parked tenant holds zero)."""
        per_block = self.block_ways * (self.dim + 1) * 4  # fp32 sums+counts
        return len(self.tables.get(tenant, ())) * per_block

    # -- tenant lifecycle ---------------------------------------------------
    def create(self, tenant: int) -> None:
        if tenant in self.n_ways:
            raise ValueError(f"tenant {tenant} already exists in bank pool")
        self.tables[tenant] = []
        self.n_ways[tenant] = 0

    def drop(self, tenant: int) -> None:
        """Free every block the tenant holds (resident or parked)."""
        for bid in self.tables.pop(tenant, ()):
            self.pool.free(bid)
        self.n_ways.pop(tenant, None)
        self._parked.pop(tenant, None)

    def is_resident(self, tenant: int) -> bool:
        return tenant in self.n_ways and tenant not in self._parked

    # -- enrollment ---------------------------------------------------------
    def _grow(self, tenant: int) -> None:
        """Append one zeroed block to the tenant's table.  Zeroing on alloc
        (not free) keeps the residue contract local: a block recycled from
        another tenant never leaks its old sums into fresh ways."""
        table = self.tables[tenant]
        if len(table) >= self.max_tenant_blocks:
            raise RuntimeError(
                f"tenant {tenant} at max_ways={self.max_ways}")
        bid = self.pool.alloc()  # may raise PoolExhausted (back-pressure)
        self.s_sums = self.s_sums.at[bid].set(0.0)
        self.counts = self.counts.at[bid].set(0.0)
        table.append(bid)

    def add_class(self, tenant: int, shot_embeddings) -> int:
        """Enroll the tenant's next way from (k, V) shot embeddings.
        Returns the new global way index.  Raises at the way cap (host
        guard — the op-level masked no-op of ``store_add_class`` has no
        traced counterpart here because tables are host state)."""
        if tenant in self._parked:
            raise RuntimeError(f"tenant {tenant} is parked; unpark first")
        way = self.n_ways[tenant]
        if way >= self.max_ways:
            raise RuntimeError(
                f"tenant {tenant} at max_ways={self.max_ways}")
        if way % self.block_ways == 0:
            self._grow(tenant)
        bid = self.tables[tenant][way // self.block_ways]
        r = way % self.block_ways
        s = jnp.asarray(shot_embeddings, jnp.float32).sum(axis=0)
        k = jnp.float32(np.asarray(shot_embeddings).shape[0])
        # .set on both leaves (the bank_add_class residue rule)
        self.s_sums = self.s_sums.at[bid, r].set(s)
        self.counts = self.counts.at[bid, r].set(k)
        self.n_ways[tenant] = way + 1
        return way

    def update_class(self, tenant: int, way: int, shot_embeddings) -> None:
        """Refine an enrolled way with more shots (running mean, Eq. 3/6)."""
        if tenant in self._parked:
            raise RuntimeError(f"tenant {tenant} is parked; unpark first")
        if not 0 <= way < self.n_ways[tenant]:
            raise ValueError(f"way {way} not enrolled for tenant {tenant} "
                             f"({self.n_ways[tenant]} ways)")
        bid = self.tables[tenant][way // self.block_ways]
        r = way % self.block_ways
        s = jnp.asarray(shot_embeddings, jnp.float32).sum(axis=0)
        k = jnp.float32(np.asarray(shot_embeddings).shape[0])
        self.s_sums = self.s_sums.at[bid, r].add(s)
        self.counts = self.counts.at[bid, r].add(k)

    def set_way(self, tenant: int, way: int, s_sum, count) -> None:
        """Overwrite one way's running sums (the rehearsal-rebuild path)."""
        if tenant in self._parked:
            raise RuntimeError(f"tenant {tenant} is parked; unpark first")
        if not 0 <= way < self.n_ways[tenant]:
            raise ValueError(f"way {way} not enrolled for tenant {tenant}")
        bid = self.tables[tenant][way // self.block_ways]
        r = way % self.block_ways
        self.s_sums = self.s_sums.at[bid, r].set(
            jnp.asarray(s_sum, jnp.float32))
        self.counts = self.counts.at[bid, r].set(jnp.float32(count))

    # -- park / unpark ------------------------------------------------------
    def park(self, tenant: int) -> None:
        """Copy the tenant's blocks to host and free the device rows.
        Idempotent; a zero-way tenant parks to an empty blob."""
        if tenant not in self.n_ways:
            raise KeyError(f"unknown tenant {tenant}")
        if tenant in self._parked:
            return
        bids = self.tables[tenant]
        self._parked[tenant] = {
            "s_sums": np.asarray(self.s_sums[np.asarray(bids, np.int32)])
            if bids else np.zeros((0, self.block_ways, self.dim), np.float32),
            "counts": np.asarray(self.counts[np.asarray(bids, np.int32)])
            if bids else np.zeros((0, self.block_ways), np.float32),
        }
        for bid in bids:
            self.pool.free(bid)
        self.tables[tenant] = []

    def unpark(self, tenant: int) -> None:
        """Re-allocate blocks and scatter the parked fp32 bytes back — the
        row contents are bit-identical to what ``park`` copied out."""
        blob = self._parked.pop(tenant, None)
        if blob is None:
            return
        n = blob["s_sums"].shape[0]
        try:
            bids = [self.pool.alloc() for _ in range(n)]
        except Exception:
            self._parked[tenant] = blob  # failed unpark leaves it parked
            raise
        if bids:
            idx = jnp.asarray(np.asarray(bids, np.int32))
            self.s_sums = self.s_sums.at[idx].set(
                jnp.asarray(blob["s_sums"]))
            self.counts = self.counts.at[idx].set(
                jnp.asarray(blob["counts"]))
        self.tables[tenant] = bids

    # -- persistence --------------------------------------------------------
    def pack(self, tenant: int) -> dict:
        """JSON-able host copy of the tenant's bank (resident or parked):
        rows flattened to (blocks * block_ways, V) — layout-free, so a
        spill restores into any pool geometry with the same block_ways."""
        if tenant in self._parked:
            blob = self._parked[tenant]
            s, c = blob["s_sums"], blob["counts"]
        else:
            bids = self.tables[tenant]
            s = (np.asarray(self.s_sums[np.asarray(bids, np.int32)])
                 if bids else np.zeros((0, self.block_ways, self.dim),
                                       np.float32))
            c = (np.asarray(self.counts[np.asarray(bids, np.int32)])
                 if bids else np.zeros((0, self.block_ways), np.float32))
        return {"s_sums": s.reshape(-1, self.dim).tolist(),
                "counts": c.reshape(-1).tolist(),
                "n_ways": int(self.n_ways[tenant])}

    def adopt(self, tenant: int, packed: dict) -> None:
        """Create ``tenant`` from a ``pack`` blob, PARKED (zero device
        rows) — residency is re-established lazily on first use."""
        self.create(tenant)
        n_ways = int(packed["n_ways"])
        s = np.asarray(packed["s_sums"], np.float32).reshape(-1, self.dim)
        c = np.asarray(packed["counts"], np.float32).reshape(-1)
        n_blocks = (n_ways + self.block_ways - 1) // self.block_ways
        need = n_blocks * self.block_ways
        if s.shape[0] < need:
            pad = need - s.shape[0]
            s = np.concatenate([s, np.zeros((pad, self.dim), np.float32)])
            c = np.concatenate([c, np.zeros((pad,), np.float32)])
        self.n_ways[tenant] = n_ways
        self._parked[tenant] = {
            "s_sums": s[:need].reshape(n_blocks, self.block_ways, self.dim),
            "counts": c[:need].reshape(n_blocks, self.block_ways),
        }

    # -- the scan-side view --------------------------------------------------
    def slot_tables(self, tenant_of_slot) -> tuple[np.ndarray, np.ndarray]:
        """Per-slot gather view for ``paged_bank_fc``: a NULL-padded
        (S, max_tenant_blocks) int32 block table plus the (S,) way counts.
        Slots whose tenant is absent or parked read all-NULL rows with a
        way count of 0 (every row masked to -inf)."""
        S = len(tenant_of_slot)
        tables = np.full((S, self.max_tenant_blocks), NULL_BLOCK, np.int32)
        ways = np.zeros(S, np.int32)
        for s, t in enumerate(tenant_of_slot):
            t = int(t)
            if t < 0 or t in self._parked or t not in self.n_ways:
                continue
            bids = self.tables[t]
            tables[s, :len(bids)] = bids
            ways[s] = self.n_ways[t]
        return tables, ways

    # -- introspection ------------------------------------------------------
    def stats(self) -> dict:
        return {"blocks_live": self.pool.n_live,
                "blocks_free": self.pool.n_free,
                "block_ways": self.block_ways,
                "resident_tenants": sum(1 for t in self.n_ways
                                        if t not in self._parked),
                "parked_tenants": len(self._parked)}


def paged_bank_fc(s_sums_pool, counts_pool, tables, n_ways):
    """FC weights/bias per SLOT from the paged pool — ``store_fc`` over a
    block-table gather.  tables: (S, MB) int32 block ids (NULL-padded);
    n_ways: (S,) int32.  Returns W (S, MB*BW, V), b (S, MB*BW) with every
    row >= n_ways[s] masked to bias -inf (NULL/garbage rows never win)."""
    s = s_sums_pool[tables]                   # (S, MB, BW, V)
    c = counts_pool[tables]                   # (S, MB, BW)
    S, MB, BW, V = s.shape
    s = s.reshape(S, MB * BW, V)
    c = c.reshape(S, MB * BW)
    w = s / jnp.maximum(c, 1.0)[..., None]
    b = -jnp.sum(jnp.square(w), axis=-1) / 2.0
    live = jnp.arange(MB * BW)[None, :] < n_ways[:, None]
    b = jnp.where(live, b, -jnp.inf)
    return w, b
