"""Request-loop façade over the multi-tenant streaming session subsystem.

``StreamSessionService`` virtualizes the paper's deployment — one shared TCN
embedder, many per-user prototype classifiers, O(receptive-field) stream
state per user — behind five verbs:

    open_session / push_audio / enroll_shots / poll / close

All active sessions advance through ONE jitted batched call per tick over a
fixed compiled shape (sessions/state.grid_step): admission, eviction to the
host-side parking lot, slot reuse, and mid-stream tenant enrollment all
happen without recompiling.  A parked session resumes bit-identically in
any free slot because its entire stream position is its packed pytree.

Built for the TCN bundle (models/build.build_tcn_bundle); the LM slot grid
in serving/engine.py shares the same SlotScheduler.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protonet import pn_logits_banked
from repro.models.tcn import tcn_empty_state
from repro.sessions.scheduler import SlotScheduler
from repro.sessions.state import (
    grid_init,
    grid_step,
    pack_slot,
    reset_slot,
    slot_state_bytes,
    unpack_slot,
)
from repro.sessions.tenancy import (
    bank_add_class,
    bank_clear_tenant,
    bank_fc,
    bank_init,
    bank_update_class,
)

NO_TENANT = -1


@dataclass
class _Session:
    tenant: int = NO_TENANT
    dedicated: bool = False  # tenant row was created for this session
    steps: int = 0
    last: dict | None = None


class StreamSessionService:
    """Multi-tenant streaming TCN service over a fixed slot grid."""

    def __init__(self, bundle, params, bn_state=None, *, n_slots: int = 8,
                 max_tenants: int = 8, max_ways: int = 8,
                 max_sessions: int | None = None, quantize: bool = False):
        cfg = bundle.cfg
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_ways = max_ways
        bn_state = bn_state if bn_state is not None else tcn_empty_state(cfg)

        self.states = grid_init(cfg, n_slots)
        self.bank = bank_init(max_tenants, max_ways, cfg.embed_dim)
        self.sched = SlotScheduler(n_slots, max_sessions)
        self.parking: dict[int, dict] = {}        # sid -> host pytree
        self.sessions: dict[int, _Session] = {}
        self.tenant_of_slot = np.full(n_slots, NO_TENANT, np.int32)
        self._free_tenants = list(range(max_tenants))
        self._tenant_ways = np.zeros(max_tenants, np.int32)  # host mirror
        self._next_sid = 0
        self.evictions = 0

        def _step(states, x, active, bank, tenant_ids):
            new_states, emb, logits = grid_step(
                params, bn_state, cfg, states, x, active, quantize=quantize)
            w, b = bank_fc(bank)
            return new_states, emb, logits, pn_logits_banked(emb, w, b, tenant_ids)

        self._step = jax.jit(_step)
        # shot embedding for enrollment — the TCN bundle's embed_fn honours
        # the service's BN stats and quantize mode
        self._embed = jax.jit(lambda x: bundle.embed_fn(
            params, {"x": x}, state=bn_state, quantize=quantize))

    # -- tenants ------------------------------------------------------------
    def create_tenant(self) -> int:
        if not self._free_tenants:
            raise RuntimeError("tenant bank full")
        return self._free_tenants.pop(0)

    def close_tenant(self, tenant: int) -> None:
        if any(s.tenant == tenant for s in self.sessions.values()):
            raise RuntimeError(f"tenant {tenant} still has open sessions")
        self.bank = bank_clear_tenant(self.bank, tenant)
        self._tenant_ways[tenant] = 0
        self._free_tenants.append(tenant)

    # -- session lifecycle --------------------------------------------------
    def open_session(self, tenant: int | None = NO_TENANT) -> int:
        """Admit a session.  ``tenant=None`` creates a dedicated tenant
        (freed again when the session closes); ``NO_TENANT`` (default)
        classifies with the shared global head."""
        dedicated = tenant is None
        claimed = dedicated
        if dedicated:
            tenant = self.create_tenant()
        elif tenant != NO_TENANT:
            if not 0 <= tenant < len(self._tenant_ways):
                raise ValueError(
                    f"tenant {tenant} out of range [0, {len(self._tenant_ways)})")
            if tenant in self._free_tenants:  # claim an uncreated row
                self._free_tenants.remove(tenant)
                claimed = True
        sid = self._next_sid
        self._next_sid += 1
        try:
            self.sched.admit(sid)  # may raise AdmissionError (back-pressure)
        except Exception:
            if claimed:  # don't leak the tenant row on refused admission
                self._free_tenants.insert(0, tenant)
            raise
        self.sessions[sid] = _Session(tenant=tenant, dedicated=dedicated)
        self._bind(sid)
        return sid

    def _bind(self, sid: int, pinned: set[int] = frozenset()) -> int:
        slot, evicted = self.sched.bind(sid, pinned)
        if evicted is not None:
            self.parking[evicted] = pack_slot(self.states, slot)
            self.evictions += 1
        if sid in self.parking:
            self.states = unpack_slot(self.states, slot, self.parking.pop(sid))
        elif self.sessions[sid].steps == 0:
            self.states = reset_slot(self.states, slot)
        else:  # rebinding after evicted==None cannot lose state
            raise AssertionError("bound session missing parked state")
        self.tenant_of_slot[slot] = self.sessions[sid].tenant
        return slot

    def park(self, sid: int) -> None:
        """Explicitly swap a session's stream state to host memory."""
        slot = self.sched.park(sid)
        if slot is not None:
            self.parking[sid] = pack_slot(self.states, slot)
            self.tenant_of_slot[slot] = NO_TENANT

    def close(self, sid: int) -> None:
        slot = self.sched.release(sid)
        if slot is not None:
            self.tenant_of_slot[slot] = NO_TENANT
        self.parking.pop(sid, None)
        sess = self.sessions.pop(sid)
        # a dedicated tenant row dies with its last session: if other
        # sessions share the row, ownership passes to one of them so the
        # row is still freed when the final sharer closes
        if sess.dedicated:
            sharers = [s for s in self.sessions.values()
                       if s.tenant == sess.tenant]
            if sharers:
                sharers[0].dedicated = True
            else:
                self.close_tenant(sess.tenant)

    # -- the hot path -------------------------------------------------------
    def push_audio(self, samples: dict[int, Any]) -> dict[int, dict]:
        """Advance every session in ``samples`` one timestep.

        samples: {sid: (C_in,) sample}.  All pushed sessions step in ONE
        jitted batched call; parked sessions are resumed first (possibly
        evicting idle ones).  Returns {sid: {emb, logits, tenant_logits,
        pred, step}}."""
        if len(samples) > self.n_slots:
            raise ValueError(
                f"{len(samples)} sessions pushed but only {self.n_slots} slots; "
                "split the push or grow the grid")
        pinned = set(samples)
        for sid in samples:
            if sid not in self.sessions:
                raise KeyError(f"unknown session {sid}")
            self.sched.touch(sid)
            if not self.sched.is_bound(sid):
                self._bind(sid, pinned)

        x = np.zeros((self.n_slots, self.cfg.tcn_in_channels), np.float32)
        active = np.zeros(self.n_slots, bool)
        slot_of = {}
        for sid, sample in samples.items():
            slot = self.sched.slot_of[sid]
            slot_of[sid] = slot
            x[slot] = np.asarray(sample, np.float32).reshape(-1)
            active[slot] = True

        self.states, emb, logits, tlogits = self._step(
            self.states, jnp.asarray(x), jnp.asarray(active), self.bank,
            jnp.asarray(self.tenant_of_slot))
        emb, logits, tlogits = (np.asarray(emb), np.asarray(logits),
                                np.asarray(tlogits))

        out = {}
        for sid, slot in slot_of.items():
            sess = self.sessions[sid]
            sess.steps += 1
            personalized = (sess.tenant != NO_TENANT
                            and self._tenant_ways[sess.tenant] > 0)
            res = {
                "emb": emb[slot],
                "logits": logits[slot],
                "tenant_logits": tlogits[slot] if personalized else None,
                "pred": int(tlogits[slot].argmax()) if personalized
                        else int(logits[slot].argmax()),
                "step": sess.steps,
            }
            sess.last = res
            out[sid] = res
        return out

    # -- FSL / CL enrollment (live, mid-stream) -----------------------------
    def enroll_shots(self, sid: int, shots, *, embedded: bool = False,
                     way: int | None = None) -> int:
        """Enroll k shots as a new way (or refine ``way``) for the session's
        tenant.  shots: (k, T, C_in) raw clips (embedded via the shared
        backbone) or (k, V) embeddings when ``embedded=True``.  The tenant's
        very next ``push_audio`` classifies against the updated bank."""
        tenant = self.sessions[sid].tenant
        if tenant == NO_TENANT:
            raise ValueError("session has no tenant; open with tenant=None "
                             "or an explicit tenant id to personalize")
        emb = jnp.asarray(shots) if embedded else self._embed(jnp.asarray(shots))
        if way is None:
            if self._tenant_ways[tenant] >= self.max_ways:
                raise RuntimeError(f"tenant {tenant} at max_ways={self.max_ways}")
            self.bank = bank_add_class(self.bank, tenant, emb)
            way = int(self._tenant_ways[tenant])
            self._tenant_ways[tenant] += 1
        else:
            if not 0 <= way < self._tenant_ways[tenant]:
                raise ValueError(
                    f"way {way} not enrolled for tenant {tenant} "
                    f"({self._tenant_ways[tenant]} ways); omit way= to enroll")
            self.bank = bank_update_class(self.bank, tenant, way, emb)
        return way

    # -- introspection ------------------------------------------------------
    def poll(self, sid: int) -> dict:
        sess = self.sessions[sid]
        return {
            "state": "active" if self.sched.is_bound(sid) else "parked",
            "slot": self.sched.slot_of.get(sid),
            "tenant": None if sess.tenant == NO_TENANT else sess.tenant,
            "n_ways": int(self._tenant_ways[sess.tenant])
                      if sess.tenant != NO_TENANT else 0,
            "steps": sess.steps,
            "last": sess.last,
        }

    def stats(self) -> dict:
        return {
            "n_slots": self.n_slots,
            "bound": len(self.sched.slot_of),
            "parked": len(self.parking),
            "live_sessions": self.sched.live_sessions,
            "evictions": self.evictions,
            "slot_state_bytes": slot_state_bytes(self.states),
        }
