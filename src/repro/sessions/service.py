"""Request-loop façades over the multi-tenant slot-grid session subsystem.

Two layers live here:

``SlotGridService`` — the service-AGNOSTIC core.  Everything that made the
TCN streaming service churn-tolerant turns out to be independent of what a
"slot" holds: a fixed compiled slot grid, admission control + LRU/cost
eviction (sessions/scheduler), a host-side parking lot of packed slot
columns, power-of-two chunk padding buckets (compiled programs bounded by
log2(T_chunk)+1), and checkpoint/store spill/restore of the lot.  Concrete
services supply four state hooks — ``_pack``/``_unpack``/``_reset`` move
one slot's column between device and host, ``_session_cls`` carries the
per-session host record — plus optional lifecycle/persistence hooks.  The
TCN service parks O(receptive-field) ring pytrees; the LM service
(sessions/lm.py) parks KV-cache columns truncated to the live position; a
third modality would only write the hooks.

``StreamSessionService`` — the TCN streaming façade on top of it:

    open_session / push_audio / enroll_shots / poll / close

The hot path is *chunk-native*: ``push_audio`` takes ragged per-session
time chunks {sid: (t_i, C_in)}, pads them onto the compiled (S, T_chunk)
grid, and advances every pushed session through ``sessions/state.grid_scan``
— a ``lax.scan`` over time inside ONE jitted dispatch, so a tick costs one
host↔device round trip for S×T_chunk samples instead of S.  Ragged lengths
become per-step validity masks, so short chunks and absent sessions stay
bit-frozen.  A single (C_in,) sample is the T=1 special case and keeps the
historical per-sample result surface.

A parked session resumes bit-identically in any free slot because its
entire stream position is its packed pytree; with ``quantize=True``
parkings are nibble-packed (~8x smaller, still bit-identical).
``spill_parking``/``restore_parking`` persist the lot through
checkpoint/store so sessions survive restarts.

``fused=True`` (or REPRO_TCN_FUSED=1) swaps the chunk body for the fused
kernel fast path: BN and the log2 weight quantization are baked once at
construction (models/tcn.bake_stream_params), and each tick runs one
fused block op per TCN block (kernels/tcn_block.py) over the ring-buffer
taps instead of a per-sample ``lax.scan`` — same slot grid, same parking
lot, same bit-exact park/resume; only the executor changes.  On the
baked params the fused executor is bit-identical to ``grid_scan``
(tests/test_streaming_chunk.py); vs an UNFUSED service on the raw params
outputs are allclose only, because BN folding reassociates by one ULP.

Passing a ``mesh`` shards the slot grid over the mesh's ``data`` axis and
the tenant banks over ``model`` (sessions/state.grid_pspecs,
sessions/tenancy.bank_pspecs); on a 1-device mesh everything degenerates
to replicated and behaviour is unchanged.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_sessions, save_sessions
from repro.configs.runtime import RuntimeConfig
from repro.core.protonet import pn_logits_banked
from repro.obs.device import (
    decode_occupancy,
    occupancy_stats,
    valid_stats,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.models.tcn import bake_stream_params, tcn_empty_state
from repro.sessions.scheduler import AdmissionError, SlotScheduler
from repro.sessions.state import (
    grid_init,
    grid_pspecs,
    grid_scan,
    make_grid_fused,
    pack_slot,
    parked_bytes,
    reset_slot,
    slot_park_bytes,
    unpack_slot,
)
from repro.sessions.bankpool import PagedBankPool, paged_bank_fc
from repro.sessions.rehearsal import RehearsalBuffer
from repro.sessions.tenancy import (
    TenantBank,
    bank_add_class,
    bank_clear_tenant,
    bank_fc,
    bank_init,
    bank_pack_tenant,
    bank_pspecs,
    bank_row_bytes,
    bank_unpack_tenant,
    bank_update_class,
)

NO_TENANT = -1


@dataclass
class SessionRecord:
    """Minimal per-session host record; services subclass for extra fields.
    ``steps`` doubles as the fresh-session marker: a bound session with
    steps == 0 gets a zeroed column instead of a parked blob."""
    steps: int = 0
    last: dict | None = None


# ---------------------------------------------------------------------------
# Service-agnostic slot-grid core
# ---------------------------------------------------------------------------

class SlotGridService:
    """Fixed compiled slot grid + scheduler + parking lot + persistence.

    Subclasses must provide the device-state hooks:

      _pack(slot, sid) -> blob   one slot's column -> host parked blob
      _unpack(slot, blob)        parked blob -> column of ``slot``
      _reset(slot)               zero a column for a fresh session

    and may override the lifecycle hooks (_on_bind/_on_unbind/_on_close)
    and the spill/restore meta hooks (_session_spill_meta/_spill_extra/
    _restore_validate/_restore_apply/_restore_session).  All placement
    policy (free slots, LRU, pinning, cost-aware tie-breaks, admission
    back-pressure) stays in sessions/scheduler.SlotScheduler.
    """

    _session_cls = SessionRecord
    _service_name = "grid"  # metrics/trace label; subclasses override
    # services with real per-tenant state (prototype banks) set this so the
    # serving plane forwards its routing tenant into open_session too
    tenant_aware = False

    def __init__(self, n_slots: int, *, t_chunk: int = 1,
                 max_sessions: int | None = None,
                 cost_fn: Callable[[int], float] | None = None,
                 stale_window: int = 0,
                 metrics: MetricsRegistry | None = None,
                 tracer=None, device_counters: bool | None = None,
                 runtime: RuntimeConfig | None = None):
        if t_chunk < 1:
            raise ValueError(f"t_chunk must be >= 1, got {t_chunk}")
        self.n_slots = n_slots
        self.t_chunk = t_chunk
        self.sched = SlotScheduler(n_slots, max_sessions, cost_fn=cost_fn,
                                   stale_window=stale_window)
        self.parking: dict[int, dict] = {}        # sid -> host blob
        self.sessions: dict[int, Any] = {}        # sid -> session record
        self._next_sid = 0
        # -- runtime switches (configs/runtime.RuntimeConfig): ONE resolved
        # view of the historical env vars; per-field kwargs stay at the top
        # of the precedence (explicit kwarg > runtime/env > default)
        self.runtime = runtime if runtime is not None \
            else RuntimeConfig.resolve()
        # -- telemetry plane (repro.obs): every counter the service keeps
        # lives in ONE registry; pass ``metrics=`` to share a registry
        # across services (a multi-worker front-end), default is private.
        # The tracer defaults to the process-global one (REPRO_TRACE=path
        # enables it); ``device_counters`` compiles the instrumented scan
        # twins (extra in-jit stats outputs, bit-identical session state).
        self.metrics_registry = metrics if metrics is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.device_counters = bool(
            self.runtime.pick("device_counters", device_counters))
        svc = self._service_name
        reg = self.metrics_registry
        self._c_dispatches = reg.counter("dispatches_total", service=svc)
        self._c_evictions = reg.counter("evictions_total", service=svc)
        self._g_bound = reg.gauge("sessions_bound", service=svc)
        self._g_parked = reg.gauge("sessions_parked", service=svc)
        # parking-lot host footprint, maintained incrementally at the blob
        # store/take sites (summing every blob per mutation would be
        # O(parked * leaves) on the bind path)
        self._g_parked_bytes = reg.gauge("parked_bytes", service=svc)
        self._parked_blob_bytes: dict[int, int] = {}  # sid -> host bytes
        self._lat_hists: dict[str, Any] = {}  # shape -> Histogram (cached)

    # -- parking-lot accounting ---------------------------------------------
    @property
    def parked_blob_bytes(self) -> int:
        """Total host bytes of the parking lot (exact: sums each parked
        blob's array bytes, nibble-packed and block-granular blobs count
        as stored)."""
        return sum(self._parked_blob_bytes.values())

    def _park_store(self, sid: int, blob) -> None:
        self.parking[sid] = blob
        self._parked_blob_bytes[sid] = parked_bytes(blob)
        self._g_parked_bytes.set(self.parked_blob_bytes)

    def _park_take(self, sid: int, default=None):
        blob = self.parking.pop(sid, default)
        if self._parked_blob_bytes.pop(sid, None) is not None:
            self._g_parked_bytes.set(self.parked_blob_bytes)
        return blob

    # -- telemetry ----------------------------------------------------------
    # Backward-compat surface for the historical bare-int counters: reads
    # and writes route through the registry, so ``svc.dispatches`` and
    # ``svc.metrics()["dispatches_total"]`` can never disagree.
    @property
    def dispatches(self) -> int:
        """Jitted calls (the amortization metric)."""
        return int(self._c_dispatches.value)

    @dispatches.setter
    def dispatches(self, v: int) -> None:
        self._c_dispatches.value = v

    @property
    def evictions(self) -> int:
        return int(self._c_evictions.value)

    @evictions.setter
    def evictions(self, v: int) -> None:
        self._c_evictions.value = v

    def metrics(self) -> dict:
        """JSON snapshot of the service's metrics registry."""
        return self.metrics_registry.snapshot()

    def _latency_hist(self, shape: str):
        h = self._lat_hists.get(shape)
        if h is None:
            h = self.metrics_registry.histogram(
                "dispatch_latency_us", service=self._service_name,
                shape=shape)
            self._lat_hists[shape] = h
        return h

    def _record_dispatch(self, seconds: float, shape: str) -> None:
        """One jitted call completed: count it, record wall time in the
        per-compiled-shape log2 histogram, refresh occupancy gauges."""
        self._c_dispatches.inc()
        self._latency_hist(shape).record(seconds * 1e6)
        self._g_bound.set(len(self.sched.slot_of))
        self._g_parked.set(len(self.parking))
        self.tracer.counter(f"{self._service_name}_sessions",
                            bound=len(self.sched.slot_of),
                            parked=len(self.parking))

    def _ingest_occupancy(self, stats) -> None:
        """Fold one dispatch's device-side occupancy vector
        (obs.device.occupancy_stats) into the registry."""
        occ = decode_occupancy(stats)
        svc = self._service_name
        reg = self.metrics_registry
        reg.counter("device_live_steps_total", service=svc).inc(
            occ["live_steps"])
        reg.counter("device_masked_steps_total", service=svc).inc(
            occ["total_steps"] - occ["live_steps"])
        reg.gauge("device_lane_occupancy", service=svc).set(
            occ["lane_occupancy"])
        reg.gauge("device_pad_waste", service=svc).set(occ["pad_waste"])
        reg.gauge("device_live_step_ratio", service=svc).set(
            occ["live_step_ratio"])

    # -- state hooks (subclass responsibility) ------------------------------
    def _pack(self, slot: int, sid: int) -> dict:
        raise NotImplementedError

    def _unpack(self, slot: int, blob: dict) -> None:
        raise NotImplementedError

    def _reset(self, slot: int) -> None:
        raise NotImplementedError

    def _on_bind(self, sid: int, slot: int) -> None:
        pass

    def _on_unbind(self, slot: int) -> None:
        pass

    def _on_evict(self, sid: int, slot: int) -> None:
        """Lifecycle hook for the eviction path: ``sid`` was just packed
        off ``slot`` to make room for another session.  Distinct from
        ``_on_unbind`` (park/close), which fires when a slot goes truly
        idle — an evicted slot is re-occupied immediately."""

    def _on_close(self, sid: int, sess) -> None:
        pass

    # -- lifecycle ----------------------------------------------------------
    def _alloc_sid(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        return sid

    def open_session(self, **kw) -> int:
        """Admit a session and place it on a slot (may evict an idle one)."""
        sid = self._alloc_sid()
        self.sched.admit(sid)  # may raise AdmissionError (back-pressure)
        self.sessions[sid] = self._session_cls(**kw)
        self._bind(sid)
        return sid

    def _bind(self, sid: int, pinned: set[int] = frozenset()) -> int:
        with self.tracer.span("bind", cat=self._service_name, sid=sid):
            slot, evicted = self.sched.bind(sid, pinned)
            if evicted is not None:
                with self.tracer.span("pack", cat=self._service_name,
                                      sid=evicted, slot=slot):
                    blob = self._pack(slot, evicted)
                self._park_store(evicted, blob)
                self._c_evictions.inc()
                self._on_evict(evicted, slot)
                if self.tracer.enabled:
                    cost = self.sched.cost_fn(evicted) \
                        if self.sched.cost_fn is not None else None
                    self.tracer.instant("evict", cat=self._service_name,
                                        victim=evicted, slot=slot,
                                        for_sid=sid, park_cost=cost)
            if sid in self.parking:
                with self.tracer.span("unpack", cat=self._service_name,
                                      sid=sid, slot=slot):
                    self._unpack(slot, self._park_take(sid))
                self.tracer.instant("resume", cat=self._service_name,
                                    sid=sid, slot=slot)
            elif self.sessions[sid].steps == 0:
                self._reset(slot)
            else:  # rebinding after evicted==None cannot lose state
                raise AssertionError("bound session missing parked state")
            self._on_bind(sid, slot)
        return slot

    def park(self, sid: int) -> None:
        """Explicitly swap a session's slot column to host memory.
        Raises ``KeyError`` for a sid that was never admitted (the same
        contract as ``_touch_and_bind``); parking an already-parked
        session stays a no-op."""
        if sid not in self.sessions:
            raise KeyError(f"unknown session {sid}")
        slot = self.sched.park(sid)
        if slot is not None:
            with self.tracer.span("park", cat=self._service_name,
                                  sid=sid, slot=slot):
                self._park_store(sid, self._pack(slot, sid))
            self._on_unbind(slot)

    def resume(self, sid: int) -> None:
        """Eagerly bind a parked session back onto a slot WITHOUT advancing
        it — the inverse of ``park``.  ``push`` resumes lazily as part of
        its pre-dispatch placement, so calling this first is never
        required; a front-end uses it to prepay the unpack cost before a
        latency-sensitive push.  Raises ``KeyError`` for a sid that was
        never admitted; resuming a bound session just refreshes its LRU
        clock."""
        if sid not in self.sessions:
            raise KeyError(f"unknown session {sid}")
        self.sched.touch(sid)
        if not self.sched.is_bound(sid):
            self._bind(sid)

    def push(self, work: dict[int, Any]) -> dict[int, Any]:
        """Advance sessions by one ragged batch of work — the protocol hot
        path (sessions.SessionService).  The payload type is the service's:
        the TCN service takes ``{sid: (t, C_in) chunk}``, the LM service
        ``{sid: n_tokens}``.  Concrete services alias their historical
        verb (``push_audio`` / ``decode``) onto this name."""
        raise NotImplementedError

    def close(self, sid: int) -> None:
        slot = self.sched.release(sid)
        if slot is not None:
            self._on_unbind(slot)
        self._park_take(sid)
        sess = self.sessions.pop(sid)
        self._on_close(sid, sess)

    def enroll(self, sid: int, shots, **kwargs) -> int:
        """Protocol verb (sessions.SessionService): streaming on-device
        learning.  Services with a learnable head override this (the TCN
        façade aliases ``enroll_shots``); everyone else keeps the protocol
        surface but refuses the verb."""
        raise NotImplementedError(
            f"{self._service_name} service does not support enrollment")

    def _touch_and_bind(self, sids) -> None:
        """Pre-dispatch placement: pin this tick's sessions, then bind any
        that are parked (possibly evicting idle neighbors)."""
        pinned = set(sids)
        for sid in sids:
            if sid not in self.sessions:
                raise KeyError(f"unknown session {sid}")
            self.sched.touch(sid)
            if not self.sched.is_bound(sid):
                self._bind(sid, pinned)

    # -- chunk padding buckets ----------------------------------------------
    def _tick_len(self, remaining: int) -> int:
        """Bucketed tick length: full T_chunk while enough work remains,
        else the next power of two — bounds compiled programs to
        log2(T_chunk)+1 shapes instead of one per ragged length."""
        if remaining >= self.t_chunk:
            return self.t_chunk
        n = 1
        while n < remaining:
            n <<= 1
        return min(n, self.t_chunk)

    # -- persistence --------------------------------------------------------
    def _session_spill_meta(self, sid: int) -> dict:
        return {"steps": self.sessions[sid].steps}

    def _spill_extra(self) -> dict:
        return {}

    def _restore_validate(self, parking: dict, meta: dict) -> None:
        pass

    def _restore_apply(self, meta: dict) -> None:
        pass

    def _restore_session(self, info: dict):
        return self._session_cls(steps=int(info.get("steps", 0)))

    def spill_parking(self, path: str, *, include_bound: bool = False) -> str:
        """Persist the parking lot to disk through checkpoint/store, so
        sessions survive process restarts.  ``include_bound=True`` parks
        every bound session first — a full drain for planned shutdown."""
        if include_bound:
            for sid in list(self.sched.slot_of):
                self.park(sid)
        meta = {"next_sid": self._next_sid,
                "sessions": {str(sid): self._session_spill_meta(sid)
                             for sid in self.parking}}
        meta.update(self._spill_extra())
        return save_sessions(path, self.parking, meta)

    def restore_parking(self, path: str) -> list[int]:
        """Adopt a spilled parking lot into this (possibly fresh) service:
        sessions re-enter parked, with their sids and host records intact;
        the next push resumes them bit-identically.  Returns the restored
        sids.

        All-or-nothing: every check (sid collisions, admission capacity,
        service-specific validation) runs BEFORE the first mutation, so a
        refused restore leaves the service untouched."""
        parking, meta = load_sessions(path)
        meta = meta or {"next_sid": 0, "sessions": {}}
        for sid in sorted(parking):
            if sid in self.sessions:
                raise ValueError(f"session {sid} already live; refuse to "
                                 "overwrite on restore")
        cap = self.sched.max_sessions
        if cap is not None and self.sched.live_sessions + len(parking) > cap:
            raise AdmissionError(
                f"restoring {len(parking)} sessions would exceed capacity "
                f"({self.sched.live_sessions}/{cap} live)")
        self._restore_validate(parking, meta)
        self._restore_apply(meta)
        restored = []
        for sid, parked in sorted(parking.items()):
            info = meta["sessions"].get(str(sid), {})
            self.sched.admit(sid)
            self.sessions[sid] = self._restore_session(info)
            self._park_store(sid, parked)
            restored.append(sid)
        self._next_sid = max(self._next_sid, int(meta.get("next_sid", 0)))
        self._post_restore(restored, meta)
        return restored

    def _post_restore(self, restored: list[int], meta: dict) -> None:
        """Hook: runs once after a successful restore with the spill meta
        (so subclasses never need to re-read the file)."""

    # -- live handoff / crash recovery --------------------------------------
    # The serving plane's fault-tolerance layer (serving/plane.py drain/
    # recover/steal + the per-op spill journal) is built on these four
    # verbs.  They reuse the exact park/spill machinery above, so every
    # bit-identity property of park/resume carries over to handoff.

    def export_session(self, sid: int) -> tuple[dict, dict]:
        """Snapshot one live session as ``(parked blob, spill meta)``
        WITHOUT closing it — the plane's spill-epoch primitive.  A bound
        session is parked first (park/resume is bit-identical, so the
        snapshot has no behavioral effect); it stays live and lazily
        re-binds on its next push.  Raises ``KeyError`` for unknown sids
        and ``RuntimeError`` for sessions with no packable state (e.g. a
        retired LM session whose slot was already freed)."""
        if sid not in self.sessions:
            raise KeyError(f"unknown session {sid}")
        self.park(sid)
        if sid not in self.parking:
            raise RuntimeError(f"session {sid} holds no packable state "
                               "(retired?); nothing to export")
        return self.parking[sid], self._session_spill_meta(sid)

    def detach_session(self, sid: int) -> tuple[dict, dict]:
        """Remove a session from this service and return its packed
        ``(blob, meta)`` for adoption elsewhere (drain handoff / work
        stealing).  Unlike ``close``, the session is NOT ending —
        ``_on_close`` side effects (dedicated-tenant teardown) do not
        fire; the meta carries everything the peer needs to recreate the
        host record via ``adopt_session``."""
        blob, meta = self.export_session(sid)
        self._park_take(sid)
        self.sched.release(sid)
        self.sessions.pop(sid)
        self._on_detach(sid)
        return blob, meta

    def _on_detach(self, sid: int) -> None:
        """Hook: the session just left for another worker (NOT a close)."""

    def adopt_session(self, blob: dict, meta: dict) -> int:
        """Admit a session exported by a peer's ``detach_session`` /
        ``export_session`` under a FRESH local sid (worker-local ids from
        different services may collide).  The session enters parked; its
        next push resumes it bit-identically.  All validation (geometry,
        admission capacity) runs before the first mutation."""
        meta = dict(meta or {})
        self._adopt_validate(blob, meta)
        cap = self.sched.max_sessions
        if cap is not None and self.sched.live_sessions + 1 > cap:
            raise AdmissionError(
                f"adopting a session would exceed capacity "
                f"({self.sched.live_sessions}/{cap} live)")
        sid = self._alloc_sid()
        self.sched.admit(sid)
        self.sessions[sid] = self._restore_session(meta)
        self._park_store(sid, blob)
        self._on_adopt(sid, meta)
        return sid

    def _adopt_validate(self, blob: dict, meta: dict) -> None:
        """Hook: refuse a geometry-incompatible blob BEFORE mutation."""

    def _on_adopt(self, sid: int, meta: dict) -> None:
        """Hook: runs once after a successful single-session adoption."""

    # -- tenant-state handoff (tenant-aware services override) --------------
    def live_tenants(self) -> list:
        """Tenant ids currently holding state on this service; the base
        grid has none (the plane guards with ``tenant_aware`` anyway)."""
        return []

    def export_tenant(self, tenant) -> dict:
        raise NotImplementedError(
            f"{self._service_name} service has no per-tenant state")

    def adopt_tenant(self, tenant, blob: dict) -> int:
        raise NotImplementedError(
            f"{self._service_name} service has no per-tenant state")

    # -- introspection ------------------------------------------------------
    def _extra_stats(self) -> dict:
        return {}

    def _slot_state_bytes(self) -> int:
        """STRUCTURAL parked footprint of one session (content-independent)
        — part of the frozen stats schema, so every service must price it."""
        raise NotImplementedError

    def stats(self) -> dict:
        """Introspection snapshot.  The leading keys are the FROZEN shared
        schema (sessions.STATS_SCHEMA) every service must emit identically
        — the protocol conformance test asserts it, so the services can
        never drift apart again; ``_extra_stats`` appends service-specific
        extras under keys outside the schema."""
        return {
            "service": self._service_name,
            "n_slots": self.n_slots,
            "t_chunk": self.t_chunk,
            "bound": len(self.sched.slot_of),
            "parked": len(self.parking),
            "live_sessions": self.sched.live_sessions,
            "evictions": self.evictions,
            "dispatches": self.dispatches,
            "parked_blob_bytes": self.parked_blob_bytes,
            "slot_state_bytes": self._slot_state_bytes(),
            **self._extra_stats(),
        }


# ---------------------------------------------------------------------------
# The TCN streaming service
# ---------------------------------------------------------------------------

@dataclass
class _Session(SessionRecord):
    tenant: int = NO_TENANT
    dedicated: bool = False  # tenant row was created for this session


class StreamSessionService(SlotGridService):
    """Multi-tenant streaming TCN service over a fixed slot grid."""

    _session_cls = _Session
    _service_name = "tcn"
    tenant_aware = True  # plane routing tenants bind real bank rows here

    def __init__(self, bundle, params, bn_state=None, *, n_slots: int = 8,
                 max_tenants: int = 8, max_ways: int = 8,
                 max_sessions: int | None = None, quantize: bool = False,
                 t_chunk: int = 16, mesh=None,
                 cost_fn: Callable[[int], float] | None = None,
                 stale_window: int = 0, fused: bool | None = None,
                 kernel_backend: str | None = None,
                 paged_bank: bool = False, bank_block_ways: int = 4,
                 bank_blocks: int | None = None, rehearsal_cap: int = 0,
                 metrics: MetricsRegistry | None = None, tracer=None,
                 device_counters: bool | None = None,
                 runtime: RuntimeConfig | None = None):
        super().__init__(n_slots, t_chunk=t_chunk, max_sessions=max_sessions,
                         cost_fn=cost_fn, stale_window=stale_window,
                         metrics=metrics, tracer=tracer,
                         device_counters=device_counters, runtime=runtime)
        cfg = bundle.cfg
        self.cfg = cfg
        self.max_ways = max_ways
        self.quantize = quantize
        # Fused kernel fast path (kernels/tcn_block.py): fold BN (and bake
        # the log2 weight quantization) ONCE at service construction, then
        # advance chunks through per-block fused kernels instead of the
        # per-sample scan body.  Opt-in: BN folding reassociates the BN
        # chain by one ULP, so a fused service's outputs are allclose —
        # not bit-identical — to an unfused service on the same RAW
        # params.  On the baked params the fused and scan executors ARE
        # bit-identical (tests/test_streaming_chunk.py), so park/resume
        # and cross-chunk-size exactness are preserved within a service.
        # Switch resolution: explicit kwarg > runtime/REPRO_TCN_FUSED >
        # off (configs/runtime.RuntimeConfig, the consolidated parser).
        fused = bool(self.runtime.pick("fused", fused))
        kernel_backend = self.runtime.pick("kernel_backend", kernel_backend)
        self.fused = fused
        bn_state = bn_state if bn_state is not None else tcn_empty_state(cfg)
        self._fused_params = None
        if fused:
            params, bn_state, self._fused_params = bake_stream_params(
                params, bn_state, cfg, quantize=quantize)

        self.states = grid_init(cfg, n_slots)
        # Bank layout: dense (T, max_ways, V) enroll-once table, or the
        # paged pool (sessions/bankpool.py) where way rows are allocated
        # block-at-a-time as tenants enroll past each block_ways boundary
        # and parked tenants hold zero device rows.  max_ways becomes the
        # per-tenant GROWTH CAP in paged mode (rounded up to whole blocks)
        # rather than a pre-paid allocation.
        self.paged_bank = bool(paged_bank)
        if self.paged_bank:
            mtb = -(-max_ways // bank_block_ways)  # ceil
            if bank_blocks is None:
                bank_blocks = max_tenants * mtb
            self.bankpool = PagedBankPool(bank_blocks, bank_block_ways,
                                          cfg.embed_dim, mtb)
            self.bank = None
        else:
            self.bankpool = None
            self.bank = bank_init(max_tenants, max_ways, cfg.embed_dim)
        if mesh is not None:  # shard slots over data, banks over model
            from jax.sharding import NamedSharding
            nd = lambda p: NamedSharding(mesh, p)
            self.states = jax.device_put(
                self.states, jax.tree.map(nd, grid_pspecs(cfg, mesh, n_slots)))
            if self.bank is not None:
                self.bank = jax.device_put(
                    self.bank, jax.tree.map(nd, bank_pspecs(self.bank, mesh)))
        self.mesh = mesh
        self.tenant_of_slot = np.full(n_slots, NO_TENANT, np.int32)
        self._free_tenants = list(range(max_tenants))
        self._tenant_ways = np.zeros(max_tenants, np.int32)  # host mirror
        # label-keyed streaming enrollment: per-tenant class registry so
        # repeated enroll(label=...) calls fold into ONE way's running mean
        self._tenant_labels: dict[int, dict] = {}
        # bounded latent-replay memory (sessions/rehearsal.py)
        self.rehearsal = RehearsalBuffer(rehearsal_cap) \
            if rehearsal_cap > 0 else None
        reg = self.metrics_registry
        self._c_enrolls = reg.counter("enrolls_total", service="tcn")
        self._c_enroll_shots = reg.counter("enroll_shots_total", service="tcn")
        self._h_enroll = reg.histogram("enroll_latency_us", service="tcn")
        if self.paged_bank:
            self._g_pool_live = reg.gauge("bank_pool_blocks_live",
                                          service="tcn")
            self._g_pool_free = reg.gauge("bank_pool_blocks_free",
                                          service="tcn")
            self._update_pool_gauges()
        if self.rehearsal is not None:
            self._g_rehearsal_bytes = reg.gauge("rehearsal_bytes",
                                                service="tcn")

        # params/bn enter the jitted scan as ARGUMENTS, not closure
        # constants: XLA constant-folds closure BN chains differently per
        # compiled chunk bucket, which would break the bit-exactness
        # contract between the T=1 and T=t_chunk programs (runtime data is
        # never reassociated; verified in tests/test_streaming_chunk.py).
        self._params = params
        self._bn = bn_state

        if self.paged_bank:
            # per-slot FC tables gathered through the block tables; the
            # row math is store_fc verbatim and the contraction is the
            # SAME pn_logits_banked einsum as the dense path (indexed by
            # slot instead of tenant), so at equal way counts the logits
            # are bit-identical to the dense bank path (tested)
            def _banked(emb, pool_s, pool_c, tables, ways):
                w, b = paged_bank_fc(pool_s, pool_c, tables, ways)
                s, t = emb.shape[0], emb.shape[1]
                tl = pn_logits_banked(emb.reshape(s * t, emb.shape[-1]), w, b,
                                      jnp.repeat(jnp.arange(s), t))
                return tl.reshape(s, t, -1)
        else:
            def _banked(emb, bank, tenant_ids):
                w, b = bank_fc(bank)
                s, t = emb.shape[0], emb.shape[1]
                tl = pn_logits_banked(emb.reshape(s * t, emb.shape[-1]), w, b,
                                      jnp.repeat(tenant_ids, t))
                return tl.reshape(s, t, -1)

        # device counters ride the SAME dispatch as extra outputs (one
        # in-jit reduce of the validity mask) — the state math is the
        # identical op graph, so instrumented and plain services stay
        # bit-identical on session state (tests/test_obs.py asserts it)
        dev = self.device_counters

        def _scan(p, bn, states, x, valid, *bank_args):
            new_states, emb, logits = grid_scan(
                p, bn, cfg, states, x, valid, quantize=quantize)
            out = (new_states, emb, logits, _banked(emb, *bank_args))
            return out + (valid_stats(valid),) if dev else out

        self._scan = jax.jit(_scan)
        if fused:
            fused_chunk = make_grid_fused(cfg, quantize=quantize,
                                          backend=kernel_backend)

            def _scan_fused(fp, states, x, lengths, *bank_args):
                new_states, emb, logits = fused_chunk(fp, states, x, lengths)
                out = (new_states, emb, logits,
                       _banked(emb, *bank_args))
                return out + (occupancy_stats(lengths, x.shape[1]),) \
                    if dev else out

            self._scan_fused = jax.jit(_scan_fused)
        # shot embedding for enrollment — the TCN bundle's embed_fn honours
        # the service's BN stats and quantize mode
        self._embed = jax.jit(lambda x: bundle.embed_fn(
            params, {"x": x}, state=bn_state, quantize=quantize))

    # -- slot-column state hooks --------------------------------------------
    def _pack(self, slot: int, sid: int) -> dict:
        return pack_slot(self.states, slot, pack_u4=self.quantize,
                         act_scale=self.cfg.act_scale)

    def _unpack(self, slot: int, blob: dict) -> None:
        self.states = unpack_slot(self.states, slot, blob)

    def _reset(self, slot: int) -> None:
        self.states = reset_slot(self.states, slot)

    def _on_bind(self, sid: int, slot: int) -> None:
        self.tenant_of_slot[slot] = self.sessions[sid].tenant

    def _on_unbind(self, slot: int) -> None:
        tenant = int(self.tenant_of_slot[slot])
        self.tenant_of_slot[slot] = NO_TENANT
        self._maybe_park_tenant(tenant)

    def _on_evict(self, sid: int, slot: int) -> None:
        # the eviction path bypasses _on_unbind (the slot is re-occupied
        # immediately), but the paged bank still needs to know when a
        # tenant's LAST bound session left the grid
        self._maybe_park_tenant(int(self.tenant_of_slot[slot]))

    # -- tenants ------------------------------------------------------------
    def _tenant_idle(self, tenant: int) -> bool:
        """True when no BOUND session belongs to ``tenant`` (parked
        sessions don't hold bank residency)."""
        return all(self.sessions[sid].tenant != tenant
                   for sid in self.sched.slot_of)

    def _maybe_park_tenant(self, tenant: int) -> None:
        """Paged mode: spill an idle tenant's bank rows to host so parked
        tenants hold zero device rows (the pool invariant)."""
        if (self.paged_bank and tenant != NO_TENANT
                and tenant in self.bankpool.n_ways
                and self.bankpool.is_resident(tenant)
                and self._tenant_idle(tenant)):
            self.bankpool.park(tenant)
            self._update_pool_gauges()

    def _ensure_bank_resident(self, tenant: int) -> None:
        if self.paged_bank and not self.bankpool.is_resident(tenant):
            self.bankpool.unpark(tenant)  # may raise PoolExhausted
            self._update_pool_gauges()

    def _update_pool_gauges(self) -> None:
        self._g_pool_live.set(self.bankpool.pool.n_live)
        self._g_pool_free.set(self.bankpool.pool.n_free)

    def create_tenant(self) -> int:
        if not self._free_tenants:
            raise RuntimeError("tenant bank full")
        tenant = self._free_tenants.pop(0)
        if self.paged_bank:
            self.bankpool.create(tenant)
        return tenant

    def close_tenant(self, tenant: int) -> None:
        if any(s.tenant == tenant for s in self.sessions.values()):
            raise RuntimeError(f"tenant {tenant} still has open sessions")
        if self.paged_bank:
            self.bankpool.drop(tenant)
            self._update_pool_gauges()
        else:
            self.bank = bank_clear_tenant(self.bank, tenant)
        self._tenant_labels.pop(tenant, None)
        if self.rehearsal is not None:
            self.rehearsal.drop(tenant)
        self._tenant_ways[tenant] = 0
        self._free_tenants.append(tenant)

    # -- session lifecycle --------------------------------------------------
    def open_session(self, tenant: int | None = NO_TENANT) -> int:
        """Admit a session.  ``tenant=None`` creates a dedicated tenant
        (freed again when the session closes); ``NO_TENANT`` (default)
        classifies with the shared global head."""
        dedicated = tenant is None
        claimed = dedicated
        if dedicated:
            tenant = self.create_tenant()
        elif tenant != NO_TENANT:
            if not 0 <= tenant < len(self._tenant_ways):
                raise ValueError(
                    f"tenant {tenant} out of range [0, {len(self._tenant_ways)})")
            if tenant in self._free_tenants:  # claim an uncreated row
                self._free_tenants.remove(tenant)
                if self.paged_bank:
                    self.bankpool.create(tenant)
                claimed = True
        sid = self._alloc_sid()
        try:
            self.sched.admit(sid)  # may raise AdmissionError (back-pressure)
        except Exception:
            if claimed:  # don't leak the tenant row on refused admission
                if self.paged_bank:
                    self.bankpool.drop(tenant)
                self._free_tenants.insert(0, tenant)
            raise
        self.sessions[sid] = _Session(tenant=tenant, dedicated=dedicated)
        self._bind(sid)
        return sid

    def _on_close(self, sid: int, sess) -> None:
        # a dedicated tenant row dies with its last session: if other
        # sessions share the row, ownership passes to one of them so the
        # row is still freed when the final sharer closes
        if sess.dedicated:
            sharers = [s for s in self.sessions.values()
                       if s.tenant == sess.tenant]
            if sharers:
                sharers[0].dedicated = True
            else:
                self.close_tenant(sess.tenant)

    # -- persistence hooks ---------------------------------------------------
    def _session_spill_meta(self, sid: int) -> dict:
        s = self.sessions[sid]
        return {"tenant": s.tenant, "dedicated": s.dedicated, "steps": s.steps}

    def _spill_extra(self) -> dict:
        tenant_meta = {}
        for sid in self.parking:
            t = self.sessions[sid].tenant
            if t == NO_TENANT or str(t) in tenant_meta:
                continue
            if self.paged_bank:
                tenant_meta[str(t)] = self.bankpool.pack(t)
            else:
                row = bank_pack_tenant(self.bank, t)
                tenant_meta[str(t)] = {
                    "s_sums": row["s_sums"].tolist(),
                    "counts": row["counts"].tolist(),
                    "n_ways": int(self._tenant_ways[t]),
                }
        return {"tenants": tenant_meta}

    def _restore_validate(self, parking: dict, meta: dict) -> None:
        for t_str in meta.get("tenants", {}):
            t = int(t_str)
            if t >= len(self._tenant_ways):
                raise ValueError(f"spill references tenant {t} beyond "
                                 f"max_tenants={len(self._tenant_ways)}")
            if t not in self._free_tenants:
                raise ValueError(f"tenant {t} already in use; refuse to "
                                 "overwrite its prototype row on restore")

    def _restore_apply(self, meta: dict) -> None:
        for t_str, row in meta.get("tenants", {}).items():
            t = int(t_str)
            self._free_tenants.remove(t)
            if self.paged_bank:
                # adopted PARKED (zero device rows); the first push or
                # enroll re-establishes residency
                self.bankpool.adopt(t, row)
            else:
                self.bank = bank_unpack_tenant(self.bank, t, {
                    "s_sums": np.asarray(row["s_sums"], np.float32),
                    "counts": np.asarray(row["counts"], np.float32),
                    "n_ways": np.asarray(row["n_ways"], np.int32)})
            self._tenant_ways[t] = int(row["n_ways"])

    def _restore_session(self, info: dict):
        return _Session(tenant=int(info.get("tenant", NO_TENANT)),
                        dedicated=bool(info.get("dedicated", False)),
                        steps=int(info.get("steps", 0)))

    # -- the hot path -------------------------------------------------------
    def push_audio(self, chunks: dict[int, Any]) -> dict[int, dict]:
        """Advance sessions by ragged time chunks.

        chunks: {sid: x} where x is a (t_i, C_in) chunk or a single (C_in,)
        sample (the T=1 special case).  All pushed sessions advance through
        chunked ``grid_scan`` dispatches over the compiled (S, T_chunk)
        grid; sessions absent from ``chunks`` (and the padded tail of short
        chunks) stay bit-frozen.  Parked sessions are resumed first
        (possibly evicting idle ones).

        Returns {sid: result}.  For a (t_i, C_in) chunk the result carries
        per-sample sequences — emb (t_i, V), logits (t_i, n),
        tenant_logits (t_i, ways) | None — plus the end-of-chunk
        classification ``pred`` and cumulative ``step``.  For a (C_in,)
        sample the historical surface is kept: emb (V,), logits (n,)."""
        if len(chunks) > self.n_slots:
            raise ValueError(
                f"{len(chunks)} sessions pushed but only {self.n_slots} slots; "
                "split the push or grow the grid")
        c_in = self.cfg.tcn_in_channels
        arrs, scalar = {}, {}
        for sid, v in chunks.items():
            a = np.asarray(v, np.float32)
            scalar[sid] = a.ndim == 1
            if a.ndim == 1:
                a = a[None]
            if a.ndim != 2 or a.shape[1] != c_in:
                raise ValueError(
                    f"session {sid}: expected (C_in,) or (t, C_in) with "
                    f"C_in={c_in}, got shape {np.asarray(v).shape}")
            if a.shape[0] == 0:
                raise ValueError(f"session {sid}: empty chunk")
            arrs[sid] = a
        self._touch_and_bind(chunks)
        if self.paged_bank:
            # every pushed session's tenant must hold its bank rows on
            # device before the dispatch reads them through the tables
            for sid in arrs:
                t = self.sessions[sid].tenant
                if t != NO_TENANT:
                    self._ensure_bank_resident(t)

        slot_of = {sid: self.sched.slot_of[sid] for sid in arrs}
        lens = {sid: a.shape[0] for sid, a in arrs.items()}
        max_len = max(lens.values())
        pieces = {sid: [] for sid in arrs}  # per-tick (emb, logits, tl) slices
        off = 0
        while off < max_len:
            t_pad = self._tick_len(max_len - off)
            x = np.zeros((self.n_slots, t_pad, c_in), np.float32)
            tick_lens = np.zeros(self.n_slots, np.int32)
            for sid, a in arrs.items():
                seg = a[off:off + t_pad]
                if seg.shape[0]:
                    x[slot_of[sid], :seg.shape[0]] = seg
                    tick_lens[slot_of[sid]] = seg.shape[0]
            shape = f"T{t_pad}"
            dev_stats = None
            if self.paged_bank:
                tables, ways = self.bankpool.slot_tables(self.tenant_of_slot)
                bank_args = (self.bankpool.s_sums, self.bankpool.counts,
                             jnp.asarray(tables), jnp.asarray(ways))
            else:
                bank_args = (self.bank, jnp.asarray(self.tenant_of_slot))
            t0 = time.perf_counter()
            with self.tracer.span("dispatch", cat="tcn", shape=shape,
                                  lanes=len(arrs),
                                  fused=self.fused):
                if self.fused:
                    self.states, emb, logits, tlogits, *dev = \
                        self._scan_fused(
                            self._fused_params, self.states, jnp.asarray(x),
                            jnp.asarray(tick_lens), *bank_args)
                else:
                    valid = np.arange(t_pad)[None, :] < tick_lens[:, None]
                    self.states, emb, logits, tlogits, *dev = self._scan(
                        self._params, self._bn, self.states, jnp.asarray(x),
                        jnp.asarray(valid), *bank_args)
                emb, logits, tlogits = (np.asarray(emb), np.asarray(logits),
                                        np.asarray(tlogits))
                if self.paged_bank:
                    # table width is block-granular (>= max_ways); keep
                    # the result surface mode-independent
                    tlogits = tlogits[..., :self.max_ways]
                if dev:
                    dev_stats = np.asarray(dev[0])
            self._record_dispatch(time.perf_counter() - t0, shape)
            if dev_stats is not None:
                self._ingest_occupancy(dev_stats)
            for sid in arrs:
                n = min(max(lens[sid] - off, 0), t_pad)
                if n:
                    s = slot_of[sid]
                    pieces[sid].append(
                        (emb[s, :n], logits[s, :n], tlogits[s, :n]))
            off += t_pad

        out = {}
        for sid in arrs:
            sess = self.sessions[sid]
            sess.steps += lens[sid]
            e, l, tl = (np.concatenate([p[i] for p in pieces[sid]])
                        for i in range(3))
            personalized = (sess.tenant != NO_TENANT
                            and self._tenant_ways[sess.tenant] > 0)
            head = tl if personalized else l
            if scalar[sid]:
                res = {"emb": e[-1], "logits": l[-1],
                       "tenant_logits": tl[-1] if personalized else None,
                       "pred": int(head[-1].argmax()), "step": sess.steps}
            else:
                res = {"emb": e, "logits": l,
                       "tenant_logits": tl if personalized else None,
                       "pred": int(head[-1].argmax()), "step": sess.steps}
            sess.last = res
            out[sid] = res
        return out

    # protocol verb (sessions.SessionService): the TCN payload is audio
    push = push_audio

    # -- FSL / CL enrollment (live, mid-stream) -----------------------------
    def enroll_shots(self, sid: int, shots, *, embedded: bool = False,
                     way: int | None = None, label=None) -> int:
        """Streaming enrollment: fold k shots into the session's tenant
        bank and return the way index.  shots: (k, T, C_in) raw clips
        (embedded via the shared backbone) or (k, V) embeddings when
        ``embedded=True``.  The tenant's very next ``push_audio``
        classifies against the updated bank.

        Three addressing modes, all incremental per-class running means
        (Eq. 6 over the s_sums/counts layout):

          * ``way=None, label=None`` — append a NEW way (one-shot CL);
          * ``way=j``                — refine an enrolled way;
          * ``label=x``              — streaming: the first enroll of a
            label appends a way, later enrolls of the same label refine
            it — the caller never tracks way indices.

        In paged-bank mode the tenant's rows grow a block at a time from
        the shared pool (PoolExhausted = back-pressure) and a parked
        tenant is made resident for the update (and re-parked if it has
        no bound sessions, preserving the zero-device-rows invariant)."""
        t0 = time.perf_counter()
        tenant = self.sessions[sid].tenant
        if tenant == NO_TENANT:
            raise ValueError("session has no tenant; open with tenant=None "
                             "or an explicit tenant id to personalize")
        with self.tracer.span("enroll", cat="tcn", sid=sid, tenant=tenant):
            emb = jnp.asarray(shots) if embedded \
                else self._embed(jnp.asarray(shots))
            if label is not None:
                if way is not None:
                    raise ValueError("pass way= or label=, not both")
                way = self._tenant_labels.setdefault(tenant, {}).get(label)
            if way is None:
                if self._tenant_ways[tenant] >= self.max_ways:
                    raise RuntimeError(
                        f"tenant {tenant} at max_ways={self.max_ways}")
                if self.paged_bank:
                    self._ensure_bank_resident(tenant)
                    way = self.bankpool.add_class(tenant, emb)
                    self._update_pool_gauges()
                else:
                    self.bank = bank_add_class(self.bank, tenant, emb)
                    way = int(self._tenant_ways[tenant])
                self._tenant_ways[tenant] += 1
                if label is not None:
                    self._tenant_labels[tenant][label] = way
            else:
                if not 0 <= way < self._tenant_ways[tenant]:
                    raise ValueError(
                        f"way {way} not enrolled for tenant {tenant} "
                        f"({self._tenant_ways[tenant]} ways); omit way= to "
                        "enroll")
                if self.paged_bank:
                    self._ensure_bank_resident(tenant)
                    self.bankpool.update_class(tenant, way, emb)
                else:
                    self.bank = bank_update_class(self.bank, tenant, way, emb)
            if self.rehearsal is not None:
                self.rehearsal.add(tenant, way, np.asarray(emb))
                self._g_rehearsal_bytes.set(self.rehearsal.nbytes())
            # honest latency: the bank update must have landed on device
            jax.block_until_ready(
                self.bankpool.s_sums if self.paged_bank else self.bank.s_sums)
            self._maybe_park_tenant(tenant)
        self._c_enrolls.inc()
        self._c_enroll_shots.inc(int(np.asarray(shots).shape[0]))
        self._h_enroll.record((time.perf_counter() - t0) * 1e6)
        return way

    # protocol verb (sessions.SessionService): learning is first-class
    enroll = enroll_shots

    def rehearse_tenant(self, tenant: int) -> int:
        """Rebuild every enrolled way of ``tenant`` from the bounded
        rehearsal buffer (latent replay: dequantized u4 log2 embeddings
        re-summed into prototype rows), REPLACING the exact running sums.
        Returns the number of ways rebuilt.  The served CL bench measures
        the accuracy cost of exactly this substitution."""
        if self.rehearsal is None:
            raise RuntimeError(
                "service built with rehearsal_cap=0; no buffer to replay")
        n = int(self._tenant_ways[tenant])
        if self.paged_bank:
            self._ensure_bank_resident(tenant)
        for way in range(n):
            s, k = self.rehearsal.rebuild(tenant, way, self.cfg.embed_dim)
            if self.paged_bank:
                self.bankpool.set_way(tenant, way, s, k)
            else:
                self.bank = TenantBank(
                    s_sums=self.bank.s_sums.at[tenant, way].set(
                        jnp.asarray(s)),
                    counts=self.bank.counts.at[tenant, way].set(
                        jnp.float32(k)),
                    n_ways=self.bank.n_ways)
        if self.paged_bank:
            self._maybe_park_tenant(tenant)
        return n

    # -- tenant-state handoff (serving plane drain/recover) -----------------
    def live_tenants(self) -> list[int]:
        return [t for t in range(len(self._tenant_ways))
                if t not in self._free_tenants]

    def export_tenant(self, tenant: int) -> dict:
        """Layout-free host snapshot of one tenant's learned state — the
        bank running sums truncated to enrolled ways (Eq. 6 state), the
        label->way registry, and the rehearsal reservoirs — for live
        handoff to a peer worker.  Non-destructive; either bank layout
        adopts it (the paged pool pads rows back to whole blocks)."""
        if not 0 <= tenant < len(self._tenant_ways) \
                or tenant in self._free_tenants:
            raise KeyError(f"tenant {tenant} not in use")
        n = int(self._tenant_ways[tenant])
        dim = self.cfg.embed_dim
        if self.paged_bank:
            row = self.bankpool.pack(tenant)
            s = np.asarray(row["s_sums"], np.float32).reshape(-1, dim)[:n]
            c = np.asarray(row["counts"], np.float32).reshape(-1)[:n]
        else:
            row = bank_pack_tenant(self.bank, tenant)
            s = np.asarray(row["s_sums"], np.float32)[:n]
            c = np.asarray(row["counts"], np.float32)[:n]
        blob = {"s_sums": s, "counts": c, "n_ways": n,
                "labels": dict(self._tenant_labels.get(tenant, {}))}
        if self.rehearsal is not None:
            blob["rehearsal"] = self.rehearsal.export_tenant(tenant)
        return blob

    def adopt_tenant(self, tenant: int | None, blob: dict) -> int:
        """Install a peer's ``export_tenant`` blob under ``tenant`` (must
        be a free row) or under a fresh row when ``tenant is None`` —
        dedicated tenants keep service-LOCAL ids, so the plane remaps
        them on handoff.  Returns the id actually used.  Paged banks
        adopt PARKED (zero device rows until first use)."""
        if tenant is None:
            if not self._free_tenants:
                raise RuntimeError("tenant bank full")
            tenant = self._free_tenants[0]
        if not 0 <= tenant < len(self._tenant_ways):
            raise ValueError(f"tenant {tenant} out of range "
                             f"[0, {len(self._tenant_ways)})")
        if tenant not in self._free_tenants:
            raise ValueError(f"tenant {tenant} already in use; refuse to "
                             "overwrite its prototype rows")
        n = int(blob["n_ways"])
        if n > self.max_ways:
            raise ValueError(f"blob carries {n} ways but this service caps "
                             f"at max_ways={self.max_ways}")
        dim = self.cfg.embed_dim
        s = np.asarray(blob["s_sums"], np.float32).reshape(-1, dim)[:n]
        c = np.asarray(blob["counts"], np.float32).reshape(-1)[:n]
        self._free_tenants.remove(tenant)
        if self.paged_bank:
            self.bankpool.adopt(tenant, {"s_sums": s, "counts": c,
                                         "n_ways": n})
        else:
            sp = np.zeros((self.max_ways, dim), np.float32)
            cp = np.zeros((self.max_ways,), np.float32)
            sp[:n], cp[:n] = s, c
            self.bank = bank_unpack_tenant(self.bank, tenant, {
                "s_sums": sp, "counts": cp, "n_ways": np.int32(n)})
        self._tenant_ways[tenant] = n
        if blob.get("labels"):
            self._tenant_labels[tenant] = dict(blob["labels"])
        if self.rehearsal is not None and blob.get("rehearsal"):
            self.rehearsal.adopt_tenant(tenant, blob["rehearsal"])
        return tenant

    def _adopt_validate(self, blob: dict, meta: dict) -> None:
        t = int(meta.get("tenant", NO_TENANT))
        if t == NO_TENANT:
            return
        if not 0 <= t < len(self._tenant_ways):
            raise ValueError(f"session references tenant {t} beyond "
                             f"max_tenants={len(self._tenant_ways)}")
        if t in self._free_tenants:
            raise ValueError(f"session references tenant {t} but no such "
                             "row is in use here; adopt_tenant first")

    # -- introspection ------------------------------------------------------
    def poll(self, sid: int) -> dict:
        sess = self.sessions[sid]
        return {
            "state": "active" if self.sched.is_bound(sid) else "parked",
            "slot": self.sched.slot_of.get(sid),
            "tenant": None if sess.tenant == NO_TENANT else sess.tenant,
            "n_ways": int(self._tenant_ways[sess.tenant])
                      if sess.tenant != NO_TENANT else 0,
            "steps": sess.steps,
            "last": sess.last,
        }

    def _slot_state_bytes(self) -> int:
        # structural, not content-dependent, so stable for CI tracking:
        # what one session costs in the parking lot (nibble-packed when
        # the service runs quantize=True)
        return slot_park_bytes(self.cfg, quantize=self.quantize)

    def _extra_stats(self) -> dict:
        # what one tenant's prototype row costs in a spill (the paper's
        # 26 B/way personalization-cost story); paged mode prices one
        # BLOCK (the allocation granule) and reports pool occupancy
        if self.paged_bank:
            bp = self.bankpool
            extra = {"tenant_row_bytes":
                     bp.block_ways * (self.cfg.embed_dim + 1) * 4}
            extra.update({f"bank_pool_{k}": v for k, v in bp.stats().items()})
        else:
            extra = {"tenant_row_bytes": bank_row_bytes(self.bank)}
        extra["fused"] = self.fused
        extra["paged_bank"] = self.paged_bank
        if self.rehearsal is not None:
            extra["rehearsal_bytes"] = self.rehearsal.nbytes()
        return extra
