"""Bounded rehearsal memory of u4 log2-quantized shot embeddings.

Running-mean prototypes (Eq. 6) are exact but *irreversible*: once shots
are folded into ``s_sums`` they cannot be re-weighted, re-clustered, or
replayed after a backbone update.  Latent-replay CL (Ravaglia et al.,
PAPERS.md) keeps a small buffer of frozen-layer activations instead and
rebuilds the classifier from it — trading a bounded, quantized memory for
the ability to recompute.  This module is that buffer for the prototype
head: per (tenant, way) reservoirs of shot embeddings stored as 4-bit
signed log2 codes (quant/log2.py, the paper's weight codebook) packed two
to a byte with one fp32 scale per shot — ~V/2 + 4 bytes per shot vs 4V
for fp32.

``rebuild`` dequantizes a reservoir and re-sums it into (s_sum, count)
rows; the served CL bench measures the accuracy cost of classifying from
rebuilt-quantized prototypes against the exact running sums as the class
count grows, and ``check_regression --cl`` holds the gap.

Reservoir sampling keeps each class's buffer a uniform sample of ALL its
shots ever offered, so long-lived classes do not bias toward recency.
Deterministic: the reservoir RNG is seeded per buffer.
"""

from __future__ import annotations

import numpy as np

from repro.quant.log2 import (
    compute_scale,
    dequantize_log2,
    pack_nibbles,
    quantize_log2,
    unpack_nibbles,
)


class RehearsalBuffer:
    """Per-(tenant, way) bounded reservoirs of quantized embeddings."""

    def __init__(self, cap_per_class: int = 8, seed: int = 0):
        if cap_per_class < 1:
            raise ValueError(
                f"cap_per_class must be >= 1, got {cap_per_class}")
        self.cap = int(cap_per_class)
        self._rng = np.random.default_rng(seed)
        # (tenant, way) -> list of (packed u4 codes (ceil(V/2),), fp32 scale)
        self._mem: dict[tuple[int, int], list] = {}
        self._seen: dict[tuple[int, int], int] = {}  # shots ever offered

    @staticmethod
    def _encode(row: np.ndarray):
        v = row.astype(np.float32)
        if v.shape[0] % 2:  # pack_nibbles needs an even last axis
            v = np.concatenate([v, np.zeros(1, np.float32)])
        scale = float(np.asarray(compute_scale(v)))
        codes = np.asarray(quantize_log2(v, scale))
        return np.asarray(pack_nibbles(codes)), scale

    @staticmethod
    def _decode(packed: np.ndarray, scale: float, dim: int) -> np.ndarray:
        codes = np.asarray(unpack_nibbles(packed))
        return np.asarray(dequantize_log2(codes, scale))[:dim]

    def add(self, tenant: int, way: int, embeddings) -> None:
        """Offer (k, V) shot embeddings to the (tenant, way) reservoir."""
        emb = np.asarray(embeddings, np.float32)
        key = (tenant, way)
        mem = self._mem.setdefault(key, [])
        for row in emb:
            seen = self._seen.get(key, 0)
            item = self._encode(row)
            if len(mem) < self.cap:
                mem.append(item)
            else:  # reservoir: keep a uniform sample of all shots offered
                j = int(self._rng.integers(0, seen + 1))
                if j < self.cap:
                    mem[j] = item
            self._seen[key] = seen + 1

    def n_shots(self, tenant: int, way: int) -> int:
        return len(self._mem.get((tenant, way), ()))

    def rebuild(self, tenant: int, way: int, dim: int):
        """Dequantize the reservoir into prototype rows: (s_sum (V,) fp32,
        count).  Raises KeyError when the class has no buffered shots."""
        mem = self._mem.get((tenant, way))
        if not mem:
            raise KeyError(f"no rehearsal shots for tenant {tenant} "
                           f"way {way}")
        rows = np.stack([self._decode(p, s, dim) for p, s in mem])
        return rows.astype(np.float32).sum(axis=0), len(mem)

    def drop(self, tenant: int) -> None:
        for key in [k for k in self._mem if k[0] == tenant]:
            del self._mem[key]
            self._seen.pop(key, None)

    def export_tenant(self, tenant: int) -> dict:
        """One tenant's reservoirs (packed codes + scales + seen counters,
        keyed by way) for live handoff to a peer buffer.  Non-destructive;
        the lists are copied shallowly and the packed arrays are never
        mutated in place, so the blob stays valid while this buffer keeps
        taking shots."""
        out = {}
        for (t, way), mem in self._mem.items():
            if t == tenant:
                out[way] = {"shots": list(mem),
                            "seen": self._seen.get((t, way), len(mem))}
        return out

    def adopt_tenant(self, tenant: int, blob: dict) -> None:
        """Install reservoirs exported by a peer's ``export_tenant``.
        Refuses a (tenant, way) that already holds shots here.  Reservoir
        sampling continues with THIS buffer's RNG — per-buffer
        determinism, as with every seeded component."""
        for way in blob:
            if (tenant, int(way)) in self._mem:
                raise ValueError(f"tenant {tenant} way {way} already has "
                                 "rehearsal shots; refuse to overwrite")
        for way, ent in blob.items():
            key = (tenant, int(way))
            self._mem[key] = list(ent["shots"])
            self._seen[key] = int(ent["seen"])

    def nbytes(self, tenant: int | None = None) -> int:
        """Host bytes of the buffer (packed codes + one fp32 scale per
        shot) — the bounded-memory claim the bench reports."""
        total = 0
        for (t, _), mem in self._mem.items():
            if tenant is not None and t != tenant:
                continue
            total += sum(p.nbytes + 4 for p, _ in mem)
        return total
