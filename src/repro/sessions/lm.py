"""LM serving sessions: KV-cache park/resume + chunked multi-token decode.

This brings the LM path to full parity with the TCN streaming service.
The same slot-grid virtualization applies — many more sessions than the
compiled batch, LRU/cost eviction to a host parking lot, bit-identical
resume in any slot — but a slot's state is a KV-cache COLUMN (every cache
leaf sliced along its per-session axis) instead of a ring-buffer pytree,
and a "time chunk" is a TOKEN chunk: ``decode_scan`` runs ``jax.lax.scan``
over T masked greedy-decode steps inside one jitted dispatch, so decoding
amortizes the host↔device round trip exactly the way ``grid_scan`` does
for audio samples (KV-cache chunk ≙ time chunk).

Key differences from the historical ``serving.LMServer`` loop:

  * per-lane positions — each slot decodes at its OWN ``pos`` (the lane
    body is ``jax.vmap`` of a B=1 decode), so admitting or resuming a
    session can never perturb in-flight neighbors (no snapshot/rollback),
    and prefill is just the first steps of the same scan (forced tokens
    from the prompt instead of greedy feedback): one dispatch replaces the
    one-dispatch-per-token prefill AND decode loops;
  * params enter the jitted scan as ARGUMENTS (the core/streaming
    discipline), so the T=1 and T=T_chunk programs are bit-identical per
    step — a chunked decode emits exactly the tokens of per-step decoding;
  * positions are int32 END TO END (host mirrors included) and guarded:
    a lane's steps are clamped to ``seq_cap - pos`` and a session that
    reaches the cap is *retired* (slot freed, outputs kept) instead of
    silently wrapping its cache writes;
  * a parked blob is the cache column truncated to the first ``pos``
    positions (sessions/state.pack_column), so parked bytes are O(pos) —
    per-session costs are genuinely non-uniform, which is what makes the
    scheduler's cost-aware eviction policy bite across mixed fp32 TCN /
    u4 TCN / KV sessions;
  * TRUE chunked prefill — on bundles whose cache is entirely
    position-indexed, ``open_session`` feeds the prompt body through
    multi-token cached steps (``make_prefill_column`` over
    ``bundle.step_fn``) in largest-first pow2 chunks: the prompt MATH is
    amortized (causal attention over each whole chunk), not just the
    dispatch, and the cache is bit-identical to token-at-a-time prefill;
  * speculative continuation — ``decode_scan``'s forced-token inputs
    verify drafts; sessions/spec.py layers the drafter/verifier on top.

Passing ``mesh=`` shards every cache leaf's per-session axis over the
mesh's ``data`` axis (sessions/state.column_pspecs — the per-leaf-axis
analog of the TCN grid's ``grid_pspecs``); a 1-device mesh degenerates to
replicated, and placement survives decode dispatches
(tests/test_multidevice.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.runtime import RuntimeConfig
from repro.obs.device import occupancy_stats
from repro.sessions.paging import BlockPool, PoolExhausted, PrefixCache, prefix_keys
from repro.sessions.service import SessionRecord, SlotGridService
from repro.sessions.state import (
    PAGED_MARKER,
    column_pspecs,
    copy_block,
    gather_column,
    leaf_axes,
    make_pools,
    pack_blocks,
    pack_column,
    paged_flags,
    split_blocks,
    unpack_blocks,
    unpack_column,
)


def make_decode_scan(decode_fn, batch_axes, seq_axes=None):
    """Build a chunked greedy decoder from a bundle's single-step decode.

    Returns ``scan(params, cache, tok, pos, inp, n_inp, n_steps)``:

      cache               batched cache pytree (``batch_axes`` per leaf)
      tok     (S,) i32    pending feedback token per lane
      pos     (S,) i32    per-lane position
      inp     (S, T) i32  forced (prompt) tokens, consumed left to right
      n_inp   (S,) i32    forced-token count per lane
      n_steps (S,) i32    valid steps per lane (<= T); the rest are masked

    Step j of lane s feeds ``inp[s, j]`` while ``j < n_inp[s]`` (prefill),
    else the previous argmax (greedy decode), at position ``pos[s] + j``.
    Lanes are independent ``vmap`` bodies, so each writes its cache column
    at its own position; steps past ``n_steps`` leave the lane's position
    and feedback token bit-frozen.  Masked-step cache discipline is
    per-leaf, keyed by ``seq_axes`` (pass the tree from
    ``state.leaf_axes``; None treats every leaf as position-indexed):

      * position-indexed leaves (seq axis >= 0, i.e. KV rows) are masked
        by POSITION, not by value — a masked step still writes its
        (meaningless) k/v at the lane's frozen ``pos``, which no consumer
        ever reads: the next valid step rewrites the row before
        attending, and parking truncates the blob to [0, pos).  Callers
        must therefore pass every lane's TRUE position even for fully
        masked lanes (pos 0 would corrupt live history); the payoff is a
        scan body that costs O(one row write), not O(whole cache select),
        per step;
      * recurrent leaves (no seq axis — RWKV wkv state, Mamba conv/ssm
        state) have no overwritten-before-read property (every step
        mutates them cumulatively), so they ARE value-masked with
        ``jnp.where`` — they are O(D) per lane, so the select is cheap.

    Returns ``(cache, tok, pos, y (S, T) i32)`` — ``y[s, j]`` is the
    argmax after step j (callers mask by their emission rule).

    Jit it with params as an ARGUMENT; T=1 then recovers per-token decode
    bit-exactly and any chunking of the same token stream is bit-identical
    (tests/test_lm_sessions.py)."""

    recurrent = (jax.tree.map(lambda _: False, batch_axes) if seq_axes is None
                 else jax.tree.map(lambda sax: sax < 0, seq_axes))

    def scan(params, cache, tok, pos, inp, n_inp, n_steps):
        def body(carry, xs):
            cache, tok, pos = carry
            inp_t, j = xs

            def lane(col, tk, ps, it, ni, ns):
                c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                 col, batch_axes)
                t = jnp.where(j < ni, it, tk)
                logits, c2 = decode_fn(params, c,
                                       {"tokens": t[None, None], "pos": ps})
                c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                  c2, batch_axes)
                y = jnp.argmax(logits[0], -1).astype(jnp.int32)
                v = j < ns
                keep = lambda n, o: jnp.where(v, n, o)
                # per-leaf masked-step discipline (see docstring): KV rows
                # are masked by position (frozen ps, row rewritten before
                # any read), recurrent leaves by value
                c2 = jax.tree.map(
                    lambda new, old, rec: keep(new, old) if rec else new,
                    c2, col, recurrent)
                return c2, keep(y, tk), keep(ps + 1, ps), y

            cache, tok, pos, y = jax.vmap(
                lane, in_axes=(batch_axes, 0, 0, 0, 0, 0),
                out_axes=(batch_axes, 0, 0, 0))(
                    cache, tok, pos, inp_t, n_inp, n_steps)
            return (cache, tok, pos), y

        T = inp.shape[1]
        (cache, tok, pos), ys = jax.lax.scan(
            body, (cache, tok, pos),
            (jnp.moveaxis(inp, 1, 0), jnp.arange(T, dtype=jnp.int32)))
        return cache, tok, pos, jnp.moveaxis(ys, 0, 1)

    return scan


def make_decode_scan_paged(decode_fn, batch_axes, seq_axes, block_len):
    """Paged twin of ``make_decode_scan``: the same scan, reading the
    cache through per-lane block tables.

    The signature gains the tables: ``scan(params, cache, tables, tok,
    pos, inp, n_inp, n_steps)`` with ``tables`` (S, max_blocks) int32.
    Every seq-axis leaf of ``cache`` is a shared
    ``(..., n_blocks + 1, block_len, ...)`` pool (state.make_pools);
    recurrent leaves keep their dense per-lane layout and the dense
    scan's value-masking discipline unchanged.

    Bit-identity contract: each lane gathers its table row into the EXACT
    dense column (state.gather_column) and runs the *same* ``decode_fn``
    graph on it, then writes back only the one block containing the
    step's row.  A masked lane's write lands in its own frozen-position
    block — or, for free/retired lanes whose table rows are cleared, in
    the reserved NULL block 0 — so no step can touch bytes another
    session or the prefix registry still reads: shared blocks are cloned
    by the service BEFORE they enter a lane's write range (copy-on-
    write), and rows past a lane's kv_len are masked to -inf inside the
    attention itself — exactly the discipline that already makes stale
    dense rows (e.g. rejected speculative suffixes) unobservable."""

    recurrent = jax.tree.map(lambda sax: sax < 0, seq_axes)
    pooled = jax.tree.map(lambda sax: sax >= 0, seq_axes)
    # pool leaves broadcast whole into every lane (shared memory); dense
    # leaves still slice their per-lane column
    col_axes = jax.tree.map(
        lambda bax, pg: None if pg else bax, batch_axes, pooled)

    def scan(params, cache, tables, tok, pos, inp, n_inp, n_steps):
        def body(carry, xs):
            cache, tok, pos = carry
            inp_t, j = xs

            def lane(cs, row, tk, ps, it, ni, ns):
                col = jax.tree.map(
                    lambda a, bax, pg: gather_column(a, row, bax) if pg else a,
                    cs, batch_axes, pooled)
                c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                 col, batch_axes)
                t = jnp.where(j < ni, it, tk)
                logits, c2 = decode_fn(params, c,
                                       {"tokens": t[None, None], "pos": ps})
                c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                  c2, batch_axes)
                y = jnp.argmax(logits[0], -1).astype(jnp.int32)
                v = j < ns
                keep = lambda n, o: jnp.where(v, n, o)
                c2 = jax.tree.map(
                    lambda new, old, rec: keep(new, old) if rec else new,
                    c2, col, recurrent)
                # write back ONLY the block holding this step's row; the
                # rest of the gathered column is untouched pool bytes
                b = ps // block_len
                upd = jax.tree.map(
                    lambda a, bax, pg: jax.lax.dynamic_slice_in_dim(
                        a, b * block_len, block_len, axis=bax) if pg else a,
                    c2, batch_axes, pooled)
                return upd, row[b], keep(y, tk), keep(ps + 1, ps), y

            upd, pb, tok, pos, y = jax.vmap(
                lane, in_axes=(col_axes, 0, 0, 0, 0, 0, 0),
                out_axes=(batch_axes, 0, 0, 0, 0))(
                    cache, tables, tok, pos, inp_t, n_inp, n_steps)
            cache = jax.tree.map(
                lambda a, u, bax, pg:
                    a.at[(slice(None),) * bax + (pb,)].set(u) if pg else u,
                cache, upd, batch_axes, pooled)
            return (cache, tok, pos), y

        T = inp.shape[1]
        (cache, tok, pos), ys = jax.lax.scan(
            body, (cache, tok, pos),
            (jnp.moveaxis(inp, 1, 0), jnp.arange(T, dtype=jnp.int32)))
        return cache, tok, pos, jnp.moveaxis(ys, 0, 1)

    return scan


def make_prefill_column(step_fn, batch_axes):
    """Build the true chunked-prefill step: one session's cache column is
    sliced out of the grid, advanced by a whole (1, S) prompt chunk through
    the bundle's multi-token cached ``step_fn`` (causal attention over the
    chunk at once — prompt MATH amortized, not just dispatch), and written
    back.  ``slot`` and ``pos`` are traced, so one compiled program per
    chunk length serves every slot and position.

    Returns ``prefill(params, cache, slot, tokens (1, S), pos) -> cache``.

    Exactness: the chunk program computes the same per-token K/V rows as
    token-at-a-time stepping up to f32 ULP reassociation; under the KV
    bundles' bf16 cache dtype the rounding absorbs that, so the resulting
    cache column is bit-identical to the scan prefill's (asserted in
    tests/test_lm_sessions.py).  Callers keep the LAST prompt token out of
    the chunks and feed it through the decode scan instead, so the first
    sampled token comes from the exact same S=1 program either way."""

    def prefill(params, cache, slot, tokens, pos):
        col = jax.tree.map(
            lambda a, ax: jax.lax.dynamic_slice_in_dim(a, slot, 1, ax),
            cache, batch_axes)
        _, col = step_fn(params, col, {"tokens": tokens, "pos": pos})
        return jax.tree.map(
            lambda a, c, ax: jax.lax.dynamic_update_slice_in_dim(
                a, c.astype(a.dtype), slot, ax),
            cache, col, batch_axes)

    return prefill


def make_prefill_paged(step_fn, batch_axes, block_len):
    """Paged twin of ``make_prefill_column``: the lane's column is
    gathered through its block-table ``row``, advanced by the SAME
    multi-token cached ``step_fn``, and scattered back block-wise over
    the whole row.  Blocks the lane does not own map to the reserved
    NULL block, which absorbs their (never-read) writes; shared prefix
    blocks receive their own bytes back bit-identically (the chunk
    program only rewrites rows [pos, pos+S) and passes every other row
    through).  Only built for fully position-indexed bundles — the same
    ``parallel_safe`` gate as chunked prefill itself.

    Returns ``prefill(params, cache, row (max_blocks,) i32, tokens
    (1, S), pos) -> cache``."""

    def prefill(params, cache, row, tokens, pos):
        col = jax.tree.map(
            lambda a, bax: jnp.expand_dims(gather_column(a, row, bax), bax),
            cache, batch_axes)
        _, col = step_fn(params, col, {"tokens": tokens, "pos": pos})

        def put(a, c, bax):
            blk = split_blocks(jnp.squeeze(c.astype(a.dtype), bax),
                               bax, block_len)
            return a.at[(slice(None),) * bax + (row,)].set(blk)

        return jax.tree.map(put, cache, col, batch_axes)

    return prefill


def pow2_chunks(n: int, cap: int) -> list[int]:
    """Largest-first power-of-two decomposition of ``n`` with chunks <= cap:
    the prefill chunk schedule.  Exact partition (prompt chunks cannot pad —
    every fed token writes cache rows), and compiled programs stay bounded
    by log2(cap)+1 shapes; a 255-token body at cap 128 is
    [128, 64, 32, 16, 8, 4, 2, 1] — 8 dispatches instead of 255 steps."""
    if cap < 1:
        raise ValueError(f"chunk cap must be >= 1, got {cap}")
    cap = 1 << (cap.bit_length() - 1)  # round down to a power of two
    out = []
    while n > 0:
        c = min(cap, 1 << (n.bit_length() - 1))
        out.append(c)
        n -= c
    return out


@dataclass
class _LMSession(SessionRecord):
    prompt: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    tok: int = 0        # pending greedy feedback token
    done: bool = False  # retired at seq_cap (outputs kept, slot freed)


class LMSessionService(SlotGridService):
    """Slot-grid LM serving with KV park/resume and chunked decode.

    ``open_session(prompt)`` admits a request (evicting an idle LRU/cheap
    session if the grid is full); ``decode({sid: n})`` greedily generates n
    tokens per session — consuming any still-pending prompt feed first —
    in bucketed scan dispatches of up to ``t_chunk`` tokens each.  Device
    state is ONLY the cache grid; positions, pending prompts, and feedback
    tokens are int32 host mirrors rebuilt per dispatch, so a parked blob
    is just the truncated cache column.  ``outputs[sid]`` survives close
    and retirement (the historical LMServer contract)."""

    _session_cls = _LMSession
    _service_name = "lm"

    def __init__(self, bundle, params, *, n_slots: int = 8,
                 seq_cap: int = 512, t_chunk: int = 16,
                 max_sessions: int | None = None, prefill_chunk: int = 64,
                 mesh=None, cost_fn=None, stale_window: int = 0,
                 metrics=None, tracer=None,
                 device_counters: bool | None = None,
                 paged: bool | None = None, block_len: int = 16,
                 n_blocks: int | None = None, prefix_cache: bool = True,
                 runtime: RuntimeConfig | None = None):
        if cost_fn is None:
            cost_fn = self._park_cost  # O(pos) bytes: cost-aware by default
        super().__init__(n_slots, t_chunk=t_chunk, max_sessions=max_sessions,
                         cost_fn=cost_fn, stale_window=stale_window,
                         metrics=metrics, tracer=tracer,
                         device_counters=device_counters, runtime=runtime)
        self.bundle = bundle
        self.seq_cap = int(seq_cap)
        self._params = params
        # per-leaf session/sequence axes by eval_shape diffing — never by
        # matching concrete extents that might coincide with n_slots
        self._batch_axes = leaf_axes(
            lambda: bundle.empty_cache(n_slots, seq_cap),
            lambda: bundle.empty_cache(n_slots + 1, seq_cap))
        self._seq_axes = leaf_axes(
            lambda: bundle.empty_cache(n_slots, seq_cap),
            lambda: bundle.empty_cache(n_slots, seq_cap + 1))
        for ax in jax.tree.leaves(self._batch_axes):
            if ax < 0:
                raise ValueError("cache has a leaf without a per-session "
                                 "axis; cannot virtualize slots")
        # true chunked prefill: only where EVERY cache leaf is
        # position-indexed (a seq axis to write rows into).  Recurrent
        # leaves (RWKV wkv state, Mamba conv/ssm state) advance by value
        # through a reassociated chunk recurrence — not bit-identical to
        # per-token stepping — so those families keep the forced-token
        # scan prefill (still dispatch-amortized by t_chunk).
        self.parallel_safe = all(
            sax >= 0 for sax in jax.tree.leaves(self._seq_axes))
        # paged slot memory: seq-axis leaves become shared block pools
        # read through per-lane int32 block tables (ROADMAP: the capacity
        # lever).  Bundles with no seq-axis leaf at all (pure recurrent —
        # RWKV) have nothing to page and silently stay dense.
        paged = self.runtime.pick("paged", paged)
        self.paged = bool(paged) and any(
            sax >= 0 for sax in jax.tree.leaves(self._seq_axes))
        self.block_len = int(block_len)
        if self.paged and mesh is not None:
            raise ValueError("paged=True does not compose with mesh= "
                             "sharding yet; use the dense layout on meshes")
        if self.paged and self.seq_cap % self.block_len:
            raise ValueError(f"seq_cap={self.seq_cap} must be a multiple "
                             f"of block_len={self.block_len}")
        self.cache = bundle.empty_cache(n_slots, seq_cap)
        if self.paged:
            self.max_blocks = self.seq_cap // self.block_len
            # default pool = the dense layout's byte budget (n_slots full
            # columns); heavy-tailed real lengths then fit many times more
            # resident sessions in the same bytes (the capacity bench)
            self.pool = BlockPool(int(n_blocks) if n_blocks is not None
                                  else n_slots * self.max_blocks)
            self._paged_flags = paged_flags(self._batch_axes, self._seq_axes)
            self._all_paged = all(jax.tree.leaves(self._paged_flags))
            self.cache = make_pools(self.cache, self._batch_axes,
                                    self._seq_axes, self.pool.extent,
                                    self.block_len)
            self._table = np.zeros((n_slots, self.max_blocks), np.int32)
            self._blocks: dict[int, list[int]] = {}
            # exact-prefix CoW sharing needs every leaf paged: a recurrent
            # leaf cannot skip prompt steps by adopting cache rows
            self._prefix = (PrefixCache(self.pool)
                            if prefix_cache and self.parallel_safe else None)
            reg = self.metrics_registry
            reg.gauge("pool_blocks_total", service=self._service_name).set(
                self.pool.n_blocks)
            self._g_pool_free = reg.gauge(
                "pool_blocks_free", service=self._service_name)
            self._g_pool_live = reg.gauge(
                "pool_blocks_live", service=self._service_name)
            self._g_pool_shared = reg.gauge(
                "pool_blocks_cow_shared", service=self._service_name)
            self._update_pool_gauges()
        # closed-form parked-footprint coefficients (the eviction cost_fn
        # runs per victim candidate on every bind — no re-tracing there)
        self._park_fixed = self._park_per_pos = 0
        for leaf, bax, sax in zip(
                jax.tree.leaves(jax.eval_shape(
                    lambda: bundle.empty_cache(n_slots, self.seq_cap))),
                jax.tree.leaves(self._batch_axes),
                jax.tree.leaves(self._seq_axes)):
            per = leaf.size // leaf.shape[bax] * leaf.dtype.itemsize
            if sax >= 0:
                self._park_per_pos += per // self.seq_cap
            else:
                self._park_fixed += per
        self.outputs: dict[int, list[int]] = {}
        # the un-jitted scan stays reachable so the speculative decoder and
        # the instrumented twin below wrap the SAME program body
        if self.paged:
            self._decode_scan_raw = make_decode_scan_paged(
                bundle.decode_fn, self._batch_axes, self._seq_axes,
                self.block_len)
        else:
            self._decode_scan_raw = make_decode_scan(
                bundle.decode_fn, self._batch_axes, self._seq_axes)
        self._decode_scan = jax.jit(self._decode_scan_raw)
        # instrumented twin: identical scan + one in-jit reduce of the
        # per-lane step counts (obs.device) as an extra output — session
        # state and tokens stay bit-identical (tests/test_obs.py)
        self._decode_scan_inst = None
        if self.device_counters:
            raw = self._decode_scan_raw
            if self.paged:
                def _inst(params, cache, tables, tok, pos, inp, n_inp,
                          n_steps):
                    cache, tok, pos, ys = raw(params, cache, tables, tok,
                                              pos, inp, n_inp, n_steps)
                    return (cache, tok, pos, ys,
                            occupancy_stats(n_steps, inp.shape[1]))
            else:
                def _inst(params, cache, tok, pos, inp, n_inp, n_steps):
                    cache, tok, pos, ys = raw(params, cache, tok, pos, inp,
                                              n_inp, n_steps)
                    return (cache, tok, pos, ys,
                            occupancy_stats(n_steps, inp.shape[1]))

            self._decode_scan_inst = jax.jit(_inst)
        step_fn = getattr(bundle, "step_fn", None)
        self.prefill_chunk = (int(prefill_chunk)
                              if prefill_chunk and self.parallel_safe
                              and step_fn is not None else 0)
        if self.prefill_chunk:
            self._prefill_col = jax.jit(
                make_prefill_paged(step_fn, self._batch_axes, self.block_len)
                if self.paged
                else make_prefill_column(step_fn, self._batch_axes))
        if mesh is not None:  # shard the session axis of every leaf -> data
            from jax.sharding import NamedSharding
            specs = column_pspecs(
                jax.eval_shape(lambda: bundle.empty_cache(n_slots, seq_cap)),
                self._batch_axes, mesh)
            self.cache = jax.device_put(
                self.cache, jax.tree.map(lambda p: NamedSharding(mesh, p),
                                         specs))
        self.mesh = mesh

    # -- block-pool management (paged only) ---------------------------------
    def _update_pool_gauges(self) -> None:
        self._g_pool_free.set(self.pool.n_free)
        self._g_pool_live.set(self.pool.n_live)
        self._g_pool_shared.set(self.pool.n_shared)

    def _device_table(self):
        """The per-lane block tables as a device array — rebuilt per
        dispatch from the int32 host mirror (tiny: n_slots x max_blocks)."""
        return jnp.asarray(self._table)

    def _alloc_blocks(self, n: int) -> list[int]:
        """O(1)-per-block allocation; a dry pool first reclaims LRU
        prefix-registry pins (blocks no live session shares).  All-or-
        nothing: on exhaustion the partial allocation is rolled back and
        PoolExhausted (an AdmissionError) propagates — paged capacity
        pressure surfaces as admission back-pressure, never as a silent
        eviction storm."""
        out: list[int] = []
        try:
            for _ in range(n):
                while (self.pool.n_free == 0 and self._prefix is not None
                       and self._prefix.release_lru()):
                    pass
                out.append(self.pool.alloc())
        except PoolExhausted:
            for b in reversed(out):
                self.pool.free(b)
            raise PoolExhausted(
                f"block pool exhausted ({self.pool.n_live}/"
                f"{self.pool.n_blocks} blocks live, "
                f"{len(self.sched.slot_of)} bound sessions); close or park "
                f"sessions, or grow n_blocks") from None
        return out

    def _ensure_blocks(self, sid: int, start: int, end: int) -> None:
        """Grow ``sid``'s table to cover cache rows [0, end) and make the
        write range [start, end) exclusively owned (copy-on-write: a
        shared block is cloned into a fresh one before the lane may write
        it, so prefix-sharing tenants never see each other's bytes)."""
        if not self.paged or end <= 0:
            return
        bl = self.block_len
        need = min(-(-end // bl), self.max_blocks)
        bids = self._blocks.setdefault(sid, [])
        slot = self.sched.slot_of[sid]
        while len(bids) < need:
            bid = self._alloc_blocks(1)[0]
            self._table[slot, len(bids)] = bid
            bids.append(bid)
        for i in range(max(start // bl, 0), need):
            nb, src = self.pool.writable(bids[i])
            if src is not None:  # shared: clone device bytes src -> nb
                self.cache = jax.tree.map(
                    lambda a, pg, bax:
                        copy_block(a, src, nb, bax) if pg else a,
                    self.cache, self._paged_flags, self._batch_axes)
                bids[i] = nb
                self._table[slot, i] = nb
        self._update_pool_gauges()

    def _trim_blocks(self, sid: int) -> None:
        """Free blocks wholly past the session's position — the paged
        form of rejected-suffix rollback (the dense path scrubs rows by
        position; the paged path returns whole blocks to the pool)."""
        if not self.paged:
            return
        bids = self._blocks.get(sid)
        if not bids:
            return
        keep = min(len(bids), -(-self.sessions[sid].steps // self.block_len))
        if keep == len(bids):
            return
        for b in bids[keep:]:
            self.pool.free(b)
        del bids[keep:]
        slot = self.sched.slot_of.get(sid)
        if slot is not None:
            self._table[slot, keep:] = 0
        self._update_pool_gauges()

    def _free_session_blocks(self, sid: int) -> None:
        for b in self._blocks.pop(sid, []):
            self.pool.free(b)

    # -- slot-column state hooks --------------------------------------------
    def _pack(self, slot: int, sid: int) -> dict:
        sess = self.sessions[sid]
        if not self.paged:
            return {"kv": pack_column(self.cache, self._batch_axes, slot,
                                      trunc_axes=self._seq_axes,
                                      trunc_len=sess.steps)}
        # paged park: gather ONLY the blocks covering [0, steps) — the
        # O(pos) truncation contract, now block-granular — then free the
        # session's device blocks (a parked session owns none; resume
        # allocates fresh ones, content is position-independent through
        # the table).  The blob carries a [block_len, n_keep] marker so a
        # differently-paged or dense service refuses it atomically.
        bids = self._blocks.get(sid, [])
        n_keep = min(len(bids), -(-sess.steps // self.block_len))
        keep = bids[:n_keep]
        blob = jax.tree.map(
            lambda a, bax, pg:
                (pack_blocks(a, keep, bax) if pg
                 else np.asarray(a[(slice(None),) * bax + (slot,)])),
            self.cache, self._batch_axes, self._paged_flags)
        self._free_session_blocks(sid)
        self._update_pool_gauges()
        return {"kv": blob,
                PAGED_MARKER: np.asarray([self.block_len, n_keep], np.int32)}

    def _unpack(self, slot: int, blob: dict) -> None:
        if not self.paged:
            self.cache = unpack_column(self.cache, self._batch_axes, slot,
                                       blob["kv"])
            return
        sid = self.sched.sid_of[slot]
        n_keep = int(np.asarray(blob[PAGED_MARKER]).reshape(-1)[1])
        bids = self._alloc_blocks(n_keep)
        self._blocks[sid] = bids
        self._table[slot, :] = 0
        self._table[slot, :n_keep] = bids

        def put(a, bax, pg, p):
            if pg:
                return unpack_blocks(a, bids, p, bax)
            col = np.asarray(p)
            if col.dtype != a.dtype and col.dtype.itemsize == a.dtype.itemsize:
                col = col.view(a.dtype)  # npz round trip loses exotic dtypes
            return a.at[(slice(None),) * bax + (slot,)].set(
                jnp.asarray(col, a.dtype))

        self.cache = jax.tree.map(put, self.cache, self._batch_axes,
                                  self._paged_flags, blob["kv"])
        self._update_pool_gauges()

    def _reset(self, slot: int) -> None:
        if not self.paged:
            self.cache = jax.tree.map(
                lambda a, ax: a.at[(slice(None),) * ax + (slot,)].set(0),
                self.cache, self._batch_axes)
            return
        # O(1) admission: clearing the table row (host int32) makes every
        # stale pool byte unreachable — reads past a lane's kv_len are
        # -inf-masked inside attention and the NULL block absorbs masked
        # writes, so no device scrub is needed (the same discipline that
        # keeps stale dense rows after speculative rollback unobservable)
        self._blocks[self.sched.sid_of[slot]] = []
        self._table[slot, :] = 0
        if not self._all_paged:  # recurrent leaves are value-carried: zero
            self.cache = jax.tree.map(
                lambda a, bax, pg:
                    a if pg
                    else a.at[(slice(None),) * bax + (slot,)].set(0),
                self.cache, self._batch_axes, self._paged_flags)

    def _on_unbind(self, slot: int) -> None:
        # an unbound slot's table row must be all-NULL: a masked lane's
        # per-step write follows its row, and only NULL may absorb it
        if self.paged:
            self._table[slot, :] = 0

    def _on_close(self, sid: int, sess) -> None:
        if self.paged:
            self._free_session_blocks(sid)
            self._update_pool_gauges()

    # -- cost model ---------------------------------------------------------
    def _park_cost(self, sid: int) -> float:
        """Host bytes this session would occupy parked: O(pos) — the
        non-uniform cost the eviction policy trades against staleness.
        Paged parking is block-granular, so the cost rounds up to the
        owned-block boundary."""
        steps = self.sessions[sid].steps
        if self.paged:
            steps = -(-steps // self.block_len) * self.block_len
        return float(self.kv_park_bytes(steps))

    def kv_park_bytes(self, pos: int) -> int:
        """STRUCTURAL parked footprint of a KV session at position ``pos``
        (content-independent): sequence-axis leaves scale with pos, fixed
        leaves (recurrent states, cross caches) count whole."""
        return self._park_fixed + self._park_per_pos * min(pos, self.seq_cap)

    # -- session lifecycle --------------------------------------------------
    def open_session(self, prompt) -> int:
        """Admit a request and (on KV bundles) chunk-prefill its prompt.

        With ``prefill_chunk`` active, all but the last prompt token are
        fed HERE through multi-token cached steps in a largest-first pow2
        chunk schedule (``pow2_chunks``) — causal attention over each whole
        chunk amortizes the prompt math.  The final prompt token stays
        pending so the first sampled token still comes from the decode
        scan's exact S=1 program; on recurrent bundles the whole prompt is
        fed lazily by the first ``decode`` (forced-token scan steps), as
        before."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size >= self.seq_cap:
            raise ValueError(f"prompt of {prompt.size} tokens >= "
                             f"seq_cap={self.seq_cap}")
        sid = self._alloc_sid()
        self.sched.admit(sid)  # may raise AdmissionError (back-pressure)
        self.sessions[sid] = _LMSession(prompt=prompt)
        self.outputs[sid] = []
        try:
            self._bind(sid)
            if self.prefill_chunk and prompt.size > 1:
                self._prefill_prompt(sid, prompt)
        except PoolExhausted:
            # all-or-nothing admission: unwind every trace of the session
            # (blocks, slot, records) before re-raising the back-pressure
            self._abort_open(sid)
            raise
        return sid

    def _abort_open(self, sid: int) -> None:
        self._free_session_blocks(sid)
        slot = self.sched.release(sid)
        if slot is not None:
            self._on_unbind(slot)
        self.sessions.pop(sid, None)
        self.outputs.pop(sid, None)
        self._update_pool_gauges()

    def _prefill_prompt(self, sid: int, prompt: np.ndarray) -> None:
        slot = self.sched.slot_of[sid]
        off = 0
        if self.paged and self._prefix is not None:
            # copy-on-write prefix sharing: full prompt blocks already in
            # the registry (common system prompts across tenants) map to
            # the same physical blocks — their prefill chunks are skipped
            off = self._adopt_prefix(sid, slot, prompt)
            self.sessions[sid].steps = off
        for n in pow2_chunks(prompt.size - 1 - off, self.prefill_chunk):
            t0 = time.perf_counter()
            with self.tracer.span("prefill", cat="lm", sid=sid,
                                  shape=f"P{n}", pos=off):
                toks = jnp.asarray(prompt[off:off + n])[None]
                if self.paged:
                    self._ensure_blocks(sid, off, off + n)
                    self.cache = self._prefill_col(
                        self._params, self.cache,
                        jnp.asarray(self._table[slot]), toks, jnp.int32(off))
                else:
                    self.cache = self._prefill_col(
                        self._params, self.cache, jnp.int32(slot), toks,
                        jnp.int32(off))
            self._record_dispatch(time.perf_counter() - t0, f"P{n}")
            off += n
        self.sessions[sid].steps = off
        if self.paged and self._prefix is not None:
            self._register_prefix(sid, prompt)

    def _adopt_prefix(self, sid: int, slot: int, prompt) -> int:
        """Longest-prefix match of the prompt body's FULL blocks against
        the registry; hits are adopted by reference (no prefill compute,
        no new bytes).  Returns the adopted position offset."""
        keys = prefix_keys(prompt[:-1], self.block_len)
        hits = self._prefix.match(keys) if keys else []
        if not hits:
            return 0
        bids = self._blocks.setdefault(sid, [])
        bids.extend(hits)
        self._table[slot, :len(hits)] = hits
        self.metrics_registry.counter(
            "prefix_block_hits_total", service=self._service_name).inc(
                len(hits))
        self._update_pool_gauges()
        return len(hits) * self.block_len

    def _register_prefix(self, sid: int, prompt) -> None:
        """Register the session's full prompt-body blocks so later
        tenants with the same prefix share them (each entry pins its
        block with a registry reference, surviving the donor's park)."""
        keys = prefix_keys(prompt[:-1], self.block_len)
        for key, bid in zip(keys, self._blocks.get(sid, [])):
            self._prefix.insert(key, bid)
        self._update_pool_gauges()

    def _retire(self, sid: int) -> None:
        """Take a session that hit seq_cap out of rotation: slot freed for
        reuse, outputs kept, record marked done (a further decode raises).
        Paged grids also return the session's blocks to the pool and
        NULL the table row before the slot can host a masked lane."""
        if self.paged:
            slot = self.sched.slot_of.get(sid)
            if slot is not None:
                self._table[slot, :] = 0
            self._free_session_blocks(sid)
            self._update_pool_gauges()
        self.sched.release(sid)
        self.sessions[sid].done = True
        self.metrics_registry.counter("retired_total", service="lm").inc()
        self.tracer.instant("retire", cat="lm", sid=sid,
                            pos=self.sessions[sid].steps)

    # -- the hot path -------------------------------------------------------
    def _validate_want(self, want: dict[int, int]) -> None:
        """The decode admission contract — shared with the speculative
        decoder (sessions/spec.py) so the two paths cannot drift."""
        if len(want) > self.n_slots:
            raise ValueError(
                f"{len(want)} sessions pushed but only {self.n_slots} slots; "
                "split the decode or grow the grid")
        for sid, n in want.items():
            if sid not in self.sessions:
                raise KeyError(f"unknown session {sid}")
            if self.sessions[sid].done:
                raise RuntimeError(f"session {sid} retired at "
                                   f"seq_cap={self.seq_cap}")
            if n < 0:
                raise ValueError(f"session {sid}: want {n} < 0")

    def decode(self, want: dict[int, int]) -> dict[int, list[int]]:
        """Greedily generate ``want[sid]`` tokens per session.

        All pushed sessions advance through chunked ``decode_scan``
        dispatches over the compiled (S, T_chunk) grid (power-of-two
        padding buckets, like push_audio); absent sessions stay bit-frozen.
        Parked sessions are resumed first (possibly evicting idle ones).
        A session whose position would pass ``seq_cap`` is truncated to the
        cap and retired.  Returns {sid: newly generated tokens}."""
        self._validate_want(want)
        self._touch_and_bind(want)

        # steps to run per lane: feed the prompt remainder, then generate.
        # Emission invariant: with Q = max(len(prompt), 1), step f emits a
        # token iff f >= Q - 1, so generated = max(0, fed - Q + 1).
        remaining = {}
        for sid, n in want.items():
            sess = self.sessions[sid]
            q = max(len(sess.prompt), 1)
            gen = max(0, sess.steps - q + 1)
            steps = gen + n + q - 1 - sess.steps
            steps = min(steps, self.seq_cap - sess.steps)  # overflow guard
            remaining[sid] = max(steps, 0)

        out = {sid: [] for sid in want}
        while any(remaining.values()):
            t_pad = self._tick_len(max(remaining.values()))
            inp = np.zeros((self.n_slots, t_pad), np.int32)
            n_inp = np.zeros(self.n_slots, np.int32)
            n_steps = np.zeros(self.n_slots, np.int32)
            tok = np.zeros(self.n_slots, np.int32)
            pos = np.zeros(self.n_slots, np.int32)
            # every BOUND lane must carry its true position even when it is
            # not decoded this tick: a masked step still writes (discarded)
            # k/v at row pos, which is only harmless at the lane's own
            # frozen position (rewritten before its next read) — at pos 0
            # it would corrupt live history (decode_scan's masking rule)
            for slot, bsid in self.sched.sid_of.items():
                pos[slot] = min(self.sessions[bsid].steps, self.seq_cap - 1)
            lanes = {}
            for sid, rem in remaining.items():
                if rem == 0:
                    continue
                sess = self.sessions[sid]
                s = self.sched.slot_of[sid]
                lanes[sid] = s
                n = min(rem, t_pad)
                if self.paged:  # CoW-safe blocks for this tick's writes
                    self._ensure_blocks(sid, sess.steps, sess.steps + n)
                feed = sess.prompt[sess.steps : sess.steps + n]
                inp[s, :feed.size] = feed
                n_inp[s] = feed.size
                n_steps[s] = n
                tok[s] = sess.tok
                pos[s] = sess.steps
            scan = self._decode_scan_inst or self._decode_scan
            shape = f"T{t_pad}"
            t0 = time.perf_counter()
            with self.tracer.span("dispatch", cat="lm", shape=shape,
                                  lanes=len(lanes)):
                args = ((self._device_table(),) if self.paged else ()) + (
                    jnp.asarray(tok), jnp.asarray(pos), jnp.asarray(inp),
                    jnp.asarray(n_inp), jnp.asarray(n_steps))
                self.cache, tok2, _, ys, *dev = scan(
                    self._params, self.cache, *args)
                tok2, ys = np.asarray(tok2), np.asarray(ys)
            self._record_dispatch(time.perf_counter() - t0, shape)
            if dev:
                self._ingest_occupancy(np.asarray(dev[0]))
            for sid, s in lanes.items():
                sess = self.sessions[sid]
                q = max(len(sess.prompt), 1)
                n = int(n_steps[s])
                emitted = [int(ys[s, j]) for j in range(n)
                           if sess.steps + j >= q - 1]
                self.outputs[sid].extend(emitted)
                out[sid].extend(emitted)
                sess.steps += n
                sess.tok = int(tok2[s])
                remaining[sid] -= n
                sess.last = {"tokens": emitted, "step": sess.steps}
        for sid in want:
            if self.sessions[sid].steps >= self.seq_cap:
                self._retire(sid)
        return out

    # protocol verb (sessions.SessionService): the LM payload is a token
    # budget per session
    push = decode

    # -- persistence hooks ---------------------------------------------------
    def _session_spill_meta(self, sid: int) -> dict:
        s = self.sessions[sid]
        return {"steps": int(s.steps), "tok": int(s.tok),
                "prompt": np.asarray(s.prompt).tolist(),
                "outputs": self.outputs.get(sid, [])}

    def _restore_session(self, info: dict):
        return _LMSession(steps=int(info.get("steps", 0)),
                          tok=int(info.get("tok", 0)),
                          prompt=np.asarray(info.get("prompt", []), np.int32))

    def _spill_extra(self) -> dict:
        if not self.paged:
            return {}
        return {"paged": {"block_len": self.block_len,
                          "n_blocks": self.pool.n_blocks}}

    def _restore_validate(self, parking: dict, meta: dict) -> None:
        """All-or-nothing gate: a spill from an incompatible service (longer
        seq_cap, different cache geometry, different PAGING geometry) must
        be refused BEFORE any mutation, not crash mid-_bind on the first
        decode — a half-admitted paged spill would leak pool blocks."""
        pm = (meta or {}).get("paged")
        if not self.paged and pm is not None:
            raise ValueError(
                f"incompatible LM spill: paged-layout spill (block_len="
                f"{pm.get('block_len')}, n_blocks={pm.get('n_blocks')}) "
                f"offered to a dense-layout service")
        if self.paged and pm is not None and (
                int(pm.get("block_len", -1)) != self.block_len
                or int(pm.get("n_blocks", 0)) > self.pool.n_blocks):
            raise ValueError(
                f"incompatible LM spill: pool geometry (block_len="
                f"{pm.get('block_len')}, n_blocks={pm.get('n_blocks')}) "
                f"does not fit this service's (block_len={self.block_len}, "
                f"n_blocks={self.pool.n_blocks})")
        for sid, blob in parking.items():
            info = meta.get("sessions", {}).get(str(sid), {})
            self._validate_blob(sid, blob, info)

    def _validate_blob(self, sid, blob: dict, info: dict) -> None:
        """One parked blob's geometry checks — shared by the bulk restore
        gate above and the single-session ``adopt_session`` path the
        serving plane's drain/recover handoff rides."""
        if int(info.get("steps", 0)) > self.seq_cap:
            raise ValueError(
                f"session {sid} parked at position {info.get('steps')} "
                f"> this service's seq_cap={self.seq_cap}")
        pv = blob.get(PAGED_MARKER) if isinstance(blob, dict) else None
        if self.paged != (pv is not None):
            raise ValueError(
                f"incompatible LM spill: session {sid} blob is "
                f"{'paged' if pv is not None else 'dense'}-layout but "
                f"this service is "
                f"{'paged' if self.paged else 'dense'}-layout")
        if self.paged:
            bl, n_keep = (int(x) for x in
                          np.asarray(pv).reshape(-1)[:2])
            if bl != self.block_len:
                raise ValueError(
                    f"incompatible LM spill: session {sid} parked with "
                    f"block_len={bl} != this service's {self.block_len}")
            if n_keep > self.max_blocks:
                raise ValueError(
                    f"incompatible LM spill: session {sid} owns "
                    f"{n_keep} blocks > this service's per-session max "
                    f"{self.max_blocks}")

            def check_paged(a, bax, pg, p):
                got = np.asarray(p).shape
                want = ((a.shape[:bax] + (n_keep,) + a.shape[bax + 1:])
                        if pg else a.shape[:bax] + a.shape[bax + 1:])
                if got != want:
                    raise ValueError(
                        f"session {sid}: parked cache leaf {got} does "
                        f"not fit this service's "
                        f"{'pool blocks' if pg else 'column'} {want}")
                return None

            try:
                jax.tree.map(check_paged, self.cache, self._batch_axes,
                             self._paged_flags, blob["kv"])
            except (KeyError, ValueError, TypeError) as e:
                raise ValueError(f"incompatible LM spill: {e}") from e
            return

        def check(a, bax, sax, p):
            want = a.shape[:bax] + a.shape[bax + 1:]
            got = np.asarray(p).shape
            t = sax - (sax > bax) if sax >= 0 else -1
            ok = len(got) == len(want) and all(
                (g <= w if i == t else g == w)
                for i, (g, w) in enumerate(zip(got, want)))
            if not ok:
                raise ValueError(
                    f"session {sid}: parked cache leaf {got} does not "
                    f"fit this service's column {want}")
            return None

        try:
            jax.tree.map(check, self.cache, self._batch_axes,
                         self._seq_axes, blob["kv"])
        except (KeyError, ValueError, TypeError) as e:
            raise ValueError(f"incompatible LM spill: {e}") from e

    def _adopt_validate(self, blob: dict, meta: dict) -> None:
        # single-session handoff from a peer worker: same geometry gate as
        # the bulk restore, against the incoming session's own meta
        self._validate_blob("<adopting>", blob, meta)

    def _on_adopt(self, sid: int, meta: dict) -> None:
        self.outputs[sid] = [int(t) for t in meta.get("outputs", [])]

    def _on_detach(self, sid: int) -> None:
        # the peer rebuilds outputs from the handoff meta; keeping the
        # stale list here would just leak across a long churn
        self.outputs.pop(sid, None)

    def _post_restore(self, restored: list[int], meta: dict) -> None:
        # generated outputs live outside the session record so they survive
        # close/retire; rebuild them from the spill meta
        for sid in restored:
            info = meta.get("sessions", {}).get(str(sid), {})
            self.outputs[sid] = [int(t) for t in info.get("outputs", [])]

    # -- introspection ------------------------------------------------------
    @property
    def slot_pos(self) -> np.ndarray:
        """Per-slot int32 positions (0 for free slots) — the host mirror the
        historical LMServer exposed as ``pos``."""
        pos = np.zeros(self.n_slots, np.int32)
        for slot, sid in self.sched.sid_of.items():
            pos[slot] = self.sessions[sid].steps
        return pos

    def poll(self, sid: int) -> dict:
        sess = self.sessions[sid]
        state = ("done" if sess.done else
                 "active" if self.sched.is_bound(sid) else "parked")
        return {"state": state, "slot": self.sched.slot_of.get(sid),
                "steps": sess.steps,
                "prompt_left": max(0, len(sess.prompt) - sess.steps),
                "generated": len(self.outputs.get(sid, [])),
                "last": sess.last}

    def _slot_state_bytes(self) -> int:
        # structural footprint of one full slot column (pos = seq_cap)
        return self.kv_park_bytes(self.seq_cap)

    def _extra_stats(self) -> dict:
        out = {"seq_cap": self.seq_cap,
               "parked_cost_by_sid": {sid: self._park_cost(sid)
                                      for sid in self.parking}}
        if self.paged:
            out["paged"] = {
                "block_len": self.block_len,
                "n_blocks": self.pool.n_blocks,
                "blocks_free": self.pool.n_free,
                "blocks_live": self.pool.n_live,
                "blocks_cow_shared": self.pool.n_shared,
                "prefix_entries":
                    len(self._prefix) if self._prefix is not None else 0,
            }
        return out
