"""Structure-of-arrays session state for the multi-tenant streaming layer.

A *slot grid* stacks ``n_slots`` independent single-session streaming
pytrees (core/streaming.stream_init_single) leaf-wise: rings become
(S, n, c), step counters (S,).  One ``jax.vmap`` of ``stream_step_single``
advances every slot in a single jitted call — the batched math is identical
to ``stream_step`` but each slot keeps its OWN step counter, so sessions
admitted at different wall-clock times stay phase-correct.

``grid_scan`` is the chunk-native hot path: ``vmap(stream_scan_single)``
runs a ``lax.scan`` over a whole (S, T, C_in) time chunk inside ONE jitted
dispatch — S×T samples per host↔device round trip instead of S.  Ragged
per-slot chunk lengths become a (S, T) validity mask (``lengths_to_valid``)
so short chunks pad to the compiled T without perturbing any stream.

Inactive slots / invalid steps are *bit-frozen*: the vmapped step still
computes them (the compiled shape is fixed — that is the whole point, no
recompiles as sessions come and go), but a ``jnp.where`` on the mask
discards their writes, so a parked/free slot's state is exactly the state
at its last active step.

``pack_slot``/``unpack_slot`` move one slot's column of the SoA to/from host
memory (numpy) — the parking lot for evicted sessions.  Because a session's
state is position-independent (no leaf encodes the slot index), a parked
session can resume in ANY free slot bit-identically.  With ``pack_u4=True``
ring leaves that sit exactly on the u4 fake-quant grid (the quantized
service's case) are stored as packed nibbles — ~8x fewer parking-lot bytes,
still bit-identical on resume (exactness is *verified per leaf* at pack
time; non-representable leaves, e.g. block 0's raw-input ring, stay fp32).

``grid_pspecs`` shards the slot axis over the mesh's ``data`` axis through
the same logical-axis rules table the rest of the codebase uses
(sharding/rules: "slots" -> "data", "tenants" -> "model"), so one service
spans a mesh without recompiles; on a 1-device mesh everything degenerates
to replicated and the service runs unchanged.

``leaf_axes``/``pack_column``/``unpack_column`` generalize the parking-lot
machinery to state pytrees whose per-session axis is NOT leading — an LM
KV cache stacks sessions on axis 1 of (L, B, S, H, Dh) leaves.  The axis
tree is derived by shape-diffing two ``eval_shape`` builds (never by
sniffing concrete extents that might coincide), and KV columns are
truncated to the session's live positions on pack, so a parked KV blob
costs O(pos) host bytes — the genuinely non-uniform per-session cost the
scheduler's cost-aware eviction exploits (sessions/lm.py).

``zero_from_column`` is the position-range rollback helper (scrub a
column's sequence rows >= a position back to canonical zeros — what a
park+resume round trip would rebuild), and ``column_pspecs`` is
``grid_pspecs`` for those arbitrary-axis grids: each leaf's session axis
goes to the "slots" rule's mesh axis, so the LM grid mesh-shards exactly
like the TCN grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import (
    make_fused_chunk,
    stream_init_single,
    stream_scan_single,
    stream_step_single,
)
from repro.models.config import ArchConfig
from repro.sharding.rules import DEFAULT_RULES, pspec_sized, resolve_rules


def grid_init(cfg: ArchConfig, n_slots: int, dtype=jnp.float32) -> dict:
    """Stacked session state: every single-session leaf gains a leading
    (n_slots,) axis."""
    one = stream_init_single(cfg, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((n_slots,) + a.shape, a.dtype), one)


def grid_step(params, bn_state, cfg: ArchConfig, states: dict, x: jax.Array,
              active: jax.Array, *, quantize: bool = False):
    """Advance all S slots one sample.  x: (S, C_in); active: (S,) bool.

    Returns (new_states, emb (S, V), logits (S, n_classes)).  Slots with
    active=False keep their previous state bit-exactly (outputs for those
    rows are computed but meaningless — callers mask them)."""
    step = lambda st, xt: stream_step_single(
        params, bn_state, cfg, st, xt, quantize=quantize)
    stepped, emb, logits = jax.vmap(step)(states, x)
    keep = lambda new, old: jnp.where(
        jnp.reshape(active, active.shape + (1,) * (new.ndim - 1)), new, old)
    return jax.tree.map(keep, stepped, states), emb, logits


def lengths_to_valid(lengths, t_chunk: int) -> jax.Array:
    """Per-slot chunk lengths (S,) -> (S, T) step-validity mask."""
    return jnp.arange(t_chunk)[None, :] < jnp.asarray(lengths)[:, None]


def grid_scan(params, bn_state, cfg: ArchConfig, states: dict, x: jax.Array,
              valid: jax.Array, *, quantize: bool = False):
    """Advance all S slots over a T-sample chunk in ONE dispatch.

    x: (S, T, C_in); valid: (S, T) bool (``lengths_to_valid`` of the ragged
    per-slot lengths).  Returns (new_states, embs (S, T, V), logits
    (S, T, n_classes)).

    Bit-exactness contract: step t of a slot whose valid[s, :t+1] is all
    True matches the t-th of T sequential ``grid_step`` calls exactly
    (the scan body IS the vmapped single step; invalid steps freeze state
    through the same ``jnp.where`` discipline).  T=1 with valid=active
    recovers ``grid_step``.  When jitting, pass params/bn_state as jit
    ARGUMENTS (see stream_scan_single) so the contract holds across
    separately compiled chunk sizes."""
    scan1 = lambda st, xc, vc: stream_scan_single(
        params, bn_state, cfg, st, xc, vc, quantize=quantize)
    return jax.vmap(scan1)(states, x, valid)


def make_grid_fused(cfg: ArchConfig, *, quantize: bool = False,
                    backend: str | None = None):
    """Fused-kernel twin of ``grid_scan`` (kernel backend resolved ONCE).

    Returns ``fused(fused_params, states, x, lengths)`` over the same SoA
    slot grid: x (S, T, C_in), lengths (S,) valid-PREFIX lengths (ragged
    chunks are always prefixes of the padded tick — the (S, T) masks
    ``grid_scan`` takes are ``lengths_to_valid`` of these).  One fused
    block op per TCN block replaces the T-step scan body; ring taps feed
    the kernels directly (no per-chunk re-pad).  On baked params
    (models/tcn.bake_stream_params) outputs at positions < lengths and
    the end state are bit-identical to ``grid_scan``; pass fused_params
    as jit ARGUMENTS (same cross-program discipline)."""
    return make_fused_chunk(cfg, quantize=quantize, backend=backend)


def grid_pspecs(cfg: ArchConfig, mesh, n_slots: int, rules: dict | None = None):
    """PartitionSpec tree for the slot grid: the leading slot axis goes to
    the mesh axis the "slots" logical rule names (``data`` by default); all
    per-session dims stay replicated.  Divisibility-gated (pspec_sized):
    a grid that doesn't divide the data axis falls back to replicated, so
    the same service construction works on ANY mesh, including 1 device."""
    rules = resolve_rules(DEFAULT_RULES if rules is None else rules, mesh)
    one = jax.eval_shape(lambda: stream_init_single(cfg))

    def spec(leaf):
        shape = (n_slots,) + leaf.shape
        axes = ("slots",) + (None,) * leaf.ndim
        return pspec_sized(axes, rules, shape, mesh)

    return jax.tree.map(spec, one)


# ---------------------------------------------------------------------------
# Parking lot: host-side pack/unpack of one slot's column
# ---------------------------------------------------------------------------

_U4_KEY = "u4c"


def _is_packed(x) -> bool:
    return isinstance(x, dict) and _U4_KEY in x


def _pack_leaf_u4(a: np.ndarray, act_scale: float):
    """Pack one host leaf to nibbles IFF that is exactly invertible.

    The quantized service's ring contents are fake-quant u4 activations —
    values on the grid {0, s, 2s, ..., 15s} — so round(a/s) recovers the
    4-bit codes and ``codes * s`` rebuilds the identical fp32 bits.  The
    reconstruction is *checked here*; any leaf off the grid (block 0's
    ring1 holds the raw unquantized input) is left as-is, keeping
    park/resume unconditionally bit-identical."""
    a = np.asarray(a)
    if a.ndim < 1 or a.shape[-1] % 2 != 0 or a.dtype != np.float32:
        return None
    s = np.float32(act_scale)
    q = np.round(a / s)
    if not ((q >= 0) & (q <= 15)).all():
        return None
    if not np.array_equal(q.astype(np.float32) * s, a):
        return None
    u = q.astype(np.uint8)
    return {_U4_KEY: (u[..., 0::2] | (u[..., 1::2] << 4)).astype(np.uint8),
            "scale": s}


def _unpack_leaf(p) -> np.ndarray:
    if not _is_packed(p):
        return np.asarray(p)
    packed = np.asarray(p[_U4_KEY])
    s = np.float32(p["scale"])
    lo = packed & 0xF
    hi = packed >> 4
    q = np.stack([lo, hi], axis=-1).reshape(
        *packed.shape[:-1], packed.shape[-1] * 2)
    return q.astype(np.float32) * s


def pack_slot(states: dict, slot: int, *, pack_u4: bool = False,
              act_scale: float = 0.25) -> dict:
    """Copy one slot's session state to host memory (the parking lot).

    pack_u4=True additionally stores u4-grid ring leaves as packed nibbles
    (2 codes/byte) — the quantized service's ~8x parking-lot compression."""
    parked = jax.tree.map(lambda a: np.asarray(a[slot]), states)
    if not pack_u4:
        return parked

    def enc(a):
        p = _pack_leaf_u4(a, act_scale)
        return a if p is None else p

    return {"t": parked["t"], "blocks": jax.tree.map(enc, parked["blocks"])}


def decode_parked(parked: dict) -> dict:
    """Plain fp32-array view of a parked pytree (nibble leaves expanded)."""
    return jax.tree.map(_unpack_leaf, parked, is_leaf=_is_packed)


def unpack_slot(states: dict, slot: int, parked: dict) -> dict:
    """Restore a parked session into ``slot`` (any free slot works — state
    is slot-position independent).  Accepts raw or nibble-packed parkings."""
    return jax.tree.map(
        lambda a, p: a.at[slot].set(jnp.asarray(p, a.dtype)),
        states, decode_parked(parked))


def reset_slot(states: dict, slot: int) -> dict:
    """Zero one slot (fresh session: empty rings, t=0)."""
    return jax.tree.map(lambda a: a.at[slot].set(jnp.zeros_like(a[slot])),
                        states)


def parked_bytes(parked: dict) -> int:
    """Host bytes of one parked session (packed leaves count packed)."""
    return int(sum(np.asarray(a).nbytes for a in jax.tree.leaves(parked)))


def slot_state_bytes(states: dict) -> int:
    """Per-slot parked-state footprint in bytes (host copy of one column)."""
    n_slots = jax.tree.leaves(states)[0].shape[0]
    total = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(states))
    return total // n_slots


# ---------------------------------------------------------------------------
# Generalized columns: per-leaf session axes (LM KV caches and friends)
# ---------------------------------------------------------------------------

def leaf_axes(make_a, make_b):
    """Per-leaf axis tree: for each leaf, the first axis whose extent
    differs between ``jax.eval_shape(make_a)`` and ``jax.eval_shape(make_b)``
    (-1 where no axis differs).  Build the two trees with one structural
    parameter changed (B vs B+1 for the session axis, S vs S+1 for the
    sequence axis) — axis identity by construction, never by matching a
    concrete extent that might coincide with another dim."""
    sa, sb = jax.eval_shape(make_a), jax.eval_shape(make_b)

    def axis_of(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return -1

    return jax.tree.map(axis_of, sa, sb)


def _col_index(ax: int, slot: int) -> tuple:
    return (slice(None),) * ax + (slot,)


def pack_column(tree, axes, slot: int, *, trunc_axes=None, trunc_len=None,
                pack_u4: bool = False, act_scale: float = 0.25) -> dict:
    """Copy one session's column of an arbitrary SoA pytree to host memory.

    ``axes`` is the per-leaf session-axis tree (``leaf_axes``); every leaf
    must have one (ax >= 0).  With ``trunc_axes``/``trunc_len``, leaves that
    carry a sequence axis are sliced to their first ``trunc_len`` positions
    (a KV cache column is only populated up to the session's position, so a
    parked blob costs O(pos) bytes; leaves without a sequence axis — e.g.
    recurrent states — are kept whole).  ``pack_u4`` routes each leaf
    through the same exactness-checked nibble packer the TCN parking lot
    uses; leaves off the u4 grid stay raw, so the blob is unconditionally
    bit-identical on resume."""
    def enc(a, ax, tax):
        if ax < 0:
            raise ValueError("pack_column: leaf without a session axis")
        col = np.asarray(a[_col_index(ax, slot)])
        if tax is not None and tax >= 0 and trunc_len is not None:
            t = tax - (tax > ax)  # axis index after the session axis is gone
            col = np.ascontiguousarray(
                col[(slice(None),) * t + (slice(0, int(trunc_len)),)])
        if pack_u4:
            p = _pack_leaf_u4(col, act_scale)
            if p is not None:
                return p
        return col

    if trunc_axes is None:
        trunc_axes = jax.tree.map(lambda _: -1, axes)
    return jax.tree.map(enc, tree, axes, trunc_axes)


def unpack_column(tree, axes, slot: int, parked: dict):
    """Restore a ``pack_column`` blob into ``slot`` of ``tree`` (any free
    slot works — columns are slot-position independent).  Truncated leaves
    are zero-extended back to the compiled extent: positions past the
    parked length were never written by the per-lane decode, so zero is
    exactly the uninterrupted run's content."""
    def put(a, ax, p):
        col = np.asarray(p)
        if col.dtype != a.dtype and col.dtype.itemsize == a.dtype.itemsize:
            col = col.view(a.dtype)  # npz round trip loses exotic dtypes
        want = a.shape[:ax] + a.shape[ax + 1:]
        if col.shape != want:  # zero-extend a truncated sequence axis
            full = np.zeros(want, col.dtype)
            full[tuple(slice(0, s) for s in col.shape)] = col
            col = full
        return a.at[_col_index(ax, slot)].set(jnp.asarray(col, a.dtype))

    return jax.tree.map(put, tree, axes, decode_parked(parked))


def zero_from_column(tree, axes, seq_axes, slot: int, start):
    """Zero one session's sequence rows at positions >= ``start``.

    The position-range rollback helper: after a speculative verify writes
    K+1 rows of which only m+1 were accepted, the rejected tail
    [start, seq_cap) of the slot's column is scrubbed so the device cache
    is CANONICAL — bit-identical to what a park (O(pos) truncation) +
    resume (zero-extension) of the same session would rebuild.  Leaves
    without a sequence axis (recurrent states) are untouched: their
    rollback is by carried VALUE inside the verify program itself, never
    by position.  ``start`` may be a traced int32 (one compiled program
    serves every rollback position)."""
    start = jnp.asarray(start, jnp.int32)

    def scrub(a, bax, sax):
        if sax < 0:
            return a
        t = sax - (sax > bax)  # seq axis index within the column
        col = a[_col_index(bax, slot)]
        pos = jnp.arange(col.shape[t])
        keep = (pos < start).reshape(
            (1,) * t + (-1,) + (1,) * (col.ndim - t - 1))
        return a.at[_col_index(bax, slot)].set(
            jnp.where(keep, col, jnp.zeros((), a.dtype)))

    return jax.tree.map(scrub, tree, axes, seq_axes)


def column_pspecs(tree_shapes, batch_axes, mesh, rules: dict | None = None):
    """PartitionSpec tree for an arbitrary-axis slot grid: each leaf's
    per-session axis (``batch_axes``, from ``leaf_axes``) goes to the mesh
    axis the "slots" logical rule names (``data`` by default); every other
    dim stays replicated.  The LM KV-cache analog of ``grid_pspecs`` —
    sessions there live on axis 1 of (L, B, S, H, Dh) leaves, not axis 0.
    Divisibility-gated (pspec_sized), so construction works on ANY mesh."""
    rules = resolve_rules(DEFAULT_RULES if rules is None else rules, mesh)

    def spec(leaf, bax):
        axes = tuple("slots" if i == bax else None for i in range(leaf.ndim))
        return pspec_sized(axes, rules, leaf.shape, mesh)

    return jax.tree.map(spec, tree_shapes, batch_axes)


# ---------------------------------------------------------------------------
# Paged columns: shared block pools + per-lane block tables
# ---------------------------------------------------------------------------
#
# The paged layout replaces each seq-axis leaf's dense (..., B, S, ...)
# storage with a shared (..., n_blocks + 1, block_len, ...) pool; a lane
# owns rows through an int32 table row (max_blocks = S // block_len
# entries; 0 is the reserved NULL block, see sessions/paging.py).  The
# layout is only defined for leaves whose sequence axis immediately
# follows the session axis (sax == bax + 1 — true of every KV/MLA/cross
# cache in models/build.py), because then swapping (B, S) -> (n_blocks,
# block_len) keeps every other axis in place and a gather + reshape
# reconstructs the EXACT dense column: decode programs read through
# ``gather_column`` and stay bit-identical to the dense path.  Leaves
# without a sequence axis (recurrent states) keep their dense per-lane
# storage.

PAGED_MARKER = "pv"  # paged park-blob marker: np.int32 [block_len, n_keep]


def paged_flags(batch_axes, seq_axes):
    """Per-leaf bool tree: True where the leaf pages (has a seq axis)."""
    return jax.tree.map(lambda bax, sax: sax >= 0, batch_axes, seq_axes)


def make_pools(cache, batch_axes, seq_axes, extent: int, block_len: int):
    """Dense cache tree -> mixed pool tree: every seq-axis leaf becomes a
    shared pool with ``extent`` physical blocks of ``block_len`` rows
    (extent counts the NULL block); recurrent leaves pass through."""
    def mk(a, bax, sax):
        if sax < 0:
            return a
        if sax != bax + 1:
            raise ValueError(
                f"paged layout needs the sequence axis adjacent to the "
                f"session axis (got bax={bax}, sax={sax})")
        if a.shape[sax] % block_len:
            raise ValueError(
                f"seq_cap {a.shape[sax]} not divisible by "
                f"block_len {block_len}")
        shape = list(a.shape)
        shape[bax], shape[sax] = extent, block_len
        return jnp.zeros(tuple(shape), a.dtype)

    return jax.tree.map(mk, cache, batch_axes, seq_axes)


def gather_column(pool, row, bax: int):
    """One lane's dense column view of a pool: gather the table row's
    blocks and merge (max_blocks, block_len) -> S at axis ``bax``.  Used
    INSIDE the jitted decode programs — the gathered column is
    bit-identical to the dense layout's column at every live position."""
    g = jnp.take(pool, row, axis=bax)
    shape = g.shape[:bax] + (g.shape[bax] * g.shape[bax + 1],) + g.shape[bax + 2:]
    return g.reshape(shape)


def split_blocks(col, bax: int, block_len: int):
    """Inverse of the ``gather_column`` merge: (..., S, ...) column ->
    (..., S // block_len, block_len, ...) block stack at axis ``bax``."""
    nb = col.shape[bax] // block_len
    return col.reshape(col.shape[:bax] + (nb, block_len) + col.shape[bax + 1:])


def pack_blocks(pool, bids, bax: int) -> np.ndarray:
    """Copy a session's owned blocks to host memory — the paged analog of
    ``pack_column``'s O(pos) truncation: park moves ONLY the blocks the
    session owns, (..., len(bids), block_len, ...) bytes."""
    idx = jnp.asarray(np.asarray(bids, np.int32))
    return np.asarray(jnp.take(pool, idx, axis=bax))


def unpack_blocks(pool, bids, blocks, bax: int):
    """Scatter a ``pack_blocks`` blob into freshly-allocated blocks of the
    pool (any free blocks work — pool content is position-independent
    through the table indirection)."""
    blk = np.asarray(blocks)
    if blk.dtype != pool.dtype and blk.dtype.itemsize == pool.dtype.itemsize:
        blk = blk.view(pool.dtype)  # npz round trip loses exotic dtypes
    idx = jnp.asarray(np.asarray(bids, np.int32))
    return pool.at[(slice(None),) * bax + (idx,)].set(jnp.asarray(blk, pool.dtype))


def copy_block(pool, src: int, dst: int, bax: int):
    """Device copy of one block (the copy-on-write clone: a write into a
    shared block first duplicates its bytes into the writer's fresh
    block, leaving every other referent untouched)."""
    blk = pool[(slice(None),) * bax + (src,)]
    return pool.at[(slice(None),) * bax + (dst,)].set(blk)


def slot_park_bytes(cfg: ArchConfig, *, quantize: bool = False) -> int:
    """STRUCTURAL parked footprint of one session — content-independent,
    so it is a stable metric (the actual ``parked_bytes`` of a given
    parking can only be <= this: packing is decided per leaf at pack time
    and an off-grid leaf stays fp32).  Under ``quantize=True`` every ring
    that carries fake-quant u4 activations nibble-packs (n * c/2 bytes
    + a 4-byte scale); block 0's ring1 holds the RAW input and never
    packs, nor does any odd-channel ring; the step counter is int32."""
    from repro.core.streaming import ring_sizes
    total = 4  # t (int32)
    for i, rs in enumerate(ring_sizes(cfg).values()):
        for ring, (n, c) in rs.items():
            packable = (quantize and c % 2 == 0
                        and not (i == 0 and ring == "ring1"))
            total += n * (c // 2) + 4 if packable else n * c * 4
    return total
