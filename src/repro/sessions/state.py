"""Structure-of-arrays session state for the multi-tenant streaming layer.

A *slot grid* stacks ``n_slots`` independent single-session streaming
pytrees (core/streaming.stream_init_single) leaf-wise: rings become
(S, n, c), step counters (S,).  One ``jax.vmap`` of ``stream_step_single``
advances every slot in a single jitted call — the batched math is identical
to ``stream_step`` but each slot keeps its OWN step counter, so sessions
admitted at different wall-clock times stay phase-correct.

Inactive slots are *bit-frozen*: the vmapped step still computes them (the
compiled shape is fixed — that is the whole point, no recompiles as sessions
come and go), but a ``jnp.where`` on the active mask discards their writes,
so a parked/free slot's state is exactly the state at its last active step.

``pack_slot``/``unpack_slot`` move one slot's column of the SoA to/from host
memory (numpy) — the parking lot for evicted sessions.  Because a session's
state is position-independent (no leaf encodes the slot index), a parked
session can resume in ANY free slot bit-identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import stream_init_single, stream_step_single
from repro.models.config import ArchConfig


def grid_init(cfg: ArchConfig, n_slots: int, dtype=jnp.float32) -> dict:
    """Stacked session state: every single-session leaf gains a leading
    (n_slots,) axis."""
    one = stream_init_single(cfg, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((n_slots,) + a.shape, a.dtype), one)


def grid_step(params, bn_state, cfg: ArchConfig, states: dict, x: jax.Array,
              active: jax.Array, *, quantize: bool = False):
    """Advance all S slots one sample.  x: (S, C_in); active: (S,) bool.

    Returns (new_states, emb (S, V), logits (S, n_classes)).  Slots with
    active=False keep their previous state bit-exactly (outputs for those
    rows are computed but meaningless — callers mask them)."""
    step = lambda st, xt: stream_step_single(
        params, bn_state, cfg, st, xt, quantize=quantize)
    stepped, emb, logits = jax.vmap(step)(states, x)
    keep = lambda new, old: jnp.where(
        jnp.reshape(active, active.shape + (1,) * (new.ndim - 1)), new, old)
    return jax.tree.map(keep, stepped, states), emb, logits


def pack_slot(states: dict, slot: int) -> dict:
    """Copy one slot's session state to host memory (the parking lot)."""
    return jax.tree.map(lambda a: np.asarray(a[slot]), states)


def unpack_slot(states: dict, slot: int, parked: dict) -> dict:
    """Restore a parked session into ``slot`` (any free slot works — state
    is slot-position independent)."""
    return jax.tree.map(
        lambda a, p: a.at[slot].set(jnp.asarray(p, a.dtype)), states, parked)


def reset_slot(states: dict, slot: int) -> dict:
    """Zero one slot (fresh session: empty rings, t=0)."""
    return jax.tree.map(lambda a: a.at[slot].set(jnp.zeros_like(a[slot])),
                        states)


def slot_state_bytes(states: dict) -> int:
    """Per-slot parked-state footprint in bytes (host copy of one column)."""
    n_slots = jax.tree.leaves(states)[0].shape[0]
    total = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(states))
    return total // n_slots
