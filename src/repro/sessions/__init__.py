"""Multi-tenant streaming session subsystem.

Virtualizes the paper's per-user deployment (shared TCN embedder + per-user
prototype classifiers + O(R) stream state) over a fixed compiled slot grid:

  * state.py     — structure-of-arrays vmapped session state, pack/unpack
  * tenancy.py   — stacked per-tenant PrototypeStore banks
  * scheduler.py — admission control, LRU eviction, slot reuse
  * service.py   — open_session / push_audio / enroll_shots / poll / close
"""

from repro.sessions.scheduler import AdmissionError, CapacityError, SlotScheduler
from repro.sessions.service import NO_TENANT, StreamSessionService
from repro.sessions.state import (
    decode_parked,
    grid_init,
    grid_pspecs,
    grid_scan,
    grid_step,
    lengths_to_valid,
    pack_slot,
    parked_bytes,
    reset_slot,
    slot_park_bytes,
    slot_state_bytes,
    unpack_slot,
)
from repro.sessions.tenancy import (
    TenantBank,
    bank_add_class,
    bank_clear_tenant,
    bank_fc,
    bank_init,
    bank_pack_tenant,
    bank_pspecs,
    bank_store,
    bank_unpack_tenant,
    bank_update_class,
)

__all__ = [
    "AdmissionError", "CapacityError", "SlotScheduler",
    "NO_TENANT", "StreamSessionService",
    "decode_parked", "grid_init", "grid_pspecs", "grid_scan", "grid_step",
    "lengths_to_valid", "pack_slot", "parked_bytes", "reset_slot",
    "slot_park_bytes", "slot_state_bytes", "unpack_slot",
    "TenantBank", "bank_add_class", "bank_clear_tenant", "bank_fc",
    "bank_init", "bank_pack_tenant", "bank_pspecs", "bank_store",
    "bank_unpack_tenant", "bank_update_class",
]
