"""Multi-tenant session subsystem over fixed compiled slot grids.

Virtualizes the paper's per-user deployment (shared backbone + per-user
state + many more sessions than compiled slots) for BOTH serving paths:

  * state.py     — structure-of-arrays vmapped session state, pack/unpack
                   (leading-axis TCN grids and arbitrary-axis KV columns)
  * tenancy.py   — stacked per-tenant PrototypeStore banks
  * scheduler.py — admission control, LRU/cost eviction, slot reuse
  * service.py   — SlotGridService (service-agnostic core) + the TCN
                   façade: open_session / push_audio / enroll_shots / poll
  * lm.py        — LM sessions: KV-cache park/resume + decode_scan chunked
                   multi-token decode (KV-cache chunk ≙ time chunk) + true
                   chunked prefill (multi-token cached steps)
  * spec.py      — speculative decoding: pluggable drafters + draft-verify
                   dispatches (exact forced-token scan / parallel chunk)
  * paging.py    — paged slot memory: block-pool allocator, CoW refcounts,
                   exact-prefix block registry (LMSessionService paged=True)
"""

from repro.sessions.lm import (
    LMSessionService,
    make_decode_scan,
    make_decode_scan_paged,
    make_prefill_column,
    make_prefill_paged,
    pow2_chunks,
)
from repro.sessions.paging import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    PrefixCache,
    prefix_keys,
)
from repro.sessions.scheduler import AdmissionError, CapacityError, SlotScheduler
from repro.sessions.spec import (
    SpeculativeDecoder,
    make_verify_chunk,
    make_verify_chunk_paged,
    make_verify_scan,
    make_verify_scan_paged,
    ngram_drafter,
)
from repro.sessions.service import (
    NO_TENANT,
    SessionRecord,
    SlotGridService,
    StreamSessionService,
)
from repro.sessions.state import (
    column_pspecs,
    decode_parked,
    gather_column,
    grid_init,
    grid_pspecs,
    grid_scan,
    grid_step,
    leaf_axes,
    lengths_to_valid,
    make_grid_fused,
    make_pools,
    pack_blocks,
    pack_column,
    pack_slot,
    parked_bytes,
    reset_slot,
    slot_park_bytes,
    slot_state_bytes,
    split_blocks,
    unpack_blocks,
    unpack_column,
    unpack_slot,
    zero_from_column,
)
from repro.sessions.tenancy import (
    TenantBank,
    bank_add_class,
    bank_clear_tenant,
    bank_fc,
    bank_init,
    bank_pack_tenant,
    bank_pspecs,
    bank_row_bytes,
    bank_store,
    bank_unpack_tenant,
    bank_update_class,
)

__all__ = [
    "AdmissionError", "CapacityError", "SlotScheduler",
    "NO_TENANT", "SessionRecord", "SlotGridService", "StreamSessionService",
    "LMSessionService", "make_decode_scan", "make_decode_scan_paged",
    "make_prefill_column", "make_prefill_paged", "pow2_chunks",
    "NULL_BLOCK", "BlockPool", "PoolExhausted", "PrefixCache", "prefix_keys",
    "SpeculativeDecoder", "make_verify_chunk", "make_verify_chunk_paged",
    "make_verify_scan", "make_verify_scan_paged", "ngram_drafter",
    "column_pspecs", "decode_parked", "gather_column", "grid_init",
    "grid_pspecs", "grid_scan", "grid_step",
    "leaf_axes", "lengths_to_valid", "make_grid_fused", "make_pools",
    "pack_blocks", "pack_column", "pack_slot",
    "parked_bytes", "reset_slot", "slot_park_bytes", "slot_state_bytes",
    "split_blocks", "unpack_blocks", "unpack_column", "unpack_slot",
    "zero_from_column",
    "TenantBank", "bank_add_class", "bank_clear_tenant", "bank_fc",
    "bank_init", "bank_pack_tenant", "bank_pspecs", "bank_row_bytes",
    "bank_store", "bank_unpack_tenant", "bank_update_class",
]
