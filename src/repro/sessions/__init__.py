"""Multi-tenant session subsystem over fixed compiled slot grids.

Virtualizes the paper's per-user deployment (shared backbone + per-user
state + many more sessions than compiled slots) for BOTH serving paths:

  * state.py     — structure-of-arrays vmapped session state, pack/unpack
                   (leading-axis TCN grids and arbitrary-axis KV columns)
  * tenancy.py   — stacked per-tenant PrototypeStore banks
  * scheduler.py — admission control, LRU/cost eviction, slot reuse
  * service.py   — SlotGridService (service-agnostic core) + the TCN
                   façade: open_session / push_audio / enroll_shots / poll
  * lm.py        — LM sessions: KV-cache park/resume + decode_scan chunked
                   multi-token decode (KV-cache chunk ≙ time chunk) + true
                   chunked prefill (multi-token cached steps)
  * spec.py      — speculative decoding: pluggable drafters + draft-verify
                   dispatches (exact forced-token scan / parallel chunk)
  * paging.py    — paged slot memory: block-pool allocator, CoW refcounts,
                   exact-prefix block registry (LMSessionService paged=True)
  * bankpool.py  — paged tenant banks: block-granular prototype rows over
                   the same allocator (StreamSessionService paged_bank=True)
  * rehearsal.py — bounded latent-replay buffer of u4 log2 embeddings

Both concrete services conform to the structural ``SessionService``
protocol defined here (open_session / push / enroll / park / resume /
close / poll / metrics / stats); the async serving plane
(serving/plane.py) programs against the protocol only.  ``enroll`` is
the streaming-learning verb — services without a learnable head keep
the surface but raise ``NotImplementedError``.  ``stats()`` always contains the
``STATS_SCHEMA`` keys and ``metrics()`` snapshots always contain the
``METRICS_SCHEMA`` series — asserted for both services by
tests/test_service_protocol.py.
"""

from typing import Any, Protocol, runtime_checkable

from repro.sessions.lm import (
    LMSessionService,
    make_decode_scan,
    make_decode_scan_paged,
    make_prefill_column,
    make_prefill_paged,
    pow2_chunks,
)
from repro.sessions.bankpool import PagedBankPool, paged_bank_fc
from repro.sessions.paging import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    PrefixCache,
    prefix_keys,
)
from repro.sessions.rehearsal import RehearsalBuffer
from repro.sessions.scheduler import AdmissionError, CapacityError, SlotScheduler
from repro.sessions.spec import (
    SpeculativeDecoder,
    make_verify_chunk,
    make_verify_chunk_paged,
    make_verify_scan,
    make_verify_scan_paged,
    ngram_drafter,
)
from repro.sessions.service import (
    NO_TENANT,
    SessionRecord,
    SlotGridService,
    StreamSessionService,
)
from repro.sessions.state import (
    column_pspecs,
    decode_parked,
    gather_column,
    grid_init,
    grid_pspecs,
    grid_scan,
    grid_step,
    leaf_axes,
    lengths_to_valid,
    make_grid_fused,
    make_pools,
    pack_blocks,
    pack_column,
    pack_slot,
    parked_bytes,
    reset_slot,
    slot_park_bytes,
    slot_state_bytes,
    split_blocks,
    unpack_blocks,
    unpack_column,
    unpack_slot,
    zero_from_column,
)
from repro.sessions.tenancy import (
    TenantBank,
    bank_add_class,
    bank_clear_tenant,
    bank_fc,
    bank_init,
    bank_pack_tenant,
    bank_pspecs,
    bank_row_bytes,
    bank_store,
    bank_unpack_tenant,
    bank_update_class,
)

# -- the unified service surface -------------------------------------------
# Keys every SessionService.stats() dict carries (extras allowed on top:
# the TCN adds fused/tenant_row_bytes, the LM adds seq_cap/paged/...).
# Frozen here so the two services can never drift apart again.
STATS_SCHEMA = (
    "service",            # "tcn" | "lm"
    "n_slots",            # compiled grid width
    "t_chunk",            # compiled chunk length
    "bound",              # sessions currently on slots
    "parked",             # sessions parked to host
    "live_sessions",      # bound + parked
    "evictions",          # lifetime eviction count
    "dispatches",         # lifetime compiled-scan dispatches
    "parked_blob_bytes",  # actual host bytes in the parking lot
    "slot_state_bytes",   # structural bytes of ONE full slot column
)

# Metric series both services register at construction (label service=
# "tcn"|"lm"), so a fresh service's metrics() snapshot always carries
# them — dashboards and the serve_load bench rely on their presence.
METRICS_SCHEMA = (
    "dispatches_total",
    "evictions_total",
    "sessions_bound",
    "sessions_parked",
    "parked_bytes",
)


@runtime_checkable
class SessionService(Protocol):
    """Structural protocol for slot-grid session services.

    ``StreamSessionService`` (payload: audio chunks) and
    ``LMSessionService`` (payload: token budgets) both conform; the
    async serving plane and any other front-end program against THIS
    surface only.  ``push`` is the ragged hot path: a dict keyed by
    session id whose values are service-specific work descriptions;
    absent sessions stay bit-frozen, so how pushes are grouped into
    calls never changes what any one session computes (the contract
    continuous batching builds on).
    """

    n_slots: int

    def open_session(self, *args: Any, **kwargs: Any) -> int: ...
    def push(self, work: dict[int, Any]) -> dict[int, Any]: ...
    def enroll(self, sid: int, shots: Any, **kwargs: Any) -> int: ...
    def park(self, sid: int) -> None: ...
    def resume(self, sid: int) -> None: ...
    def close(self, sid: int) -> None: ...
    def poll(self, sid: int) -> dict: ...
    def metrics(self) -> dict: ...
    def stats(self) -> dict: ...


__all__ = [
    "SessionService", "STATS_SCHEMA", "METRICS_SCHEMA",
    "AdmissionError", "CapacityError", "SlotScheduler",
    "NO_TENANT", "SessionRecord", "SlotGridService", "StreamSessionService",
    "LMSessionService", "make_decode_scan", "make_decode_scan_paged",
    "make_prefill_column", "make_prefill_paged", "pow2_chunks",
    "NULL_BLOCK", "BlockPool", "PoolExhausted", "PrefixCache", "prefix_keys",
    "PagedBankPool", "paged_bank_fc", "RehearsalBuffer",
    "SpeculativeDecoder", "make_verify_chunk", "make_verify_chunk_paged",
    "make_verify_scan", "make_verify_scan_paged", "ngram_drafter",
    "column_pspecs", "decode_parked", "gather_column", "grid_init",
    "grid_pspecs", "grid_scan", "grid_step",
    "leaf_axes", "lengths_to_valid", "make_grid_fused", "make_pools",
    "pack_blocks", "pack_column", "pack_slot",
    "parked_bytes", "reset_slot", "slot_park_bytes", "slot_state_bytes",
    "split_blocks", "unpack_blocks", "unpack_column", "unpack_slot",
    "zero_from_column",
    "TenantBank", "bank_add_class", "bank_clear_tenant", "bank_fc",
    "bank_init", "bank_pack_tenant", "bank_pspecs", "bank_row_bytes",
    "bank_store", "bank_unpack_tenant", "bank_update_class",
]
