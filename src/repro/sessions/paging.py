"""Block-pool allocator for paged slot memory.

Host-side bookkeeping for the vLLM-style paged cache layout: every
seq-axis cache leaf becomes a shared ``(..., n_blocks + 1, block_len,
...)`` pool and each lane reads it through an int32 block-table row.
This module owns the free list, the per-block refcounts that make
copy-on-write prefix sharing safe, and the exact-prefix registry that
maps full prompt blocks to physical block ids.

Design points:

  * Block id 0 is the reserved NULL block.  It is never allocated and
    never read at a live position — it exists so that masked lanes and
    unbound slots have a harmless scatter target (their per-step write
    lands in block 0's garbage instead of another session's memory).
    ``BlockPool(n)`` therefore manages usable ids ``1..n`` over a device
    pool of physical extent ``n + 1``.
  * Refcounts: a block is owned by every session whose table references
    it plus (optionally) the prefix registry.  ``free`` decrements and
    returns the block to the free list at zero; ``writable`` implements
    the CoW contract — exclusive blocks are returned as-is, shared ones
    get a fresh id (the caller copies the device bytes ``src -> new``).
  * ``PrefixCache`` keys full prompt blocks by the EXACT token tuple of
    the chain up to and including that block (not a hash — collisions
    would silently corrupt another tenant's stream).  Matching takes a
    reference per hit; entries hold one registry reference each and are
    reclaimed LRU-first when the pool runs dry.

Exhaustion raises :class:`PoolExhausted`, a subclass of the scheduler's
``AdmissionError`` — paged capacity pressure surfaces through the same
back-pressure contract as live-session admission control.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.sessions.scheduler import AdmissionError

NULL_BLOCK = 0


class PoolExhausted(AdmissionError):
    """Raised when the block pool has no free block (park or close
    sessions, or construct the service with a larger ``n_blocks``)."""


class BlockPool:
    """Free-list allocator over ``n_blocks`` usable blocks with per-block
    refcounts (ids ``1..n_blocks``; id 0 is the reserved NULL block)."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        self.n_blocks = int(n_blocks)
        # physical extent of the device pool axis (usable blocks + NULL)
        self.extent = self.n_blocks + 1
        self._refs = [0] * self.extent
        # LIFO free list: recently-freed blocks are re-used first (their
        # pool bytes are more likely to still be in cache)
        self._free = list(range(self.n_blocks, 0, -1))
        self._n_shared = 0  # blocks with refcount >= 2, kept incrementally

    # -- stats ------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def n_shared(self) -> int:
        return self._n_shared

    def refcount(self, bid: int) -> int:
        return self._refs[bid]

    # -- allocation -------------------------------------------------------
    def alloc(self) -> int:
        """O(1): pop a free block with refcount 1."""
        if not self._free:
            raise PoolExhausted(
                f"block pool exhausted ({self.n_blocks} blocks, 0 free)")
        bid = self._free.pop()
        self._refs[bid] = 1
        return bid

    def ref(self, bid: int) -> int:
        """Take an extra reference (prefix sharing / registry pin)."""
        if bid == NULL_BLOCK or self._refs[bid] <= 0:
            raise ValueError(f"ref of unallocated block {bid}")
        self._refs[bid] += 1
        if self._refs[bid] == 2:
            self._n_shared += 1
        return bid

    def free(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at 0."""
        if bid == NULL_BLOCK:
            raise ValueError("free of the reserved NULL block")
        if self._refs[bid] <= 0:
            raise ValueError(f"double free of block {bid}")
        self._refs[bid] -= 1
        if self._refs[bid] == 1:
            self._n_shared -= 1
        elif self._refs[bid] == 0:
            self._free.append(bid)

    def writable(self, bid: int) -> tuple[int, int | None]:
        """Copy-on-write gate before a session writes into ``bid``.

        Returns ``(bid, None)`` when the block is exclusively owned, or
        ``(new_bid, bid)`` when it was shared: the caller's reference is
        moved to a fresh block and the caller must copy the device bytes
        ``bid -> new_bid`` before writing."""
        if bid == NULL_BLOCK or self._refs[bid] <= 0:
            raise ValueError(f"writable() on unallocated block {bid}")
        if self._refs[bid] == 1:
            return bid, None
        new = self.alloc()
        self.free(bid)  # drop the caller's share (refcount stays >= 1)
        return new, bid

    def check(self) -> None:
        """Invariant audit (tests): free list and refcounts reconcile."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list contains duplicates")
        if NULL_BLOCK in free or self._refs[NULL_BLOCK] != 0:
            raise AssertionError("NULL block leaked into circulation")
        for bid in range(1, self.extent):
            r = self._refs[bid]
            if r < 0:
                raise AssertionError(f"negative refcount on block {bid}")
            if (r == 0) != (bid in free):
                raise AssertionError(
                    f"block {bid}: refcount {r} disagrees with free list")
        shared = sum(1 for r in self._refs if r >= 2)
        if shared != self._n_shared:
            raise AssertionError(
                f"shared counter {self._n_shared} != recount {shared}")


def prefix_keys(tokens, block_len: int) -> list[tuple[int, ...]]:
    """Chain keys for every FULL block of ``tokens``: key ``i`` is the
    exact tuple of all tokens up to and including block ``i`` (prefix
    chains are content-addressed without hash-collision risk)."""
    toks = [int(t) for t in tokens]
    n_full = len(toks) // block_len
    return [tuple(toks[: (i + 1) * block_len]) for i in range(n_full)]


class PrefixCache:
    """Exact-prefix registry: full prompt blocks -> physical block ids.

    Each entry pins its block with one registry reference, so a donor
    session can park/close and later tenants still share the bytes.
    ``match`` returns the longest chain of hits (taking one reference
    per hit for the caller); ``release_lru`` drops the least-recently
    -matched entry so exhausted pools can reclaim registry-only blocks.
    """

    def __init__(self, pool: BlockPool, max_entries: int | None = None):
        self.pool = pool
        self.max_entries = max_entries
        self._map: OrderedDict[tuple[int, ...], int] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._map)

    def match(self, keys: list[tuple[int, ...]]) -> list[int]:
        """Longest-prefix match.  Returns the shared block ids (one NEW
        reference taken per returned block — the caller owns them)."""
        out: list[int] = []
        for key in keys:
            bid = self._map.get(key)
            if bid is None:
                self.misses += 1
                break
            self._map.move_to_end(key)
            out.append(self.pool.ref(bid))
            self.hits += 1
        return out

    def insert(self, key: tuple[int, ...], bid: int) -> None:
        """Register a full block (no-op if the chain key is known)."""
        if key in self._map:
            self._map.move_to_end(key)
            return
        self.pool.ref(bid)
        self._map[key] = bid
        if self.max_entries is not None and len(self._map) > self.max_entries:
            self.release_lru()

    def release_lru(self) -> bool:
        """Evict the least-recently-matched entry, dropping its registry
        reference (frees the block iff no session still shares it).
        Returns False when the registry is empty."""
        if not self._map:
            return False
        _, bid = self._map.popitem(last=False)
        self.pool.free(bid)
        return True

    def clear(self) -> None:
        while self.release_lru():
            pass
