"""Speculative decoding on the LM slot grid: draft cheap, verify batched.

``LMSessionService.decode`` already amortizes the host↔device DISPATCH over
token chunks, but each generated token still costs one sequential scan step
— the greedy feedback loop serializes the math.  Speculation breaks that
serialization the classic way: a cheap drafter proposes K tokens per lane,
and ONE slot-grid dispatch verifies all of them, accepting the longest
prefix the model itself would have generated and rolling the lane back to
the last accepted position.  ``decode_scan``'s forced-token inputs already
*express* verify-a-draft (prefill is the same mechanism), so the verifier
is a thin layer over machinery PR 3 built.

Two verify modes, selected by what exactness costs on each architecture:

  * ``verify="scan"`` (default, EXACT) — drafts ride the forced-token path
    of a masked token scan.  On pure-KV bundles (GQA / MLA) this is
    literally the service's own ``decode_scan`` program: every live step
    receives exactly the token plain greedy decode would have fed, so the
    accepted prefix is bit-identical to non-speculative ``decode()`` BY
    PROGRAM IDENTITY, for any drafter, across park/resume
    (tests/test_speculative.py).  Bundles with recurrent cache leaves
    (RWKV wkv state, Mamba conv/ssm state) need rollback by carried VALUE
    — a step past the first mismatch must not touch them — so they run
    ``make_verify_scan``, the same lane body with a per-step ``alive``
    mask: KV rows stay masked by POSITION, recurrent leaves by VALUE (the
    per-leaf discipline of sessions/lm.make_decode_scan).
  * ``verify="parallel"`` (throughput) — one multi-token cached step
    (``bundle.step_fn``, the chunked-prefill path) computes all K+1
    verify positions with causal attention over the chunk at once: the
    matmul work of K+1 sequential steps in ONE weight pass, which is the
    actual speculative-decoding speedup (decode is weight-bandwidth
    bound).  Chunk-form reductions are reassociated vs per-step decode,
    so outputs are greedy-consistent under the chunk program rather than
    bitwise-equal to the sequential scan; pure-KV bundles only (rejected
    KV rows are dead by position — rewritten before any read, truncated
    out of parked blobs; recurrent leaves would need per-step state
    snapshots).  The bench gates this mode >=1.3x plain decode at K=4
    with the self-draft drafter (benchmarks/session_throughput.py).

Rollback never copies state.  A lane that accepted m of K drafts simply
sets its host position to ``pos + m + 1``: KV rows written past that are
unreachable (every future step rewrites its row before attending, parking
truncates blobs to O(pos), ``state.zero_from_column`` can scrub them to
canonical zeros when wanted), and recurrent leaves were frozen by the
``alive`` mask the moment the first draft missed.

Drafters are pluggable callbacks ``drafter(history, k) -> <=k tokens``
(history = the session's full prompt + generated stream).  The built-in
``ngram_drafter`` is the self-draft used by the bench: it proposes the
continuation that followed the most recent occurrence of the current
suffix in the session's OWN stream — free to evaluate, stateless across
park/resume, and effective exactly when decoding is repetitive (which is
when speculation should win).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.device import acceptance_stats
from repro.sessions.state import gather_column, split_blocks

# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------


def ngram_drafter(n: int = 3, window: int = 128):
    """Self-draft from the session's own token stream.

    Proposes up to k tokens by suffix matching: find the most recent
    earlier occurrence of the current (n-1)-token suffix within the last
    ``window`` tokens and propose what followed it, extending greedily
    (each proposal joins the context for the next).  Backs off to shorter
    suffixes down to 1; returns fewer than k tokens (possibly none) when
    the suffix has never been seen — a truncated draft is a valid draft.
    The window bounds host-side draft cost to O(k * n * window) per lane
    regardless of how long the session has been generating."""
    if n < 2:
        raise ValueError(f"ngram order must be >= 2, got {n}")

    def _next(h: list, order: int):
        for m in range(order - 1, 0, -1):  # longest suffix first
            if len(h) <= m:
                continue
            ctx = h[-m:]
            for j in range(len(h) - m - 1, -1, -1):
                if h[j:j + m] == ctx:
                    return h[j + m]
        return None

    def draft(history, k: int) -> np.ndarray:
        h = [int(t) for t in np.asarray(history).reshape(-1)[-window:]]
        out = []
        for _ in range(int(k)):
            t = _next(h, n)
            if t is None:
                break
            out.append(t)
            h.append(t)
        return np.asarray(out, np.int32)

    return draft


# ---------------------------------------------------------------------------
# Verify programs
# ---------------------------------------------------------------------------


def make_verify_scan(decode_fn, batch_axes, seq_axes=None):
    """Masked verify scan for bundles with recurrent cache leaves.

    Returns ``verify(params, cache, tok, pos, draft, n_draft, active)``:

      tok     (S,)   i32   pending feedback token per lane
      pos     (S,)   i32   per-lane TRUE position (even for inactive lanes)
      draft   (S, K) i32   proposed tokens, left-aligned
      n_draft (S,)   i32   valid drafts per lane (<= K)
      active  (S,)   bool  lanes verified this dispatch

    Runs K+1 steps.  Step 0 feeds ``tok``; step j >= 1 feeds
    ``draft[:, j-1]``.  A lane is *alive* at step j iff it is active and
    every previous step's argmax matched its draft — the first mismatch
    kills the lane for the rest of the scan, which IS the rollback:
    recurrent leaves are committed only on alive steps (masked by value),
    so they end holding exactly the state at the last accepted position;
    KV rows follow ``make_decode_scan``'s position-masked discipline
    (dead steps rewrite the lane's frozen row, which no consumer reads).
    Alive steps receive exactly the tokens plain greedy decode would
    have fed, so their outputs are the plain decode stream.

    Returns ``(cache, ys (S, K+1))``; the caller takes ``m`` = length of
    the matching prefix of ``ys`` vs ``draft`` and emits ``ys[:, :m+1]``.
    """
    recurrent = (jax.tree.map(lambda _: False, batch_axes) if seq_axes is None
                 else jax.tree.map(lambda sax: sax < 0, seq_axes))

    def verify(params, cache, tok, pos, draft, n_draft, active):
        S, K = draft.shape
        zero = jnp.zeros((S, 1), jnp.int32)
        d_in = jnp.concatenate([zero, draft], axis=1)   # fed at step j >= 1
        d_chk = jnp.concatenate([draft, zero], axis=1)  # judged at step j

        def body(carry, xs):
            cache, tok, pos, alive = carry
            din_t, dchk_t, j = xs

            def lane(col, tk, ps, al, di, dc, nd):
                c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                 col, batch_axes)
                t = jnp.where(j > 0, di, tk)
                logits, c2 = decode_fn(params, c,
                                       {"tokens": t[None, None], "pos": ps})
                c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                  c2, batch_axes)
                y = jnp.argmax(logits[0], -1).astype(jnp.int32)
                keep = lambda nw, od: jnp.where(al, nw, od)
                c2 = jax.tree.map(
                    lambda nw, od, rec: keep(nw, od) if rec else nw,
                    c2, col, recurrent)
                match = al & (j < nd) & (y == dc)
                return c2, keep(y, tk), keep(ps + 1, ps), match, y

            cache, tok, pos, alive, y = jax.vmap(
                lane, in_axes=(batch_axes, 0, 0, 0, 0, 0, 0),
                out_axes=(batch_axes, 0, 0, 0, 0))(
                    cache, tok, pos, alive, din_t, dchk_t, n_draft)
            return (cache, tok, pos, alive), y

        (cache, _, _, _), ys = jax.lax.scan(
            body, (cache, tok, pos, active),
            (jnp.moveaxis(d_in, 1, 0), jnp.moveaxis(d_chk, 1, 0),
             jnp.arange(K + 1, dtype=jnp.int32)))
        return cache, jnp.moveaxis(ys, 0, 1)

    return verify


def make_verify_scan_paged(decode_fn, batch_axes, seq_axes, block_len):
    """Paged twin of ``make_verify_scan`` for mixed bundles (recurrent +
    seq-axis leaves) running over a block pool.

    Signature gains the tables: ``verify(params, cache, tables, tok, pos,
    draft, n_draft, active)``.  Same alive-mask semantics as the dense
    verify scan — recurrent leaves commit by VALUE only on alive steps,
    KV rows by POSITION — but each lane gathers its pooled leaves through
    its block-table row and writes back only the one block holding the
    step's row (lm.make_decode_scan_paged's discipline).  Dead steps
    rewrite the lane's frozen-position block, or the NULL block for
    cleared table entries; either way no bytes another session reads."""
    recurrent = jax.tree.map(lambda sax: sax < 0, seq_axes)
    pooled = jax.tree.map(lambda sax: sax >= 0, seq_axes)
    col_axes = jax.tree.map(
        lambda bax, pg: None if pg else bax, batch_axes, pooled)

    def verify(params, cache, tables, tok, pos, draft, n_draft, active):
        S, K = draft.shape
        zero = jnp.zeros((S, 1), jnp.int32)
        d_in = jnp.concatenate([zero, draft], axis=1)
        d_chk = jnp.concatenate([draft, zero], axis=1)

        def body(carry, xs):
            cache, tok, pos, alive = carry
            din_t, dchk_t, j = xs

            def lane(cs, row, tk, ps, al, di, dc, nd):
                col = jax.tree.map(
                    lambda a, bax, pg: gather_column(a, row, bax) if pg else a,
                    cs, batch_axes, pooled)
                c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                 col, batch_axes)
                t = jnp.where(j > 0, di, tk)
                logits, c2 = decode_fn(params, c,
                                       {"tokens": t[None, None], "pos": ps})
                c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                  c2, batch_axes)
                y = jnp.argmax(logits[0], -1).astype(jnp.int32)
                keep = lambda nw, od: jnp.where(al, nw, od)
                c2 = jax.tree.map(
                    lambda nw, od, rec: keep(nw, od) if rec else nw,
                    c2, col, recurrent)
                b = ps // block_len
                upd = jax.tree.map(
                    lambda a, bax, pg: jax.lax.dynamic_slice_in_dim(
                        a, b * block_len, block_len, axis=bax) if pg else a,
                    c2, batch_axes, pooled)
                match = al & (j < nd) & (y == dc)
                return upd, row[b], keep(y, tk), keep(ps + 1, ps), match, y

            upd, pb, tok, pos, alive, y = jax.vmap(
                lane, in_axes=(col_axes, 0, 0, 0, 0, 0, 0, 0),
                out_axes=(batch_axes, 0, 0, 0, 0, 0))(
                    cache, tables, tok, pos, alive, din_t, dchk_t, n_draft)
            cache = jax.tree.map(
                lambda a, u, bax, pg:
                    a.at[(slice(None),) * bax + (pb,)].set(u) if pg else u,
                cache, upd, batch_axes, pooled)
            return (cache, tok, pos, alive), y

        (cache, _, _, _), ys = jax.lax.scan(
            body, (cache, tok, pos, active),
            (jnp.moveaxis(d_in, 1, 0), jnp.moveaxis(d_chk, 1, 0),
             jnp.arange(K + 1, dtype=jnp.int32)))
        return cache, jnp.moveaxis(ys, 0, 1)

    return verify


def make_verify_chunk(step_fn, batch_axes):
    """Parallel verify for pure-KV bundles: all K+1 positions in one
    multi-token cached step per lane (vmapped B=1, per-lane positions —
    the chunked-prefill program pointed at [tok, draft...]).

    Returns ``verify(params, cache, toks (S, K+1), pos, active) ->
    (cache, ys (S, K+1))``.  Inactive lanes are value-masked whole — the
    O(column) select is paid once per dispatch and amortized over the
    K+1 tokens, unlike the scan body where it would cost every step.
    Callers must keep ``pos + K + 1 <= seq_cap`` for every lane (a K+1
    row block cannot clamp without shifting over live history); lanes too
    close to the cap take the plain scan path instead."""

    def verify(params, cache, toks, pos, active):
        def lane(col, tk, ps, act):
            c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                             col, batch_axes)
            logits, c2 = step_fn(params, c, {"tokens": tk[None], "pos": ps})
            c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                              c2, batch_axes)
            c2 = jax.tree.map(lambda nw, od: jnp.where(act, nw, od), c2, col)
            return c2, jnp.argmax(logits[0], -1).astype(jnp.int32)

        return jax.vmap(lane, in_axes=(batch_axes, 0, 0, 0),
                        out_axes=(batch_axes, 0))(cache, toks, pos, active)

    return verify


def make_verify_chunk_paged(step_fn, batch_axes, seq_axes, block_len):
    """Paged twin of ``make_verify_chunk``: each lane gathers its column
    through its block-table row, runs the SAME multi-token cached step,
    and scatters the column back block-wise over the whole row
    (lm.make_prefill_paged's write pattern).

    Signature gains the tables: ``verify(params, cache, tables, toks,
    pos, active) -> (cache, ys)``.  Inactive lanes are value-masked
    whole, so they scatter their own gathered bytes back bit-identically;
    table entries a lane does not own map to the reserved NULL block,
    whose duplicate writes all carry block 0's pass-through bytes.
    Callers must have allocated (CoW-cloned) every block covering
    ``[pos, pos + K + 1)`` for active lanes before dispatch."""
    pooled = jax.tree.map(lambda sax: sax >= 0, seq_axes)
    col_axes = jax.tree.map(
        lambda bax, pg: None if pg else bax, batch_axes, pooled)

    def verify(params, cache, tables, toks, pos, active):
        def lane(cs, row, tk, ps, act):
            col = jax.tree.map(
                lambda a, bax, pg: gather_column(a, row, bax) if pg else a,
                cs, batch_axes, pooled)
            c = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                             col, batch_axes)
            logits, c2 = step_fn(params, c, {"tokens": tk[None], "pos": ps})
            c2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                              c2, batch_axes)
            c2 = jax.tree.map(lambda nw, od: jnp.where(act, nw, od), c2, col)
            out = jax.tree.map(
                lambda a, ref, bax, pg: split_blocks(
                    a.astype(ref.dtype), bax, block_len) if pg else a,
                c2, cs, batch_axes, pooled)
            return out, jnp.argmax(logits[0], -1).astype(jnp.int32)

        blks, ys = jax.vmap(lane, in_axes=(col_axes, 0, 0, 0, 0),
                            out_axes=(batch_axes, 0))(
                                cache, tables, toks, pos, active)
        cache = jax.tree.map(
            lambda a, u, bax, pg:
                a.at[(slice(None),) * bax + (tables,)].set(u) if pg else u,
            cache, blks, batch_axes, pooled)
        return cache, ys

    return verify


# ---------------------------------------------------------------------------
# The drafter/verifier layer over LMSessionService
# ---------------------------------------------------------------------------


class SpeculativeDecoder:
    """Speculative ``decode`` over an ``LMSessionService``.

    ``decode(want)`` has the plain service's contract — generate
    ``want[sid]`` greedy tokens per session, resuming parked sessions,
    retiring at seq_cap — but each dispatch verifies a K-token draft per
    lane instead of generating one token per scan step.  With
    ``verify="scan"`` (default) the emitted stream is bit-identical to
    ``service.decode`` for ANY drafter on every architecture; with
    ``verify="parallel"`` (pure-KV bundles) verification runs as one
    multi-token forward per lane — the throughput mode.

    The drafter is advisory only: it never touches device state, so a
    session can be evicted, parked, spilled to disk, and resumed between
    (or inside) speculative calls without the drafter needing any
    rollback — its input is always the session's host-side token stream.
    """

    def __init__(self, service, drafter=None, *, k: int = 4,
                 verify: str = "scan"):
        if k < 1:
            raise ValueError(f"draft length k must be >= 1, got {k}")
        if verify not in ("scan", "parallel"):
            raise ValueError(f"verify must be 'scan' or 'parallel', "
                             f"got {verify!r}")
        self.svc = service
        self.k = int(k)
        self.drafter = drafter if drafter is not None else ngram_drafter()
        self.verify = verify
        # verify programs are cached ON the service so every decoder over
        # the same grid shares one jitted program (and its compile cache)
        if verify == "parallel":
            if not service.parallel_safe:
                raise ValueError(
                    "parallel verify needs every cache leaf position-indexed "
                    "(recurrent RWKV/Mamba leaves roll back by value); use "
                    "verify='scan' for this bundle")
            if getattr(service.bundle, "step_fn", None) is None:
                raise ValueError(
                    "parallel verify needs the bundle's multi-token cached "
                    "step_fn; this bundle has none — use verify='scan'")
            if getattr(service, "paged", False):
                self._verify_chunk = getattr(
                    service, "_spec_verify_chunk_paged", None)
                if self._verify_chunk is None:
                    self._verify_chunk = service._spec_verify_chunk_paged = \
                        jax.jit(make_verify_chunk_paged(
                            service.bundle.step_fn, service._batch_axes,
                            service._seq_axes, service.block_len))
            else:
                self._verify_chunk = getattr(
                    service, "_spec_verify_chunk", None)
                if self._verify_chunk is None:
                    self._verify_chunk = service._spec_verify_chunk = jax.jit(
                        make_verify_chunk(service.bundle.step_fn,
                                          service._batch_axes))
        elif not service.parallel_safe:
            # recurrent leaves: the alive-masked scan (value rollback)
            if getattr(service, "paged", False):
                self._verify_scan = getattr(
                    service, "_spec_verify_scan_paged", None)
                if self._verify_scan is None:
                    self._verify_scan = service._spec_verify_scan_paged = \
                        jax.jit(make_verify_scan_paged(
                            service.bundle.decode_fn, service._batch_axes,
                            service._seq_axes, service.block_len))
            else:
                self._verify_scan = getattr(service, "_spec_verify_scan",
                                            None)
                if self._verify_scan is None:
                    self._verify_scan = service._spec_verify_scan = jax.jit(
                        make_verify_scan(service.bundle.decode_fn,
                                         service._batch_axes,
                                         service._seq_axes))
        # pure-KV scan mode reuses service._decode_scan verbatim (see
        # _dispatch): same compiled program as plain decode => bit-identity
        # by program identity, and zero extra compilations.
        self.drafted = 0       # draft tokens submitted for verification
        self.accepted = 0      # draft tokens accepted
        self.accepts: dict[int, int] = {}  # per-session accepted counts
        # telemetry: draft economics report through the SERVICE's registry
        # (one surface per grid), labeled by verify mode
        reg = service.metrics_registry
        self._c_drafted = reg.counter("spec_drafted_total", service="lm",
                                      verify=verify)
        self._c_accepted = reg.counter("spec_accepted_total", service="lm",
                                       verify=verify)
        # device-side acceptance twin: the verify program additionally
        # returns each lane's matching-prefix length, computed in-jit from
        # outputs it already materializes (obs.device.acceptance_stats).
        # Same state math; tests pin it against the host rollback
        # arithmetic bit-for-bit.
        self._verify_inst = None
        self.last_device_accepts = None  # (S,) of the latest dispatch
        if service.device_counters:
            self._verify_inst = self._build_instrumented()

    def _build_instrumented(self):
        """Jitted verify twin returning (cache, ys, per-lane accepted).
        Paged services thread the block tables through as an extra leading
        device argument; the state math is otherwise identical."""
        svc = self.svc
        paged = getattr(svc, "paged", False)
        if self.verify == "parallel":
            if paged:
                raw = make_verify_chunk_paged(
                    svc.bundle.step_fn, svc._batch_axes, svc._seq_axes,
                    svc.block_len)

                def inst(params, cache, tables, toks, pos, active, n_draft):
                    cache, ys = raw(params, cache, tables, toks, pos, active)
                    return cache, ys, acceptance_stats(ys, toks[:, 1:],
                                                       n_draft)

                return jax.jit(inst)
            raw = make_verify_chunk(svc.bundle.step_fn, svc._batch_axes)

            def inst(params, cache, toks, pos, active, n_draft):
                cache, ys = raw(params, cache, toks, pos, active)
                return cache, ys, acceptance_stats(ys, toks[:, 1:], n_draft)

            return jax.jit(inst)
        if svc.parallel_safe:
            raw = svc._decode_scan_raw  # paged or dense signature alike

            if paged:
                def inst(params, cache, tables, tok, pos, inp, n_inp,
                         n_steps, n_draft):
                    cache, _, _, ys = raw(params, cache, tables, tok, pos,
                                          inp, n_inp, n_steps)
                    return cache, ys, acceptance_stats(ys, inp[:, 1:],
                                                       n_draft)
            else:
                def inst(params, cache, tok, pos, inp, n_inp, n_steps,
                         n_draft):
                    cache, _, _, ys = raw(params, cache, tok, pos, inp,
                                          n_inp, n_steps)
                    return cache, ys, acceptance_stats(ys, inp[:, 1:],
                                                       n_draft)

            return jax.jit(inst)
        if paged:
            raw = make_verify_scan_paged(svc.bundle.decode_fn,
                                         svc._batch_axes, svc._seq_axes,
                                         svc.block_len)

            def inst(params, cache, tables, tok, pos, draft, n_draft,
                     active):
                cache, ys = raw(params, cache, tables, tok, pos, draft,
                                n_draft, active)
                return cache, ys, acceptance_stats(ys, draft, n_draft)

            return jax.jit(inst)
        raw = make_verify_scan(svc.bundle.decode_fn, svc._batch_axes,
                               svc._seq_axes)

        def inst(params, cache, tok, pos, draft, n_draft, active):
            cache, ys = raw(params, cache, tok, pos, draft, n_draft, active)
            return cache, ys, acceptance_stats(ys, draft, n_draft)

        return jax.jit(inst)

    # -- introspection ------------------------------------------------------
    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    def stats(self) -> dict:
        return {"k": self.k, "verify": self.verify, "drafted": self.drafted,
                "accepted": self.accepted,
                "acceptance_rate": self.acceptance_rate,
                "accepts": dict(self.accepts)}

    # -- dispatch plumbing --------------------------------------------------
    def _dispatch(self, tok, pos, draft, n_draft, n_steps):
        """One batched verify over the grid.  Returns ys (S, K+1).

        With device counters enabled on the service, the instrumented
        verify twin also returns per-lane accepted counts computed in-jit
        (``last_device_accepts``); the state math is identical either way."""
        svc = self.svc
        inst = self._verify_inst
        shape = f"V{self.k + 1}"
        acc = None
        # paged services read/write the cache through the lane block
        # tables: one extra leading device arg, same program body
        tb = (svc._device_table(),) if getattr(svc, "paged", False) else ()
        t0 = time.perf_counter()
        with svc.tracer.span("verify", cat="spec", shape=shape,
                             mode=self.verify,
                             lanes=int((n_steps > 0).sum())):
            if self.verify == "parallel":
                toks = np.concatenate([tok[:, None], draft], axis=1)
                # inactive lanes are value-masked, but their (K+1)-row write
                # must still land in bounds or the update would clamp-shift
                active = n_steps > 0
                pos = np.minimum(pos, svc.seq_cap - self.k - 1) \
                    .astype(np.int32)
                if inst is not None:
                    svc.cache, ys, acc = inst(
                        svc._params, svc.cache, *tb, jnp.asarray(toks),
                        jnp.asarray(pos), jnp.asarray(active),
                        jnp.asarray(n_draft))
                else:
                    svc.cache, ys = self._verify_chunk(
                        svc._params, svc.cache, *tb, jnp.asarray(toks),
                        jnp.asarray(pos), jnp.asarray(active))
            elif svc.parallel_safe:
                # pure-KV exact mode: the service's own decode_scan, drafts
                # as forced tokens.  Steps past a mismatch feed the (wrong)
                # draft and write rows past the accepted position — dead by
                # position, exactly like decode_scan's masked steps.
                inp = np.concatenate([tok[:, None], draft], axis=1)
                if inst is not None:
                    svc.cache, ys, acc = inst(
                        svc._params, svc.cache, *tb, jnp.asarray(tok),
                        jnp.asarray(pos), jnp.asarray(inp),
                        jnp.asarray(n_steps), jnp.asarray(n_steps),
                        jnp.asarray(n_draft))
                else:
                    svc.cache, _, _, ys = svc._decode_scan(
                        svc._params, svc.cache, *tb, jnp.asarray(tok),
                        jnp.asarray(pos), jnp.asarray(inp),
                        jnp.asarray(n_steps), jnp.asarray(n_steps))
            else:
                if inst is not None:
                    svc.cache, ys, acc = inst(
                        svc._params, svc.cache, *tb, jnp.asarray(tok),
                        jnp.asarray(pos), jnp.asarray(draft),
                        jnp.asarray(n_draft), jnp.asarray(n_steps > 0))
                else:
                    svc.cache, ys = self._verify_scan(
                        svc._params, svc.cache, *tb, jnp.asarray(tok),
                        jnp.asarray(pos), jnp.asarray(draft),
                        jnp.asarray(n_draft), jnp.asarray(n_steps > 0))
            ys = np.asarray(ys)
        svc._record_dispatch(time.perf_counter() - t0, shape)
        if acc is not None:
            self.last_device_accepts = np.asarray(acc)
            svc.metrics_registry.counter(
                "spec_device_accepted_total", service="lm").inc(
                    int(self.last_device_accepts.sum()))
        return ys

    # -- the speculative hot path -------------------------------------------
    def decode(self, want: dict[int, int]) -> dict[int, list[int]]:
        """Generate ``want[sid]`` tokens per session, speculatively.

        Identical surface and bookkeeping to ``LMSessionService.decode``;
        any still-pending prompt is consumed through the service first
        (chunked prefill / forced-token scan), then generation proceeds in
        draft-verify dispatches of up to K+1 tokens per lane.  Never emits
        more than asked: the last draft of a request is truncated to the
        remaining budget."""
        svc = self.svc
        svc._validate_want(want)

        out = {sid: [] for sid in want}
        remaining = {sid: n for sid, n in want.items() if n > 0}
        # prompt still pending: its tokens are KNOWN, which is prefill, not
        # speculation — route through the service (one call consumes the
        # whole remainder and emits the first sampled token)
        pending = [sid for sid in remaining
                   if svc.sessions[sid].steps < len(svc.sessions[sid].prompt)]
        if pending:
            first = svc.decode({sid: 1 for sid in pending})
            for sid, toks in first.items():
                out[sid] += toks
                remaining[sid] -= len(toks)

        while True:
            live = {sid: r for sid, r in remaining.items()
                    if r > 0 and not svc.sessions[sid].done}
            if not live:
                break
            if self.verify == "parallel":
                # lanes too close to the cap for a K+1-row block finish on
                # the plain scan (bounded: at most K+1 tokens left to cap)
                tail = {sid: min(r, svc.seq_cap - svc.sessions[sid].steps)
                        for sid, r in live.items()
                        if svc.sessions[sid].steps + self.k + 1 > svc.seq_cap}
                if tail:
                    got = svc.decode(tail)
                    for sid, toks in got.items():
                        out[sid] += toks
                        remaining[sid] -= len(toks)
                    continue
            svc._touch_and_bind(live)

            S, K = svc.n_slots, self.k
            draft = np.zeros((S, K), np.int32)
            n_draft = np.zeros(S, np.int32)
            n_steps = np.zeros(S, np.int32)
            tok = np.zeros(S, np.int32)
            pos = np.zeros(S, np.int32)
            # bound-but-absent lanes carry their true (clamped) position:
            # the masked-step discipline of make_decode_scan
            for slot, bsid in svc.sched.sid_of.items():
                pos[slot] = min(svc.sessions[bsid].steps, svc.seq_cap - 1)
            lanes = {}
            for sid, rem in live.items():
                sess = svc.sessions[sid]
                s = svc.sched.slot_of[sid]
                lanes[sid] = s
                ks = max(min(K, rem - 1, svc.seq_cap - sess.steps - 1), 0)
                hist = np.concatenate(
                    [sess.prompt, np.asarray(svc.outputs[sid], np.int32)])
                d = np.asarray(self.drafter(hist, ks),
                               np.int32).reshape(-1)[:ks]
                draft[s, :d.size] = d
                n_draft[s] = d.size
                n_steps[s] = d.size + 1
                tok[s] = sess.tok
                pos[s] = sess.steps
                if getattr(svc, "paged", False):
                    # the verify writes rows [steps, steps + n) — K+1 whole
                    # rows in parallel mode (rejected rows land in owned
                    # blocks and are trimmed after rollback), the masked
                    # scan writes at most n_steps rows
                    n = self.k + 1 if self.verify == "parallel" \
                        else int(n_steps[s])
                    svc._ensure_blocks(sid, sess.steps,
                                       min(sess.steps + n, svc.seq_cap))

            if not n_draft.any():
                # nothing to verify anywhere (cold drafters, or every lane
                # down to a 1-token budget): a K+1-wide verify would spend
                # K+1 steps per emitted token, so take the plain scan for
                # this round instead — same program family, same stream
                got = svc.decode({sid: 1 for sid in live})
                for sid, toks in got.items():
                    out[sid] += toks
                    remaining[sid] -= len(toks)
                continue

            ys = self._dispatch(tok, pos, draft, n_draft, n_steps)

            for sid, s in lanes.items():
                sess = svc.sessions[sid]
                nd = int(n_draft[s])
                m = 0
                while m < nd and ys[s, m] == draft[s, m]:
                    m += 1
                emitted = [int(t) for t in ys[s, :m + 1]]
                self.drafted += nd
                self.accepted += m
                self._c_drafted.inc(nd)
                self._c_accepted.inc(m)
                self.accepts[sid] = self.accepts.get(sid, 0) + m
                svc.outputs[sid].extend(emitted)
                out[sid].extend(emitted)
                sess.steps += m + 1
                sess.tok = int(ys[s, m])
                remaining[sid] -= m + 1
                sess.last = {"tokens": emitted, "step": sess.steps,
                             "accepted": m}
                if getattr(svc, "paged", False):
                    # rollback frees the rejected suffix's blocks instead
                    # of zeroing ranges — they return to the pool now
                    svc._trim_blocks(sid)
            for sid in lanes:
                if svc.sessions[sid].steps >= svc.seq_cap:
                    svc._retire(sid)
        return out
