"""Admission control + LRU eviction over a fixed compiled slot grid.

Pure host-side bookkeeping (no jax): the compiled batch shape never changes,
so scaling to many more sessions than slots is purely a question of *which*
sessions occupy the grid.  The scheduler tracks a free list, a logical-clock
LRU order, and which sessions are parked (state swapped to host memory);
the service layer performs the actual pack/unpack.

Policies:
  * admission — at most ``max_sessions`` live (bound + parked) sessions;
    beyond that ``open_session`` is refused (AdmissionError), back-pressure
    instead of silent degradation;
  * placement — a free slot if any, else evict the least-recently-touched
    *idle* bound session (sessions being stepped this tick are pinned by
    the caller via ``touch``);
  * cost-aware eviction — an optional ``cost_fn(sid) -> bytes`` callback
    breaks staleness near-ties in favour of the cheapest-to-park session:
    among candidates whose last_used clock is within ``stale_window`` of
    the oldest (window 0 = exact LRU ties only), the minimum park cost
    wins.  Costs are genuinely non-uniform across the services built on
    this scheduler: fp32 TCN parkings are fixed O(receptive-field) bytes,
    the quantized service's nibble-packed parkings ~8x less, and LM KV
    parkings grow O(pos) with the session's decoded length
    (sessions/lm.LMSessionService wires that in as its default cost_fn) —
    one policy arbitrates all of them;
  * release — closing a session frees its slot for immediate reuse.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


class AdmissionError(RuntimeError):
    """Raised when the service is at its live-session capacity."""


class CapacityError(RuntimeError):
    """Raised when a placement needs a slot but every slot is pinned."""


@dataclass
class SlotScheduler:
    n_slots: int
    max_sessions: int | None = None  # None = unlimited live sessions
    cost_fn: Callable[[int], float] | None = None  # sid -> park cost (bytes)
    stale_window: int = 0  # staleness tolerance for cost-aware tie-breaks

    clock: int = 0
    slot_of: dict[int, int] = field(default_factory=dict)   # bound sid -> slot
    sid_of: dict[int, int] = field(default_factory=dict)    # slot -> sid
    last_used: dict[int, int] = field(default_factory=dict)  # sid -> clock
    parked: set[int] = field(default_factory=set)

    # -- queries ------------------------------------------------------------
    @property
    def live_sessions(self) -> int:
        return len(self.slot_of) + len(self.parked)

    @property
    def free_slots(self) -> list[int]:
        return [s for s in range(self.n_slots) if s not in self.sid_of]

    def is_bound(self, sid: int) -> bool:
        return sid in self.slot_of

    def is_parked(self, sid: int) -> bool:
        return sid in self.parked

    # -- lifecycle ----------------------------------------------------------
    def admit(self, sid: int) -> None:
        """Register a new live session (admission control gate)."""
        if self.max_sessions is not None and self.live_sessions >= self.max_sessions:
            raise AdmissionError(
                f"at capacity: {self.live_sessions}/{self.max_sessions} live sessions")
        self.parked.add(sid)  # born parked; bind() places it
        self.touch(sid)

    def touch(self, sid: int) -> None:
        """Mark a session as recently used (pins it against this tick's
        eviction sweep — eviction always picks the LRU minimum)."""
        self.clock += 1
        self.last_used[sid] = self.clock

    def bind(self, sid: int, pinned: set[int] = frozenset()) -> tuple[int, int | None]:
        """Place ``sid`` on a slot.  Returns (slot, evicted_sid|None); the
        caller must park the evicted session's state before overwriting the
        slot.  ``pinned`` sids are never evicted (they are being stepped in
        the same batched call)."""
        if sid in self.slot_of:
            return self.slot_of[sid], None
        free = self.free_slots
        evicted = None
        if free:
            slot = free[0]
        else:
            victims = [s for s in self.slot_of if s != sid and s not in pinned]
            if not victims:
                raise CapacityError("all slots pinned; cannot place session")
            lu = lambda s: self.last_used.get(s, 0)
            if self.cost_fn is None:
                evicted = min(victims, key=lu)
            else:
                oldest = min(lu(s) for s in victims)
                pool = [s for s in victims if lu(s) - oldest <= self.stale_window]
                evicted = min(pool, key=lambda s: (self.cost_fn(s), lu(s)))
            slot = self.slot_of.pop(evicted)
            del self.sid_of[slot]
            self.parked.add(evicted)
        self.parked.discard(sid)
        self.slot_of[sid] = slot
        self.sid_of[slot] = sid
        return slot, evicted

    def park(self, sid: int) -> int | None:
        """Explicitly unbind a session (caller packs its state to host).
        Returns the freed slot, or None if the session was not bound."""
        slot = self.slot_of.pop(sid, None)
        if slot is not None:
            del self.sid_of[slot]
            self.parked.add(sid)
        return slot

    def release(self, sid: int) -> int | None:
        """Close a session: frees its slot (if bound) for immediate reuse."""
        self.parked.discard(sid)
        self.last_used.pop(sid, None)
        slot = self.slot_of.pop(sid, None)
        if slot is not None:
            del self.sid_of[slot]
        return slot
