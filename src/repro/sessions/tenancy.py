"""Per-tenant prototype banks — the FSL/CL personalization layer (§III-A).

Each tenant owns a ``PrototypeStore`` (core/protonet.py): FC rows extracted
from its enrolled keyword shots.  ``TenantBank`` stacks up to ``max_tenants``
stores into one (T, max_ways, V) table so that every active session slot can
classify against *its own* tenant's personalized keyword set inside the same
batched contraction (core/protonet.pn_logits_banked) — no per-tenant
dispatch, no recompile when a tenant enrolls a new way mid-stream.

Enrollment is the paper's CL path verbatim: appending a way is writing one
(V,) sum row + one count (26 B/way on the ASIC); refining a way is adding to
the sum (Eq. 3).  Both are ``.at[]`` updates on the stacked arrays, so a
live stream sees its new class on the very next step.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protonet import PrototypeStore, store_fc
from repro.sharding.rules import DEFAULT_RULES, pspec_sized, resolve_rules


class TenantBank(NamedTuple):
    """Stacked PrototypeStores: one row per tenant."""
    s_sums: jax.Array   # (T, max_ways, V)
    counts: jax.Array   # (T, max_ways)
    n_ways: jax.Array   # (T,) int32


def bank_init(max_tenants: int, max_ways: int, dim: int) -> TenantBank:
    return TenantBank(
        s_sums=jnp.zeros((max_tenants, max_ways, dim), jnp.float32),
        counts=jnp.zeros((max_tenants, max_ways), jnp.float32),
        n_ways=jnp.zeros((max_tenants,), jnp.int32),
    )


def bank_pspecs(bank: TenantBank, mesh, rules: dict | None = None) -> TenantBank:
    """PartitionSpec tree for a TenantBank: the leading tenant axis goes to
    the mesh axis the "tenants" logical rule names (``model`` by default,
    matching the psum path protonet documents for the distributed segment
    sum); ways/embedding dims stay replicated.  Divisibility-gated, so a
    bank that doesn't divide the model axis replicates instead of failing."""
    rules = resolve_rules(DEFAULT_RULES if rules is None else rules, mesh)

    def spec(leaf):
        axes = ("tenants",) + (None,) * (leaf.ndim - 1)
        return pspec_sized(axes, rules, leaf.shape, mesh)

    return jax.tree.map(spec, bank)


def bank_store(bank: TenantBank, tenant: int) -> PrototypeStore:
    """View one tenant's row as a standalone PrototypeStore."""
    return PrototypeStore(s_sums=bank.s_sums[tenant],
                          counts=bank.counts[tenant],
                          n_ways=bank.n_ways[tenant])


def bank_set_store(bank: TenantBank, tenant: int,
                   store: PrototypeStore) -> TenantBank:
    return TenantBank(
        s_sums=bank.s_sums.at[tenant].set(store.s_sums),
        counts=bank.counts.at[tenant].set(store.counts),
        n_ways=bank.n_ways.at[tenant].set(store.n_ways),
    )


def bank_add_class(bank: TenantBank, tenant: int,
                   shot_embeddings: jax.Array) -> TenantBank:
    """Enroll one new way for ``tenant`` from its (k, V) shot embeddings.

    Overflow contract matches ``store_add_class``: at max_ways the update
    is a masked no-op (``.at[tenant, way]`` would clamp onto the last
    learned row otherwise).  The service's host mirror raises before this
    point; direct callers get an unchanged bank instead of corruption."""
    max_ways = bank.s_sums.shape[1]
    ok = bank.n_ways[tenant] < max_ways
    way = jnp.minimum(bank.n_ways[tenant], max_ways - 1)
    s = shot_embeddings.astype(jnp.float32).sum(axis=0)
    k = jnp.float32(shot_embeddings.shape[0])
    return TenantBank(
        # .set (not .add) on BOTH leaves: a new way must not inherit residue
        # from a previously cleared or misused row
        s_sums=bank.s_sums.at[tenant, way].set(
            jnp.where(ok, s, bank.s_sums[tenant, way])),
        counts=bank.counts.at[tenant, way].set(
            jnp.where(ok, k, bank.counts[tenant, way])),
        n_ways=bank.n_ways.at[tenant].add(ok.astype(jnp.int32)),
    )


def bank_update_class(bank: TenantBank, tenant: int, way,
                      shot_embeddings: jax.Array) -> TenantBank:
    """Refine an existing way with more shots (prototype refinement, Eq. 3)."""
    s = shot_embeddings.astype(jnp.float32).sum(axis=0)
    return TenantBank(
        s_sums=bank.s_sums.at[tenant, way].add(s),
        counts=bank.counts.at[tenant, way].add(shot_embeddings.shape[0]),
        n_ways=bank.n_ways,
    )


def bank_clear_tenant(bank: TenantBank, tenant: int) -> TenantBank:
    """Free a tenant row (tenant closed) for reuse."""
    return TenantBank(
        s_sums=bank.s_sums.at[tenant].set(0.0),
        counts=bank.counts.at[tenant].set(0.0),
        n_ways=bank.n_ways.at[tenant].set(0),
    )


def bank_fc(bank: TenantBank):
    """Stacked FC tables: W (T, max_ways, V), b (T, max_ways).

    ``store_fc`` vmapped over the tenant axis — unlearned ways get bias
    -inf per tenant, so a tenant with 3 enrolled ways never predicts way 5
    even though neighbors in the bank may have it."""
    stacked = PrototypeStore(bank.s_sums, bank.counts, bank.n_ways)
    return jax.vmap(store_fc)(stacked)


def bank_pack_tenant(bank: TenantBank, tenant: int) -> dict:
    """Host-side copy of one tenant's row — the unit the service spills
    alongside a parked session so personalization survives restarts
    (StreamSessionService._spill_extra / _restore_apply)."""
    return {"s_sums": np.asarray(bank.s_sums[tenant]),
            "counts": np.asarray(bank.counts[tenant]),
            "n_ways": np.asarray(bank.n_ways[tenant])}


def bank_row_bytes(bank: TenantBank) -> int:
    """Host bytes of one tenant row (the per-tenant spill cost): the paper's
    26 B/way on the ASIC corresponds to s_sums + counts + n_ways here."""
    return int((bank.s_sums.nbytes + bank.counts.nbytes) // bank.s_sums.shape[0]
               + bank.n_ways.dtype.itemsize)


def bank_unpack_tenant(bank: TenantBank, tenant: int, packed: dict) -> TenantBank:
    return TenantBank(
        s_sums=bank.s_sums.at[tenant].set(jnp.asarray(packed["s_sums"])),
        counts=bank.counts.at[tenant].set(jnp.asarray(packed["counts"])),
        n_ways=bank.n_ways.at[tenant].set(jnp.asarray(packed["n_ways"])),
    )
