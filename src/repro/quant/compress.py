"""Gradient compression for the data-parallel all-reduce.

Int8 error-feedback (EF) compression: each device quantizes its local gradient
(plus carried-over error) to int8 with a per-leaf scale before the all-reduce,
and accumulates the quantization residual locally.  EF guarantees the *sum* of
transmitted updates converges to the sum of true gradients, so SGD/Adam still
converge (1-bit-Adam / EF-SGD literature).  This cuts DP all-reduce traffic 4x
vs fp32 (2x vs bf16) — a distributed-optimization lever for the pod axis,
whose DCN bandwidth dominates the collective roofline term at 2+ pods.

Used by the trainer via ``shard_map`` over the (pod, data) axes: compress ->
psum -> decompress; see training/trainer.py (``grad_compression='int8_ef'``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(jnp.zeros_like, params)


def compress_int8(g: jax.Array, err: jax.Array):
    """Returns (codes int8, scale f32 scalar, new_err)."""
    x = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    decoded = codes.astype(g.dtype) * scale
    return codes, scale, x - decoded


def decompress_int8(codes: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return codes.astype(dtype) * scale


def compress_tree(grads, err_state):
    """Tree-wise EF-int8 compression. Returns (codes_tree, scales_tree, new_err)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [compress_int8(g, e) for g, e in zip(flat_g, flat_e)]
    codes = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_err = treedef.unflatten([o[2] for o in out])
    return codes, scales, new_err


def decompress_tree(codes, scales, dtype=jnp.float32):
    return jax.tree.map(lambda c, s: decompress_int8(c, s, dtype), codes, scales)
