"""4-bit signed log2 ("power-of-two") weight quantization — the paper's §III-C.

Codebook (one nibble, two's-complement q in [-8, 7]):

    value(q) = 0                                   if q == 0
             = sign(q) * 2^(1 - |q|) * scale       otherwise

i.e. representable magnitudes are ``scale * {1, 1/2, 1/4, ..., 1/128}`` — the
same 128:1 dynamic range as int8 in half the bits (the paper's claim), with an
explicit zero code.  On the ASIC the multiply becomes a bit shift; on TPU the
equivalent is keeping weights *packed* (2/byte) through HBM->VMEM and expanding
with ``exp2`` inside the Pallas kernel (see kernels/log2_matmul.py).

Activations are 4-bit unsigned uniform (post-ReLU), per the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Positive codes reach |q|=7 (exp -6); negative codes reach |q|=8 (exp -7),
# mirroring int8's mild asymmetry.
_MAX_POS_CODE = 7
_MAX_NEG_CODE = 8


def compute_scale(w: jax.Array) -> jax.Array:
    """Per-tensor symmetric scale: maps max|w| to the top code (2^0 * scale)."""
    return jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)


def quantize_log2(w: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize real weights to int8 nibble codes in [-8, 7]."""
    a = jnp.abs(w) / scale
    # e = round(-log2(a)); magnitudes below 2^-(max_code-0.5) round to zero.
    e = jnp.round(-jnp.log2(jnp.maximum(a, 2.0 ** -12)))
    pos = w > 0
    max_e = jnp.where(pos, _MAX_POS_CODE - 1, _MAX_NEG_CODE - 1)
    code = (jnp.clip(e, 0, max_e) + 1).astype(jnp.int8)
    code = jnp.where(pos, code, -code)
    code = jnp.where((e > max_e) | (w == 0), jnp.int8(0), code)
    return code.astype(jnp.int8)


def dequantize_log2(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Decode nibble codes back to real values."""
    mag = jnp.exp2((1.0 - jnp.abs(q.astype(jnp.float32))))
    val = jnp.sign(q.astype(jnp.float32)) * mag * scale
    return jnp.where(q == 0, 0.0, val).astype(dtype)


def fake_quant_log2(w: jax.Array, scale: jax.Array | None = None) -> jax.Array:
    """Straight-through-estimator fake quantization for QAT."""
    if scale is None:
        scale = jax.lax.stop_gradient(compute_scale(w))
    wq = dequantize_log2(quantize_log2(w, scale), scale, dtype=w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


# ---------------------------------------------------------------------------
# 4-bit unsigned uniform activations (post-ReLU), per-tensor scale.
# ---------------------------------------------------------------------------

def quantize_act_u4(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), 0, 15).astype(jnp.uint8)


def dequantize_act_u4(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(dtype)) * scale


def fake_quant_act_u4(x: jax.Array, scale: jax.Array | None = None) -> jax.Array:
    """STE fake-quant for activations; also simulates the 4-bit clip (overflow)."""
    if scale is None:
        scale = jax.lax.stop_gradient(jnp.maximum(jnp.max(x) / 15.0, 1e-12))
    xq = dequantize_act_u4(quantize_act_u4(x, scale), scale, dtype=x.dtype)
    return x + jax.lax.stop_gradient(xq - x)


# ---------------------------------------------------------------------------
# Nibble packing: two 4-bit codes per uint8 (even nibble = low bits).
# ---------------------------------------------------------------------------

def pack_nibbles(q: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8,7] into uint8 pairs along the last axis.

    The last axis must be even; output last axis is half the size.
    """
    if q.shape[-1] % 2 != 0:
        raise ValueError(f"last axis must be even, got {q.shape}")
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(p: jax.Array) -> jax.Array:
    """Inverse of pack_nibbles: uint8 -> int8 codes in [-8,7] (sign-extended)."""
    lo = (p & 0xF).astype(jnp.int32)
    hi = ((p >> 4) & 0xF).astype(jnp.int32)
    both = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
    return (((both ^ 8) - 8)).astype(jnp.int8)  # sign-extend nibble
