from repro.quant.log2 import (
    compute_scale,
    quantize_log2,
    dequantize_log2,
    fake_quant_log2,
    quantize_act_u4,
    dequantize_act_u4,
    fake_quant_act_u4,
    pack_nibbles,
    unpack_nibbles,
)
from repro.quant import compress

__all__ = [
    "compute_scale",
    "quantize_log2",
    "dequantize_log2",
    "fake_quant_log2",
    "quantize_act_u4",
    "dequantize_act_u4",
    "fake_quant_act_u4",
    "pack_nibbles",
    "unpack_nibbles",
    "compress",
]
