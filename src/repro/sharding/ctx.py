"""Ambient sharding context.

Model code annotates activations with *logical* axis names via
``constrain(x, ("batch", "seq_act", None))``.  Inside a launcher that has
activated a mesh + rules (``with shard_ctx(mesh, rules): ...``) these become
real ``with_sharding_constraint`` calls; in single-device tests they are
no-ops.  This is how one model definition serves 1-device smoke tests, the
16x16 pod mesh and the 2x16x16 multi-pod mesh unchanged.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax

from repro.sharding.rules import pspec, resolve_rules

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


@contextmanager
def shard_ctx(mesh, rules):
    """Activate (mesh, logical rules) for constrain() calls inside jit."""
    resolved = resolve_rules(rules, mesh)
    prev = getattr(_state, "rules", None)
    _state.rules = resolved
    # jax.set_mesh is the post-0.5 spelling; on 0.4.x the Mesh context
    # manager provides the same ambient mesh for bare-PartitionSpec
    # with_sharding_constraint calls.
    set_mesh = getattr(jax, "set_mesh", None)
    try:
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            yield resolved
    finally:
        _state.rules = prev


def constrain(x, logical_axes: tuple):
    """Apply a sharding constraint by logical axis names (no-op without ctx)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, pspec(logical_axes, rules))
