"""Logical-axis sharding rules (homemade flax-partitioning equivalent).

Every parameter is declared once as a ``ParamDef`` carrying its shape *and* a
tuple of logical axis names; ``init_params`` materializes the tree and
``param_pspecs`` maps logical names -> mesh axes through a rules table.  This
keeps model code mesh-agnostic: switching DP/TP/SP/EP layouts = switching the
rules dict, which is exactly the hillclimbing lever §Perf iterates on.

Mesh axes (launch/mesh.py): ``("pod", "data", "model")`` multi-pod or
``("data", "model")`` single-pod.  Rules below reference ``"data"``/``"model"``
/``"dp"`` (= pod+data); ``resolve_rules`` drops the pod axis on 1-pod meshes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis names; len == len(shape); None = replicated dim
    init: str = "normal"  # normal | zeros | ones | embed
    dtype: Any = jnp.float32
    scale: float | None = None  # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Default logical-axis -> mesh-axis rules (Megatron-style TP + vocab/expert
# sharding over "model"; batch over pod+data; sequence-parallel activations).
DEFAULT_RULES: dict = {
    # --- parameters ---
    "vocab": "model",          # embedding & LM-head vocab dim
    # FSDP/ZeRO-3: the d_model row dim of every weight matrix is sharded
    # over the data axis; GSPMD all-gathers per layer inside the scan and
    # reduce-scatters grads — params+Adam drop from O(N/TP) to O(N/chips).
    "embed": "data",
    "heads": "model",          # attention head dim (column-parallel qkv)
    "kv_heads": "model",       # GQA kv heads
    "attn_out": "model",       # row-parallel attention output (contracting dim)
    "ffn": "model",            # column-parallel FFN hidden
    "ffn_in": "model",         # row-parallel FFN output (contracting dim)
    "experts": "model",        # expert parallelism
    "layers": None,            # stacked-layer leading dim (scanned)
    "conv_k": None,            # conv kernel taps
    "channels": "model",       # TCN channels
    "channels_in": None,
    "state": None,             # SSM/RWKV state dims
    "kv_lora": None,           # MLA compressed-kv rank
    "proto": None,             # prototype store (ways)
    # --- streaming sessions (sessions/state.py, sessions/tenancy.py) ---
    "slots": "data",           # session slot-grid leading axis
    "tenants": "model",        # stacked per-tenant prototype banks
    # --- activations ---
    "batch": "dp",             # expands to ("pod","data") on multi-pod meshes
    "seq": None,               # sequence dim of *inputs* (tokens)
    "seq_act": "model",        # sequence-parallel saved activations
    "heads_act": "model",      # attention-head dim of activations
    "act_embed": None,
}


def resolve_rules(rules: dict, mesh) -> dict:
    """Expand the virtual 'dp' axis to the mesh's actual DP axes."""
    has_pod = "pod" in mesh.axis_names
    out = {}
    for k, v in rules.items():
        if v == "dp":
            out[k] = ("pod", "data") if has_pod else "data"
        else:
            out[k] = v
    return out


def pspec(axes: tuple, rules: dict) -> P:
    parts = []
    for a in axes:
        parts.append(None if a is None else rules.get(a))
    # Trim trailing Nones for tidiness.
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def pspec_sized(axes: tuple, rules: dict, shape: tuple, mesh) -> P:
    """pspec() that drops any axis whose dim isn't divisible by the mesh
    extent (jit in_shardings requires exact divisibility; e.g. a 256206
    vocab cannot shard 16 ways and falls back to replicated)."""
    parts = []
    for dim, a in zip(shape, axes):
        m = None if a is None else rules.get(a)
        if m is not None and dim % _axis_size(mesh, m) != 0:
            m = None
        parts.append(m)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_pspecs(defs, rules: dict, mesh=None):
    """Map a tree of ParamDef -> tree of PartitionSpec."""
    if mesh is None:
        return jax.tree.map(
            lambda d: pspec(d.axes, rules),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return jax.tree.map(
        lambda d: pspec_sized(d.axes, rules, d.shape, mesh),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    if d.init == "normal":
        # He/LeCun-style fan-in scaling on the second-to-last dim by default.
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
        std = d.scale if d.scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    raise ValueError(f"unknown init {d.init}")


def init_params(defs, key):
    """Materialize a ParamDef tree into an array tree (split keys per leaf)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([_init_leaf(d, k) for d, k in zip(leaves, keys)])


def abstract_params(defs):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )
