from repro.sharding.rules import (
    ParamDef,
    DEFAULT_RULES,
    resolve_rules,
    pspec,
    param_pspecs,
    init_params,
    abstract_params,
)

__all__ = [
    "ParamDef",
    "DEFAULT_RULES",
    "resolve_rules",
    "pspec",
    "param_pspecs",
    "init_params",
    "abstract_params",
]
