"""Pallas TPU kernel: matmul with nibble-packed 4-bit signed log2 weights.

The TPU-native form of the paper's MatMul-free PE array (§III-C): the ASIC
replaces multipliers with bit-shifters; the MXU multiplies for free, so the
transferable win is *bandwidth* — weights stay packed (2 codes/byte) through
HBM->VMEM and are expanded in-kernel with exp2 (the bit-shift analogue)
immediately before the MXU dot.  Vs bf16 weights this is a 4x cut in weight
bytes, which is exactly what the decode-shape roofline is bound by.

Tiling: grid (M/bm, N/bn); the full K strip of x (bm, K) and of the packed
weights (K, bn/2) live in VMEM per tile.  v5e VMEM is ~16 MiB: defaults
bm=256, bn=512, K<=8192 use  256*8192*4 + 8192*256 = 10.4 MiB.  MXU dims
(bm, bn multiples of 128) are hardware-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, scale_ref, o_ref):
    x = x_ref[...]                       # (bm, K)
    pw = w_ref[...]                      # (K, bn//2) uint8
    scale = scale_ref[0]
    # unpack two nibbles per byte -> (K, bn), sign-extend 4-bit two's compl.
    lo = (pw & 0xF).astype(jnp.int32)
    hi = ((pw >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(pw.shape[0], pw.shape[1] * 2)
    codes = (codes ^ 8) - 8
    # decode: value = sign * 2^(1-|code|) * scale   (the ASIC's bit shift)
    mag = jnp.exp2(1.0 - jnp.abs(codes).astype(jnp.float32))
    w = jnp.where(codes == 0, 0.0, jnp.sign(codes).astype(jnp.float32) * mag)
    w = w * scale
    o_ref[...] = jnp.dot(x.astype(jnp.float32), w,
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def log2_matmul(x, w_packed, scale, *, bm: int = 256, bn: int = 512,
                interpret: bool = False):
    """x: (M, K); w_packed: (K, N//2) uint8; scale: () f32 -> (M, N) f32.

    ``interpret`` is an explicit static parameter: backend selection happens
    once in kernels/dispatch (never re-probed per trace under jit).
    """
    M, K = x.shape
    N = w_packed.shape[1] * 2
    bm = min(bm, M)
    bn = min(bn, N)
    # pad M/N up to tile multiples (K strip is always whole)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    xp = jnp.pad(x, ((0, Mp - M), (0, 0))) if Mp != M else x
    wp = jnp.pad(w_packed, ((0, 0), (0, (Np - N) // 2))) if Np != N else w_packed
    out = pl.pallas_call(
        _kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn // 2), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(xp, wp, scale.reshape(1))
    return out[:M, :N]
