from repro.kernels.ops import dilated_conv_op, log2_matmul_op, proto_extract_op

__all__ = ["dilated_conv_op", "log2_matmul_op", "proto_extract_op"]
