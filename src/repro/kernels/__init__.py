from repro.kernels import dispatch, ref
from repro.kernels.ops import (
    make_dilated_conv_op,
    make_log2_matmul_op,
    make_proto_extract_op,
)
from repro.kernels.tcn_block import expand_weight, make_block_fn

__all__ = [
    "dispatch", "ref",
    "make_dilated_conv_op", "make_log2_matmul_op", "make_proto_extract_op",
    "expand_weight", "make_block_fn",
]
