"""Pallas TPU kernel: fused prototypical parameter extraction (§III-A, Fig. 6
steps 2+3 — the "prototypical parameter extractor" module).

One pass produces both FC parameters from the support embeddings:
    W = onehot @ emb          (class-wise shot sums, Eq. 3)
    b = -(1/2k) ||W||^2       (Eq. 6 bias)
The square-and-reduce happens in VMEM right after the dot, so the sums never
round-trip to HBM — the kernel analogue of the ASIC reusing the inference
datapath with a few cycles of extra control logic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(oh_ref, emb_ref, w_ref, b_ref, *, inv_2k: float):
    oh = oh_ref[...]      # (bn, Nk)
    emb = emb_ref[...]    # (Nk, V)
    w = jnp.dot(oh.astype(jnp.float32), emb.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    w_ref[...] = w
    b_ref[...] = -jnp.sum(jnp.square(w), axis=-1) * inv_2k


@functools.partial(jax.jit, static_argnames=("k", "bn", "interpret"))
def proto_extract(emb, onehot, k: int, *, bn: int = 128,
                  interpret: bool = False):
    """emb: (Nk, V); onehot: (N, Nk) dispatch matrix -> (W (N,V), b (N,)).

    ``interpret`` is an explicit static parameter: backend selection happens
    once in kernels/dispatch (never re-probed per trace under jit).
    """
    N, Nk = onehot.shape
    V = emb.shape[1]
    bn = min(bn, N)
    Np = -(-N // bn) * bn
    oh = jnp.pad(onehot, ((0, Np - N), (0, 0))) if Np != N else onehot
    w, b = pl.pallas_call(
        functools.partial(_kernel, inv_2k=1.0 / (2.0 * k)),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, Nk), lambda i: (i, 0)),
            pl.BlockSpec((Nk, V), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, V), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, V), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        interpret=interpret,
    )(oh, emb)
    return w[:N], b[:N]
