"""Fused TCN residual block — the streaming slot-grid hot loop in ONE op.

The paper's win is an *integrated* datapath (§III-B/C): conv taps, BN,
ReLU and the residual add flow through the PE array without round-tripping
activations to memory.  The TPU/JAX analogue fuses one whole residual
block — the k tap-shifted matmuls of both convs, the BN scale/bias baked
into weights at session-open (models/tcn.bake_stream_params), the u4
activation fake-quant, and the residual add — so the chunked scan body
stops materializing per-op ``(S*T, C)`` intermediates and stops re-padding
the chunk per call: the conv history comes in as the session's ring-buffer
taps (a ``(k-1)*d``-row strip prefix), not a fresh ``jnp.pad``.

Layout contract (shared by every backend):

    strip1: (S, n1+T, Cin)  time-ordered [ring1 history | chunk]
    hist2:  (S, n2, C)      time-ordered ring2 history
    p:      {"conv1_w", "conv1_b", "conv2_w", "conv2_b"[, "down_w",
             "down_b"]} — weights are fp32 arrays, or, for quantized
            sessions, nibble-packed log2 codes ``{"codes": uint8
            (..., C//2), "scale": ()}`` expanded *in-kernel* (2 codes/byte
            at rest, the 4x weight-byte cut per dispatch)

    -> (h (S, T, C) block output, mid (S, T, C) conv1 activation)

``mid`` is returned because the caller owns the ring updates: the tail of
[hist2 | mid] is exactly what ring2 must hold after the chunk.

Bit-exactness: on baked (BN-folded, pre-fake-quantized) params the fused
block is bit-identical to the per-sample ``stream_step`` path — the tap
sums accumulate in the same order, the matmuls share XLA's K-sequential
reduction (row-count invariant), and every elementwise op replicates the
scan body's exact expression (tests/test_kernels.py fuzzes this).

Backends (kernels/dispatch.py, resolved once at op construction):
``ref`` is the batched-jnp fast path (the CPU win BENCH_kernels.json
gates); ``mosaic``/``triton``/``interpret`` lower one ``pl.pallas_call``
per block with the whole time strip in VMEM (channel counts are <=64, so
even a 16k-step strip is ~4 MiB — the dilated_conv sizing argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch
from repro.quant.log2 import (
    dequantize_act_u4,
    dequantize_log2,
    quantize_act_u4,
    unpack_nibbles,
)


def expand_weight(w):
    """Nibble-packed log2 codes -> fp32 weights; fp32 arrays pass through.

    This is the out-of-kernel twin of the in-kernel expansion — both call
    the same quant.log2 helpers, so the expanded values are bit-identical
    to the baked scan-path weights."""
    if isinstance(w, dict):
        return dequantize_log2(unpack_nibbles(w["codes"]), w["scale"])
    return w


def _qa(x, act_scale: float):
    """Value form of quant.log2.fake_quant_act_u4 (the STE minus its
    stop_gradient — these kernels are inference-only, and stop_gradient
    has no Mosaic lowering rule).  Same expression, same bits."""
    s = jnp.float32(act_scale)
    xq = dequantize_act_u4(quantize_act_u4(x, s), s, dtype=x.dtype)
    return x + (xq - x)


# ---------------------------------------------------------------------------
# ref backend: batched jnp (the CPU fast path)
# ---------------------------------------------------------------------------

def tcn_block_fused(strip1, hist2, p, *, dilation: int, k: int,
                    act_scale: float = 0.25, quantize: bool = False):
    """Fused block on plain jnp: k tap-shifted batched matmuls per conv.

    Each tap j of conv c reads the static slice ``strip[:, j*d : j*d+T]``
    — dilation-aware by construction, no zero-tap work, no im2col."""
    d = dilation
    T = strip1.shape[1] - (k - 1) * d
    qa = (lambda a: _qa(a, act_scale)) if quantize else (lambda a: a)
    w1 = expand_weight(p["conv1_w"])
    y = sum(strip1[:, j * d:j * d + T] @ w1[j] for j in range(k))
    y = qa(jax.nn.relu(y + p["conv1_b"]))
    strip2 = jnp.concatenate([hist2, y], axis=1)
    w2 = expand_weight(p["conv2_w"])
    y2 = sum(strip2[:, j * d:j * d + T] @ w2[j] for j in range(k))
    y2 = y2 + p["conv2_b"]
    x_cur = strip1[:, (k - 1) * d:]
    if "down_w" in p:
        res = x_cur @ expand_weight(p["down_w"])[0] + p["down_b"]
    else:
        res = x_cur
    return qa(jax.nn.relu(y2 + res)), y


# ---------------------------------------------------------------------------
# pallas backend: one pallas_call per block, grid over slots
# ---------------------------------------------------------------------------

def _read_w(refs, i, packed: bool):
    """Read weight operand(s) starting at refs[i]; returns (w, next_i).
    Packed weights are expanded IN-KERNEL: uint8 nibbles cross HBM->VMEM,
    exp2 (the ASIC's bit shift) rebuilds fp32 right before the MXU dot."""
    if not packed:
        return refs[i][...], i + 1
    codes = unpack_nibbles(refs[i][...])
    return dequantize_log2(codes, refs[i + 1][0]), i + 2


def _block_kernel(*refs, k: int, dilation: int, T: int, act_scale: float,
                  quantize: bool, packed: tuple, has_down: bool):
    d = dilation
    qa = (lambda a: _qa(a, act_scale)) if quantize else (lambda a: a)
    h_ref, mid_ref = refs[-2], refs[-1]
    strip1 = refs[0][0]                  # (n1+T, Cin)
    w1, i = _read_w(refs, 1, packed[0])
    b1 = refs[i][...]
    hist2 = refs[i + 1][0]               # (n2, C)
    w2, i = _read_w(refs, i + 2, packed[1])
    b2 = refs[i][...]
    i += 1
    acc = jnp.zeros((T, w1.shape[2]), jnp.float32)
    for j in range(k):
        tap = jax.lax.dynamic_slice_in_dim(strip1, j * d, T, axis=0)
        acc = acc + tap @ w1[j]
    y = qa(jax.nn.relu(acc + b1))
    strip2 = jnp.concatenate([hist2, y], axis=0)
    acc2 = jnp.zeros((T, w2.shape[2]), jnp.float32)
    for j in range(k):
        tap = jax.lax.dynamic_slice_in_dim(strip2, j * d, T, axis=0)
        acc2 = acc2 + tap @ w2[j]
    acc2 = acc2 + b2
    x_cur = jax.lax.dynamic_slice_in_dim(strip1, (k - 1) * d, T, axis=0)
    if has_down:
        dw, i = _read_w(refs, i, packed[2])
        res = x_cur @ dw[0] + refs[i][...]
        i += 1
    else:
        res = x_cur
    h_ref[0] = qa(jax.nn.relu(acc2 + res))
    mid_ref[0] = y


def _w_operands(w, specs, operands):
    """Append a weight's operand(s) + BlockSpec(s); returns packed flag."""
    if isinstance(w, dict):
        operands += [w["codes"], w["scale"].reshape(1)]
        specs += [pl.BlockSpec(w["codes"].shape, lambda i: (0,) * w["codes"].ndim),
                  pl.BlockSpec((1,), lambda i: (0,))]
        return True
    operands.append(w)
    specs.append(pl.BlockSpec(w.shape, lambda i: (0,) * w.ndim))
    return False


def tcn_block_pallas(strip1, hist2, p, *, dilation: int, k: int,
                     act_scale: float = 0.25, quantize: bool = False,
                     interpret: bool = False):
    """The fused block as one ``pl.pallas_call``: grid (S,), one slot per
    program, full time strips in VMEM.  Same layout contract and same
    bits as ``tcn_block_fused``."""
    S, L1, _ = strip1.shape
    n2, C = hist2.shape[1], hist2.shape[2]
    T = L1 - (k - 1) * dilation
    operands = [strip1]
    specs = [pl.BlockSpec((1,) + strip1.shape[1:], lambda i: (i, 0, 0))]
    p1 = _w_operands(p["conv1_w"], specs, operands)
    operands.append(p["conv1_b"])
    specs.append(pl.BlockSpec(p["conv1_b"].shape, lambda i: (0,)))
    operands.append(hist2)
    specs.append(pl.BlockSpec((1,) + hist2.shape[1:], lambda i: (i, 0, 0)))
    p2 = _w_operands(p["conv2_w"], specs, operands)
    operands.append(p["conv2_b"])
    specs.append(pl.BlockSpec(p["conv2_b"].shape, lambda i: (0,)))
    has_down = "down_w" in p
    pd = False
    if has_down:
        pd = _w_operands(p["down_w"], specs, operands)
        operands.append(p["down_b"])
        specs.append(pl.BlockSpec(p["down_b"].shape, lambda i: (0,)))
    out = pl.pallas_call(
        functools.partial(_block_kernel, k=k, dilation=dilation, T=T,
                          act_scale=act_scale, quantize=quantize,
                          packed=(p1, p2, pd), has_down=has_down),
        grid=(S,),
        in_specs=specs,
        out_specs=[pl.BlockSpec((1, T, C), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, T, C), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, T, C), jnp.float32),
                   jax.ShapeDtypeStruct((S, T, C), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[0], out[1]


dispatch.register(
    "tcn_block",
    ref=tcn_block_fused,
    pallas=lambda interp: functools.partial(tcn_block_pallas, interpret=interp),
)


def make_block_fn(backend: str | None = None):
    """Resolve the fused-block implementation ONCE (dispatch layer).

    Returns ``block_fn(strip1, hist2, p, *, dilation, k, act_scale,
    quantize) -> (h, mid)``; the backend choice (and the pallas
    ``interpret`` static flag) is baked in — never re-probed under jit."""
    return dispatch.build("tcn_block", backend)
