"""Public op constructors over the backend registry (kernels/dispatch.py).

Backend choice happens ONCE, at op construction (``make_*_op``), never at
trace time: the old per-call ``use_pallas`` flags and the trace-time
``jax.default_backend()`` probes are gone.  ``backend=None``/"auto"
resolves from REPRO_KERNEL_BACKEND or the platform (TPU -> mosaic,
GPU -> triton, CPU -> the jnp oracle); "interpret" forces pallas
interpret mode (parity testing); "ref" forces the oracle.

    conv_op = make_dilated_conv_op(cfg.kernel_backend)  # resolve once
    y = conv_op(x, w, b, dilation)                      # hot loop
"""

from __future__ import annotations

import functools

from repro.kernels import dispatch, ref
from repro.kernels.dilated_conv import dilated_causal_conv
from repro.kernels.log2_matmul import log2_matmul
from repro.kernels.proto_extract import proto_extract

dispatch.register(
    "log2_matmul",
    ref=ref.log2_matmul_ref,
    pallas=lambda interp: functools.partial(log2_matmul, interpret=interp),
)
dispatch.register(
    "dilated_conv",
    ref=ref.dilated_conv_ref,
    pallas=lambda interp: functools.partial(dilated_causal_conv,
                                            interpret=interp),
)
dispatch.register(
    "proto_extract",
    ref=ref.proto_extract_ref,
    pallas=lambda interp: functools.partial(proto_extract, interpret=interp),
)


def make_log2_matmul_op(backend: str | None = None):
    """(x (M, K), w_packed (K, N//2) u8, scale ()) -> (M, N) f32."""
    return dispatch.build("log2_matmul", backend)


def make_dilated_conv_op(backend: str | None = None):
    """(x (B, T, Cin), w (K, Cin, Cout), b, dilation) -> (B, T, Cout) f32."""
    return dispatch.build("dilated_conv", backend)


def make_proto_extract_op(backend: str | None = None):
    """(emb (Nk, V), onehot (N, Nk), k) -> (W (N, V), b (N,))."""
    return dispatch.build("proto_extract", backend)
