"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode against the jnp
oracles in ref.py; on TPU they compile to Mosaic.  ``use_pallas=False``
switches any call site to the oracle — the dry-run lowers the pure-JAX path.
"""

from __future__ import annotations


from repro.kernels import ref
from repro.kernels.dilated_conv import dilated_causal_conv
from repro.kernels.log2_matmul import log2_matmul
from repro.kernels.proto_extract import proto_extract


def log2_matmul_op(x, w_packed, scale, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.log2_matmul_ref(x, w_packed, scale)
    return log2_matmul(x, w_packed, scale)


def dilated_conv_op(x, w, b, dilation: int, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.dilated_conv_ref(x, w, b, dilation)
    return dilated_causal_conv(x, w, b, dilation)


def proto_extract_op(emb, onehot, k: int, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.proto_extract_ref(emb, onehot, k)
    return proto_extract(emb, onehot, k)
