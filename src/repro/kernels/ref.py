"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.log2 import dequantize_log2, unpack_nibbles


def log2_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array) -> jax.Array:
    """x: (M, K) float; w_packed: (K, N//2) uint8 nibble-packed log2 codes;
    scale: scalar.  Returns (M, N) f32 = x @ dequant(w)."""
    codes = unpack_nibbles(w_packed)           # (K, N)
    w = dequantize_log2(codes, scale)          # f32
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def dilated_conv_ref(x: jax.Array, w: jax.Array, b: jax.Array, dilation: int) -> jax.Array:
    """Causal dilated conv1d oracle. x: (B,T,Cin); w: (K,Cin,Cout)."""
    k = w.shape[0]
    pad = (k - 1) * dilation
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1,), padding=[(pad, 0)], rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    ) + b.astype(jnp.float32)


def proto_extract_ref(emb: jax.Array, onehot: jax.Array, k: int):
    """PN parameter extraction oracle (Eq. 3+6).

    emb: (Nk, V); onehot: (N, Nk) class-dispatch matrix (rows sum to k).
    Returns (W (N, V) = class-wise sums, b (N,) = -(1/2k)||W||^2)."""
    w = jnp.dot(onehot.astype(jnp.float32), emb.astype(jnp.float32))
    b = -jnp.sum(jnp.square(w), axis=-1) / (2.0 * k)
    return w, b


def tcn_block_ref(strip1, hist2, w1, b1, w2, b2, down_w=None, down_b=None,
                  *, dilation: int, k: int, act_scale: float = 0.25,
                  quantize: bool = False):
    """Fused-TCN-block oracle: a per-POSITION lax.scan with explicit tap
    gathers — structurally the ``stream_step`` path (the binding contract
    the fused kernels are held bit-identical to), not the batched-matmul
    form the kernels use.  Weights arrive pre-expanded fp32 (BN folded);
    strip1: (S, n+T, Cin) time-ordered [history | chunk], hist2: (S, n, C).
    Returns (h (S, T, C), mid (S, T, C))."""
    from repro.quant.log2 import fake_quant_act_u4

    d = dilation
    n = (k - 1) * d
    T = strip1.shape[1] - n
    qa = (lambda a: fake_quant_act_u4(a, jnp.float32(act_scale))) \
        if quantize else (lambda a: a)

    def step(buf2, pos):
        taps1 = [jax.lax.dynamic_slice_in_dim(strip1, pos + j * d, 1,
                                              axis=1)[:, 0] for j in range(k)]
        y = sum(tp @ w1[j] for j, tp in enumerate(taps1)) + b1
        y = qa(jax.nn.relu(y))
        buf2 = jax.lax.dynamic_update_slice_in_dim(buf2, y[:, None], n + pos,
                                                   axis=1)
        taps2 = [jax.lax.dynamic_slice_in_dim(buf2, pos + j * d, 1,
                                              axis=1)[:, 0] for j in range(k)]
        y2 = sum(tp @ w2[j] for j, tp in enumerate(taps2)) + b2
        x_cur = strip1[:, n + pos]
        res = x_cur @ down_w[0] + down_b if down_w is not None else x_cur
        return buf2, (qa(jax.nn.relu(y2 + res)), y)

    buf2 = jnp.concatenate(
        [hist2, jnp.zeros((strip1.shape[0], T) + hist2.shape[2:],
                          hist2.dtype)], axis=1)
    _, (h, mid) = jax.lax.scan(step, buf2, jnp.arange(T))
    return jnp.swapaxes(h, 0, 1), jnp.swapaxes(mid, 0, 1)
