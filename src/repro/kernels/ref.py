"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.log2 import dequantize_log2, unpack_nibbles


def log2_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array) -> jax.Array:
    """x: (M, K) float; w_packed: (K, N//2) uint8 nibble-packed log2 codes;
    scale: scalar.  Returns (M, N) f32 = x @ dequant(w)."""
    codes = unpack_nibbles(w_packed)           # (K, N)
    w = dequantize_log2(codes, scale)          # f32
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def dilated_conv_ref(x: jax.Array, w: jax.Array, b: jax.Array, dilation: int) -> jax.Array:
    """Causal dilated conv1d oracle. x: (B,T,Cin); w: (K,Cin,Cout)."""
    k = w.shape[0]
    pad = (k - 1) * dilation
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1,), padding=[(pad, 0)], rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    ) + b.astype(jnp.float32)


def proto_extract_ref(emb: jax.Array, onehot: jax.Array, k: int):
    """PN parameter extraction oracle (Eq. 3+6).

    emb: (Nk, V); onehot: (N, Nk) class-dispatch matrix (rows sum to k).
    Returns (W (N, V) = class-wise sums, b (N,) = -(1/2k)||W||^2)."""
    w = jnp.dot(onehot.astype(jnp.float32), emb.astype(jnp.float32))
    b = -jnp.sum(jnp.square(w), axis=-1) / (2.0 * k)
    return w, b


def wkv6_chunk_ref(r, k, v, log_w, u, state):
    """One WKV6 chunk oracle: naive per-step recurrence over the chunk.
    r,k,v,log_w: (C, H, Dh); u: (H, Dh); state: (H, Dh, Dh)."""
    C = r.shape[0]
    ys = []
    S = state.astype(jnp.float32)
    for t in range(C):
        rt, kt, vt = (a[t].astype(jnp.float32) for a in (r, k, v))
        y = jnp.einsum("hi,hij->hj", rt, S) + \
            jnp.einsum("hi,hi,hi,hj->hj", rt, u.astype(jnp.float32), kt, vt)
        S = jnp.exp(log_w[t].astype(jnp.float32))[..., None] * S + \
            jnp.einsum("hi,hj->hij", kt, vt)
        ys.append(y)
    return jnp.stack(ys, 0), S
