"""Pallas TPU kernel: causal dilated conv1d (the TCN hot loop, §III-B).

Dilation-aware by construction: the kernel gathers exactly the k real taps
per output step (shifted views of the input strip), never touching the
zero-valued graph nodes that a dense im2col / 2D-kernel emulation would
multiply (TCN-CUTIE's 80% wasted MACs, per the paper).  Each grid cell owns
one batch row and one Cout tile; the full (left-padded) time strip sits in
VMEM — TCN channel counts are small (<=64), so even 16k-step raw audio is
16k*64*4 B = 4 MiB, within v5e VMEM.  The k tap-shifted matmuls hit the MXU
back-to-back and accumulate in registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, dilation: int, T: int):
    x = x_ref[0]          # (T + (k-1)*d, Cin) left-padded strip
    w = w_ref[...]        # (k, Cin, bco)
    b = b_ref[...]        # (bco,)
    acc = jnp.zeros((T, w.shape[2]), jnp.float32)
    for j in range(k):
        tap = jax.lax.dynamic_slice_in_dim(x, j * dilation, T, axis=0)
        acc = acc + jnp.dot(tap.astype(jnp.float32), w[j].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    o_ref[0] = acc + b.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("dilation", "bco", "interpret"))
def dilated_causal_conv(x, w, b, dilation: int, *, bco: int = 128,
                        interpret: bool = False):
    """x: (B, T, Cin); w: (K, Cin, Cout); b: (Cout,) -> (B, T, Cout) f32.

    ``interpret`` is an explicit static parameter: backend selection happens
    once in kernels/dispatch (never re-probed per trace under jit).
    """
    B, T, Cin = x.shape
    K, _, Cout = w.shape
    pad = (K - 1) * dilation
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    bco = min(bco, Cout)
    Cp = -(-Cout // bco) * bco
    if Cp != Cout:  # Cout tile padding (sliced back off below)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, Cp - Cout)))
        b = jnp.pad(b, (0, Cp - Cout))
    out = pl.pallas_call(
        functools.partial(_kernel, k=K, dilation=dilation, T=T),
        grid=(B, Cp // bco),
        in_specs=[
            pl.BlockSpec((1, T + pad, Cin), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((K, Cin, bco), lambda i, j: (0, 0, j)),
            pl.BlockSpec((bco,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, T, bco), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, Cp), jnp.float32),
        interpret=interpret,
    )(xp, w, b)
    return out[..., :Cout]
