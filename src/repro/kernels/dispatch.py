"""Backend dispatch for the Pallas kernels — resolved ONCE per op.

The kernels previously probed ``jax.default_backend()`` inside each call
(under ``jit`` static args, so the probe re-ran at every trace) and every
call site hardcoded ``use_pallas``.  This module centralizes the choice:

    backend      lowering                         when
    ----------   ------------------------------   -------------------------
    "mosaic"     pl.pallas_call, compiled (TPU)   auto on TPU
    "triton"     pl.pallas_call, compiled (GPU)   auto on GPU
    "interpret"  pl.pallas_call, interpret mode   forced (kernel debugging)
    "ref"        pure-jnp oracle (kernels/ref.py) auto on CPU
    "auto"       resolve from the platform        the default everywhere

``resolve()`` is called at *op construction time* (``make_*_op`` in
kernels/ops.py, service/session __init__, bundle build) — never inside a
jitted function — and the result is baked into the returned op as static
configuration.  Selection order: explicit argument > ``ArchConfig
.kernel_backend`` (callers pass it through) > ``REPRO_KERNEL_BACKEND``
env var > platform default.

On CPU "auto" resolves to the jnp oracle, NOT interpret mode: interpret
mode emulates the kernel instruction-by-instruction (orders of magnitude
slower) and exists for parity testing only.  The fused fast path's CPU
win therefore comes from the *fused* ref implementations (one batched
matmul chain per block instead of a per-sample scan), which is exactly
the speedup BENCH_kernels.json gates.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax

ENV_VAR = "REPRO_KERNEL_BACKEND"
BACKENDS = ("auto", "mosaic", "triton", "interpret", "ref")
_PLATFORM_DEFAULT = {"tpu": "mosaic", "gpu": "triton", "cuda": "triton",
                     "rocm": "triton"}


@dataclass(frozen=True)
class Resolved:
    """A backend choice fixed at op-construction time.

    ``use_pallas`` says whether the op lowers through ``pl.pallas_call``;
    ``interpret`` is the *explicit* static flag those calls receive — the
    kernels themselves never probe the platform again.
    """

    backend: str      # mosaic | triton | interpret | ref

    @property
    def use_pallas(self) -> bool:
        return self.backend != "ref"

    @property
    def interpret(self) -> bool:
        return self.backend == "interpret"


def resolve(requested: str | None = "auto") -> Resolved:
    """Resolve a requested backend to a concrete one.  Call once, outside
    jit, when constructing an op; ``None`` means "auto"."""
    req = (requested or "auto").lower()
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if req == "auto" and env:
        req = env
    if req not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {req!r}; expected one of {BACKENDS}")
    if req == "auto":
        req = _PLATFORM_DEFAULT.get(jax.default_backend(), "ref")
    return Resolved(req)


# ---------------------------------------------------------------------------
# Op registry: op name -> {backend-class: impl builder}
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, dict[str, object]] = {}


def register(op: str, *, ref, pallas) -> None:
    """Register the two implementation classes of an op: the jnp oracle
    (``ref``) and a builder ``pallas(interpret: bool) -> callable`` that
    bakes the static interpret flag in."""
    _REGISTRY[op] = {"ref": ref, "pallas": pallas}


def build(op: str, backend: str | None = "auto"):
    """Resolve ``backend`` once and return the concrete implementation for
    ``op``.  The returned callable carries no backend logic of its own.

    Each build reports to the process-default metrics registry and trace
    (op construction happens outside jit, so this costs one dict lookup),
    which makes "what lowered where" visible in any metrics snapshot —
    the first question when a run is slow on the wrong backend."""
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; registered: "
                       f"{sorted(_REGISTRY)}")
    r = resolve(backend)
    from repro.obs.metrics import default_registry
    from repro.obs.trace import get_tracer
    default_registry().counter("kernel_ops_built_total", op=op,
                               backend=r.backend).inc()
    get_tracer().instant("kernel_build", cat="kernels", op=op,
                         backend=r.backend)
    entry = _REGISTRY[op]
    if not r.use_pallas:
        return entry["ref"]
    return entry["pallas"](r.interpret)


def registered_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
