"""Batched serving engines — thin clients of the sessions subsystem.

Slot lifecycle (admission, reuse, LRU bookkeeping) lives in
``sessions/scheduler.SlotScheduler``; both servers here keep a fixed
compiled batch shape and move requests on/off slots between steps without
recompiling.

The dual-mode idea from the paper maps here to two engine presets:
  * "low-power"  — small batch, latency-optimized (the 4x4 array analogue),
  * "throughput" — full batch, maximize tokens/s (the 16x16 analogue).

For the TCN architecture serving means *streaming*: ``TCNStreamServer`` is
now a façade over ``sessions/service.StreamSessionService`` — one session
per stream, all advanced by the service's chunked ``grid_scan`` (a whole
time chunk per jitted dispatch).  Use the service directly for multi-tenant
personalization, park/resume, and session churn; this class keeps the
historical push(x_t)->(emb, logits) surface for fixed lockstep stream
grids and adds push_chunk(x (S, T, C)) as the amortized hot path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.sessions.scheduler import SlotScheduler
from repro.sessions.service import StreamSessionService


@dataclass
class ServeConfig:
    max_batch: int = 8
    seq_cap: int = 512
    mode: str = "throughput"  # throughput | low-power (paper's dual mode)

    def effective_batch(self):
        return self.max_batch if self.mode == "throughput" else max(1, self.max_batch // 4)


class LMServer:
    def __init__(self, bundle, params, cfg: ServeConfig):
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        B, S = cfg.effective_batch(), cfg.seq_cap
        self.cache = bundle.empty_cache(B, S)
        self.pos = np.zeros(B, np.int64)
        self.tokens = np.zeros((B, 1), np.int32)
        self.outputs: dict[int, list] = {}
        self._decode = jax.jit(bundle.decode_fn)
        self.sched = SlotScheduler(B)
        self._next_id = 0
        # per-leaf batch axis, derived from the bundle (the axis whose extent
        # tracks B) — no shape-sniffing against concrete dims that might
        # coincide with B.  -1 marks leaves without a per-slot column.
        sa = jax.eval_shape(lambda: bundle.empty_cache(B, S))
        sb = jax.eval_shape(lambda: bundle.empty_cache(B + 1, S))
        def axis_of(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return -1
        self._cache_axes = jax.tree.leaves(jax.tree.map(axis_of, sa, sb))

    @staticmethod
    def _col(ax: int, slot: int):
        return (slice(None),) * ax + (slot,)

    def add_request(self, prompt: np.ndarray) -> int:
        """Admit a request into a free slot (prefill via step-wise decode).

        LM slots hold a KV cache that is not parked to host (unlike TCN
        stream state), so admission is free-slot-only — no eviction.
        Step-wise prefill is batch-synchronized (every slot's cache row is
        written at the prompt's low positions), so live slots' cache columns
        are snapshotted before and restored after — admission never perturbs
        in-flight requests."""
        if not self.sched.free_slots:
            raise RuntimeError("no free slots")
        rid = self._next_id
        self._next_id += 1
        self.sched.admit(rid)
        slot, _ = self.sched.bind(rid)
        # jax arrays are immutable: the pre-prefill cache stays intact, so
        # after prefill we graft ONLY the new slot's column onto it — one
        # on-device column copy, live slots untouched by construction.
        before, treedef = jax.tree.flatten(self.cache)
        for tok in prompt:
            self.tokens[slot, 0] = tok
            self._step_single(slot)
        after = jax.tree.leaves(self.cache)
        self.cache = jax.tree.unflatten(treedef, [
            a if ax < 0 else b.at[self._col(ax, slot)].set(a[self._col(ax, slot)])
            for b, a, ax in zip(before, after, self._cache_axes)])
        self.outputs[rid] = []
        return rid

    def _step_single(self, slot):
        # batch-synchronized decode at this slot's position; other slots'
        # cache rows are written but masked out of outputs.
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(self.tokens),
             "pos": jnp.asarray(self.pos[slot], jnp.int32)})
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    def step(self):
        """One greedy decode step for every active slot."""
        if not self.sched.sid_of:
            return
        pos = int(self.pos.max())
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(self.tokens), "pos": jnp.asarray(pos, jnp.int32)})
        nxt = np.asarray(logits).argmax(-1)
        for slot, rid in list(self.sched.sid_of.items()):
            tok = int(nxt[slot])
            self.outputs[rid].append(tok)
            self.tokens[slot, 0] = tok
            self.pos[slot] = pos + 1
            # no touch(): LM admission is free-slot-only, LRU never consulted
            if self.pos[slot] >= self.cfg.seq_cap - 1:
                self._release(rid)  # slot freed

    def _release(self, rid: int):
        """Free a request's slot AND scrub it: reset its position/token and
        zero its cache column, so the next occupant prefills from position 0
        like a fresh slot (and a capped slot can't pin step()'s shared
        max-pos forever)."""
        slot = self.sched.release(rid)
        if slot is None:
            return
        self.pos[slot] = 0
        self.tokens[slot, 0] = 0
        leaves, treedef = jax.tree.flatten(self.cache)
        self.cache = jax.tree.unflatten(treedef, [
            l if ax < 0 else l.at[self._col(ax, slot)].set(0)
            for l, ax in zip(leaves, self._cache_axes)])

    def finish(self, rid: int):
        self._release(rid)


class TCNStreamServer:
    """Real-time streaming classification (the paper's KWS deployment):
    one jitted chunked scan advances all streams; O(R) state per stream.

    Thin client of StreamSessionService: n_streams lockstep sessions on an
    n_streams-slot grid (no churn, no tenants — the historical surface).
    ``push_chunk`` is the dispatch-amortized hot path (T samples per jitted
    call); ``push`` keeps the per-sample surface as its T=1 special case."""

    def __init__(self, bundle, params, bn_state, n_streams: int, quantize=False,
                 t_chunk: int = 16):
        self.cfg = bundle.cfg
        self.service = StreamSessionService(
            bundle, params, bn_state, n_slots=n_streams, max_tenants=1,
            max_ways=1, quantize=quantize, t_chunk=t_chunk)
        self.sids = [self.service.open_session() for _ in range(n_streams)]

    def push(self, x_t: np.ndarray):
        """x_t: (n_streams, C_in) one sample per stream -> (emb, logits)."""
        res = self.service.push_audio(
            {sid: x_t[i] for i, sid in enumerate(self.sids)})
        emb = np.stack([res[sid]["emb"] for sid in self.sids])
        logits = np.stack([res[sid]["logits"] for sid in self.sids])
        return emb, logits

    def push_chunk(self, x: np.ndarray):
        """x: (n_streams, T, C_in) a time chunk per stream.  Returns
        per-sample (embs (n_streams, T, V), logits (n_streams, T, n)) —
        bit-exact vs T sequential push() calls, at a fraction of the
        dispatches (ceil(T / t_chunk) jitted calls total)."""
        res = self.service.push_audio(
            {sid: x[i] for i, sid in enumerate(self.sids)})
        embs = np.stack([res[sid]["emb"] for sid in self.sids])
        logits = np.stack([res[sid]["logits"] for sid in self.sids])
        return embs, logits
