"""Batched serving engine.

Continuous-batching-lite over a fixed slot grid: every LM bundle serves a
(B, S_cap) cache; requests occupy slots with their own positions and an
active mask, so finished requests free slots for new ones between steps
without recompiling (pos is a traced per-slot vector in the sampler only;
the model decode step itself is batch-synchronized per the bundle API and
per-slot answers are masked).

The dual-mode idea from the paper maps here to two engine presets:
  * "low-power"  — small batch, latency-optimized (the 4x4 array analogue),
  * "throughput" — full batch, maximize tokens/s (the 16x16 analogue).

For the TCN architecture serving means *streaming*: core/streaming.py state
advanced one audio sample per step; `TCNStreamServer` wraps it with the same
slot semantics.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import stream_init, stream_step


@dataclass
class ServeConfig:
    max_batch: int = 8
    seq_cap: int = 512
    mode: str = "throughput"  # throughput | low-power (paper's dual mode)

    def effective_batch(self):
        return self.max_batch if self.mode == "throughput" else max(1, self.max_batch // 4)


class LMServer:
    def __init__(self, bundle, params, cfg: ServeConfig):
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        B, S = cfg.effective_batch(), cfg.seq_cap
        self.cache = bundle.empty_cache(B, S)
        self.pos = np.zeros(B, np.int64)
        self.active = np.zeros(B, bool)
        self.tokens = np.zeros((B, 1), np.int32)
        self.outputs: dict[int, list] = {}
        self._decode = jax.jit(bundle.decode_fn)
        self._next_id = 0
        self._slot_req = [-1] * B

    def add_request(self, prompt: np.ndarray) -> int:
        """Admit a request into a free slot (prefill via step-wise decode)."""
        free = [i for i in range(len(self.active)) if not self.active[i]]
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        rid = self._next_id
        self._next_id += 1
        # per-slot prefill: feed prompt tokens one at a time (slot-local pos);
        # bulk prefill via bundle.prefill_fn is used when batch arrives empty.
        for t, tok in enumerate(prompt):
            self.tokens[slot, 0] = tok
            self._step_single(slot)
        self.active[slot] = True
        self._slot_req[slot] = rid
        self.outputs[rid] = []
        return rid

    def _step_single(self, slot):
        # batch-synchronized decode at this slot's position; other slots'
        # cache rows are written but masked out of outputs.
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(self.tokens),
             "pos": jnp.asarray(self.pos[slot], jnp.int32)})
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    def step(self, greedy: bool = True):
        """One decode step for every active slot."""
        if not self.active.any():
            return
        pos = int(self.pos.max())
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(self.tokens), "pos": jnp.asarray(pos, jnp.int32)})
        logits = np.asarray(logits)
        nxt = logits.argmax(-1) if greedy else logits.argmax(-1)
        for i in range(len(self.active)):
            if self.active[i]:
                tok = int(nxt[i])
                self.outputs[self._slot_req[i]].append(tok)
                self.tokens[i, 0] = tok
                self.pos[i] = pos + 1
                if self.pos[i] >= self.cfg.seq_cap - 1:
                    self.active[i] = False  # slot freed

    def finish(self, rid: int):
        for i, r in enumerate(self._slot_req):
            if r == rid:
                self.active[i] = False
                self._slot_req[i] = -1


class TCNStreamServer:
    """Real-time streaming classification (the paper's KWS deployment):
    one jitted step advances all streams one sample; O(R) state per stream."""

    def __init__(self, bundle, params, bn_state, n_streams: int, quantize=False):
        self.cfg = bundle.cfg
        self.params = params
        self.bn_state = bn_state
        self.state = stream_init(self.cfg, n_streams)
        self._step = jax.jit(
            lambda st, x: stream_step(params, bn_state, self.cfg, st, x,
                                      quantize=quantize))

    def push(self, x_t: np.ndarray):
        """x_t: (n_streams, C_in) one sample per stream -> (emb, logits)."""
        self.state, emb, logits = self._step(self.state, jnp.asarray(x_t))
        return np.asarray(emb), np.asarray(logits)
