"""Batched serving engines — thin clients of the sessions subsystem.

Slot lifecycle (admission, reuse, LRU/cost eviction) lives in
``sessions/scheduler.SlotScheduler``; both servers here keep a fixed
compiled batch shape and move requests on/off slots between steps without
recompiling.

The dual-mode idea from the paper maps here to two engine presets:
  * "low-power"  — small batch, latency-optimized (the 4x4 array analogue),
  * "throughput" — full batch, maximize tokens/s (the 16x16 analogue).

Both engines are now façades over slot-grid services:

``LMServer`` wraps ``sessions/lm.LMSessionService`` — per-lane positions,
chunked ``decode_scan`` dispatches (prefill is the forced-token prefix of
the same scan), KV-cache park/resume, int32 positions with a seq_cap
retirement guard.  The historical surface is preserved: by default
``max_sessions`` equals the batch, so admission beyond the grid raises
(the pre-park/resume contract); pass ``ServeConfig(max_sessions=...)``
larger than the batch — or use the service directly — to oversubscribe
with LRU eviction to the host parking lot.

``TCNStreamServer`` wraps ``sessions/service.StreamSessionService`` —
one session per stream, all advanced by the service's chunked ``grid_scan``
(a whole time chunk per jitted dispatch).  Use the service directly for
multi-tenant personalization, park/resume, and session churn.

Both servers now expose the unified ``sessions.SessionService`` surface
(open_session / push / park / resume / close / poll / metrics / stats) by
delegation, so they can sit behind the async plane or be driven directly.
The historical spellings — ``add_request``/``finish`` on LMServer, the
array-payload ``push``/``push_chunk`` on TCNStreamServer — remain as
deprecation shims that emit ``DeprecationWarning`` naming the protocol
call to migrate to.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from repro.sessions.lm import LMSessionService
from repro.sessions.service import StreamSessionService
from repro.sessions.spec import SpeculativeDecoder


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new} (the SessionService "
                  f"protocol surface)", DeprecationWarning, stacklevel=3)


@dataclass
class ServeConfig:
    max_batch: int = 8
    seq_cap: int = 512
    mode: str = "throughput"  # throughput | low-power (paper's dual mode)
    decode_chunk: int = 16    # token-chunk bucket cap per jitted dispatch
    max_sessions: int | None = None  # None: == batch (no oversubscription)
    prefill_chunk: int = 64   # true chunked prefill cap (0 = scan prefill)
    speculative: int = 0      # draft length K (0 = plain greedy decode)
    spec_verify: str = "scan"  # scan (exact) | parallel (throughput)

    def effective_batch(self):
        return self.max_batch if self.mode == "throughput" else max(1, self.max_batch // 4)


class LMServer:
    """Historical add_request/step/outputs/finish surface over the LM
    session service.  One ``step()`` greedily decodes one token for every
    live request in a single chunked dispatch; a request's first step also
    consumes its prompt (forced-token steps of the same scan)."""

    def __init__(self, bundle, params, cfg: ServeConfig):
        self.bundle = bundle
        self.cfg = cfg
        B = cfg.effective_batch()
        self.service = LMSessionService(
            bundle, params, n_slots=B, seq_cap=cfg.seq_cap,
            t_chunk=cfg.decode_chunk, prefill_chunk=cfg.prefill_chunk,
            max_sessions=B if cfg.max_sessions is None else cfg.max_sessions)
        # opt-in speculation: step(n) drafts K tokens/lane per dispatch and
        # verifies them in one shot (sessions/spec.py); n=1 steps cannot
        # speculate (a draft needs headroom) and fall through to the plain
        # scan inside the decoder
        self.spec = (SpeculativeDecoder(self.service, k=cfg.speculative,
                                        verify=cfg.spec_verify)
                     if cfg.speculative else None)

    # historical mirrors -----------------------------------------------------
    @property
    def sched(self):
        return self.service.sched

    @property
    def outputs(self) -> dict[int, list[int]]:
        return self.service.outputs

    @property
    def pos(self) -> np.ndarray:
        """Per-slot int32 positions (0 for free slots)."""
        return self.service.slot_pos

    # protocol surface (sessions.SessionService, by delegation) --------------
    @property
    def n_slots(self) -> int:
        return self.service.n_slots

    def open_session(self, prompt: np.ndarray) -> int:
        """Admit a request.  With the default ``max_sessions`` (== batch)
        a full grid raises AdmissionError (a RuntimeError) — back-pressure,
        the historical contract; with a larger cap the LRU idle request is
        parked to host memory instead and resumes bit-identically."""
        return self.service.open_session(prompt)

    def push(self, work: dict[int, int]) -> dict[int, list[int]]:
        """{sid: token budget} -> {sid: new tokens} (protocol hot path)."""
        return self.service.push(work)

    def park(self, sid: int) -> None:
        self.service.park(sid)

    def resume(self, sid: int) -> None:
        self.service.resume(sid)

    def close(self, sid: int) -> None:
        self.service.close(sid)

    def poll(self, sid: int) -> dict:
        return self.service.poll(sid)

    def enroll(self, sid: int, shots, **kwargs) -> int:
        return self.service.enroll(sid, shots, **kwargs)

    def stats(self) -> dict:
        return self.service.stats()

    # deprecation shims (historical spellings) -------------------------------
    def add_request(self, prompt: np.ndarray) -> int:
        _deprecated("LMServer.add_request(prompt)",
                    "LMServer.open_session(prompt)")
        return self.open_session(prompt)

    def finish(self, rid: int):
        _deprecated("LMServer.finish(rid)", "LMServer.close(rid)")
        self.close(rid)

    def step(self, n: int = 1):
        """Advance every live request — bound AND parked — by ``n`` greedy
        tokens (default 1, the historical contract).  With oversubscription
        the live set can exceed the grid, so requests advance in waves of
        at most ``n_slots`` (each wave's binds may park the previous wave's
        LRU members; every request still gains exactly n tokens per step).
        With ``ServeConfig(speculative=K)`` each wave decodes through the
        drafter/verifier layer instead of the plain scan."""
        live = [sid for sid, s in sorted(self.service.sessions.items())
                if not s.done]
        decode = self.spec.decode if self.spec is not None \
            else self.service.decode
        for i in range(0, len(live), self.service.n_slots):
            decode({sid: n for sid in live[i:i + self.service.n_slots]})

    def metrics(self) -> dict:
        """Telemetry snapshot of the underlying service (obs registry)."""
        return self.service.metrics()


class TCNStreamServer:
    """Real-time streaming classification (the paper's KWS deployment):
    one jitted chunked scan advances all streams; O(R) state per stream.

    Thin client of StreamSessionService: n_streams lockstep sessions on an
    n_streams-slot grid (no churn, no tenants — the historical surface).
    ``push_chunk`` is the dispatch-amortized hot path (T samples per jitted
    call); ``push`` keeps the per-sample surface as its T=1 special case."""

    def __init__(self, bundle, params, bn_state, n_streams: int, quantize=False,
                 t_chunk: int = 16):
        self.cfg = bundle.cfg
        self.service = StreamSessionService(
            bundle, params, bn_state, n_slots=n_streams, max_tenants=1,
            max_ways=1, quantize=quantize, t_chunk=t_chunk)
        self.sids = [self.service.open_session() for _ in range(n_streams)]

    # protocol surface (sessions.SessionService, by delegation) --------------
    @property
    def n_slots(self) -> int:
        return self.service.n_slots

    def open_session(self, *args, **kwargs) -> int:
        return self.service.open_session(*args, **kwargs)

    def push(self, work):
        """Protocol hot path: ``{sid: (T, C_in) chunk} -> {sid: result}``.

        The historical array spelling — ``push(x_t)`` with one
        ``(n_streams, C_in)`` sample per lockstep stream, returning
        stacked ``(emb, logits)`` — still works as a deprecation shim."""
        if isinstance(work, dict):
            return self.service.push(work)
        _deprecated("TCNStreamServer.push(x_t array)",
                    "TCNStreamServer.push({sid: chunk})")
        x_t = np.asarray(work)
        res = self.service.push(
            {sid: x_t[i] for i, sid in enumerate(self.sids)})
        emb = np.stack([res[sid]["emb"] for sid in self.sids])
        logits = np.stack([res[sid]["logits"] for sid in self.sids])
        return emb, logits

    def park(self, sid: int) -> None:
        self.service.park(sid)

    def resume(self, sid: int) -> None:
        self.service.resume(sid)

    def close(self, sid: int) -> None:
        self.service.close(sid)

    def poll(self, sid: int) -> dict:
        return self.service.poll(sid)

    def enroll(self, sid: int, shots, **kwargs) -> int:
        return self.service.enroll(sid, shots, **kwargs)

    def stats(self) -> dict:
        return self.service.stats()

    # deprecation shims (historical spellings) -------------------------------
    def push_chunk(self, x: np.ndarray):
        """x: (n_streams, T, C_in) a time chunk per stream.  Returns
        per-sample (embs (n_streams, T, V), logits (n_streams, T, n)) —
        bit-exact vs T sequential push() calls, at a fraction of the
        dispatches (ceil(T / t_chunk) jitted calls total)."""
        _deprecated("TCNStreamServer.push_chunk(x)",
                    "TCNStreamServer.push({sid: chunk})")
        x = np.asarray(x)
        res = self.service.push(
            {sid: x[i] for i, sid in enumerate(self.sids)})
        embs = np.stack([res[sid]["emb"] for sid in self.sids])
        logits = np.stack([res[sid]["logits"] for sid in self.sids])
        return embs, logits

    def metrics(self) -> dict:
        """Telemetry snapshot of the underlying service (obs registry)."""
        return self.service.metrics()
