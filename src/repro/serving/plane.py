"""Async continuous-batching request plane over SessionService workers.

The device-side machinery (chunk-native scans, O(1) paged admission,
cost-aware eviction) already tolerates sessions joining and leaving
between chunk dispatches; what was missing is a front-end that exploits
it.  ``ServingPlane`` is that front-end: an asyncio facade that accepts
ragged per-session pushes, accumulates whatever arrived while the grid
was busy into the next continuous batch, and drives one or more
slot-grid workers (any ``sessions.SessionService``) with tenant-affinity
routing.

Bit-identity contract
---------------------
The plane NEVER changes what a session computes — only when its work is
grouped with other sessions' work.  This rides directly on the services'
chunk-invariance guarantee: absent lanes in a ``push`` dispatch stay
bit-frozen and every lane's computation depends only on its own state
and payload, so ``push({a: wa, b: wb})`` gives each of a and b exactly
the bits of ``push({a: wa})`` / ``push({b: wb})`` run alone.  The load
bench (benchmarks/serve_load.py) holds this end to end against a
synchronous control replay.

Concurrency model
-----------------
One asyncio worker task per service owns ALL mutation of that service —
there are no locks because there is no cross-task sharing.  Compiled
dispatches run synchronously inside the worker task (they hold the GIL
anyway; an executor would add latency without adding parallelism).  Each
cycle the worker takes the longest FIFO prefix of its queue that fits
one grid dispatch: control ops (open/enroll/park/resume/close/poll)
execute inline — admission and bank updates happen BETWEEN chunk
dispatches, never inside one —
and pushes accumulate into a ragged batch, cut at the first op that
cannot join (duplicate session in batch, or batch already n_slots wide).
Strict-prefix cutting makes ordering per worker global-FIFO, which is
stronger than the per-session FIFO clients rely on.

Back-pressure
-------------
Bounded resources surface as ``Rejected`` (retryable) instead of
unbounded queueing: a full per-worker op queue (``reason="queue_full"``),
service admission failure — ``AdmissionError`` / ``PoolExhausted``
(``reason="admission"``, original exception chained) — and, with
deadlines enabled, ops that expired while queued (``reason="deadline"``).
Every retryable rejection carries a ``retry_after`` hint derived from the
worker's queue depth and an EMA of its per-op service time, so clients
back off proportionally to actual congestion instead of blindly.

Fault tolerance
---------------
Workers move through an explicit health state machine, exported as the
``plane_worker_health`` gauge::

    healthy ──drain()──> draining ──> drained ──undrain()──> healthy
       │
       └─WorkerCrashed─> crashed ──recover()──> recovering ──> healthy

*Spill journal.*  With ``checkpoint_every=1`` the plane snapshots every
session it touched after each completed op (``export_session`` — the
park/spill pack path, so snapshots are bit-exact) plus the owning
tenant's bank/rehearsal state after each enroll.  A completed op is
journaled before the worker can execute the next one, so the journal
always equals the state clients have observed: an op that dies with the
worker was never acknowledged, its retry replays from the journaled
pre-op state, and the retried stream is bit-identical.  Larger values
trade journal traffic for a bounded loss window; ``0`` (default)
disables journaling entirely.

*Crash / recover.*  ``WorkerCrashed`` (serving/faults.py) marks the
worker crashed, fails everything queued with retryable
``Rejected(reason="crash")``, and — by default — schedules ``recover``:
adopt the worker's journaled tenants, then its journaled sessions, onto
the replacement service, rebuild the plane registry, and record MTTR in
the ``plane_mttr_us`` histogram.  Sessions with no spill epoch are
counted in ``lost_sessions`` (zero under ``checkpoint_every=1`` — the
chaos suite's ratchet).

*Drain / handoff.*  ``drain(worker)`` stops new ops (retryable
``Rejected(reason="draining")``), lets the accepted queue finish, then
migrates every owned session AND every tenant's learned state (prototype
banks, label registry, rehearsal reservoirs) to healthy peers via
``detach_session``/``export_tenant`` → ``adopt_*``.  The plane registry
is updated in the same step, so ``resume``/``push`` on a handed-off
session land on the new worker — handoff and resume compose.

*Work stealing.*  With ``steal_threshold=N``, a worker whose queue runs
N ops deeper than the coldest healthy peer sheds sessions that have no
queued ops (whole tenant groups only, so banks never split) to the
peers.

Faults are injected — never emergent — via serving/faults.py, activated
by ``RuntimeConfig(chaos=...)`` / ``REPRO_CHAOS``; with the field unset
no injector exists on the call path.
"""

from __future__ import annotations

import asyncio
import math
import random
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.configs.runtime import RuntimeConfig
from repro.obs import default_registry, get_tracer
from repro.sessions import AdmissionError, SessionService
from repro.serving.faults import TransientError, WorkerCrashed

__all__ = ["Rejected", "RetryPolicy", "ServingPlane",
           "HEALTHY", "DRAINING", "DRAINED", "CRASHED", "RECOVERING"]

# worker health states (gauge codes in _HEALTH_CODE)
HEALTHY = "healthy"
DRAINING = "draining"
DRAINED = "drained"
CRASHED = "crashed"
RECOVERING = "recovering"
_HEALTH_CODE = {HEALTHY: 0, DRAINING: 1, DRAINED: 2, CRASHED: 3,
                RECOVERING: 4}

_REJECT_REASONS = ("queue_full", "admission", "deadline", "crash",
                   "draining", "transient", "no_worker")


class Rejected(RuntimeError):
    """A request the plane refused under load or failure.  ``retryable``
    is True for transient conditions (full queue, admission
    back-pressure, expired deadline, crashed/draining worker, transient
    worker fault): retry with backoff.  ``reason`` is a stable label
    ("queue_full" | "admission" | "deadline" | "crash" | "draining" |
    "transient" | "no_worker" | "closed").  ``retry_after`` (seconds),
    when set, is the plane's congestion-derived hint for the MINIMUM
    useful backoff — ``RetryPolicy.delay`` honors it."""

    def __init__(self, msg: str, *, reason: str, retryable: bool = True,
                 retry_after: float | None = None):
        super().__init__(msg)
        self.reason = reason
        self.retryable = retryable
        self.retry_after = retry_after


@dataclass
class RetryPolicy:
    """Seeded exponential backoff with jitter, floored by the server's
    ``retry_after`` hint — THE retry discipline for plane clients
    (benchmarks/serve_load.py dedupes its ad-hoc backoff onto this).
    Deterministic for a given seed, like every other component."""

    base_s: float = 0.0002
    cap_s: float = 0.05
    factor: float = 2.0
    jitter: float = 0.5     # +- fraction of the computed delay
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    def delay(self, attempt: int, retry_after: float | None = None) -> float:
        """Backoff before retry number ``attempt`` (0-based).  A server
        ``retry_after`` hint acts as a floor: backing off less than the
        server's own congestion estimate just re-feeds the storm."""
        d = min(self.cap_s, self.base_s * self.factor ** attempt)
        d *= 1.0 + self.jitter * (2.0 * self._rng.random() - 1.0)
        if retry_after is not None:
            d = max(d, retry_after)
        return d

    async def sleep(self, attempt: int,
                    retry_after: float | None = None) -> None:
        await asyncio.sleep(self.delay(attempt, retry_after))


@dataclass
class _Op:
    kind: str          # open | push | enroll | park | resume | close | poll
    fut: asyncio.Future
    sid: int | None = None       # worker-local sid (None for open)
    psid: int | None = None      # plane-level sid (known for every kind)
    work: Any = None             # push payload / enroll shots
    args: tuple = ()             # open_session positional args
    kwargs: dict = field(default_factory=dict)
    deadline: float | None = None  # absolute monotonic; checked at dequeue


class _Worker:
    """One service + its op queue + the task that owns both."""

    def __init__(self, idx: int, service: SessionService, max_queue: int):
        self.idx = idx
        self.service = service
        self.max_queue = max_queue
        self.queue: deque[_Op] = deque()
        self.wake = asyncio.Event()
        self.task: asyncio.Task | None = None
        self.live = 0  # plane-tracked open sessions (routing load signal)
        self.health = HEALTHY
        self.psid_of: dict[int, int] = {}  # local sid -> plane sid
        self.crashed_at: float | None = None
        self.ema_op_s = 1e-3       # EMA of per-op service time (retry hints)
        self.dirty: set[int] = set()          # psids awaiting a journal epoch
        self.dirty_tenants: set[int] = set()  # service tids awaiting one
        self.ops_since_ckpt = 0
        self.steal_pending = False

    @property
    def load(self) -> int:
        return len(self.queue) + self.live


class ServingPlane:
    """Asyncio front-end multiplexing sessions over SessionService workers.

    ::

        plane = ServingPlane([svc_a, svc_b])
        async with plane:
            psid = await plane.open_session(prompt, tenant="alice")
            toks = await plane.push(psid, 4)      # payload per service kind
            await plane.close(psid)

    Session ids returned here (``psid``) are plane-level: the plane maps
    them to (worker, local sid) internally, so two workers can hand out
    colliding local ids safely — and so sessions can MOVE between
    workers (drain handoff, work stealing, crash recovery) without
    clients noticing.  ``tenant=`` pins a tenant's sessions to one
    worker (stable crc32 hash over the currently-healthy workers) so
    per-tenant state — prototype banks, CoW prefix blocks — stays where
    it is warm; tenantless sessions go to the least-loaded worker.

    Fault-tolerance knobs (all off by default; see module docstring):
    ``checkpoint_every`` enables the spill journal (1 = exact recovery),
    ``default_deadline_s`` bounds queue wait per op, ``steal_threshold``
    enables work stealing, ``auto_recover`` controls whether a crashed
    worker is rebuilt immediately, and ``worker_factory`` supplies fresh
    services when ``runtime.chaos`` wraps the workers in FaultInjectors.
    """

    def __init__(self, workers: list[SessionService] | SessionService, *,
                 max_queue: int = 1024, runtime: RuntimeConfig | None = None,
                 metrics=None, tracer=None, checkpoint_every: int = 0,
                 default_deadline_s: float | None = None,
                 steal_threshold: int = 0, auto_recover: bool = True,
                 worker_factory: Callable[[], SessionService] | None = None):
        if not isinstance(workers, (list, tuple)):
            workers = [workers]
        if not workers:
            raise ValueError("ServingPlane needs at least one worker")
        self.runtime = runtime if runtime is not None else RuntimeConfig.resolve()
        self.workers = [_Worker(i, svc, max_queue)
                        for i, svc in enumerate(workers)]
        if self.runtime.chaos:
            # config-level activation: wrap each worker in a FaultInjector
            # acting out the plan.  Workers already wrapped (a test built
            # its own injectors) are left alone.
            from repro.serving.faults import FaultInjector, FaultPlan
            plan = FaultPlan.parse(self.runtime.chaos)
            for w in self.workers:
                if not isinstance(w.service, FaultInjector):
                    w.service = FaultInjector(w.service, plan,
                                              factory=worker_factory)
        self.checkpoint_every = int(checkpoint_every)
        self.default_deadline_s = default_deadline_s
        self.steal_threshold = int(steal_threshold)
        self.auto_recover = bool(auto_recover)
        self.metrics_registry = metrics if metrics is not None \
            else default_registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        reg = self.metrics_registry
        self._c_batches = reg.counter("plane_batches_total")
        self._c_enrolls = reg.counter("plane_enrolls_total")
        self._c_rejected = {r: reg.counter("plane_rejected_total", reason=r)
                            for r in _REJECT_REASONS}
        self._c_crashes = reg.counter("plane_crashes_total")
        self._c_recoveries = reg.counter("plane_recoveries_total")
        self._c_handoffs = reg.counter("plane_handoffs_total")
        self._c_steals = reg.counter("plane_steals_total")
        self._h_mttr = reg.histogram("plane_mttr_us")
        self._h_lanes = reg.histogram("plane_batch_lanes")
        self._g_depth = [reg.gauge("plane_queue_depth", worker=str(w.idx))
                         for w in self.workers]
        self._g_health = [reg.gauge("plane_worker_health", worker=str(w.idx))
                          for w in self.workers]
        self._sessions: dict[int, tuple[_Worker, int]] = {}  # psid -> (w, sid)
        self._next_psid = 0
        self._running = False
        # fault-tolerance state: the per-session spill journal, the
        # per-(worker, service tid) tenant-state journal, and explicit
        # tenant -> worker pins created by handoffs (consulted by _route
        # before the affinity hash, so moved tenants stay moved)
        self._journal: dict[int, dict] = {}
        self._tenant_journal: dict[tuple[int, int], dict] = {}
        self._tenant_home: dict[Any, int] = {}
        self._lost = 0

    # -- lifecycle -----------------------------------------------------------
    async def __aenter__(self) -> "ServingPlane":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start(self) -> None:
        if self._running:
            return
        self._running = True
        for w in self.workers:
            w.task = asyncio.ensure_future(self._run_worker(w))

    async def aclose(self) -> None:
        """Stop the workers.  Queued ops are failed with a non-retryable
        ``Rejected`` rather than silently dropped."""
        if not self._running:
            return
        self._running = False
        for w in self.workers:
            w.wake.set()
        await asyncio.gather(*(w.task for w in self.workers if w.task),
                             return_exceptions=True)
        for w in self.workers:
            while w.queue:
                op = w.queue.popleft()
                if not op.fut.done():
                    op.fut.set_exception(Rejected(
                        "plane closed", reason="closed", retryable=False))
        if self.runtime.trace_path:
            self.tracer.export(self.runtime.trace_path)

    # -- public async surface ------------------------------------------------
    async def open_session(self, *args, tenant=None,
                           deadline_s: float | None = None, **kwargs) -> int:
        """Admit a session; returns a plane-level session id.  Raises
        ``Rejected(retryable=True)`` when the target worker's queue is full
        or its service refuses admission (``AdmissionError`` — including
        ``PoolExhausted`` under the paged layout).

        ``tenant`` picks the worker (stable affinity hash over healthy
        workers, overridden by handoff pins), and for tenant-aware
        services (``service.tenant_aware``, e.g. the TCN slot grid's
        per-tenant prototype banks) it is ALSO forwarded to
        ``open_session`` so the session binds to that tenant's bank —
        every later ``enroll``/``push`` then lands on the worker holding
        the tenant's rows.  For affinity-only services (LM) it routes
        without being forwarded."""
        w = self._route(tenant)
        if tenant is not None and getattr(w.service, "tenant_aware", False):
            kwargs = {**kwargs, "tenant": tenant}
        psid = self._next_psid
        self._next_psid += 1
        op = _Op("open", self._fut(), psid=psid, args=args, kwargs=kwargs)
        self._enqueue(w, op, deadline_s)
        return await op.fut

    async def push(self, psid: int, work, *,
                   deadline_s: float | None = None) -> Any:
        """Advance one session by one service-specific work item (TCN: an
        audio chunk; LM: a token budget).  The plane groups concurrent
        pushes into one grid dispatch; the result is bit-identical to
        pushing alone."""
        w, sid = self._lookup(psid)
        op = _Op("push", self._fut(), sid=sid, psid=psid, work=work)
        self._enqueue(w, op, deadline_s)
        return await op.fut

    async def enroll(self, psid: int, shots, *,
                     deadline_s: float | None = None, **kwargs) -> int:
        """Streaming enrollment: fold shots into the session's tenant bank
        (sessions.SessionService.enroll).  Tenant affinity is free — the
        session already lives on its tenant's worker, so the bank update
        lands where the rows are warm.  Ordered FIFO with the session's
        pushes: a push enqueued after an enroll classifies against the
        updated bank."""
        w, sid = self._lookup(psid)
        op = _Op("enroll", self._fut(), sid=sid, psid=psid, work=shots,
                 kwargs=kwargs)
        self._enqueue(w, op, deadline_s)
        self._c_enrolls.inc()
        return await op.fut

    async def park(self, psid: int) -> None:
        await self._control(psid, "park")

    async def resume(self, psid: int) -> None:
        """Bind a parked session back onto a slot.  Composes with
        handoff: the registry tracks each session's CURRENT worker, and
        a session whose worker crashed before recovery ran is re-homed
        from its last spill epoch onto a healthy peer first."""
        w, _ = self._lookup(psid)
        if w.health in (CRASHED, RECOVERING):
            self._rehome(psid)
        await self._control(psid, "resume")

    async def poll(self, psid: int) -> dict:
        return await self._control(psid, "poll")

    async def close(self, psid: int) -> None:
        return await self._control(psid, "close")

    # -- worker lifecycle ----------------------------------------------------
    def undrain(self, worker: int) -> None:
        """Return a drained worker to rotation (rolling-restart exit)."""
        w = self.workers[worker]
        if w.health != DRAINED:
            raise RuntimeError(f"worker {worker} is {w.health}, not drained")
        self._set_health(w, HEALTHY)
        w.wake.set()

    async def drain(self, worker: int) -> dict:
        """Gracefully take a worker out of rotation: stop accepting ops
        (new ones get retryable ``Rejected(reason="draining")``), finish
        everything already queued, then hand EVERY owned session — and
        every tenant's learned state — to healthy peers.  Clients keep
        their psids; the registry re-points them.  Returns a summary
        dict.  Raises if no healthy peer exists or peer capacity cannot
        take the load (the worker returns to healthy in that case)."""
        w = self.workers[worker]
        if w.health != HEALTHY:
            raise RuntimeError(f"worker {worker} is {w.health}; only a "
                               "healthy worker can drain")
        if not any(p.health == HEALTHY for p in self.workers if p is not w):
            raise RuntimeError("no healthy peer to drain to")
        self._set_health(w, DRAINING)
        try:
            while w.queue:          # accepted ops finish normally
                w.wake.set()
                await asyncio.sleep(0)
            if w.health != DRAINING:
                raise RuntimeError(f"worker {worker} crashed while draining")
            extra = list(w.service.live_tenants()) \
                if getattr(w.service, "tenant_aware", False) else []
            n_sess, n_ten = self._migrate(w, sorted(w.psid_of.values()),
                                          extra_tenants=extra)
            self._c_handoffs.inc(n_sess)
        except BaseException:
            if w.health == DRAINING:
                self._set_health(w, HEALTHY)
                w.wake.set()
            raise
        self._set_health(w, DRAINED)
        return {"worker": worker, "moved_sessions": n_sess,
                "moved_tenants": n_ten}

    async def recover(self, worker: int) -> dict:
        """Rebuild a crashed worker from the spill journal: adopt its
        journaled tenants, then its journaled sessions, onto the
        replacement service and re-point the registry.  Sessions without
        a spill epoch are dropped and counted in ``lost_sessions`` (zero
        when ``checkpoint_every=1``).  Runs automatically on crash unless
        ``auto_recover=False``.  Records MTTR in ``plane_mttr_us``."""
        w = self.workers[worker]
        if w.health != CRASHED:
            return {"worker": worker, "recovered": 0, "lost": 0,
                    "skipped": f"worker is {w.health}"}
        self._set_health(w, RECOVERING)
        svc = w.service          # the fresh replacement service
        for (wi, tid), blob in sorted(self._tenant_journal.items()):
            if wi == w.idx:
                svc.adopt_tenant(tid, blob)
        w.psid_of.clear()
        mine = sorted(psid for psid, (ww, _) in self._sessions.items()
                      if ww is w)
        recovered = lost = 0
        for psid in mine:
            ent = self._journal.get(psid)
            if ent is None:
                self._sessions.pop(psid)
                lost += 1
                continue
            sid = svc.adopt_session(ent["blob"], ent["meta"])
            self._sessions[psid] = (w, sid)
            w.psid_of[sid] = psid
            recovered += 1
        w.live = recovered
        self._lost += lost
        self._set_health(w, HEALTHY)
        mttr = time.monotonic() - (w.crashed_at or time.monotonic())
        w.crashed_at = None
        self._h_mttr.record(mttr * 1e6)
        self._c_recoveries.inc()
        w.wake.set()
        return {"worker": worker, "recovered": recovered, "lost": lost,
                "mttr_s": mttr}

    # -- sync introspection --------------------------------------------------
    def metrics(self) -> dict:
        return self.metrics_registry.snapshot()

    def stats(self) -> dict:
        return {"n_workers": len(self.workers),
                "live_sessions": len(self._sessions),
                "queue_depths": [len(w.queue) for w in self.workers],
                "health": [w.health for w in self.workers],
                "lost_sessions": self._lost,
                "journal_sessions": len(self._journal),
                "workers": [w.service.stats() for w in self.workers]}

    # -- internals -----------------------------------------------------------
    def _fut(self) -> asyncio.Future:
        return asyncio.get_running_loop().create_future()

    def _lookup(self, psid: int) -> tuple[_Worker, int]:
        try:
            return self._sessions[psid]
        except KeyError:
            raise KeyError(f"unknown plane session {psid}") from None

    def _set_health(self, w: _Worker, health: str) -> None:
        w.health = health
        self._g_health[w.idx].set(_HEALTH_CODE[health])

    def _retry_hint(self, w: _Worker) -> float:
        """Congestion-derived backoff floor: what the worker's current
        queue will take to clear at its recent per-op pace."""
        return min(1.0, max(1e-3, len(w.queue) * w.ema_op_s))

    def _reject(self, reason: str, msg: str, *, retryable: bool = True,
                retry_after: float | None = None,
                cause: BaseException | None = None) -> Rejected:
        if reason in self._c_rejected:
            self._c_rejected[reason].inc()
        rej = Rejected(msg, reason=reason, retryable=retryable,
                       retry_after=retry_after)
        if cause is not None:
            rej.__cause__ = cause
        return rej

    def _route(self, tenant) -> _Worker:
        healthy = [w for w in self.workers if w.health == HEALTHY]
        if not healthy:
            raise self._reject("no_worker", "no healthy worker available",
                               retry_after=0.01)
        if tenant is not None:
            home = self._tenant_home.get(tenant)
            if home is not None and self.workers[home].health == HEALTHY:
                return self.workers[home]
            # stable across processes (hash() is salted; crc32 is not)
            h = zlib.crc32(str(tenant).encode())
            return healthy[h % len(healthy)]
        return min(healthy, key=lambda w: w.load)

    def _enqueue(self, w: _Worker, op: _Op,
                 deadline_s: float | None = None) -> None:
        if not self._running:
            raise Rejected("plane is not running", reason="closed",
                           retryable=False)
        if w.health != HEALTHY:
            if w.health in (CRASHED, RECOVERING):
                raise self._reject(
                    "crash", f"worker {w.idx} crashed; recovering from "
                    "last spill epoch", retry_after=self._retry_hint(w))
            raise self._reject(
                "draining", f"worker {w.idx} is {w.health}",
                retry_after=self._retry_hint(w))
        if len(w.queue) >= w.max_queue:
            raise self._reject(
                "queue_full",
                f"worker {w.idx} queue full ({w.max_queue} ops)",
                retry_after=self._retry_hint(w))
        deadline_s = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        if deadline_s is not None:
            op.deadline = time.monotonic() + deadline_s
        w.queue.append(op)
        self._g_depth[w.idx].set(len(w.queue))
        w.wake.set()
        self._maybe_steal(w)

    async def _control(self, psid: int, kind: str):
        w, sid = self._lookup(psid)
        op = _Op(kind, self._fut(), sid=sid, psid=psid)
        self._enqueue(w, op)
        return await op.fut

    async def _run_worker(self, w: _Worker) -> None:
        while self._running:
            if not w.queue:
                w.wake.clear()
                await w.wake.wait()
                continue
            # batching window: yield one loop tick so coroutines scheduled
            # in the same tick can enqueue before the batch is cut
            await asyncio.sleep(0)
            self._cycle(w)
            self._g_depth[w.idx].set(len(w.queue))
            await asyncio.sleep(0)  # let clients consume results / enqueue

    def _cycle(self, w: _Worker) -> None:
        """One scheduling cycle: execute the longest FIFO prefix of the
        queue that fits a single grid dispatch (see module docstring)."""
        svc = w.service
        t0 = time.monotonic()
        n_ops = 0
        batch: dict[int, Any] = {}
        futs: dict[int, asyncio.Future] = {}
        psids: dict[int, int] = {}
        while w.queue:
            op = w.queue[0]
            if op.fut.done():        # client cancelled while queued
                w.queue.popleft()
                continue
            if op.deadline is not None and time.monotonic() > op.deadline:
                w.queue.popleft()    # expired while queued: retryable
                op.fut.set_exception(self._reject(
                    "deadline",
                    f"op {op.kind} missed its deadline in worker {w.idx} "
                    f"queue", retry_after=self._retry_hint(w)))
                continue
            if op.kind == "push":
                if op.sid in batch or len(batch) >= svc.n_slots:
                    break            # cut: would break FIFO or overflow grid
                w.queue.popleft()
                batch[op.sid] = op.work
                futs[op.sid] = op.fut
                psids[op.sid] = op.psid
            else:
                if op.sid is not None and op.sid in batch:
                    break            # control on a batched sid: after dispatch
                w.queue.popleft()
                self._do_control(w, op)
                n_ops += 1
                if w.health == CRASHED:
                    # the queue was failed by _on_crash; lanes already cut
                    # into this cycle's batch must fail too, not hang
                    for sid, fut in futs.items():
                        if not fut.done():
                            fut.set_exception(self._reject(
                                "crash", f"worker {w.idx} crashed before "
                                "dispatch; retry after recovery",
                                retry_after=w.ema_op_s * 4))
                    return
        if batch:
            self._dispatch(w, batch, futs, psids)
            n_ops += len(batch)
        if n_ops:
            dt = (time.monotonic() - t0) / n_ops
            w.ema_op_s += 0.2 * (dt - w.ema_op_s)

    def _do_control(self, w: _Worker, op: _Op) -> None:
        svc = w.service
        try:
            if op.kind == "open":
                res = svc.open_session(*op.args, **op.kwargs)
            elif op.kind == "enroll":
                res = svc.enroll(op.sid, op.work, **op.kwargs)
            else:
                res = getattr(svc, op.kind)(op.sid)
        except WorkerCrashed as e:
            if not op.fut.done():
                op.fut.set_exception(self._reject(
                    "crash", f"worker {w.idx} crashed during {op.kind}; "
                    "retry after recovery", retry_after=self._retry_hint(w),
                    cause=e))
            self._on_crash(w)
            return
        except TransientError as e:
            if not op.fut.done():
                op.fut.set_exception(self._reject(
                    "transient", f"transient worker failure: {e}",
                    retry_after=self._retry_hint(w), cause=e))
            return
        except AdmissionError as e:
            if not op.fut.done():
                op.fut.set_exception(self._reject(
                    "admission", f"admission refused: {e}",
                    retry_after=self._retry_hint(w), cause=e))
            return
        except Exception as e:
            if not op.fut.done():
                op.fut.set_exception(e)
            return
        if op.kind == "open":
            sid = res
            if op.fut.done():
                # client cancelled while queued: the service session must
                # not leak — close it (best effort; a fault here is a
                # normal crash)
                try:
                    svc.close(sid)
                except WorkerCrashed:
                    self._on_crash(w)
                except Exception:
                    pass
                return
            self._sessions[op.psid] = (w, sid)
            w.psid_of[sid] = op.psid
            w.live += 1
            op.fut.set_result(op.psid)
            self._mark_dirty(w, op.psid, tenant=self._tid_of(w, sid))
            return
        if op.kind == "close":
            self._forget(w, op.sid, op.psid)
        elif op.kind == "enroll":
            self._mark_dirty(w, op.psid, tenant=self._tid_of(w, op.sid))
        if not op.fut.done():
            op.fut.set_result(res)

    def _forget(self, w: _Worker, sid: int, psid: int) -> None:
        w.psid_of.pop(sid, None)
        if self._sessions.pop(psid, None) is not None:
            w.live -= 1
        self._journal.pop(psid, None)
        w.dirty.discard(psid)

    def _dispatch(self, w: _Worker, batch: dict[int, Any],
                  futs: dict[int, asyncio.Future],
                  psids: dict[int, int]) -> None:
        # drop lanes whose client cancelled between enqueue and dispatch:
        # their session must NOT advance (the client saw no result)
        live = {sid: wk for sid, wk in batch.items()
                if not futs[sid].done()}
        if not live:
            return
        self._c_batches.inc()
        self._h_lanes.record(len(live))
        try:
            with self.tracer.span("plane_batch", cat="plane",
                                  worker=w.idx, lanes=len(live)):
                out = w.service.push(live)
        except WorkerCrashed:
            for sid in live:
                if not futs[sid].done():
                    futs[sid].set_exception(self._reject(
                        "crash", f"worker {w.idx} crashed mid-batch; "
                        "retry after recovery",
                        retry_after=self._retry_hint(w)))
            self._on_crash(w)
            return
        except TransientError as e:
            # injected BEFORE any state advanced: every lane is safe to
            # retry verbatim
            for sid in live:
                if not futs[sid].done():
                    futs[sid].set_exception(self._reject(
                        "transient", f"transient worker failure: {e}",
                        retry_after=self._retry_hint(w), cause=e))
            return
        except Exception:
            # one lane's failure must not poison its batchmates: re-run
            # each lane alone (bit-identical by chunk invariance) so only
            # the offending session sees its exception
            rest = list(live.items())
            for i, (sid, wk) in enumerate(rest):
                if futs[sid].done():
                    continue
                try:
                    res = w.service.push({sid: wk})[sid]
                except WorkerCrashed:
                    for s, _ in rest[i:]:
                        if not futs[s].done():
                            futs[s].set_exception(self._reject(
                                "crash", f"worker {w.idx} crashed "
                                "mid-batch; retry after recovery",
                                retry_after=self._retry_hint(w)))
                    self._on_crash(w)
                    return
                except TransientError as e:
                    futs[sid].set_exception(self._reject(
                        "transient", f"transient worker failure: {e}",
                        retry_after=self._retry_hint(w), cause=e))
                    continue
                except Exception as e:
                    futs[sid].set_exception(e)
                    continue
                futs[sid].set_result(res)
                self._mark_dirty(w, psids[sid])
            return
        for sid, res in out.items():
            if not futs[sid].done():
                futs[sid].set_result(res)
            self._mark_dirty(w, psids[sid])

    # -- spill journal -------------------------------------------------------
    def _tid_of(self, w: _Worker, sid: int) -> int | None:
        """The service-side tenant id a session's state references, or
        None for tenantless/LM sessions.  Reads the spill meta directly
        (NOT a protocol verb, so the fault clock never ticks for the
        plane's own bookkeeping)."""
        if not getattr(w.service, "tenant_aware", False):
            return None
        t = w.service._session_spill_meta(sid).get("tenant")
        return int(t) if t is not None and int(t) >= 0 else None

    def _mark_dirty(self, w: _Worker, psid: int,
                    tenant: int | None = None) -> None:
        if not self.checkpoint_every or psid is None:
            return
        w.dirty.add(psid)
        if tenant is not None:
            w.dirty_tenants.add(tenant)
        w.ops_since_ckpt += 1
        if w.ops_since_ckpt >= self.checkpoint_every:
            self._flush_journal(w)

    def _flush_journal(self, w: _Worker) -> None:
        """One spill epoch: snapshot every touched tenant and session.
        With ``checkpoint_every=1`` this runs synchronously after EACH
        completed op — before the worker can take another — so the
        journal never lags an acknowledged result."""
        for tid in sorted(w.dirty_tenants):
            try:
                self._tenant_journal[(w.idx, tid)] = \
                    w.service.export_tenant(tid)
            except KeyError:
                self._tenant_journal.pop((w.idx, tid), None)
        for psid in sorted(w.dirty):
            ent = self._sessions.get(psid)
            if ent is None or ent[0] is not w:
                continue
            try:
                blob, meta = w.service.export_session(ent[1])
            except (KeyError, RuntimeError):
                continue    # retired/stateless: keep the previous epoch
            self._journal[psid] = {"blob": blob, "meta": meta}
        w.dirty.clear()
        w.dirty_tenants.clear()
        w.ops_since_ckpt = 0

    # -- crash handling ------------------------------------------------------
    def _on_crash(self, w: _Worker) -> None:
        """The worker's in-memory state is gone (WorkerCrashed surfaced).
        Fail everything it had accepted — none of it can run against the
        fresh service — and schedule recovery."""
        if w.health in (CRASHED, RECOVERING):
            return
        self._set_health(w, CRASHED)
        w.crashed_at = time.monotonic()
        self._c_crashes.inc()
        while w.queue:
            op = w.queue.popleft()
            if not op.fut.done():
                op.fut.set_exception(self._reject(
                    "crash", f"worker {w.idx} crashed; queued op dropped, "
                    "retry after recovery", retry_after=w.ema_op_s * 4))
        self._g_depth[w.idx].set(0)
        w.dirty.clear()
        w.dirty_tenants.clear()
        w.ops_since_ckpt = 0
        if self.auto_recover and self._running:
            asyncio.ensure_future(self.recover(w.idx))

    def _rehome(self, psid: int) -> None:
        """Re-adopt a session — and, for tenant-aware services, its whole
        journaled tenant group, so a bank is never split — from the spill
        journal onto a healthy peer while its old worker is still down
        (``auto_recover=False`` or recovery not yet scheduled)."""
        w, sid = self._sessions[psid]
        ent = self._journal.get(psid)
        if ent is None:
            raise self._reject(
                "crash", f"worker {w.idx} crashed and session {psid} has "
                "no spill epoch to re-home from", retryable=False)
        peers = [p for p in self.workers
                 if p is not w and p.health == HEALTHY]
        if not peers:
            raise self._reject("no_worker",
                               "no healthy worker to re-home onto",
                               retry_after=0.01)
        p = min(peers, key=lambda q: q.load)
        tid = ent["meta"].get("tenant")
        tid = int(tid) if tid is not None and int(tid) >= 0 else None
        group = [psid]
        new_tid = None
        if tid is not None:
            group = sorted(
                q for q, (ww, _) in self._sessions.items()
                if ww is w and q in self._journal
                and self._journal[q]["meta"].get("tenant") == tid)
            tblob = self._tenant_journal.get((w.idx, tid))
            if tblob is None:
                raise self._reject(
                    "crash", f"tenant {tid} has no journaled bank state to "
                    "re-home with", retryable=False)
            try:
                new_tid = p.service.adopt_tenant(tid, tblob)
            except ValueError:
                new_tid = p.service.adopt_tenant(None, tblob)
            del self._tenant_journal[(w.idx, tid)]
            self._tenant_journal[(p.idx, new_tid)] = tblob
            if any(not self._journal[q]["meta"].get("dedicated", False)
                   for q in group):
                self._tenant_home[tid] = p.idx
        for q in group:
            e = self._journal[q]
            meta = e["meta"]
            if new_tid is not None and new_tid != tid:
                meta = {**meta, "tenant": new_tid}
                self._journal[q] = {"blob": e["blob"], "meta": meta}
            sid2 = p.service.adopt_session(e["blob"], meta)
            old_sid = self._sessions[q][1]
            w.psid_of.pop(old_sid, None)
            w.live -= 1
            self._sessions[q] = (p, sid2)
            p.psid_of[sid2] = q
            p.live += 1
            self._c_handoffs.inc()

    # -- handoff / stealing --------------------------------------------------
    def _migrate(self, w: _Worker, psids: list[int],
                 extra_tenants: list[int] = ()) -> tuple[int, int]:
        """Move the given plane sessions — and every affected tenant's
        learned state — from ``w`` onto healthy peers, updating the
        registry so clients never notice.  The caller must pass tenant
        groups WHOLE (all of a tenant's sessions on ``w`` or none);
        ``extra_tenants`` moves enrolled-but-idle tenant rows too (full
        drain).  Capacity is planned before the first mutation, so a
        refused migration leaves everything in place."""
        svc = w.service
        tenant_aware = getattr(svc, "tenant_aware", False)
        peers = [p for p in self.workers
                 if p is not w and p.health == HEALTHY]
        if not peers:
            raise RuntimeError("no healthy peer to migrate to")
        groups: dict[int, list[int]] = {}
        singles: list[int] = []
        for psid in psids:
            ww, sid = self._sessions[psid]
            if ww is not w:
                raise ValueError(f"session {psid} is not on worker {w.idx}")
            tid = self._tid_of(w, sid) if tenant_aware else None
            if tid is None:
                singles.append(psid)
            else:
                groups.setdefault(tid, []).append(psid)
        for tid in extra_tenants:
            groups.setdefault(int(tid), [])
        # plan placement against peer admission capacity BEFORE mutating
        def _cap(p: _Worker) -> float:
            sched = getattr(p.service, "sched", None)
            ms = getattr(sched, "max_sessions", None)
            return math.inf if ms is None else ms - sched.live_sessions
        avail = {p.idx: _cap(p) for p in peers}
        t_plan: dict[int, _Worker] = {}
        s_plan: dict[int, _Worker] = {}

        def _place(n: int) -> _Worker:
            ok = [p for p in peers if avail[p.idx] >= n]
            if not ok:
                raise RuntimeError(
                    f"no healthy peer has capacity for {n} migrating "
                    "sessions")
            p = min(ok, key=lambda q: q.load)
            avail[p.idx] -= n
            return p

        for tid, members in sorted(groups.items(),
                                   key=lambda kv: -len(kv[1])):
            t_plan[tid] = _place(len(members))
        for psid in singles:
            s_plan[psid] = _place(1)
        # execute: per tenant group, then tenantless singles
        n_sessions = 0
        for tid, members in sorted(groups.items()):
            p = t_plan[tid]
            detached = [(psid,) + svc.detach_session(self._sessions[psid][1])
                        for psid in members]
            for psid, _, _ in detached:
                old_sid = self._sessions[psid][1]
                w.psid_of.pop(old_sid, None)
            tblob = svc.export_tenant(tid)
            svc.close_tenant(tid)
            try:
                new_tid = p.service.adopt_tenant(tid, tblob)
            except ValueError:
                new_tid = p.service.adopt_tenant(None, tblob)
            jkey = (w.idx, tid)
            if jkey in self._tenant_journal:
                del self._tenant_journal[jkey]
            if self.checkpoint_every:
                self._tenant_journal[(p.idx, new_tid)] = tblob
            dedicated_only = True
            for psid, blob, meta in detached:
                if new_tid != tid:
                    meta = {**meta, "tenant": new_tid}
                if not meta.get("dedicated", False):
                    dedicated_only = False
                sid2 = p.service.adopt_session(blob, meta)
                self._sessions[psid] = (p, sid2)
                p.psid_of[sid2] = psid
                p.live += 1
                w.live -= 1
                if self.checkpoint_every:
                    self._journal[psid] = {"blob": blob, "meta": meta}
                n_sessions += 1
            if not (dedicated_only and detached):
                # pin explicit plane tenants to the new worker; dedicated
                # rows have service-local ids no client routes by
                self._tenant_home[tid] = p.idx
        for psid in singles:
            p = s_plan[psid]
            blob, meta = svc.detach_session(self._sessions[psid][1])
            old_sid = self._sessions[psid][1]
            w.psid_of.pop(old_sid, None)
            sid2 = p.service.adopt_session(blob, meta)
            self._sessions[psid] = (p, sid2)
            p.psid_of[sid2] = psid
            p.live += 1
            w.live -= 1
            if self.checkpoint_every:
                self._journal[psid] = {"blob": blob, "meta": meta}
            n_sessions += 1
        return n_sessions, len(groups)

    def _maybe_steal(self, w: _Worker) -> None:
        """Queue-skew trigger (called on every enqueue): when this
        worker's queue runs ``steal_threshold`` ops deeper than the
        coldest healthy peer's, shed idle sessions to the peers."""
        if not self.steal_threshold or w.steal_pending \
                or w.health != HEALTHY:
            return
        peers = [p for p in self.workers
                 if p is not w and p.health == HEALTHY]
        if not peers:
            return
        cold = min(peers, key=lambda p: len(p.queue))
        if len(w.queue) - len(cold.queue) < self.steal_threshold:
            return
        w.steal_pending = True
        asyncio.ensure_future(self._steal(w))

    async def _steal(self, w: _Worker) -> None:
        try:
            if w.health != HEALTHY:
                return
            queued = {op.sid for op in w.queue if op.sid is not None}
            tenant_aware = getattr(w.service, "tenant_aware", False)
            # candidates: sessions with nothing queued; whole tenant
            # groups only, so a bank never splits across workers
            sids_of_tid: dict[int, list[int]] = {}
            cands: list[int] = []
            for sid, psid in w.psid_of.items():
                tid = self._tid_of(w, sid) if tenant_aware else None
                if tid is None:
                    if sid not in queued:
                        cands.append(psid)
                else:
                    sids_of_tid.setdefault(tid, []).append(sid)
            for tid, sids in sids_of_tid.items():
                if all(s not in queued for s in sids):
                    cands.extend(w.psid_of[s] for s in sids)
            if not cands:
                return
            take = sorted(cands)[:max(1, len(cands) // 2)]
            n, _ = self._migrate(w, take)
            self._c_steals.inc(n)
        except RuntimeError:
            pass      # no peer capacity right now; the trigger will refire
        finally:
            w.steal_pending = False
