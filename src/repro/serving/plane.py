"""Async continuous-batching request plane over SessionService workers.

The device-side machinery (chunk-native scans, O(1) paged admission,
cost-aware eviction) already tolerates sessions joining and leaving
between chunk dispatches; what was missing is a front-end that exploits
it.  ``ServingPlane`` is that front-end: an asyncio facade that accepts
ragged per-session pushes, accumulates whatever arrived while the grid
was busy into the next continuous batch, and drives one or more
slot-grid workers (any ``sessions.SessionService``) with tenant-affinity
routing.

Bit-identity contract
---------------------
The plane NEVER changes what a session computes — only when its work is
grouped with other sessions' work.  This rides directly on the services'
chunk-invariance guarantee: absent lanes in a ``push`` dispatch stay
bit-frozen and every lane's computation depends only on its own state
and payload, so ``push({a: wa, b: wb})`` gives each of a and b exactly
the bits of ``push({a: wa})`` / ``push({b: wb})`` run alone.  The load
bench (benchmarks/serve_load.py) holds this end to end against a
synchronous control replay.

Concurrency model
-----------------
One asyncio worker task per service owns ALL mutation of that service —
there are no locks because there is no cross-task sharing.  Compiled
dispatches run synchronously inside the worker task (they hold the GIL
anyway; an executor would add latency without adding parallelism).  Each
cycle the worker takes the longest FIFO prefix of its queue that fits
one grid dispatch: control ops (open/enroll/park/resume/close/poll)
execute inline — admission and bank updates happen BETWEEN chunk
dispatches, never inside one —
and pushes accumulate into a ragged batch, cut at the first op that
cannot join (duplicate session in batch, or batch already n_slots wide).
Strict-prefix cutting makes ordering per worker global-FIFO, which is
stronger than the per-session FIFO clients rely on.

Back-pressure
-------------
Two bounded resources surface as ``Rejected`` (retryable) instead of
unbounded queueing: a full per-worker op queue (``reason="queue_full"``)
and service admission failure — ``AdmissionError`` / ``PoolExhausted``
(``reason="admission"``, original exception chained).  Clients retry
with backoff; the load bench measures goodput under exactly this churn.
"""

from __future__ import annotations

import asyncio
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.configs.runtime import RuntimeConfig
from repro.obs import default_registry, get_tracer
from repro.sessions import AdmissionError, SessionService

__all__ = ["Rejected", "ServingPlane"]


class Rejected(RuntimeError):
    """A request the plane refused under load.  ``retryable`` is True for
    transient capacity conditions (full queue, admission back-pressure):
    retry with backoff.  ``reason`` is a stable label ("queue_full" |
    "admission" | "closed")."""

    def __init__(self, msg: str, *, reason: str, retryable: bool = True):
        super().__init__(msg)
        self.reason = reason
        self.retryable = retryable


@dataclass
class _Op:
    kind: str          # open | push | enroll | park | resume | close | poll
    fut: asyncio.Future
    sid: int | None = None       # worker-local sid (None for open)
    work: Any = None             # push payload / enroll shots
    args: tuple = ()             # open_session positional args
    kwargs: dict = field(default_factory=dict)


class _Worker:
    """One service + its op queue + the task that owns both."""

    def __init__(self, idx: int, service: SessionService, max_queue: int):
        self.idx = idx
        self.service = service
        self.max_queue = max_queue
        self.queue: deque[_Op] = deque()
        self.wake = asyncio.Event()
        self.task: asyncio.Task | None = None
        self.live = 0  # plane-tracked open sessions (routing load signal)

    @property
    def load(self) -> int:
        return len(self.queue) + self.live


class ServingPlane:
    """Asyncio front-end multiplexing sessions over SessionService workers.

    ::

        plane = ServingPlane([svc_a, svc_b])
        async with plane:
            psid = await plane.open_session(prompt, tenant="alice")
            toks = await plane.push(psid, 4)      # payload per service kind
            await plane.close(psid)

    Session ids returned here (``psid``) are plane-level: the plane maps
    them to (worker, local sid) internally, so two workers can hand out
    colliding local ids safely.  ``tenant=`` pins a tenant's sessions to
    one worker (stable crc32 hash) so per-tenant state — prototype banks,
    CoW prefix blocks — stays where it is warm; tenantless sessions go to
    the least-loaded worker.
    """

    def __init__(self, workers: list[SessionService] | SessionService, *,
                 max_queue: int = 1024, runtime: RuntimeConfig | None = None,
                 metrics=None, tracer=None):
        if not isinstance(workers, (list, tuple)):
            workers = [workers]
        if not workers:
            raise ValueError("ServingPlane needs at least one worker")
        self.runtime = runtime if runtime is not None else RuntimeConfig.resolve()
        self.workers = [_Worker(i, svc, max_queue)
                        for i, svc in enumerate(workers)]
        self.metrics_registry = metrics if metrics is not None \
            else default_registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        reg = self.metrics_registry
        self._c_batches = reg.counter("plane_batches_total")
        self._c_enrolls = reg.counter("plane_enrolls_total")
        self._c_rejected = {r: reg.counter("plane_rejected_total", reason=r)
                            for r in ("queue_full", "admission")}
        self._h_lanes = reg.histogram("plane_batch_lanes")
        self._g_depth = [reg.gauge("plane_queue_depth", worker=str(w.idx))
                         for w in self.workers]
        self._sessions: dict[int, tuple[_Worker, int]] = {}  # psid -> (w, sid)
        self._next_psid = 0
        self._running = False

    # -- lifecycle -----------------------------------------------------------
    async def __aenter__(self) -> "ServingPlane":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start(self) -> None:
        if self._running:
            return
        self._running = True
        for w in self.workers:
            w.task = asyncio.ensure_future(self._run_worker(w))

    async def aclose(self) -> None:
        """Stop the workers.  Queued ops are failed with a non-retryable
        ``Rejected`` rather than silently dropped."""
        if not self._running:
            return
        self._running = False
        for w in self.workers:
            w.wake.set()
        await asyncio.gather(*(w.task for w in self.workers if w.task),
                             return_exceptions=True)
        for w in self.workers:
            while w.queue:
                op = w.queue.popleft()
                if not op.fut.done():
                    op.fut.set_exception(Rejected(
                        "plane closed", reason="closed", retryable=False))
        if self.runtime.trace_path:
            self.tracer.export(self.runtime.trace_path)

    # -- public async surface ------------------------------------------------
    async def open_session(self, *args, tenant=None, **kwargs) -> int:
        """Admit a session; returns a plane-level session id.  Raises
        ``Rejected(retryable=True)`` when the target worker's queue is full
        or its service refuses admission (``AdmissionError`` — including
        ``PoolExhausted`` under the paged layout).

        ``tenant`` picks the worker (stable affinity hash), and for
        tenant-aware services (``service.tenant_aware``, e.g. the TCN
        slot grid's per-tenant prototype banks) it is ALSO forwarded to
        ``open_session`` so the session binds to that tenant's bank —
        every later ``enroll``/``push`` then lands on the worker holding
        the tenant's rows.  For affinity-only services (LM) it routes
        without being forwarded."""
        w = self._route(tenant)
        if tenant is not None and getattr(w.service, "tenant_aware", False):
            kwargs = {**kwargs, "tenant": tenant}
        op = _Op("open", self._fut(), args=args, kwargs=kwargs)
        self._enqueue(w, op)
        sid = await op.fut
        psid = self._next_psid
        self._next_psid += 1
        self._sessions[psid] = (w, sid)
        w.live += 1
        return psid

    async def push(self, psid: int, work) -> Any:
        """Advance one session by one service-specific work item (TCN: an
        audio chunk; LM: a token budget).  The plane groups concurrent
        pushes into one grid dispatch; the result is bit-identical to
        pushing alone."""
        w, sid = self._lookup(psid)
        op = _Op("push", self._fut(), sid=sid, work=work)
        self._enqueue(w, op)
        return await op.fut

    async def enroll(self, psid: int, shots, **kwargs) -> int:
        """Streaming enrollment: fold shots into the session's tenant bank
        (sessions.SessionService.enroll).  Tenant affinity is free — the
        session already lives on its tenant's worker, so the bank update
        lands where the rows are warm.  Ordered FIFO with the session's
        pushes: a push enqueued after an enroll classifies against the
        updated bank."""
        w, sid = self._lookup(psid)
        op = _Op("enroll", self._fut(), sid=sid, work=shots, kwargs=kwargs)
        self._enqueue(w, op)
        self._c_enrolls.inc()
        return await op.fut

    async def park(self, psid: int) -> None:
        await self._control(psid, "park")

    async def resume(self, psid: int) -> None:
        await self._control(psid, "resume")

    async def poll(self, psid: int) -> dict:
        return await self._control(psid, "poll")

    async def close(self, psid: int) -> None:
        res = await self._control(psid, "close")
        w, _ = self._sessions.pop(psid)
        w.live -= 1
        return res

    # -- sync introspection --------------------------------------------------
    def metrics(self) -> dict:
        return self.metrics_registry.snapshot()

    def stats(self) -> dict:
        return {"n_workers": len(self.workers),
                "live_sessions": len(self._sessions),
                "queue_depths": [len(w.queue) for w in self.workers],
                "workers": [w.service.stats() for w in self.workers]}

    # -- internals -----------------------------------------------------------
    def _fut(self) -> asyncio.Future:
        return asyncio.get_running_loop().create_future()

    def _lookup(self, psid: int) -> tuple[_Worker, int]:
        try:
            return self._sessions[psid]
        except KeyError:
            raise KeyError(f"unknown plane session {psid}") from None

    def _route(self, tenant) -> _Worker:
        if tenant is not None:
            # stable across processes (hash() is salted; crc32 is not)
            h = zlib.crc32(str(tenant).encode())
            return self.workers[h % len(self.workers)]
        return min(self.workers, key=lambda w: w.load)

    def _enqueue(self, w: _Worker, op: _Op) -> None:
        if not self._running:
            raise Rejected("plane is not running", reason="closed",
                           retryable=False)
        if len(w.queue) >= w.max_queue:
            self._c_rejected["queue_full"].inc()
            raise Rejected(f"worker {w.idx} queue full "
                           f"({w.max_queue} ops)", reason="queue_full")
        w.queue.append(op)
        self._g_depth[w.idx].set(len(w.queue))
        w.wake.set()

    async def _control(self, psid: int, kind: str):
        w, sid = self._lookup(psid)
        op = _Op(kind, self._fut(), sid=sid)
        self._enqueue(w, op)
        return await op.fut

    async def _run_worker(self, w: _Worker) -> None:
        while self._running:
            if not w.queue:
                w.wake.clear()
                await w.wake.wait()
                continue
            # batching window: yield one loop tick so coroutines scheduled
            # in the same tick can enqueue before the batch is cut
            await asyncio.sleep(0)
            self._cycle(w)
            self._g_depth[w.idx].set(len(w.queue))
            await asyncio.sleep(0)  # let clients consume results / enqueue

    def _cycle(self, w: _Worker) -> None:
        """One scheduling cycle: execute the longest FIFO prefix of the
        queue that fits a single grid dispatch (see module docstring)."""
        svc = w.service
        batch: dict[int, Any] = {}
        futs: dict[int, asyncio.Future] = {}
        while w.queue:
            op = w.queue[0]
            if op.fut.done():        # client cancelled while queued
                w.queue.popleft()
                continue
            if op.kind == "push":
                if op.sid in batch or len(batch) >= svc.n_slots:
                    break            # cut: would break FIFO or overflow grid
                w.queue.popleft()
                batch[op.sid] = op.work
                futs[op.sid] = op.fut
            else:
                if op.sid is not None and op.sid in batch:
                    break            # control on a batched sid: after dispatch
                w.queue.popleft()
                self._do_control(svc, op)
        if batch:
            self._dispatch(w, batch, futs)

    def _do_control(self, svc: SessionService, op: _Op) -> None:
        try:
            if op.kind == "open":
                res = svc.open_session(*op.args, **op.kwargs)
            elif op.kind == "enroll":
                res = svc.enroll(op.sid, op.work, **op.kwargs)
            else:
                res = getattr(svc, op.kind)(op.sid)
        except AdmissionError as e:
            self._c_rejected["admission"].inc()
            rej = Rejected(f"admission refused: {e}", reason="admission")
            rej.__cause__ = e
            if not op.fut.done():
                op.fut.set_exception(rej)
            return
        except Exception as e:
            if not op.fut.done():
                op.fut.set_exception(e)
            return
        if not op.fut.done():
            op.fut.set_result(res)

    def _dispatch(self, w: _Worker, batch: dict[int, Any],
                  futs: dict[int, asyncio.Future]) -> None:
        # drop lanes whose client cancelled between enqueue and dispatch:
        # their session must NOT advance (the client saw no result)
        live = {sid: wk for sid, wk in batch.items()
                if not futs[sid].done()}
        if not live:
            return
        self._c_batches.inc()
        self._h_lanes.record(len(live))
        try:
            with self.tracer.span("plane_batch", cat="plane",
                                  worker=w.idx, lanes=len(live)):
                out = w.service.push(live)
        except Exception:
            # one lane's failure must not poison its batchmates: re-run
            # each lane alone (bit-identical by chunk invariance) so only
            # the offending session sees its exception
            out = {}
            for sid, wk in live.items():
                try:
                    out.update(w.service.push({sid: wk}))
                except Exception as e:
                    if not futs[sid].done():
                        futs[sid].set_exception(e)
        for sid, res in out.items():
            if not futs[sid].done():
                futs[sid].set_result(res)
