from repro.serving.engine import LMServer, ServeConfig, TCNStreamServer

__all__ = ["LMServer", "ServeConfig", "TCNStreamServer"]
