from repro.serving.engine import LMServer, ServeConfig, TCNStreamServer
from repro.serving.plane import Rejected, ServingPlane

__all__ = ["LMServer", "ServeConfig", "TCNStreamServer",
           "Rejected", "ServingPlane"]
