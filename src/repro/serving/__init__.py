from repro.serving.engine import LMServer, ServeConfig, TCNStreamServer
from repro.serving.faults import (FaultInjector, FaultPlan, TransientError,
                                  WorkerCrashed)
from repro.serving.plane import (CRASHED, DRAINED, DRAINING, HEALTHY,
                                 RECOVERING, Rejected, RetryPolicy,
                                 ServingPlane)

__all__ = ["LMServer", "ServeConfig", "TCNStreamServer",
           "Rejected", "RetryPolicy", "ServingPlane",
           "FaultInjector", "FaultPlan", "WorkerCrashed", "TransientError",
           "HEALTHY", "DRAINING", "DRAINED", "CRASHED", "RECOVERING"]
