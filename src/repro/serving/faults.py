"""Deterministic fault injection for the serving plane.

The paper's pitch for on-device learning is long-term robustness at the
edge; this module is how the serving plane's robustness is *verified*
rather than asserted.  A ``FaultPlan`` is a seeded, fully deterministic
schedule of faults indexed by per-worker protocol-verb invocation count
(NOT wall clock — the chaos suite's assertions must be independent of
event-loop interleaving), and a ``FaultInjector`` wraps any
``SessionService`` to act it out:

    crash   the worker process "dies": the wrapped service is swapped for
            a FRESH one from the factory (every slot column, block table,
            tenant bank, and rehearsal buffer is gone) and
            ``WorkerCrashed`` propagates to the plane, which recovers the
            worker from its last spill epoch (serving/plane.py).
    slow    the verb stalls for a fixed interval before executing —
            deadline-enforcement fuel.
    storm   ``open_session`` raises ``PoolExhausted`` for a span of ops
            (admission back-pressure storm); other verbs pass through.
    flake   one ``push``/``enroll`` raises ``TransientError`` BEFORE any
            state advances — an honest retryable failure.

Plan spec format (``FaultPlan.parse``), comma-separated events::

    crash@40            crash on the 40th verb invocation (0-based)
    slow@10x5:0.002     stall 2ms on ops [10, 15)
    storm@60x20         PoolExhausted opens on ops [60, 80)
    flake@25            TransientError on op 25 (push/enroll only)

``FaultPlan.seeded(seed, horizon, ...)`` draws a jittered periodic
schedule from rates — same seed, same plan, byte for byte.

Activation is config-level: ``RuntimeConfig(chaos="crash@40,...")`` (env
``REPRO_CHAOS``) makes ``ServingPlane`` wrap its workers itself; with the
field unset no injector exists anywhere on the call path — production is
untouched by construction, not by an ``if`` per verb.
"""

from __future__ import annotations

import random
import re
import time
from dataclasses import dataclass
from typing import Callable

from repro.sessions.paging import PoolExhausted

# canonical env var for the chaos plan spec; configs/runtime.py mirrors it
# (pinned equal in tests/test_service_protocol.py like every other switch)
ENV_VAR = "REPRO_CHAOS"


class WorkerCrashed(RuntimeError):
    """The worker's in-memory state is GONE (simulated process death).
    The plane must not retry against the fresh service — every session it
    held has to be re-adopted from the last spill epoch first."""


class TransientError(RuntimeError):
    """A one-shot failure that did NOT advance any state; safe to retry
    verbatim (surfaced to clients as ``Rejected(reason="transient")``)."""


_EVENT_RE = re.compile(
    r"^(?P<kind>crash|slow|storm|flake)@(?P<at>\d+)"
    r"(?:x(?P<span>\d+))?(?::(?P<seconds>[0-9.]+))?$")


@dataclass(frozen=True)
class FaultEvent:
    at: int              # 0-based verb-invocation index on the worker
    kind: str            # crash | slow | storm | flake
    span: int = 1        # ops covered: [at, at + span)
    seconds: float = 0.0  # slow: injected stall per op

    def active(self, i: int) -> bool:
        return self.at <= i < self.at + self.span

    def spec(self) -> str:
        s = f"{self.kind}@{self.at}"
        if self.span != 1:
            s += f"x{self.span}"
        if self.seconds:
            s += f":{self.seconds:g}"
        return s


class FaultPlan:
    """An immutable, order-normalized schedule of ``FaultEvent``s."""

    def __init__(self, events=()):
        self.events = tuple(sorted(events, key=lambda e: (e.at, e.kind)))
        self._horizon = max((e.at + e.span for e in self.events), default=0)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __eq__(self, other) -> bool:
        return isinstance(other, FaultPlan) and self.events == other.events

    def __repr__(self) -> str:
        return f"FaultPlan({self.spec()!r})"

    def spec(self) -> str:
        """Round-trips through ``parse`` — what the bench writes into its
        report so a failure is reproducible from the JSON alone."""
        return ",".join(e.spec() for e in self.events)

    def at(self, i: int) -> list[FaultEvent]:
        if i >= self._horizon:
            return []
        return [e for e in self.events if e.active(i)]

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        events = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad fault event {part!r}; expected "
                    "kind@at[xspan][:seconds] with kind in "
                    "crash|slow|storm|flake")
            events.append(FaultEvent(
                at=int(m["at"]), kind=m["kind"],
                span=int(m["span"] or 1),
                seconds=float(m["seconds"] or 0.0)))
        return cls(events)

    @classmethod
    def seeded(cls, seed: int, horizon: int, *, crash_every: int = 0,
               slow_every: int = 0, slow_s: float = 0.002,
               storm_every: int = 0, storm_span: int = 8,
               flake_every: int = 0) -> "FaultPlan":
        """Jittered-periodic schedule over ``horizon`` ops: each enabled
        kind fires roughly every N ops, with the phase drawn from a
        seeded RNG (uniform over the period) so plans differ across
        workers/seeds but are bit-reproducible for a given seed."""
        rng = random.Random(seed)
        events = []

        def lay(kind, every, **kw):
            if not every:
                return
            start = rng.randrange(max(1, every))
            for base in range(start, horizon, every):
                at = base + rng.randrange(max(1, every // 4 + 1))
                if at < horizon:
                    events.append(FaultEvent(at=at, kind=kind, **kw))

        lay("crash", crash_every)
        lay("slow", slow_every, seconds=slow_s)
        lay("storm", storm_every, span=storm_span)
        lay("flake", flake_every)
        return cls(events)


class FaultInjector:
    """Wrap a ``SessionService`` and act out a ``FaultPlan``.

    Only the protocol verbs are intercepted and counted; every other
    attribute (``n_slots``, ``stats``, the handoff/journal hooks the
    plane itself drives — ``export_session``, ``adopt_session``, ...)
    delegates straight to the wrapped service, so the plane's OWN
    recovery machinery can never trip a fault while repairing one.

    A crash swaps in ``factory()`` — a fresh service with the same
    geometry — and raises ``WorkerCrashed`` before any delegation, so the
    fault is atomic: an op either fully happened or not at all.
    """

    VERBS = ("open_session", "push", "enroll", "park", "resume",
             "close", "poll")

    def __init__(self, service=None, plan: FaultPlan | None = None, *,
                 factory: Callable[[], object] | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        if service is None:
            if factory is None:
                raise ValueError("need a service or a factory")
            service = factory()
        plan = plan or FaultPlan()
        if any(e.kind == "crash" for e in plan.events) and factory is None:
            raise ValueError(
                "plan injects crashes but no factory= was given to rebuild "
                "the worker's service; crash recovery needs one")
        self.service = service
        self.plan = plan
        self.factory = factory
        self._sleep = sleep
        self.ops = 0          # verb invocations seen (the plan's clock)
        self.crashes = 0
        self.faults: list[tuple[int, str]] = []  # (op index, kind) fired

    # -- the faulting gate --------------------------------------------------
    def _gate(self, verb: str) -> None:
        i = self.ops
        self.ops += 1
        for ev in self.plan.at(i):
            if ev.kind == "slow":
                self.faults.append((i, "slow"))
                self._sleep(ev.seconds)
            elif ev.kind == "crash":
                self.faults.append((i, "crash"))
                self.crashes += 1
                self.service = self.factory()
                raise WorkerCrashed(
                    f"injected crash at op {i} ({verb}); in-memory state "
                    "dropped")
            elif ev.kind == "storm" and verb == "open_session":
                self.faults.append((i, "storm"))
                raise PoolExhausted(
                    f"injected admission storm at op {i}")
            elif ev.kind == "flake" and verb in ("push", "enroll"):
                self.faults.append((i, "flake"))
                raise TransientError(
                    f"injected transient failure at op {i} ({verb})")

    # -- counted protocol verbs --------------------------------------------
    def open_session(self, *a, **kw):
        self._gate("open_session")
        return self.service.open_session(*a, **kw)

    def push(self, *a, **kw):
        self._gate("push")
        return self.service.push(*a, **kw)

    def enroll(self, *a, **kw):
        self._gate("enroll")
        return self.service.enroll(*a, **kw)

    def park(self, *a, **kw):
        self._gate("park")
        return self.service.park(*a, **kw)

    def resume(self, *a, **kw):
        self._gate("resume")
        return self.service.resume(*a, **kw)

    def close(self, *a, **kw):
        self._gate("close")
        return self.service.close(*a, **kw)

    def poll(self, *a, **kw):
        self._gate("poll")
        return self.service.poll(*a, **kw)

    # -- everything else is the wrapped service -----------------------------
    def __getattr__(self, name):
        return getattr(self.service, name)
