"""SessionService protocol conformance: both concrete services through the
same lifecycle matrix, the frozen stats()/metrics() schemas, and the
RuntimeConfig switch consolidation (precedence + env-name pinning)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RuntimeConfig, get_config
from repro.configs import runtime as rt
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import (
    METRICS_SCHEMA,
    STATS_SCHEMA,
    LMSessionService,
    SessionService,
    StreamSessionService,
)


@functools.lru_cache(maxsize=None)
def _tcn_setup():
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(
            jax.random.normal(jax.random.key(7), a.shape)), bn)
    return bundle, params, bn


@functools.lru_cache(maxsize=None)
def _lm_setup():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return bundle, params


def _make_tcn(**kw):
    bundle, params, bn = _tcn_setup()
    return StreamSessionService(bundle, params, bn, n_slots=2,
                                max_tenants=2, max_ways=2, t_chunk=4,
                                max_sessions=8, **kw)


def _make_lm(**kw):
    bundle, params = _lm_setup()
    return LMSessionService(bundle, params, n_slots=2, seq_cap=32,
                            t_chunk=4, max_sessions=8, **kw)


def _tcn_case():
    rng = np.random.default_rng(0)
    return (_make_tcn, lambda svc: svc.open_session(),
            lambda: rng.normal(size=(4, 2)).astype(np.float32))


def _lm_case():
    return (_make_lm,
            lambda svc: svc.open_session(np.array([3, 1], np.int32)),
            lambda: 2)


CASES = {"tcn": _tcn_case, "lm": _lm_case}


@pytest.fixture(params=sorted(CASES))
def case(request):
    return CASES[request.param]()


# ---------------------------------------------------------------------------
# structural conformance + lifecycle matrix
# ---------------------------------------------------------------------------

def test_conforms_to_protocol(case):
    make, _, _ = case
    svc = make()
    assert isinstance(svc, SessionService)
    for verb in ("open_session", "push", "park", "resume", "close",
                 "poll", "metrics", "stats", "enroll"):
        assert callable(getattr(svc, verb)), verb


def test_lifecycle_matrix(case):
    """open -> push -> park -> resume -> push -> close through the protocol
    verbs only, with stats() tracking every transition."""
    make, open_sess, work = case
    svc = make()
    sid = open_sess(svc)
    assert svc.stats()["live_sessions"] == 1 and svc.stats()["bound"] == 1

    r1 = svc.push({sid: work()})
    assert sid in r1

    svc.park(sid)
    st = svc.stats()
    assert st["bound"] == 0 and st["parked"] == 1
    assert st["parked_blob_bytes"] > 0

    svc.resume(sid)  # eager re-bind, no work pushed
    st = svc.stats()
    assert st["bound"] == 1 and st["parked"] == 0

    r2 = svc.push({sid: work()})
    assert sid in r2

    svc.close(sid)
    st = svc.stats()
    assert st["live_sessions"] == 0 and st["bound"] == 0 and st["parked"] == 0


def test_resume_is_bit_identical_to_lazy_rebind(case):
    """resume() then push == push on a parked session (which lazily
    rebinds): eager rebinding never perturbs session state."""
    make, open_sess, work = case
    eager, lazy = make(), make()
    a, b = open_sess(eager), open_sess(lazy)
    w = work()
    eager.push({a: w}), lazy.push({b: w})
    eager.park(a), lazy.park(b)
    eager.resume(a)
    w2 = work()
    ra, rb = eager.push({a: w2})[a], lazy.push({b: w2})[b]
    ra_l, rb_l = jax.tree.leaves(ra), jax.tree.leaves(rb)
    assert len(ra_l) == len(rb_l)
    for x, y in zip(ra_l, rb_l):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_unknown_session_raises(case):
    make, _, _ = case
    with pytest.raises(KeyError):
        make().resume(999)


# ---------------------------------------------------------------------------
# frozen schemas (the drift this PR fixes)
# ---------------------------------------------------------------------------

def test_stats_schema(case):
    make, open_sess, work = case
    svc = make()
    for _ in range(2):  # fresh AND exercised
        st = svc.stats()
        missing = [k for k in STATS_SCHEMA if k not in st]
        assert not missing, f"stats() missing schema keys: {missing}"
        assert st["service"] in ("tcn", "lm")
        assert st["slot_state_bytes"] > 0
        sid = open_sess(svc)
        svc.push({sid: work()})


def test_metrics_schema(case):
    make, _, _ = case
    snap = make().metrics()
    missing = [k for k in METRICS_SCHEMA if k not in snap]
    assert not missing, f"metrics() missing schema series: {missing}"
    for k in METRICS_SCHEMA:
        assert any(e["labels"].get("service") in ("tcn", "lm")
                   for e in snap[k]), k


# ---------------------------------------------------------------------------
# RuntimeConfig: the five consolidated switches
# ---------------------------------------------------------------------------

def test_runtime_env_names_match_owning_modules():
    """The consolidation can never drift from the subsystems it describes:
    the canonical names in configs/runtime.py == the owning modules'
    ENV_VAR constants."""
    import importlib

    from repro.kernels import dispatch as kd
    from repro.obs import device as od

    # "from repro.obs import trace" yields the Tracer instance the package
    # re-exports, not the module — import the module explicitly
    ot = importlib.import_module("repro.obs.trace")
    assert rt.ENV_KERNEL_BACKEND == kd.ENV_VAR
    assert rt.ENV_DEVICE_COUNTERS == od.ENV_VAR
    assert rt.ENV_TRACE == ot.ENV_VAR


def test_runtime_precedence(monkeypatch):
    """explicit kwarg > env > default, field by field."""
    monkeypatch.delenv(rt.ENV_PAGED, raising=False)
    monkeypatch.delenv(rt.ENV_KERNEL_BACKEND, raising=False)
    assert RuntimeConfig.resolve().paged is False          # default
    monkeypatch.setenv(rt.ENV_PAGED, "yes")
    assert RuntimeConfig.resolve().paged is True           # env
    assert RuntimeConfig.resolve(paged=False).paged is False  # kwarg wins
    monkeypatch.setenv(rt.ENV_KERNEL_BACKEND, "reference")
    assert RuntimeConfig.resolve().kernel_backend == "reference"
    assert RuntimeConfig.resolve(
        kernel_backend="fused").kernel_backend == "fused"
    # a directly-constructed config never consults the environment
    assert RuntimeConfig().paged is False
    assert RuntimeConfig().kernel_backend is None
    with pytest.raises(TypeError):
        RuntimeConfig.resolve(nonsense=1)


def test_runtime_truthiness_matches_historical_parsers(monkeypatch):
    for raw, want in [("1", True), ("true", True), ("YES ", True),
                      ("0", False), ("", False), ("no", False),
                      ("2", False)]:
        monkeypatch.setenv(rt.ENV_FUSED, raw)
        assert RuntimeConfig.resolve().fused is want, raw


def test_services_honor_runtime_config(monkeypatch):
    monkeypatch.delenv(rt.ENV_PAGED, raising=False)
    monkeypatch.delenv(rt.ENV_FUSED, raising=False)
    # RuntimeConfig beats env-default; explicit kwarg beats RuntimeConfig
    lm = _make_lm(runtime=RuntimeConfig(paged=True))
    assert lm.paged is True
    lm = _make_lm(runtime=RuntimeConfig(paged=True), paged=False)
    assert lm.paged is False
    tcn = _make_tcn(runtime=RuntimeConfig(fused=True))
    assert tcn.fused is True
    tcn = _make_tcn(runtime=RuntimeConfig(fused=True), fused=False)
    assert tcn.fused is False
    # env still works through the default-resolved RuntimeConfig
    monkeypatch.setenv(rt.ENV_PAGED, "1")
    assert _make_lm().paged is True
