"""Greedy dilation-aware streaming == full-sequence conv (paper Fig. 8c:
'identical outputs'), plus the memory-scaling claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.streaming import (
    cone_eval,
    cone_stats,
    ring_sizes,
    stream_init,
    stream_step,
    ws_inference_stats,
)
from repro.models import build_bundle
from repro.models.tcn import fold_bn, receptive_field, tcn_empty_state, tcn_forward


def _setup(channels=(8, 8, 8), k=3, cin=1, seed=0):
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=channels, tcn_kernel=k, tcn_in_channels=cin,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    bn = tcn_empty_state(cfg)
    # non-trivial BN stats
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(jax.random.normal(jax.random.key(7), a.shape)),
        bn)
    return cfg, params, bn


@pytest.mark.parametrize("channels,k", [((8, 8, 8), 3), ((6, 10, 10, 10), 2),
                                        ((16,), 7)])
def test_stream_equals_full_conv(channels, k):
    cfg, params, bn = _setup(channels, k)
    B, T = 2, 50
    x = jax.random.normal(jax.random.key(1), (B, T, 1))
    state = stream_init(cfg, B)
    step = jax.jit(lambda s, xt: stream_step(params, bn, cfg, s, xt))
    outs = []
    for t in range(T):
        state, emb, logits = step(state, x[:, t])
        outs.append(emb)
    # compare several prefixes against the full-sequence executor
    for t in (0, 1, 7, 23, T - 1):
        emb_full, _, _ = tcn_forward(params, bn, cfg, x[:, :t + 1], train=False)
        np.testing.assert_allclose(np.asarray(outs[t]), np.asarray(emb_full),
                                   rtol=2e-4, atol=2e-5)


def test_stream_equals_full_conv_quantized():
    """The MatMul-free QAT path streams identically too."""
    cfg, params, bn = _setup((8, 8), 3)
    B, T = 2, 30
    x = jnp.abs(jax.random.normal(jax.random.key(2), (B, T, 1)))
    state = stream_init(cfg, B)
    step = jax.jit(lambda s, xt: stream_step(params, bn, cfg, s, xt, quantize=True))
    for t in range(T):
        state, emb, _ = step(state, x[:, t])
    emb_full, _, _ = tcn_forward(params, bn, cfg, x, train=False, quantize=True)
    np.testing.assert_allclose(np.asarray(emb), np.asarray(emb_full),
                               rtol=2e-4, atol=2e-5)


def test_bn_folding_preserves_output():
    cfg, params, bn = _setup((8, 8, 8), 3)
    x = jax.random.normal(jax.random.key(3), (2, 40, 1))
    emb0, logit0, _ = tcn_forward(params, bn, cfg, x, train=False)
    fparams, fbn = fold_bn(params, bn, cfg)
    emb1, logit1, _ = tcn_forward(fparams, fbn, cfg, x, train=False)
    np.testing.assert_allclose(np.asarray(emb0), np.asarray(emb1),
                               rtol=1e-4, atol=1e-4)


def test_streaming_state_is_O_of_receptive_field():
    """Paper claim: activation memory independent of sequence length and
    O(R); the WS baseline grows linearly with T."""
    cfg = get_config("chameleon-tcn-audio")
    R = receptive_field(cfg)
    total_entries = sum(n * c for b in ring_sizes(cfg).values()
                        for (n, c) in b.values())
    for T in (1_000, 16_000, 1_000_000):
        g = cone_stats(cfg, T)
        ws = ws_inference_stats(cfg, T)
        assert ws["act_entries"] > g["act_entries"]
    # cone FIFOs are sequence-length independent
    assert cone_stats(cfg, 1_000)["act_entries"] == \
        cone_stats(cfg, 1_000_000)["act_entries"]
    # Fig. 8(c): ~90x memory and ~10x compute reduction at 16k
    ws16 = ws_inference_stats(cfg, 16_000)
    g16 = cone_stats(cfg, 16_000)
    assert ws16["act_entries"] / g16["act_entries"] > 50
    assert ws16["macs"] / g16["macs"] > 5


def test_paper_activation_memory_budget():
    """Paper: raw-audio KWS runs in ~2 kB of activation memory (4-bit),
    via the cone-sparse greedy execution."""
    cfg = get_config("chameleon-tcn-audio")
    kb = cone_stats(cfg, 16_000)["act_entries"] * 0.5 / 1024
    assert kb < 2.5, f"greedy FIFO state {kb:.1f} kB exceeds the paper budget"


def test_cone_eval_identical_outputs():
    """Fig. 8(c): greedy cone evaluation produces IDENTICAL outputs."""
    cfg, params, bn = _setup((8, 8, 8), 3)
    x = jax.random.normal(jax.random.key(5), (2, 50, 1))
    emb_d, logit_d, _ = tcn_forward(params, bn, cfg, x, train=False)
    emb_c, logit_c, evals = cone_eval(params, bn, cfg, x)
    np.testing.assert_allclose(np.asarray(emb_c), np.asarray(emb_d),
                               rtol=2e-4, atol=2e-5)
    assert evals < 50 * 6  # strictly fewer node evaluations than dense


def test_cone_eval_quantized():
    cfg, params, bn = _setup((8, 8), 3)
    x = jnp.abs(jax.random.normal(jax.random.key(6), (1, 40, 1)))
    emb_d, _, _ = tcn_forward(params, bn, cfg, x, train=False, quantize=True)
    emb_c, _, _ = cone_eval(params, bn, cfg, x, quantize=True)
    np.testing.assert_allclose(np.asarray(emb_c), np.asarray(emb_d),
                               rtol=2e-4, atol=2e-5)
