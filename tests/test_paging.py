"""Block-pool allocator: refcount/free-list invariants under random
alloc/free/fork(CoW)/write interleavings (satellite of the paged slot
memory PR), plus PrefixCache longest-match/LRU/registry-pin semantics.

The hypothesis property drives the allocator like the LM service does —
sessions allocate chains, fork shares blocks, writes go through the
``writable`` CoW gate, frees drop whole suffixes — and after EVERY
operation asserts the pool's own ``check()`` audit (free list and
refcounts reconcile, nothing double-circulates, NULL stays out) plus an
external model: refcounts must equal the number of model-side owners.
"""

import pytest

from _hyp import given, settings, st
from repro.sessions.paging import (
    NULL_BLOCK,
    BlockPool,
    PoolExhausted,
    PrefixCache,
    prefix_keys,
)

settings.register_profile("paging", deadline=None, max_examples=60)
settings.load_profile("paging")


# ---------------------------------------------------------------------------
# unit semantics
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip():
    pool = BlockPool(3)
    assert pool.extent == 4 and pool.n_free == 3
    a = pool.alloc()
    assert a != NULL_BLOCK and pool.refcount(a) == 1
    assert pool.n_live == 1
    pool.free(a)
    assert pool.n_free == 3 and pool.refcount(a) == 0
    pool.check()


def test_exhaustion_raises_pool_exhausted():
    from repro.sessions import AdmissionError
    pool = BlockPool(2)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()
    # capacity pressure surfaces through the admission back-pressure type
    assert issubclass(PoolExhausted, AdmissionError)


def test_double_free_and_null_free_refused():
    pool = BlockPool(2)
    a = pool.alloc()
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free(a)
    with pytest.raises(ValueError):
        pool.free(NULL_BLOCK)
    with pytest.raises(ValueError):
        pool.ref(NULL_BLOCK)
    pool.check()


def test_writable_cow_contract():
    pool = BlockPool(4)
    a = pool.alloc()
    # exclusive: write in place
    assert pool.writable(a) == (a, None)
    # shared: the writer gets a fresh block, the other owner keeps a
    pool.ref(a)
    assert pool.n_shared == 1
    new, src = pool.writable(a)
    assert src == a and new != a and new != NULL_BLOCK
    assert pool.refcount(a) == 1 and pool.refcount(new) == 1
    assert pool.n_shared == 0
    pool.check()


# ---------------------------------------------------------------------------
# the allocator property (satellite 3)
# ---------------------------------------------------------------------------

_OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "free", "fork", "write"]),
              st.integers(0, 10 ** 6)),
    min_size=1, max_size=120)


@given(ops=_OPS, n_blocks=st.integers(1, 12))
def test_allocator_never_leaks_or_double_frees(ops, n_blocks):
    """Random interleavings of session-shaped operations keep the pool
    reconciled: model-side ownership == refcounts == free-list complement.

    Model: ``owners[bid]`` counts how many model handles reference a
    block.  alloc creates a handle; free drops a random handle; fork
    duplicates one (prefix sharing); write pushes one through the CoW
    gate (possibly migrating the handle to a fresh block)."""
    pool = BlockPool(n_blocks)
    handles: list[int] = []  # one entry per model-side owner
    for op, r in ops:
        if op == "alloc":
            try:
                handles.append(pool.alloc())
            except PoolExhausted:
                # exhaustion must be consistent with the model: every
                # block is owned by someone
                assert len(set(handles)) == n_blocks
        elif op == "free" and handles:
            pool.free(handles.pop(r % len(handles)))
        elif op == "fork" and handles:
            handles.append(pool.ref(handles[r % len(handles)]))
        elif op == "write" and handles:
            i = r % len(handles)
            try:
                new, src = pool.writable(handles[i])
            except PoolExhausted:
                assert len(set(handles)) == n_blocks
                continue
            if src is not None:  # CoW: this handle migrated
                assert pool.refcount(src) >= 1
            handles[i] = new
            # after the gate the writer ALWAYS holds an exclusive block
            assert pool.refcount(new) >= 1
        # the pool's own audit after every single operation
        pool.check()
        # external reconciliation: refcounts == model ownership
        for bid in range(1, pool.extent):
            assert pool.refcount(bid) == handles.count(bid)
        assert pool.n_live == len(set(handles))
        assert pool.n_free == n_blocks - len(set(handles))
    # drain: everything frees cleanly, nothing leaked
    while handles:
        pool.free(handles.pop())
    pool.check()
    assert pool.n_free == n_blocks


# ---------------------------------------------------------------------------
# the exact-prefix registry
# ---------------------------------------------------------------------------

def test_prefix_keys_are_exact_chains():
    keys = prefix_keys([1, 2, 3, 4, 5], 2)
    assert keys == [(1, 2), (1, 2, 3, 4)]  # full blocks only, chained
    assert prefix_keys([1], 2) == []


def test_prefix_cache_longest_match_and_pins():
    pool = BlockPool(8)
    cache = PrefixCache(pool)
    chain = prefix_keys(list(range(6)), 2)  # 3 full blocks
    bids = [pool.alloc() for _ in chain]
    for key, bid in zip(chain, bids):
        cache.insert(key, bid)  # registry takes its own reference
    assert all(pool.refcount(b) == 2 for b in bids)
    # donor parks/closes: drops its refs, registry pins keep blocks live
    for b in bids:
        pool.free(b)
    assert pool.n_live == 3
    # a new session adopting the chain gets fresh references
    hits = cache.match(chain)
    assert hits == bids and all(pool.refcount(b) == 2 for b in bids)
    # divergent chain: longest-prefix stops at the first miss
    other = prefix_keys([0, 1, 2, 3, 9, 9], 2)
    hits2 = cache.match(other)
    assert hits2 == bids[:2]
    for b in hits + hits2:
        pool.free(b)
    pool.check()


def test_prefix_cache_lru_release_frees_unshared():
    pool = BlockPool(4)
    cache = PrefixCache(pool)
    a, b = pool.alloc(), pool.alloc()
    cache.insert((1,), a)
    cache.insert((2,), b)
    pool.free(a), pool.free(b)  # only registry pins remain
    assert pool.n_live == 2
    assert cache.release_lru()  # evicts (1,) — the least recently matched
    assert pool.refcount(a) == 0 and pool.refcount(b) == 1
    cache.clear()
    assert pool.n_free == 4 and not cache.release_lru()
    pool.check()
