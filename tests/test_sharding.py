"""Sharding rules + an end-to-end mini dry-run on a subprocess mesh.

Multi-device tests spawn a subprocess so the main pytest process keeps its
single CPU device (device count is locked at first jax init).
"""

import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (
    DEFAULT_RULES,
    ParamDef,
    param_pspecs,
    pspec,
    pspec_sized,
)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_pspec_mapping():
    rules = {"vocab": "model", "embed": "data", "heads": "model"}
    assert pspec(("vocab", "embed"), rules) == P("model", "data")
    assert pspec(("embed", None), rules) == P("data")
    assert pspec((None, None), rules) == P()


def test_pspec_sized_drops_indivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"vocab": "model", "embed": "data"}
    # 256206 % 16 != 0 -> vocab replicated; 1024 % 16 == 0 -> embed sharded
    assert pspec_sized(("vocab", "embed"), rules, (256206, 1024), mesh) == \
        P(None, "data")
    assert pspec_sized(("vocab", "embed"), rules, (256000, 1024), mesh) == \
        P("model", "data")


def test_param_pspecs_tree():
    defs = {"e": ParamDef((100, 32), ("vocab", "embed")),
            "n": {"w": ParamDef((32,), ("embed",))}}
    specs = param_pspecs(defs, {"vocab": "model", "embed": None})
    assert specs["e"] == P("model") and specs["n"]["w"] == P()


def test_fsdp_rule_is_default():
    """Params' d_model rows shard over data (ZeRO-3) by default."""
    assert DEFAULT_RULES["embed"] == "data"
    assert DEFAULT_RULES["heads"] == "model"


SUBPROC = """
import sys
sys.argv = ["dryrun", "--mesh", "2x2", "--smoke", "--arch", "%s",
            "--shape", "%s", "--out", "%s", "--force"]
from repro.launch import dryrun
dryrun.os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
dryrun.main()
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("olmo-1b", "train_4k"),
    ("deepseek-v2-lite-16b", "decode_32k"),
    ("rwkv6-1.6b", "long_500k"),
])
def test_mini_dryrun_subprocess(tmp_path, arch, shape):
    """The full launcher path (specs, lowering, compile, roofline record)
    on a 2x2 host mesh with reduced configs."""
    code = SUBPROC % (arch, shape, tmp_path)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    rec = json.load(open(tmp_path / files[0]))
    assert rec["n_chips"] == 4
    assert rec["flops_global_analytic"] > 0
    assert "argument_size_in_bytes" in rec["memory_analysis"]
