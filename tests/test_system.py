"""End-to-end behaviour: the paper's full loop on synthetic data.

Meta-train a small TCN embedder with the prototypical episodic loss, then
perform gradient-free on-device FSL via the PN-as-FC head and CL via the
prototype store — asserting the paper's qualitative claims (FSL accuracy >>
chance, more shots help, accuracy decays gracefully with more ways, the QAT
log2 path stays close to fp32).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import protonet as pn
from repro.data import EpisodicSampler, GlyphClasses, split_classes
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.training.optim import adamw, apply_updates

IMG = 12  # reduced glyph size -> seq len 144


@pytest.fixture(scope="module")
def trained():
    """Meta-train a tiny TCN PN embedder on synthetic glyph episodes."""
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(16, 16, 16), tcn_kernel=5, embed_dim=32, n_classes=5)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    state = tcn_empty_state(cfg)
    ds = GlyphClasses(30, seed=0, size=IMG)
    train_cls, test_cls = split_classes(30, 0.67, seed=0)
    sampler = EpisodicSampler(ds, train_cls, seed=1)

    opt_init, opt_update = adamw(2e-3)
    opt_state = opt_init(params)

    from repro.models.tcn import tcn_forward

    def episode_loss(params, state, sx, sy, qx, qy, n_ways):
        emb_s, _, new_state = tcn_forward(params, state, bundle.cfg, sx, train=True)
        emb_q, _, _ = tcn_forward(params, new_state, bundle.cfg, qx, train=True)
        s = pn.support_sums(emb_s, sy, n_ways)
        w, b = pn.pn_fc_from_sums(s, sx.shape[0] // n_ways)
        logits = pn.pn_logits(emb_q, w, b)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, qy[:, None], 1)[:, 0]
        return jnp.mean(lse - gold), new_state

    @jax.jit
    def step(params, state, opt_state, sx, sy, qx, qy):
        (loss, new_state), grads = jax.value_and_grad(
            episode_loss, has_aux=True)(params, state, sx, sy, qx, qy, 5)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), new_state, opt_state, loss

    losses = []
    for ep in range(110):
        sx, sy, qx, qy = sampler.episode(ep, n_ways=5, k_shots=3, n_query=3)
        params, state, opt_state, loss = step(
            params, state, opt_state, jnp.asarray(sx), jnp.asarray(sy),
            jnp.asarray(qx), jnp.asarray(qy))
        losses.append(float(loss))
    return cfg, bundle, params, state, ds, test_cls, losses


def _fsl_accuracy(bundle, params, state, ds, classes, n_ways, k, n_ep=8,
                  quantize=False):
    from repro.models.tcn import tcn_forward
    sampler = EpisodicSampler(ds, classes, seed=99)
    accs = []
    for ep in range(n_ep):
        sx, sy, qx, qy = sampler.episode(ep, n_ways, k, n_query=4)
        emb_s, _, _ = tcn_forward(params, state, bundle.cfg, jnp.asarray(sx),
                                  train=False, quantize=quantize)
        emb_q, _, _ = tcn_forward(params, state, bundle.cfg, jnp.asarray(qx),
                                  train=False, quantize=quantize)
        if quantize:
            w, b, _, _ = pn.pn_fc_from_sums_log2(
                pn.support_sums(emb_s, jnp.asarray(sy), n_ways), k)
        else:
            w, b = pn.pn_fc_from_sums(
                pn.support_sums(emb_s, jnp.asarray(sy), n_ways), k)
        pred = jnp.argmax(pn.pn_logits(emb_q, w, b), axis=-1)
        accs.append(float(jnp.mean(pred == jnp.asarray(qy))))
    return float(np.mean(accs))


def test_meta_training_reduces_loss(trained):
    *_, losses = trained
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


def test_fsl_beats_chance_on_unseen_classes(trained):
    cfg, bundle, params, state, ds, test_cls, _ = trained
    acc = _fsl_accuracy(bundle, params, state, ds, test_cls, n_ways=5, k=3)
    assert acc > 0.45, f"5-way acc {acc} (chance 0.2)"


def test_more_shots_help(trained):
    cfg, bundle, params, state, ds, test_cls, _ = trained
    a1 = _fsl_accuracy(bundle, params, state, ds, test_cls, 5, 1)
    a5 = _fsl_accuracy(bundle, params, state, ds, test_cls, 5, 5)
    assert a5 >= a1 - 0.05, (a1, a5)


def test_qat_log2_close_to_fp32(trained):
    cfg, bundle, params, state, ds, test_cls, _ = trained
    fp = _fsl_accuracy(bundle, params, state, ds, test_cls, 5, 3)
    q = _fsl_accuracy(bundle, params, state, ds, test_cls, 5, 3, quantize=True)
    assert q > fp - 0.25, f"log2 path collapsed: fp32={fp} log2={q}"


def test_continual_learning_curve(trained):
    """Fig. 15 shape: accuracy decays gracefully as ways grow; the store
    classifies all previously learned classes."""
    cfg, bundle, params, state, ds, test_cls, _ = trained
    from repro.models.tcn import tcn_forward
    n_total = min(8, len(test_cls))
    store = pn.store_init(n_total, cfg.embed_dim)
    accs = []
    for j in range(n_total):
        shots = ds.sample(int(test_cls[j]), 3, seed=1000 + j)
        emb, _, _ = tcn_forward(params, state, cfg, jnp.asarray(shots), train=False)
        store = pn.store_add_class(store, emb)
        # evaluate on all classes learned so far
        correct, total = 0, 0
        for jj in range(j + 1):
            q = ds.sample(int(test_cls[jj]), 4, seed=2000 + jj)
            embq, _, _ = tcn_forward(params, state, cfg, jnp.asarray(q), train=False)
            pred = pn.store_classify(store, embq)
            correct += int(jnp.sum(pred == jj))
            total += 4
        accs.append(correct / total)
    assert accs[0] > 0.9                      # 1-way is trivial
    assert accs[-1] > 1.2 / n_total           # well above chance at max ways
