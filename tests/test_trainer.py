"""Trainer mechanics: grad accumulation, compression, straggler detection."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batch
from repro.models import build_bundle
from repro.training import TrainConfig, Trainer, TrainState, make_train_step
from repro.training.optim import adamw, sgd, warmup_cosine, clip_by_global_norm


def _tiny():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=128, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_grad_accum_equivalence():
    """accum=4 over quarters == accum=1 over the full batch (same tokens)."""
    cfg, bundle, params = _tiny()
    opt = sgd(0.1, momentum=0.0)
    batch = {k: jnp.asarray(v) for k, v in lm_batch(0, 4, 32, cfg.vocab_size).items()}
    s1 = make_train_step(bundle.loss_fn, opt, grad_accum=1)
    s2 = make_train_step(bundle.loss_fn, opt, grad_accum=4)
    st = lambda: TrainState(params, opt[0](params), {}, {}, jnp.zeros((), jnp.int32))
    a, _ = jax.jit(s1)(st(), batch)
    mb = jax.tree.map(lambda x: x.reshape(4, 1, *x.shape[1:]), batch)
    b, _ = jax.jit(s2)(st(), mb)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-4)


def test_compression_converges_like_uncompressed():
    """int8-EF training tracks the uncompressed loss trajectory."""
    cfg, bundle, params = _tiny()
    data = lambda step: {k: jnp.asarray(v)
                         for k, v in lm_batch(step, 8, 32, cfg.vocab_size).items()}
    t1 = Trainer(bundle.loss_fn, params, TrainConfig(steps=30, log_every=29), data)
    _, h1 = t1.run()
    t2 = Trainer(bundle.loss_fn, params,
                 TrainConfig(steps=30, log_every=29, grad_compression="int8_ef"),
                 data)
    _, h2 = t2.run()
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 0.35, (h1[-1], h2[-1])


def test_loss_decreases_on_copy_task():
    cfg, bundle, params = _tiny()
    data = lambda step: {k: jnp.asarray(v)
                         for k, v in lm_batch(step, 8, 64, cfg.vocab_size).items()}
    tr = Trainer(bundle.loss_fn, params, TrainConfig(steps=60, log_every=1), data,
                 optimizer=adamw(3e-3))
    _, hist = tr.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_straggler_detection():
    cfg, bundle, params = _tiny()
    calls = {"n": 0}

    def slow_data(step):
        calls["n"] += 1
        if step == 15:
            time.sleep(0.0)  # the *step* is timed, not data; inject via hook
        return {k: jnp.asarray(v)
                for k, v in lm_batch(step, 2, 16, cfg.vocab_size).items()}

    tr = Trainer(bundle.loss_fn, params, TrainConfig(steps=20, log_every=100,
                                                     straggler_factor=15.0),
                 slow_data)
    # monkeypatch one slow step by wrapping the jitted fn
    orig = tr.train_step

    def sometimes_slow(state, batch):
        step_now = int(state.step)  # read BEFORE orig() donates the state
        out = orig(state, batch)
        if step_now == 15:
            time.sleep(1.0)
        return out

    tr.train_step = sometimes_slow
    tr.run()
    assert any(s == 16 or s == 15 for s, _, _ in
               [(e[0], e[1], e[2]) for e in tr.straggler_events]) or \
        len(tr.straggler_events) >= 1


def test_schedule_and_clip():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) < 0.2
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 0.05
    assert float(sched(jnp.asarray(100))) < 0.2
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
