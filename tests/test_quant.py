"""log2 4-bit quantization (paper §III-C) — unit + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.quant import (
    compress,
    compute_scale,
    dequantize_log2,
    fake_quant_act_u4,
    fake_quant_log2,
    pack_nibbles,
    quantize_log2,
    unpack_nibbles,
)

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")


def _rand(shape, seed=0, scale=0.1):
    return jax.random.normal(jax.random.key(seed), shape) * scale


class TestLog2Codes:
    def test_code_range(self):
        w = _rand((64, 64), 1)
        q = quantize_log2(w, compute_scale(w))
        assert int(q.min()) >= -8 and int(q.max()) <= 7

    def test_roundtrip_idempotent(self):
        """quantize(dequantize(q)) == q — the codebook is a fixed point."""
        w = _rand((32, 32), 2)
        s = compute_scale(w)
        q = quantize_log2(w, s)
        wd = dequantize_log2(q, s)
        q2 = quantize_log2(wd, s)
        assert jnp.all(q == q2)

    def test_relative_error_bound(self):
        """log2 rounding: worst-case rel error on representable range is
        sqrt(2)-1 (round-to-nearest in exponent space)."""
        w = _rand((128, 128), 3)
        s = compute_scale(w)
        q = quantize_log2(w, s)
        wd = dequantize_log2(q, s)
        nz = q != 0
        rel = jnp.abs(wd - w)[nz] / jnp.abs(w)[nz]
        assert float(rel.max()) <= (2 ** 0.5 - 1) + 1e-3

    def test_dynamic_range_matches_int8(self):
        """paper claim: same dynamic range as int8 (128:1) in 4 bits."""
        s = jnp.float32(1.0)
        mags = dequantize_log2(jnp.arange(-8, 8, dtype=jnp.int8), s)
        nz = jnp.abs(mags[jnp.nonzero(mags)])
        assert float(nz.max() / nz.min()) == 128.0

    def test_zero_and_signs(self):
        s = jnp.float32(1.0)
        w = jnp.array([0.0, 1.0, -1.0, 0.5, -0.25, 1e-9])
        q = quantize_log2(w, s)
        wd = dequantize_log2(q, s)
        assert wd[0] == 0.0 and wd[5] == 0.0  # exact zero + underflow->0
        np.testing.assert_allclose(wd[1:5], [1.0, -1.0, 0.5, -0.25])

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
    def test_pack_unpack_inverse(self, seed, half_cols):
        q = np.asarray(
            jax.random.randint(jax.random.key(seed), (4, half_cols * 2), -8, 8),
            np.int8)
        assert np.array_equal(np.asarray(unpack_nibbles(pack_nibbles(q))), q)

    @given(st.integers(0, 2 ** 31 - 1))
    def test_ste_fake_quant_matches_decode(self, seed):
        w = np.asarray(jax.random.normal(jax.random.key(seed), (16, 16))) * 0.3
        fq = fake_quant_log2(jnp.asarray(w))
        s = compute_scale(jnp.asarray(w))
        ref = dequantize_log2(quantize_log2(jnp.asarray(w), s), s)
        np.testing.assert_allclose(np.asarray(fq), np.asarray(ref), rtol=1e-6)

    def test_ste_gradient_passthrough(self):
        w = _rand((8, 8), 5)
        g = jax.grad(lambda w: jnp.sum(fake_quant_log2(w) * 2.0))(w)
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones((8, 8)), rtol=1e-6)

    def test_act_u4_range(self):
        x = jnp.abs(_rand((64,), 6, scale=2.0))
        fq = fake_quant_act_u4(x)
        scale = float(x.max()) / 15.0
        assert float(jnp.max(jnp.abs(fq - x))) <= scale / 2 + 1e-6
        # 16 levels max
        assert len(np.unique(np.asarray(fq))) <= 16


class TestGradCompression:
    def test_error_feedback_sums_to_truth(self):
        """EF property: cumulative transmitted approx equals cumulative true
        gradient (residual stays bounded)."""
        g = _rand((256,), 7, scale=1.0)
        err = jnp.zeros_like(g)
        sent = jnp.zeros_like(g)
        for t in range(20):
            gt = g * (1 + 0.1 * t)
            codes, scale, err = compress.compress_int8(gt, err)
            sent = sent + compress.decompress_int8(codes, scale)
        true_sum = sum(g * (1 + 0.1 * t) for t in range(20))
        resid = float(jnp.max(jnp.abs(sent + err - true_sum)))
        assert resid < 1e-3

    def test_tree_roundtrip(self):
        tree = {"a": _rand((8, 8), 8), "b": {"c": _rand((4,), 9)}}
        err = compress.init_error_state(tree)
        codes, scales, err2 = compress.compress_tree(tree, err)
        dec = compress.decompress_tree(codes, scales)
        for k, (x, y) in enumerate(zip(jax.tree.leaves(tree), jax.tree.leaves(dec))):
            assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.abs(x).max()) / 127 + 1e-6
