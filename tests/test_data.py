"""Data pipeline: determinism, seekability, episodic splits, feature shapes."""

import numpy as np

from repro.data import EpisodicSampler, GlyphClasses, KeywordAudio, lm_batch, split_classes


def test_lm_batch_deterministic_and_seekable():
    a = lm_batch(5, 4, 32, 1000)
    b = lm_batch(5, 4, 32, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(6, 4, 32, 1000)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_lm_batch_has_learnable_structure():
    b = lm_batch(0, 2, 64, 1000)
    # copy structure: second half repeats first half
    row = np.concatenate([b["tokens"][0], b["labels"][0][-1:]])
    half = len(row) // 2
    np.testing.assert_array_equal(row[:half], row[half:2 * half])


def test_glyphs_deterministic_per_class():
    ds = GlyphClasses(10, seed=1)
    a = ds.sample(3, 2, seed=7)
    b = ds.sample(3, 2, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 784, 1)
    assert 0.0 <= a.min() and a.max() <= 1.0
    # different classes are different
    c = ds.sample(4, 2, seed=7)
    assert not np.array_equal(a, c)


def test_audio_and_mfcc_shapes():
    ds = KeywordAudio(n_classes=4, seed=0)
    x = ds.sample(1, 2, seed=3)
    assert x.shape == (2, 16000, 1)
    assert np.abs(x).max() <= 1.0
    feats = ds.mfcc(x)
    assert feats.shape == (2, 63, 28)  # paper: 32ms/16ms framing -> 63 frames


def test_meta_split_classes_disjoint():
    train, test = split_classes(100, 0.7, seed=0)
    assert len(set(train) & set(test)) == 0
    assert len(train) + len(test) == 100


def test_episode_shapes_and_labels():
    ds = GlyphClasses(20, seed=0)
    train, _ = split_classes(20, 0.7, seed=0)
    sampler = EpisodicSampler(ds, train, seed=1)
    sx, sy, qx, qy = sampler.episode(0, n_ways=5, k_shots=3, n_query=2)
    assert sx.shape == (15, 784, 1) and qx.shape == (10, 784, 1)
    assert set(sy) == set(range(5)) and set(qy) == set(range(5))
    # deterministic per (seed, ep)
    sx2, *_ = sampler.episode(0, 5, 3, 2)
    np.testing.assert_array_equal(sx, sx2)
