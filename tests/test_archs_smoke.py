"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced same-family config runs one forward/train step on CPU with finite
loss + nonzero grads, and the decode path is *consistent with prefill*
(cache correctness: prefill(tokens).logits == decode step after prefix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_bundle

LM_ARCHS = [c.name for c in ASSIGNED]


def _batch(cfg, B=2, S=32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    tok = lambda k, s: jax.random.randint(k, (B, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        P = cfg.n_patches
        return {"patches": jax.random.normal(ks[0], (B, P, cfg.d_model), jnp.bfloat16),
                "tokens": tok(ks[1], S - P), "labels": tok(ks[2], S - P)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.bfloat16),
                "tokens": tok(ks[1], S), "labels": tok(ks[2], S)}
    return {"tokens": tok(ks[1], S), "labels": tok(ks[2], S)}


@pytest.mark.parametrize("name", LM_ARCHS)
def test_train_step_finite(name):
    cfg = get_config(name).smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(bundle.loss_fn, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), f"{name}: loss={loss}"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and np.isfinite(gnorm), f"{name}: bad grads"
    # output shapes: logits path exercised through loss; check metrics
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("name", LM_ARCHS)
def test_decode_consistent_with_prefill(name):
    """Cache correctness: prefill(tokens[:S]) last-logits must equal the
    decode-step logits after prefill(tokens[:S-1]) + decode(tokens[S-1])."""
    cfg = get_config(name).smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    B, S = 2, 17
    batch = _batch(cfg, B=B, S=S, seed=3)
    toks = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k in ("patches", "frames")}

    full, _ = jax.jit(bundle.prefill_fn)(params, {**extras, "tokens": toks})
    _, cache = jax.jit(bundle.prefill_fn)(params, {**extras, "tokens": toks[:, :-1]})
    # decode position counts the full prefix (incl. vlm patch tokens)
    prefix = toks.shape[1] - 1
    if cfg.family == "vlm":
        prefix += extras["patches"].shape[1]
    # grow kv caches by one slot so the decode write fits
    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == prefix:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    if cfg.family != "rwkv":  # zamba's shared-attn KV cache also grows
        cache = jax.tree.map(grow, cache)
    step, _ = jax.jit(bundle.decode_fn)(
        params, cache,
        {"tokens": toks[:, -1:], "pos": jnp.asarray(prefix, jnp.int32)})
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("name", ["chameleon-tcn", "chameleon-tcn-audio",
                                  "chameleon-tcn-kws"])
def test_tcn_presets_train(name):
    cfg = get_config(name).smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 80, cfg.tcn_in_channels))
    labels = jax.random.randint(jax.random.key(2), (4,), 0, cfg.n_classes)
    loss, (m, state) = bundle.loss_fn(params, {"x": x, "labels": labels})
    assert jnp.isfinite(loss)
    emb = bundle.embed_fn(params, {"x": x})
    assert emb.shape == (4, cfg.embed_dim) and jnp.all(jnp.isfinite(emb))


def test_paper_tcn_param_budgets():
    """The full presets respect the paper's published parameter counts."""
    from repro.launch.analytic import param_count
    from repro.models.tcn import receptive_field
    cases = {  # name -> (max params, min receptive field)
        "chameleon-tcn": (133_000, 784),        # <=133k (chip max), covers 28x28
        "chameleon-tcn-audio": (133_000, 4_000),
        "chameleon-tcn-kws": (20_000, 60),      # fits the 4x4 always-on mode
    }
    for name, (max_p, min_r) in cases.items():
        cfg = get_config(name)
        bundle = build_bundle(cfg)
        n = param_count(bundle.param_defs)
        assert n <= max_p, f"{name}: {n} params > {max_p}"
        assert receptive_field(cfg) >= min_r


def test_mla_absorbed_decode_matches_baseline():
    """Beyond-paper lever (EXPERIMENTS §Perf): decode-time MLA weight
    absorption attends in the latent space; logits must match the
    up-projection baseline to bf16 reassociation tolerance."""
    cfg = get_config("deepseek-v2-lite-16b").smoke()
    b0 = build_bundle(cfg)
    b1 = build_bundle(cfg.replace(mla_absorb=True))
    params = b0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    _, cache = jax.jit(b0.prefill_fn)(params, {"tokens": toks[:, :-1]})
    grow = lambda l: (jnp.pad(l, [(0, 0), (0, 0), (0, 1)] + [(0, 0)] * (l.ndim - 3))
                      if l.ndim >= 3 and l.shape[2] == 16 else l)
    cache = jax.tree.map(grow, cache)
    batch = {"tokens": toks[:, -1:], "pos": jnp.asarray(16, jnp.int32)}
    l0, _ = jax.jit(b0.decode_fn)(params, jax.tree.map(lambda x: x, cache), batch)
    l1, _ = jax.jit(b1.decode_fn)(params, cache, batch)
    assert bool(jnp.all(jnp.argmax(l0, -1) == jnp.argmax(l1, -1)))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=0.06)
