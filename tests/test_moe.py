"""MoE routing invariants (token-choice, per-sequence capacity, EP layout)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.moe import expert_capacity, moe_ffn, moe_param_defs
from repro.sharding.rules import init_params


def _cfg(E=8, K=2, D=32, Fe=16, cap=1.25, shared=0):
    return ArchConfig(n_experts=E, moe_topk=K, d_model=D, d_ff_expert=Fe,
                      capacity_factor=cap, n_shared_experts=shared)


def _run(cfg, B=3, S=16, seed=0):
    params = init_params(moe_param_defs(cfg), jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (B, S, cfg.d_model),
                          jnp.float32)
    y, metrics = moe_ffn(params, cfg, x)
    return params, x, y, metrics


def test_output_finite_and_shaped():
    cfg = _cfg()
    _, x, y, m = _run(cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    assert float(m["moe_aux"]) >= 1.0 - 1e-3  # Switch aux lower bound is 1


def test_no_drops_at_huge_capacity():
    cfg = _cfg(cap=100.0)
    _, _, _, m = _run(cfg)
    assert float(m["moe_dropped"]) == 0.0


def test_capacity_drops_monotone():
    lo = float(_run(_cfg(cap=0.3))[3]["moe_dropped"])
    hi = float(_run(_cfg(cap=2.0))[3]["moe_dropped"])
    assert lo >= hi


def test_zero_weight_experts_give_zero_output():
    """With all expert weights zero and no shared experts, y must be 0 —
    proves dispatch/combine indices never alias wrong tokens."""
    cfg = _cfg(shared=0)
    params = init_params(moe_param_defs(cfg), jax.random.key(0))
    params = jax.tree.map(jnp.zeros_like, params)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, _ = moe_ffn(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), 0.0)


def test_independent_sequences():
    """Per-sequence dispatch: token routing in row 0 must not depend on the
    contents of row 1 (capacity is allocated per sequence)."""
    cfg = _cfg()
    params = init_params(moe_param_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y1, _ = moe_ffn(params, cfg, x)
    x2 = x.at[1].set(jax.random.normal(jax.random.key(2), (16, cfg.d_model)))
    y2, _ = moe_ffn(params, cfg, x2)
    np.testing.assert_allclose(np.asarray(y1[0]), np.asarray(y2[0]),
                               rtol=1e-5, atol=1e-5)


def test_capacity_formula():
    cfg = _cfg(E=64, K=6, cap=1.25)
    C = expert_capacity(4096, cfg)
    assert C >= 4096 * 6 / 64 and C % 8 == 0


def test_grads_flow_to_router_and_experts():
    cfg = _cfg(shared=1)
    params = init_params(moe_param_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))

    def loss(p):
        y, m = moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * m["moe_aux"]

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["wd"]))) > 0
    assert float(jnp.sum(jnp.abs(g["shared"]["wd"]))) > 0
