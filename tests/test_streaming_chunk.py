"""Chunked scan-based streaming: bit-exactness of the lax.scan time-chunk
execution path vs the per-sample step path, across ragged lengths, ring
wraparound, quantization, sharding specs, and random push schedules."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs import get_config
from repro.core.streaming import (
    ring_sizes,
    stream_init_single,
    stream_scan_single,
    stream_step_single,
)
from repro.models import build_bundle
from repro.models.tcn import (
    bake_stream_params,
    make_fused_forward,
    tcn_empty_state,
    tcn_forward,
)
from repro.sessions import (
    StreamSessionService,
    grid_init,
    grid_pspecs,
    grid_scan,
    grid_step,
    lengths_to_valid,
    make_grid_fused,
    bank_init,
    bank_pspecs,
)

settings.register_profile("chunk", deadline=None, max_examples=30)
settings.load_profile("chunk")


@functools.lru_cache(maxsize=None)
def _setup(seed=0):
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    bn = tcn_empty_state(cfg)
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(jax.random.normal(jax.random.key(7), a.shape)),
        bn)
    return cfg, bundle, params, bn


# ---------------------------------------------------------------------------
# core/streaming: stream_scan_single
# ---------------------------------------------------------------------------

def test_stream_scan_single_matches_sequential_steps_and_forward():
    """One scanned chunk == T sequential stream_step_single calls, bit for
    bit (outputs AND end state), and matches the full-sequence conv."""
    cfg, bundle, params, bn = _setup()
    T = 30
    x = np.random.default_rng(0).normal(size=(T, 2)).astype(np.float32)
    st0 = stream_init_single(cfg)
    # params/bn as jit ARGUMENTS: the cross-program exactness discipline
    scan = jax.jit(lambda p, b, s, xc, v: stream_scan_single(p, b, cfg, s, xc, v))
    end, embs, logits = scan(params, bn, st0, jnp.asarray(x), jnp.ones(T, bool))
    st_seq = stream_init_single(cfg)
    step = jax.jit(lambda p, b, s, xt: stream_step_single(p, b, cfg, s, xt))
    for t in range(T):
        st_seq, e, l = step(params, bn, st_seq, jnp.asarray(x[t]))
        np.testing.assert_array_equal(np.asarray(embs[t]), np.asarray(e))
        np.testing.assert_array_equal(np.asarray(logits[t]), np.asarray(l))
    for a, b in zip(jax.tree.leaves(end), jax.tree.leaves(st_seq)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    emb_full, _, _ = tcn_forward(params, bn, cfg, jnp.asarray(x)[None],
                                 train=False)
    np.testing.assert_allclose(np.asarray(embs[-1]), np.asarray(emb_full[0]),
                               rtol=2e-4, atol=2e-5)


def test_stream_scan_single_invalid_tail_bit_frozen():
    """Padding a short chunk: steps past ``valid`` leave state untouched and
    T=1 scan is exactly stream_step_single."""
    cfg, bundle, params, bn = _setup()
    T = 12
    x = np.random.default_rng(1).normal(size=(T, 2)).astype(np.float32)
    scan = jax.jit(lambda p, b, s, xc, v: stream_scan_single(p, b, cfg, s, xc, v))
    step = jax.jit(lambda p, b, s, xt: stream_step_single(p, b, cfg, s, xt))
    st0 = stream_init_single(cfg)
    valid = jnp.arange(T) < 7
    end, _, _ = scan(params, bn, st0, jnp.asarray(x), valid)
    st_seq = stream_init_single(cfg)
    for t in range(7):
        st_seq, _, _ = step(params, bn, st_seq, jnp.asarray(x[t]))
    for a, b in zip(jax.tree.leaves(end), jax.tree.leaves(st_seq)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # T=1 special case degenerates to the single step
    one, e1, l1 = scan(params, bn, st_seq, jnp.asarray(x[7:8]),
                       jnp.ones(1, bool))
    ref, er, lr = step(params, bn, st_seq, jnp.asarray(x[7]))
    np.testing.assert_array_equal(np.asarray(e1[0]), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(lr))
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sessions/state: grid_scan over ragged slot grids
# ---------------------------------------------------------------------------

def test_grid_scan_ragged_bit_exact_vs_sequential_grid_step():
    """A ragged (S, T) chunk == T sequential masked grid_step calls, bit for
    bit, including a zero-length slot that must stay fully frozen."""
    cfg, bundle, params, bn = _setup()
    S, T = 4, 21
    x = np.random.default_rng(2).normal(size=(S, T, 2)).astype(np.float32)
    lens = np.array([21, 7, 0, 13])
    ga = grid_init(cfg, S)
    ga, emb_a, log_a = jax.jit(
        lambda p, b, s, xx, v: grid_scan(p, b, cfg, s, xx, v))(
            params, bn, ga, jnp.asarray(x), lengths_to_valid(lens, T))
    gb = grid_init(cfg, S)
    gstep = jax.jit(lambda p, b, s, xx, a: grid_step(p, b, cfg, s, xx, a))
    emb_b = np.zeros((S, T, cfg.embed_dim), np.float32)
    log_b = np.zeros((S, T, cfg.n_classes), np.float32)
    for t in range(T):
        gb, e, l = gstep(params, bn, gb, jnp.asarray(x[:, t]),
                         jnp.asarray(t < lens))
        emb_b[:, t], log_b[:, t] = np.asarray(e), np.asarray(l)
    emb_a, log_a = np.asarray(emb_a), np.asarray(log_a)
    for i in range(S):
        np.testing.assert_array_equal(emb_a[i, :lens[i]], emb_b[i, :lens[i]])
        np.testing.assert_array_equal(log_a[i, :lens[i]], log_b[i, :lens[i]])
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(ga["t"])[2]) == 0  # zero-length slot never moved


def test_chunk_boundaries_straddle_ring_wraparound():
    """Chunk lengths coprime with every ring depth: each chunk boundary
    lands mid-wraparound in some FIFO, and the stitched stream still matches
    an unchunked scan bit for bit."""
    cfg, bundle, params, bn = _setup()
    depths = {n for b in ring_sizes(cfg).values() for (n, _c) in b.values()}
    chunk = 7
    assert all(chunk % n != 0 for n in depths), (chunk, depths)
    T = 5 * chunk  # several full wraps of every ring
    x = np.random.default_rng(3).normal(size=(T, 2)).astype(np.float32)
    st_chunked = stream_init_single(cfg)
    scan = jax.jit(lambda p, b, s, xc: stream_scan_single(
        p, b, cfg, s, xc, jnp.ones(chunk, bool)))
    embs = []
    for off in range(0, T, chunk):
        st_chunked, e, _ = scan(params, bn, st_chunked,
                                jnp.asarray(x[off:off + chunk]))
        embs.append(np.asarray(e))
    whole, e_all, _ = jax.jit(lambda p, b, s, xc: stream_scan_single(
        p, b, cfg, s, xc, jnp.ones(T, bool)))(
            params, bn, stream_init_single(cfg), jnp.asarray(x))
    np.testing.assert_array_equal(np.concatenate(embs), np.asarray(e_all))
    for a, b in zip(jax.tree.leaves(st_chunked), jax.tree.leaves(whole)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grid_scan_t160_bit_exact_vs_160_grid_steps():
    """Acceptance: grid_scan with T_chunk=160 == 160 sequential grid_step
    calls, bit for bit (outputs and end state)."""
    cfg, bundle, params, bn = _setup()
    S, T = 2, 160
    x = np.random.default_rng(9).normal(size=(S, T, 2)).astype(np.float32)
    ga = grid_init(cfg, S)
    ga, emb_a, _ = jax.jit(
        lambda p, b, s, xx, v: grid_scan(p, b, cfg, s, xx, v))(
            params, bn, ga, jnp.asarray(x), jnp.ones((S, T), bool))
    gb = grid_init(cfg, S)
    gstep = jax.jit(lambda p, b, s, xx, a: grid_step(p, b, cfg, s, xx, a))
    active = jnp.ones(S, bool)
    emb_b = np.zeros((S, T, cfg.embed_dim), np.float32)
    for t in range(T):
        gb, e, _ = gstep(params, bn, gb, jnp.asarray(x[:, t]), active)
        emb_b[:, t] = np.asarray(e)
    np.testing.assert_array_equal(np.asarray(emb_a), emb_b)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sessions/service: ragged chunked pushes
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _services(quantize=False, t_chunk=4):
    """One chunked + one per-sample control service, reused across tests so
    each compiled bucket shape is paid for once."""
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=3, max_tenants=1,
                               quantize=quantize, t_chunk=t_chunk)
    ctl = StreamSessionService(bundle, params, bn, n_slots=3, max_tenants=1,
                               quantize=quantize, t_chunk=1)
    return svc, ctl


def _push_schedule(svc, ctl, schedule, x):
    """Run {sid: length} rounds against both services; compare bit-exactly.
    schedule: list of dicts mapping stream index -> chunk length."""
    sids = [svc.open_session() for _ in range(2)]
    cids = [ctl.open_session() for _ in range(2)]
    try:
        pos = [0, 0]
        for round_ in schedule:
            chunk = {sids[i]: x[i, pos[i]:pos[i] + n]
                     for i, n in round_.items()}
            res = svc.push_audio(chunk)
            for i, n in round_.items():
                ref_e, ref_l = [], []
                for t in range(pos[i], pos[i] + n):
                    r = ctl.push_audio({cids[i]: x[i, t]})[cids[i]]
                    ref_e.append(r["emb"])
                    ref_l.append(r["logits"])
                got = res[sids[i]]
                e = got["emb"] if got["emb"].ndim == 2 else got["emb"][None]
                l = (got["logits"] if got["logits"].ndim == 2
                     else got["logits"][None])
                np.testing.assert_array_equal(e, np.stack(ref_e))
                np.testing.assert_array_equal(l, np.stack(ref_l))
                pos[i] += n
    finally:
        for sid in sids:
            svc.close(sid)
        for cid in cids:
            ctl.close(cid)


def test_service_ragged_chunk_push_bit_exact():
    """Two sessions pushing different-length chunks in the same call match
    their per-sample controls bit for bit (incl. straddling t_chunk)."""
    svc, ctl = _services()
    x = np.random.default_rng(4).normal(size=(2, 40, 2)).astype(np.float32)
    _push_schedule(svc, ctl, [{0: 5, 1: 2}, {0: 1}, {1: 9}, {0: 6, 1: 4}], x)


def test_service_quantized_chunked_push_bit_exact():
    svc, ctl = _services(quantize=True)
    x = np.random.default_rng(5).normal(size=(2, 24, 2)).astype(np.float32)
    _push_schedule(svc, ctl, [{0: 7, 1: 3}, {0: 3, 1: 7}, {0: 2, 1: 2}], x)


def test_service_random_push_schedules_bit_exact():
    """Property: ANY interleaving of ragged chunk pushes across sessions is
    bit-exact vs per-sample stepping (hypothesis via the _hyp fallback)."""
    @given(st.integers(0, 2 ** 31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        svc, ctl = _services()
        x = rng.normal(size=(2, 36, 2)).astype(np.float32)
        schedule = []
        pos = [0, 0]
        for _ in range(4):
            round_ = {}
            for i in range(2):
                if rng.random() < 0.75 and pos[i] < x.shape[1]:
                    n = int(rng.integers(1, min(9, x.shape[1] - pos[i]) + 1))
                    round_[i] = n
                    pos[i] += n
            if round_:
                schedule.append(round_)
        _push_schedule(svc, ctl, schedule, x)
    prop()


def test_service_per_sample_surface_unchanged():
    """A (C_in,) push keeps the historical scalar result surface."""
    svc, _ = _services()
    sid = svc.open_session()
    try:
        r = svc.push_audio({sid: np.zeros(2, np.float32)})[sid]
        assert r["emb"].shape == (12,) and r["logits"].shape == (4,)
        assert r["step"] == 1 and isinstance(r["pred"], int)
    finally:
        svc.close(sid)


def test_service_rejects_malformed_chunks():
    svc, _ = _services()
    sid = svc.open_session()
    try:
        with pytest.raises(ValueError):
            svc.push_audio({sid: np.zeros((0, 2), np.float32)})  # empty
        with pytest.raises(ValueError):
            svc.push_audio({sid: np.zeros((4, 3), np.float32)})  # bad C_in
    finally:
        svc.close(sid)


def test_chunked_push_amortizes_dispatches():
    """The whole point: a 16-sample chunk costs ceil(16/4)=4 dispatches on a
    t_chunk=4 service, not 16."""
    svc, ctl = _services()
    sid = svc.open_session()
    try:
        before = svc.dispatches
        svc.push_audio({sid: np.zeros((16, 2), np.float32)})
        assert svc.dispatches - before == 4
        before = svc.dispatches
        svc.push_audio({sid: np.zeros((3, 2), np.float32)})  # pow2 bucket
        assert svc.dispatches - before == 1
    finally:
        svc.close(sid)


# ---------------------------------------------------------------------------
# Fused kernel fast path: grid executor + service, bit-identical to the
# pre-existing chunked scan (PR 2's cross-program discipline must survive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True])
def test_fused_chunk_bit_identical_to_grid_scan(quantize):
    """make_grid_fused on baked params == grid_scan on the same baked
    params, bit for bit: outputs at valid positions AND end state, over a
    multi-chunk schedule whose boundaries straddle ring wraparound, with
    ragged lengths including a frozen zero-length slot."""
    cfg, bundle, params, bn = _setup()
    scan_p, scan_bn, fused_p = bake_stream_params(params, bn, cfg,
                                                  quantize=quantize)
    S, T = 4, 7  # 7 is coprime with every ring depth of this config
    depths = {n for b in ring_sizes(cfg).values() for (n, _c) in b.values()}
    assert all(T % n != 0 for n in depths), (T, depths)
    scan = jax.jit(lambda p, b, s, xx, v: grid_scan(
        p, b, cfg, s, xx, v, quantize=quantize))
    fused = jax.jit(make_grid_fused(cfg, quantize=quantize))
    ga, gb = grid_init(cfg, S), grid_init(cfg, S)
    rng = np.random.default_rng(11)
    for step in range(5):  # several wraps of every ring
        x = rng.normal(size=(S, T, 2)).astype(np.float32)
        lens = rng.integers(0, T + 1, size=S)
        lens[step % S] = 0  # always one fully frozen slot
        ga, emb_a, log_a = scan(scan_p, scan_bn, ga, jnp.asarray(x),
                                lengths_to_valid(lens, T))
        gb, emb_b, log_b = fused(fused_p, gb, jnp.asarray(x),
                                 jnp.asarray(lens, jnp.int32))
        emb_a, emb_b = np.asarray(emb_a), np.asarray(emb_b)
        log_a, log_b = np.asarray(log_a), np.asarray(log_b)
        for i in range(S):
            np.testing.assert_array_equal(emb_a[i, :lens[i]],
                                          emb_b[i, :lens[i]])
            np.testing.assert_array_equal(log_a[i, :lens[i]],
                                          log_b[i, :lens[i]])
        for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("quantize", [False, True])
def test_fused_service_bit_identical_incl_park_resume(quantize):
    """A fused=True service == an unfused control running the existing
    chunked scan on the same baked params — bit for bit through ragged
    pushes, enrollment, tenant logits, explicit park, LRU eviction, and
    resume in a different slot."""
    cfg, bundle, params, bn = _setup()
    scan_p, scan_bn, _ = bake_stream_params(params, bn, cfg,
                                            quantize=quantize)
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=2,
                               t_chunk=4, quantize=quantize, fused=True,
                               max_sessions=4)
    ctl = StreamSessionService(bundle, scan_p, scan_bn, n_slots=2,
                               max_tenants=2, t_chunk=4, quantize=quantize,
                               max_sessions=4)
    assert svc.stats()["fused"] and not ctl.stats()["fused"]
    x = np.random.default_rng(12).normal(size=(3, 40, 2)).astype(np.float32)
    shots = np.random.default_rng(13).normal(size=(3, 12, 2)).astype(np.float32)
    fa, fb = svc.open_session(), svc.open_session(tenant=None)
    ca, cb = ctl.open_session(), ctl.open_session(tenant=None)
    for f_r, c_r in [(svc.push_audio({fa: x[0, :9], fb: x[1, :5]}),
                      ctl.push_audio({ca: x[0, :9], cb: x[1, :5]}))]:
        np.testing.assert_array_equal(f_r[fa]["emb"], c_r[ca]["emb"])
        np.testing.assert_array_equal(f_r[fb]["logits"], c_r[cb]["logits"])
    svc.enroll_shots(fb, shots)
    ctl.enroll_shots(cb, shots)
    svc.park(fa)
    ctl.park(ca)
    f_r = svc.push_audio({fa: x[0, 9:30], fb: x[1, 5:30]})
    c_r = ctl.push_audio({ca: x[0, 9:30], cb: x[1, 5:30]})
    np.testing.assert_array_equal(f_r[fa]["emb"], c_r[ca]["emb"])
    np.testing.assert_array_equal(f_r[fb]["tenant_logits"],
                                  c_r[cb]["tenant_logits"])
    assert f_r[fb]["pred"] == c_r[cb]["pred"]
    # slot pressure: opening a third session LRU-evicts; resume must be
    # bit-identical in whatever slot comes free
    fx, cx = svc.open_session(), ctl.open_session()
    assert svc.poll(fa)["state"] == "parked"
    svc.push_audio({fx: x[2, :4]})
    ctl.push_audio({cx: x[2, :4]})
    f_r = svc.push_audio({fa: x[0, 30:40]})
    c_r = ctl.push_audio({ca: x[0, 30:40]})
    np.testing.assert_array_equal(f_r[fa]["emb"], c_r[ca]["emb"])
    np.testing.assert_array_equal(f_r[fa]["logits"], c_r[ca]["logits"])


def test_fused_service_chunk_size_invariance():
    """The fused service's cross-program discipline: pushing one stream
    through different t_chunk buckets yields bit-identical outputs."""
    cfg, bundle, params, bn = _setup()
    x = np.random.default_rng(14).normal(size=(23, 2)).astype(np.float32)
    outs = []
    for t_chunk in (1, 4, 16):
        svc = StreamSessionService(bundle, params, bn, n_slots=2,
                                   t_chunk=t_chunk, fused=True)
        sid = svc.open_session()
        r = svc.push_audio({sid: x})[sid]
        outs.append((r["emb"], r["logits"]))
    for e, l in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], e)
        np.testing.assert_array_equal(outs[0][1], l)


def test_fused_forward_matches_stream_and_unfused():
    """models/tcn.make_fused_forward: bit-identical to the fused chunk
    executor run from a fresh state (same kernels, zero history == causal
    left-pad), and allclose to raw tcn_forward (BN folding reassociates
    by design — that is the documented fused-service caveat)."""
    cfg, bundle, params, bn = _setup()
    scan_p, scan_bn, fused_p = bake_stream_params(params, bn, cfg)
    B, T = 3, 30
    x = np.random.default_rng(15).normal(size=(B, T, 2)).astype(np.float32)
    fwd = jax.jit(make_fused_forward(cfg))
    emb_f, log_f = fwd(fused_p, jnp.asarray(x))
    fused = jax.jit(make_grid_fused(cfg))
    _, emb_s, log_s = fused(fused_p, grid_init(cfg, B), jnp.asarray(x),
                            jnp.full((B,), T, jnp.int32))
    np.testing.assert_array_equal(np.asarray(emb_f), np.asarray(emb_s)[:, -1])
    np.testing.assert_array_equal(np.asarray(log_f), np.asarray(log_s)[:, -1])
    emb_r, log_r, _ = tcn_forward(params, bn, cfg, jnp.asarray(x),
                                  train=False)
    np.testing.assert_allclose(np.asarray(emb_f), np.asarray(emb_r),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(log_f), np.asarray(log_r),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# sharding: grid_pspecs / bank_pspecs placement
# ---------------------------------------------------------------------------

def test_grid_pspecs_places_slots_on_data_and_banks_on_model():
    """Acceptance: every slot-grid leaf's leading axis maps to 'data' and
    every bank leaf's leading axis to 'model' (through sharding/rules)."""
    from repro.launch.mesh import make_mesh
    cfg, bundle, params, bn = _setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = jax.tree.leaves(grid_pspecs(cfg, mesh, n_slots=2))
    assert specs and all(len(p) >= 1 and p[0] == "data" for p in specs)
    bspecs = jax.tree.leaves(bank_pspecs(bank_init(2, 2, 4), mesh))
    assert bspecs and all(len(p) >= 1 and p[0] == "model" for p in bspecs)


def test_service_runs_unchanged_on_one_device_mesh():
    """Acceptance: the sharded service on a 1-device mesh is bit-identical
    to the unsharded service."""
    from repro.launch.mesh import make_mesh
    cfg, bundle, params, bn = _setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    plain = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                                 t_chunk=4)
    meshed = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                                  t_chunk=4, mesh=mesh)
    x = np.random.default_rng(6).normal(size=(14, 2)).astype(np.float32)
    a = plain.open_session()
    b = meshed.open_session()
    ra = plain.push_audio({a: x})[a]
    rb = meshed.push_audio({b: x})[b]
    np.testing.assert_array_equal(ra["emb"], rb["emb"])
    np.testing.assert_array_equal(ra["logits"], rb["logits"])
