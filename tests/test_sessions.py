"""Multi-tenant streaming session subsystem: batched-step exactness,
park/resume round-trips, tenant isolation, scheduler policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import protonet as pn
from repro.core.streaming import stream_init, stream_step
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state, tcn_forward
from repro.sessions import (
    AdmissionError,
    CapacityError,
    SlotScheduler,
    StreamSessionService,
    bank_add_class,
    bank_fc,
    bank_init,
    bank_store,
    grid_init,
    grid_step,
    pack_slot,
    unpack_slot,
)


def _setup(seed=0):
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    bn = tcn_empty_state(cfg)
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(jax.random.normal(jax.random.key(7), a.shape)),
        bn)
    return cfg, bundle, params, bn


# ---------------------------------------------------------------------------
# state.py: vmapped grid step
# ---------------------------------------------------------------------------

def test_grid_step_bit_exact_vs_batched_stream_step():
    """The vmapped SoA step IS the batched stream_step, bit for bit."""
    cfg, bundle, params, bn = _setup()
    S, T = 4, 20
    x = np.random.default_rng(0).normal(size=(S, T, 2)).astype(np.float32)
    states = grid_init(cfg, S)
    active = jnp.ones(S, bool)
    gstep = jax.jit(lambda st, xt: grid_step(params, bn, cfg, st, xt, active))
    bstate = stream_init(cfg, S)
    bstep = jax.jit(lambda st, xt: stream_step(params, bn, cfg, st, xt))
    for t in range(T):
        states, emb_g, log_g = gstep(states, jnp.asarray(x[:, t]))
        bstate, emb_b, log_b = bstep(bstate, jnp.asarray(x[:, t]))
        np.testing.assert_array_equal(np.asarray(emb_g), np.asarray(emb_b))
        np.testing.assert_array_equal(np.asarray(log_g), np.asarray(log_b))


def test_grid_step_matches_sequential_single_streams():
    """vs N separate B=1 stream_step runs: numerically identical up to CPU
    matmul-width reassociation (and to the full-sequence conv)."""
    cfg, bundle, params, bn = _setup()
    S, T = 3, 25
    x = np.random.default_rng(1).normal(size=(S, T, 2)).astype(np.float32)
    states = grid_init(cfg, S)
    active = jnp.ones(S, bool)
    gstep = jax.jit(lambda st, xt: grid_step(params, bn, cfg, st, xt, active))
    for t in range(T):
        states, emb_g, _ = gstep(states, jnp.asarray(x[:, t]))
    step1 = jax.jit(lambda st, xt: stream_step(params, bn, cfg, st, xt))
    for i in range(S):
        sti = stream_init(cfg, 1)
        for t in range(T):
            sti, e, _ = step1(sti, jnp.asarray(x[i:i + 1, t]))
        np.testing.assert_allclose(np.asarray(emb_g[i]), np.asarray(e[0]),
                                   rtol=1e-4, atol=1e-5)
    emb_full, _, _ = tcn_forward(params, bn, cfg, jnp.asarray(x), train=False)
    np.testing.assert_allclose(np.asarray(emb_g), np.asarray(emb_full),
                               rtol=2e-4, atol=2e-5)


def test_inactive_slots_bit_frozen():
    """Stepping a subset leaves every other slot's state untouched."""
    cfg, bundle, params, bn = _setup()
    S = 4
    x = np.random.default_rng(2).normal(size=(S, 2)).astype(np.float32)
    states = grid_init(cfg, S)
    for t in range(5):  # warm all slots so rings are non-trivial
        states, _, _ = grid_step(params, bn, cfg, states,
                                 jnp.asarray(x), jnp.ones(S, bool))
    before = jax.tree.map(np.asarray, states)
    active = jnp.asarray([True, False, True, False])
    after, _, _ = grid_step(params, bn, cfg, states, jnp.asarray(x), active)
    for leaf_b, leaf_a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(leaf_b[1], np.asarray(leaf_a)[1])
        np.testing.assert_array_equal(leaf_b[3], np.asarray(leaf_a)[3])
    # ...while active slots' step counters ticked
    assert np.asarray(after["t"])[0] == before["t"][0] + 1
    assert np.asarray(after["t"])[1] == before["t"][1]


def test_pack_unpack_roundtrip_any_slot():
    """Session state is slot-position independent: pack from slot i, unpack
    into slot j, identical leaves."""
    cfg, bundle, params, bn = _setup()
    states = grid_init(cfg, 3)
    x = np.random.default_rng(3).normal(size=(3, 2)).astype(np.float32)
    for t in range(7):
        states, _, _ = grid_step(params, bn, cfg, states,
                                 jnp.asarray(x), jnp.ones(3, bool))
    parked = pack_slot(states, 0)
    states2 = unpack_slot(states, 2, parked)
    for a, b in zip(jax.tree.leaves(pack_slot(states2, 2)),
                    jax.tree.leaves(parked)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# service.py: park -> evict -> resume bit-identical
# ---------------------------------------------------------------------------

def test_evict_park_resume_bit_identical():
    """A session evicted mid-stream and resumed (in a different slot) emits
    bit-identical outputs to an uninterrupted control run."""
    cfg, bundle, params, bn = _setup()
    T = 30
    rng = np.random.default_rng(4)
    xa = rng.normal(size=(T, 2)).astype(np.float32)
    xb = rng.normal(size=(T, 2)).astype(np.float32)

    control = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    c = control.open_session()
    control_out = [control.push_audio({c: xa[t]})[c] for t in range(T)]

    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    a = svc.open_session()
    victim_out = [svc.push_audio({a: xa[t]})[a] for t in range(10)]
    # two newer sessions force slot pressure; a is LRU -> evicted
    b1 = svc.open_session()
    b2 = svc.open_session()
    assert svc.poll(a)["state"] == "parked"
    for t in range(5):
        svc.push_audio({b1: xb[t], b2: xb[t]})
    # resuming a evicts an idle neighbor and lands in SOME slot
    for t in range(10, T):
        victim_out.append(svc.push_audio({a: xa[t]})[a])
    assert svc.stats()["evictions"] >= 2
    for t in (0, 9, 10, 15, T - 1):
        np.testing.assert_array_equal(victim_out[t]["emb"], control_out[t]["emb"])
        np.testing.assert_array_equal(victim_out[t]["logits"],
                                      control_out[t]["logits"])


def test_explicit_park_resume_roundtrip():
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    control = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    x = np.random.default_rng(5).normal(size=(20, 2)).astype(np.float32)
    s, c = svc.open_session(), control.open_session()
    for t in range(8):
        r1 = svc.push_audio({s: x[t]})[s]
        r2 = control.push_audio({c: x[t]})[c]
    svc.park(s)
    assert svc.poll(s)["state"] == "parked"
    for t in range(8, 20):
        r1 = svc.push_audio({s: x[t]})[s]
        r2 = control.push_audio({c: x[t]})[c]
    np.testing.assert_array_equal(r1["emb"], r2["emb"])


# ---------------------------------------------------------------------------
# tenancy: per-tenant prototype banks
# ---------------------------------------------------------------------------

def test_bank_fc_matches_per_store():
    """Stacked bank FC rows == each tenant's standalone store_fc."""
    V = 8
    rng = np.random.default_rng(6)
    bank = bank_init(3, 4, V)
    stores = [pn.store_init(4, V) for _ in range(3)]
    for tid, nw in enumerate([1, 3, 2]):
        for _ in range(nw):
            shots = jnp.asarray(rng.normal(size=(2, V)).astype(np.float32))
            bank = bank_add_class(bank, tid, shots)
            stores[tid] = pn.store_add_class(stores[tid], shots)
    w, b = bank_fc(bank)
    for tid in range(3):
        ws, bs = pn.store_fc(stores[tid])
        np.testing.assert_array_equal(np.asarray(w[tid]), np.asarray(ws))
        np.testing.assert_array_equal(np.asarray(b[tid]), np.asarray(bs))
        sv = bank_store(bank, tid)
        np.testing.assert_array_equal(np.asarray(sv.s_sums),
                                      np.asarray(stores[tid].s_sums))


def test_pn_logits_banked_gathers_per_row():
    V, W = 6, 3
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(2, W, V)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, W)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, V)).astype(np.float32))
    ids = jnp.asarray([0, 1, 1, 0])
    out = pn.pn_logits_banked(x, w, b, ids)
    for i, tid in enumerate([0, 1, 1, 0]):
        np.testing.assert_allclose(
            np.asarray(out[i]),
            np.asarray(pn.pn_logits(x[i:i + 1], w[tid], b[tid])[0]),
            rtol=1e-6)


def test_mid_stream_enrollment_isolated():
    """A tenant enrolled mid-stream classifies with its own prototypes;
    a neighbor tenant's outputs are bit-unchanged by the enrollment."""
    cfg, bundle, params, bn = _setup()
    T = 16
    rng = np.random.default_rng(8)
    xa = rng.normal(size=(T, 2)).astype(np.float32)
    xb = rng.normal(size=(T, 2)).astype(np.float32)
    shots_a = rng.normal(size=(3, 12, 2)).astype(np.float32)
    shots_a2 = rng.normal(size=(2, 12, 2)).astype(np.float32)
    shots_b = rng.normal(size=(2, 12, 2)).astype(np.float32)

    def run(enroll):
        svc = StreamSessionService(bundle, params, bn, n_slots=4,
                                   max_tenants=4, max_ways=4)
        sa = svc.open_session(tenant=None)
        sb = svc.open_session(tenant=None)
        svc.enroll_shots(sb, shots_b)
        outs_b, outs_a = [], []
        for t in range(T):
            if enroll and t == 5:
                svc.enroll_shots(sa, shots_a)
            if enroll and t == 10:
                svc.enroll_shots(sa, shots_a2)  # CL: append a second way live
            r = svc.push_audio({sa: xa[t], sb: xb[t]})
            outs_a.append(r[sa])
            outs_b.append(r[sb])
        return svc, sa, outs_a, outs_b

    svc1, sa1, a1, b1 = run(enroll=False)
    svc2, sa2, a2, b2 = run(enroll=True)

    # neighbor unaffected, bit for bit
    for t in range(T):
        np.testing.assert_array_equal(b1[t]["emb"], b2[t]["emb"])
        np.testing.assert_array_equal(b1[t]["tenant_logits"],
                                      b2[t]["tenant_logits"])
    # before enrollment sa has no personalized head; after, it classifies
    # against its own growing way set
    assert a2[4]["tenant_logits"] is None
    assert a2[5]["tenant_logits"] is not None
    assert np.isfinite(a2[9]["tenant_logits"][0])
    assert not np.isfinite(a2[9]["tenant_logits"][1])  # way 1 not yet enrolled
    assert np.isfinite(a2[10]["tenant_logits"][1])     # live CL append
    assert svc2.poll(sa2)["n_ways"] == 2
    # the personalized prediction equals the tenant's own store argmax
    store = bank_store(svc2.bank, svc2.sessions[sa2].tenant)
    expect = int(np.asarray(pn.store_classify(
        store, jnp.asarray(a2[T - 1]["emb"][None])))[0])
    assert a2[T - 1]["pred"] == expect


def test_tenant_personalization_predicts_enrolled_keyword():
    """End-to-end FSL sanity: after enrolling class prototypes through the
    shared embedder, a query clip of an enrolled class is predicted as the
    matching way."""
    from repro.data import KeywordAudio
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    svc = StreamSessionService(bundle, params, bn, n_slots=2,
                               max_tenants=2, max_ways=4)
    audio = KeywordAudio(n_classes=4, seed=0)
    sid = svc.open_session(tenant=None)
    for cls in (0, 2):
        clips = audio.mfcc(audio.sample(cls, 3, seed=10 + cls))
        svc.enroll_shots(sid, clips)
    q = audio.mfcc(audio.sample(0, 1, seed=99))[0]  # (63, 28)
    for t in range(q.shape[0]):
        res = svc.push_audio({sid: q[t]})[sid]
    assert res["tenant_logits"].shape == (4,)
    assert np.isfinite(res["tenant_logits"][:2]).all()
    assert not np.isfinite(res["tenant_logits"][2:]).any()
    assert res["pred"] in (0, 1)


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------

def test_scheduler_lru_eviction_order():
    sched = SlotScheduler(2)
    for sid in (1, 2):
        sched.admit(sid)
        sched.bind(sid)
    sched.touch(1)  # 2 is now LRU
    sched.admit(3)
    slot, evicted = sched.bind(3)
    assert evicted == 2 and sched.is_parked(2) and not sched.is_bound(2)
    assert sched.is_bound(3)


def test_scheduler_admission_control_and_release():
    sched = SlotScheduler(2, max_sessions=3)
    for sid in (1, 2, 3):
        sched.admit(sid)
    with pytest.raises(AdmissionError):
        sched.admit(4)
    sched.release(1)
    sched.admit(4)  # capacity freed
    assert sched.live_sessions == 3


def test_scheduler_pinned_slots_not_evicted():
    sched = SlotScheduler(1)
    sched.admit(1)
    sched.bind(1)
    sched.admit(2)
    with pytest.raises(CapacityError):
        sched.bind(2, pinned={1})
    slot, evicted = sched.bind(2)  # unpinned: 1 is evictable
    assert evicted == 1


def test_scheduler_slot_reuse_after_release():
    sched = SlotScheduler(2)
    sched.admit(1)
    s1, _ = sched.bind(1)
    sched.admit(2)
    sched.bind(2)
    sched.release(1)
    sched.admit(3)
    s3, evicted = sched.bind(3)
    assert s3 == s1 and evicted is None


def test_service_admission_error():
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               max_sessions=2)
    svc.open_session()
    svc.open_session()
    with pytest.raises(AdmissionError):
        svc.open_session()


def test_dedicated_tenants_recycled():
    """open(tenant=None)/close churn must not exhaust the tenant bank, and a
    refused admission must not leak the tenant row it allocated."""
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=2,
                               max_sessions=2, max_ways=2)
    for _ in range(5):  # > max_tenants iterations
        sid = svc.open_session(tenant=None)
        svc.enroll_shots(sid, np.zeros((1, 8, 2), np.float32))
        svc.close(sid)
    assert len(svc._free_tenants) == 2
    assert int(svc._tenant_ways.sum()) == 0  # rows cleared on recycle
    svc.open_session()
    svc.open_session()
    with pytest.raises(AdmissionError):
        svc.open_session(tenant=None)
    assert len(svc._free_tenants) == 2  # no leak on refused admission
    with pytest.raises(AdmissionError):
        svc.open_session(tenant=1)  # explicit claim must roll back too
    assert len(svc._free_tenants) == 2


def test_dedicated_tenant_freed_after_sharer_closes():
    """Ownership of a dedicated tenant row passes to a sharing session, so
    the row is freed whichever session closes last."""
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=4, max_tenants=2)
    s1 = svc.open_session(tenant=None)
    tid = svc.sessions[s1].tenant
    s2 = svc.open_session(tenant=tid)  # shares the dedicated row
    svc.close(s1)
    assert tid not in svc._free_tenants  # sharer still using it
    svc.close(s2)
    assert tid in svc._free_tenants  # freed by the last sharer


def test_dedicated_ownership_transfers_through_sharer_chain():
    """With three sessions on one dedicated row, closing the owner hands
    ownership to exactly ONE survivor each time; the row is freed only by
    the final close."""
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=4, max_tenants=2)
    s1 = svc.open_session(tenant=None)
    tid = svc.sessions[s1].tenant
    s2 = svc.open_session(tenant=tid)
    s3 = svc.open_session(tenant=tid)
    assert [svc.sessions[s].dedicated for s in (s1, s2, s3)] == \
        [True, False, False]
    svc.close(s1)  # owner leaves first
    owners = [s for s in (s2, s3) if svc.sessions[s].dedicated]
    assert len(owners) == 1  # exactly one survivor inherits the row
    assert tid not in svc._free_tenants
    svc.close(owners[0])  # inherited ownership transfers again
    last = s3 if owners[0] == s2 else s2
    assert svc.sessions[last].dedicated
    assert tid not in svc._free_tenants
    svc.close(last)
    assert tid in svc._free_tenants  # freed by the final sharer only
    # the freed row is recyclable: both dedicated rows open again
    s4 = svc.open_session(tenant=None)
    s5 = svc.open_session(tenant=None)
    assert {svc.sessions[s4].tenant, svc.sessions[s5].tenant} == {0, 1}


def test_enroll_refine_rejects_unenrolled_way():
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               max_ways=4)
    sid = svc.open_session(tenant=None)
    svc.enroll_shots(sid, np.zeros((1, 8, 2), np.float32))
    with pytest.raises(ValueError):
        svc.enroll_shots(sid, np.zeros((1, 8, 2), np.float32), way=3)
    svc.enroll_shots(sid, np.zeros((1, 8, 2), np.float32), way=0)  # valid


# ---------------------------------------------------------------------------
# cost-aware eviction
# ---------------------------------------------------------------------------

def test_scheduler_cost_aware_eviction_prefers_cheapest():
    """Within the staleness window the cheapest-to-park session is evicted;
    with window 0 the policy degenerates to pure LRU."""
    costs = {1: 100, 2: 10}
    sched = SlotScheduler(2, cost_fn=costs.get, stale_window=1 << 30)
    for sid in (1, 2):
        sched.admit(sid)
        sched.bind(sid)
    sched.touch(2)  # 1 is LRU, but 2 is far cheaper and "equally stale"
    sched.admit(3)
    _, evicted = sched.bind(3)
    assert evicted == 2

    costs = {1: 10, 2: 100}
    sched = SlotScheduler(2, cost_fn=costs.get)  # stale_window=0
    for sid in (1, 2):
        sched.admit(sid)
        sched.bind(sid)
    sched.touch(1)  # 2 is LRU and expensive; window 0 evicts it anyway
    sched.admit(3)
    _, evicted = sched.bind(3)
    assert evicted == 2


def test_service_cost_aware_eviction():
    cfg, bundle, params, bn = _setup()
    costs = {}
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               cost_fn=lambda sid: costs.get(sid, 0),
                               stale_window=1 << 30)
    a = svc.open_session()
    b = svc.open_session()
    costs[a], costs[b] = 5, 1
    svc.open_session()  # grid full: parks the cheapest (b), not the LRU (a)
    assert svc.poll(b)["state"] == "parked"
    assert svc.poll(a)["state"] == "active"


def test_scheduler_finite_stale_window_bounds_the_pool():
    """With a FINITE window > 0, only candidates within ``stale_window``
    clock ticks of the oldest are cost-arbitrated; a cheaper victim that
    is too fresh stays bound (the pool/min-cost path of bind())."""
    costs = {1: 50, 2: 5, 3: 1}
    sched = SlotScheduler(3, cost_fn=costs.get, stale_window=2)
    for sid in (1, 2, 3):
        sched.admit(sid)
        sched.bind(sid)
    # clocks after admit+bind: lu = {1: 1, 2: 2, 3: 3}; refresh 2 and 3
    sched.touch(2)  # lu 2 -> 4
    sched.touch(3)  # lu 3 -> 5
    sched.admit(4)
    # oldest = lu(1) = 1; pool = {1} (2 and 3 are > 2 ticks fresher), so
    # the expensive-but-stale 1 is evicted despite 3's far cheaper park
    _, evicted = sched.bind(4)
    assert evicted == 1

    # a wider window re-admits 2 to the pool and cost wins over staleness
    costs = {1: 50, 2: 5, 3: 1}
    sched = SlotScheduler(3, cost_fn=costs.get, stale_window=4)
    for sid in (1, 2, 3):
        sched.admit(sid)
        sched.bind(sid)
    sched.touch(2)  # lu = {1: 1, 2: 4, 3: 3}: pool = {1, 2, 3} minus none
    sched.touch(3)  # lu 3 -> 5: pool = {1 (0), 2 (3), 3 (4)} all <= 4
    sched.admit(4)
    _, evicted = sched.bind(4)
    assert evicted == 3  # cheapest in pool


def test_scheduler_cost_tie_breaks_by_staleness():
    """Equal park costs inside the pool fall back to LRU order — the
    (cost, last_used) secondary key."""
    sched = SlotScheduler(3, cost_fn=lambda sid: 7, stale_window=1 << 30)
    for sid in (1, 2, 3):
        sched.admit(sid)
        sched.bind(sid)
    sched.touch(1)  # 2 is now the least-recently-touched
    sched.admit(4)
    _, evicted = sched.bind(4)
    assert evicted == 2


def test_service_finite_stale_window_excludes_fresh_cheap_victim():
    """Service-level: the cheap session is outside the staleness window
    (recently pushed), so the expensive-but-stale one is parked."""
    cfg, bundle, params, bn = _setup()
    costs = {}
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               cost_fn=lambda sid: costs.get(sid, 0),
                               stale_window=1)
    a = svc.open_session()
    b = svc.open_session()
    costs[a], costs[b] = 1, 100  # a is far cheaper to park...
    x = np.zeros(cfg.tcn_in_channels, np.float32)
    for _ in range(3):  # ...but pushing keeps it fresh, outside the window
        svc.push_audio({a: x})
    svc.open_session()
    assert svc.poll(b)["state"] == "parked"
    assert svc.poll(a)["state"] == "active"


# ---------------------------------------------------------------------------
# packed-nibble parking (quantized service)
# ---------------------------------------------------------------------------

def test_quantized_parking_nibble_packed_bit_identical():
    """quantize=True parks rings as packed u4 nibbles: much smaller on
    host, still bit-identical on resume."""
    from repro.sessions import parked_bytes
    cfg, bundle, params, bn = _setup()
    mk = lambda: StreamSessionService(bundle, params, bn, n_slots=2,
                                      max_tenants=1, quantize=True, t_chunk=8)
    svc, ctl = mk(), mk()
    x = np.random.default_rng(11).normal(size=(40, 2)).astype(np.float32)
    s, c = svc.open_session(), ctl.open_session()
    svc.push_audio({s: x[:17]})
    ctl.push_audio({c: x[:17]})
    svc.park(s)
    packed = parked_bytes(svc.parking[s])
    raw = StreamSessionService(bundle, params, bn, n_slots=2,
                               max_tenants=1).stats()["slot_state_bytes"]
    # interior rings pack 8x; block 0's raw-input ring and the step counter
    # stay fp32/int32, so the whole-state ratio lands between 4x and 8x here
    assert packed * 4 <= raw, (packed, raw)
    assert svc.stats()["slot_state_bytes"] == packed
    r1 = svc.push_audio({s: x[17:]})[s]   # unpack + resume
    r2 = ctl.push_audio({c: x[17:]})[c]   # uninterrupted control
    np.testing.assert_array_equal(r1["emb"], r2["emb"])
    np.testing.assert_array_equal(r1["logits"], r2["logits"])


# ---------------------------------------------------------------------------
# parking-lot persistence (checkpoint/store spill)
# ---------------------------------------------------------------------------

def test_parking_persistence_roundtrip(tmp_path):
    """Sessions spilled to disk survive a process restart (fresh service)
    and resume bit-identically, tenant prototypes included."""
    cfg, bundle, params, bn = _setup()
    mk = lambda: StreamSessionService(bundle, params, bn, n_slots=2,
                                      max_tenants=2, max_ways=2, t_chunk=8)
    ctl = mk()
    svc = mk()
    x = np.random.default_rng(12).normal(size=(40, 2)).astype(np.float32)
    shots = np.random.default_rng(13).normal(size=(2, 10, 2)).astype(np.float32)
    c = ctl.open_session(tenant=None)
    s = svc.open_session(tenant=None)
    ctl.enroll_shots(c, shots)
    svc.enroll_shots(s, shots)
    ctl.push_audio({c: x[:25]})
    svc.push_audio({s: x[:25]})
    path = str(tmp_path / "sessions.npz")
    svc.spill_parking(path, include_bound=True)  # drain: parks s first
    assert svc.poll(s)["state"] == "parked"

    fresh = mk()  # "restart": brand-new service, same weights
    restored = fresh.restore_parking(path)
    assert restored == [s]
    assert fresh.poll(s)["steps"] == 25
    assert fresh.poll(s)["n_ways"] == 1  # tenant prototypes came back
    r1 = fresh.push_audio({s: x[25:]})[s]
    r2 = ctl.push_audio({c: x[25:]})[c]
    np.testing.assert_array_equal(r1["emb"], r2["emb"])
    np.testing.assert_array_equal(r1["logits"], r2["logits"])
    np.testing.assert_array_equal(r1["tenant_logits"], r2["tenant_logits"])
    # restored sids stay unique: the next open_session must not collide
    assert fresh.open_session() not in restored


def test_restore_refuses_live_sid_collision(tmp_path):
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    s = svc.open_session()
    svc.push_audio({s: np.zeros(2, np.float32)})
    path = str(tmp_path / "p.npz")
    svc.spill_parking(path, include_bound=True)
    with pytest.raises(ValueError):
        svc.restore_parking(path)  # s is still live here


def test_restore_refuses_tenant_in_use_and_leaves_no_trace(tmp_path):
    """A refused restore must not corrupt live tenants: validation runs
    before ANY mutation (tenant rows, scheduler, sessions)."""
    cfg, bundle, params, bn = _setup()
    mk = lambda: StreamSessionService(bundle, params, bn, n_slots=2,
                                      max_tenants=1, max_ways=2)
    src = mk()
    throwaway = src.open_session()     # sid 0: keep spilled sids != dst's
    s = src.open_session(tenant=None)  # sid 1 claims tenant 0
    src.enroll_shots(s, np.ones((1, 8, 2), np.float32))
    src.push_audio({s: np.zeros(2, np.float32)})
    src.close(throwaway)
    path = str(tmp_path / "p.npz")
    src.spill_parking(path, include_bound=True)

    dst = mk()
    d = dst.open_session(tenant=None)  # also claims tenant 0 — collision
    dst.enroll_shots(d, np.full((1, 8, 2), 2.0, np.float32))
    bank_before = np.asarray(dst.bank.s_sums).copy()
    live_before = dst.sched.live_sessions
    with pytest.raises(ValueError, match="tenant 0 already in use"):
        dst.restore_parking(path)
    np.testing.assert_array_equal(np.asarray(dst.bank.s_sums), bank_before)
    assert dst.sched.live_sessions == live_before
    assert s not in dst.sessions


def test_restore_refuses_over_capacity_atomically(tmp_path):
    cfg, bundle, params, bn = _setup()
    from repro.sessions import AdmissionError
    src = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    throwaway = src.open_session()  # sid 0: keep spilled sids != dst's
    src.close(throwaway)
    sids = [src.open_session() for _ in range(2)]
    src.push_audio({sid: np.zeros(2, np.float32) for sid in sids})
    path = str(tmp_path / "p.npz")
    src.spill_parking(path, include_bound=True)
    dst = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               max_sessions=2)
    keep = dst.open_session()
    with pytest.raises(AdmissionError):
        dst.restore_parking(path)  # 1 live + 2 restored > 2
    assert dst.sched.live_sessions == 1  # nothing half-admitted
    assert list(dst.sessions) == [keep]


# ---------------------------------------------------------------------------
# chunked push: dispatch accounting through the public surface
# ---------------------------------------------------------------------------

def test_push_audio_accepts_mixed_scalar_and_chunk():
    """One call may mix (C_in,) samples and (t, C_in) chunks; each session
    advances by its own length."""
    cfg, bundle, params, bn = _setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               t_chunk=4)
    a, b = svc.open_session(), svc.open_session()
    x = np.random.default_rng(14).normal(size=(9, 2)).astype(np.float32)
    res = svc.push_audio({a: x, b: x[0]})
    assert res[a]["emb"].shape == (9, 12) and res[a]["step"] == 9
    assert res[b]["emb"].shape == (12,) and res[b]["step"] == 1
