"""Async serving plane: continuous batching bit-identity vs the synchronous
facade, cancellation mid-batch, backpressure rejection + retry, and
park-under-load churn.  All asyncio tests run via asyncio.run (no plugin
dependency)."""

import asyncio
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_bundle
from repro.sessions import AdmissionError, LMSessionService
from repro.serving import Rejected, ServingPlane


@functools.lru_cache(maxsize=None)
def _lm_setup():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return bundle, params


def _svc(n_slots=4, max_sessions=None, **kw):
    bundle, params = _lm_setup()
    return LMSessionService(
        bundle, params, n_slots=n_slots, seq_cap=32, t_chunk=4,
        max_sessions=n_slots if max_sessions is None else max_sessions, **kw)


def _prompt(i):
    return np.array([(i % 7) + 1, ((3 * i) % 7) + 1], np.int32)


def _sync_reference(n_sessions, want):
    """Each session decoded ALONE on a fresh service — the strictest
    synchronous control (no cross-lane batching at all)."""
    out = {}
    for i in range(n_sessions):
        svc = _svc(n_slots=1, max_sessions=1)
        sid = svc.open_session(_prompt(i))
        out[i] = svc.decode({sid: want})[sid]
        svc.close(sid)
    return out


# ---------------------------------------------------------------------------
# continuous batching bit-identity
# ---------------------------------------------------------------------------

def test_concurrent_pushes_bit_identical_to_sync_facade():
    """12 interleaved clients over a 4-slot worker: whatever batches the
    plane forms, every session's tokens == its solo synchronous run."""
    N, WANT = 12, 6

    async def main():
        async with ServingPlane(_svc(n_slots=4, max_sessions=N)) as plane:
            psids = [await plane.open_session(_prompt(i)) for i in range(N)]

            async def client(i):
                toks = []
                for _ in range(3):  # ragged re-pushes keep re-batching
                    toks += await plane.push(psids[i], WANT // 3)
                return toks

            outs = await asyncio.gather(*(client(i) for i in range(N)))
            m = plane.metrics()
            batches = m["plane_batches_total"][0]["value"]
            lanes = m["plane_batch_lanes"][0]
            return outs, batches, lanes

    outs, batches, lanes = asyncio.run(main())
    ref = _sync_reference(N, WANT)
    for i in range(N):
        assert outs[i] == ref[i], f"session {i} diverged from sync facade"
    # continuous batching actually happened: fewer dispatch groups than
    # client pushes, with multi-lane batches
    assert batches < N * 3
    assert lanes["max"] > 1


def test_multi_worker_tenant_affinity():
    """Tenant routing is stable (same tenant -> same worker) and results
    stay bit-identical across workers."""

    async def main():
        workers = [_svc(n_slots=2, max_sessions=8) for _ in range(3)]
        async with ServingPlane(workers, max_queue=64) as plane:
            psids = {}
            for i in range(6):
                psids[i] = await plane.open_session(
                    _prompt(i), tenant=f"tenant-{i % 3}")
            outs = await asyncio.gather(
                *(plane.push(psids[i], 4) for i in range(6)))
            # every session of a tenant landed on the same worker
            homes = {}
            for i in range(6):
                w, _ = plane._sessions[psids[i]]
                homes.setdefault(i % 3, set()).add(w.idx)
            return outs, homes

    outs, homes = asyncio.run(main())
    ref = _sync_reference(6, 4)
    for i in range(6):
        assert outs[i] == ref[i]
    assert all(len(ws) == 1 for ws in homes.values()), homes


# ---------------------------------------------------------------------------
# cancellation mid-batch
# ---------------------------------------------------------------------------

def test_client_cancellation_leaves_batchmates_bit_identical():
    """Cancel one client while its push is queued behind a busy grid: the
    cancelled session must NOT advance, and its would-be batchmates must
    still produce exactly their solo-run tokens."""

    async def main():
        async with ServingPlane(_svc(n_slots=4, max_sessions=4)) as plane:
            psids = [await plane.open_session(_prompt(i)) for i in range(3)]
            victim = asyncio.ensure_future(plane.push(psids[0], 4))
            survivors = [asyncio.ensure_future(plane.push(psids[i], 4))
                         for i in (1, 2)]
            await asyncio.sleep(0)  # all three ops are now queued
            victim.cancel()  # cancelled while queued, before the batch cut
            res = await asyncio.gather(*survivors)
            with pytest.raises(asyncio.CancelledError):
                await victim
            polls = [await plane.poll(p) for p in psids]
            return res, polls

    res, polls = asyncio.run(main())
    ref = _sync_reference(3, 4)
    assert res[0] == ref[1] and res[1] == ref[2]
    assert polls[0]["generated"] == 0  # the cancelled session never ran
    assert polls[1]["generated"] == 4 and polls[2]["generated"] == 4


# ---------------------------------------------------------------------------
# backpressure: rejection then successful retry
# ---------------------------------------------------------------------------

def test_admission_rejection_is_retryable():
    """A full grid (max_sessions == n_slots) rejects with a retryable
    Rejected chaining the service's AdmissionError; after a close, the
    same open succeeds and decodes bit-identically."""

    async def main():
        async with ServingPlane(_svc(n_slots=2, max_sessions=2)) as plane:
            a = await plane.open_session(_prompt(0))
            b = await plane.open_session(_prompt(1))
            with pytest.raises(Rejected) as ei:
                await plane.open_session(_prompt(2))
            assert ei.value.retryable and ei.value.reason == "admission"
            assert isinstance(ei.value.__cause__, AdmissionError)
            await plane.close(b)
            c = await plane.open_session(_prompt(2))  # retry succeeds
            toks = await plane.push(c, 4)
            return toks

    toks = asyncio.run(main())
    assert toks == _sync_reference(3, 4)[2]


def test_queue_full_rejection_then_retry():
    async def main():
        async with ServingPlane(_svc(n_slots=2, max_sessions=2),
                                max_queue=2) as plane:
            p = await plane.open_session(_prompt(0))
            # saturate the op queue without yielding to the worker
            f1 = asyncio.ensure_future(plane.push(p, 1))
            f2 = asyncio.ensure_future(plane.push(p, 1))
            await asyncio.sleep(0)  # let both enqueue (queue now at cap)
            with pytest.raises(Rejected) as ei:
                await plane.push(p, 1)
            assert ei.value.retryable and ei.value.reason == "queue_full"
            await asyncio.gather(f1, f2)  # drain
            toks = await plane.push(p, 1)  # retry succeeds
            rej = plane.metrics()["plane_rejected_total"]
            reasons = {e["labels"]["reason"]: e["value"] for e in rej}
            return toks, reasons

    toks, reasons = asyncio.run(main())
    assert len(toks) == 1
    assert reasons.get("queue_full", 0) >= 1


# ---------------------------------------------------------------------------
# park under load
# ---------------------------------------------------------------------------

def test_park_under_load_bit_identical():
    """Explicit park/resume churn interleaved with concurrent pushes on an
    oversubscribed grid: every session still emits its solo-run tokens."""
    N = 6

    async def main():
        async with ServingPlane(_svc(n_slots=2, max_sessions=N)) as plane:
            psids = [await plane.open_session(_prompt(i)) for i in range(N)]

            async def churner(i):
                p = psids[i]
                toks = await plane.push(p, 2)
                await plane.park(p)       # to host, mid-lifecycle
                await plane.resume(p)     # eager re-bind (may evict others)
                toks += await plane.push(p, 2)
                return toks

            return await asyncio.gather(*(churner(i) for i in range(N)))

    outs = asyncio.run(main())
    ref = _sync_reference(N, 4)
    for i in range(N):
        assert outs[i] == ref[i], f"session {i} diverged under park churn"


# ---------------------------------------------------------------------------
# plane lifecycle
# ---------------------------------------------------------------------------

def test_close_fails_queued_ops_and_refuses_new_ones():
    async def main():
        plane = ServingPlane(_svc())
        async with plane:
            p = await plane.open_session(_prompt(0))
        with pytest.raises(Rejected) as ei:
            await plane.push(p, 1)
        assert not ei.value.retryable and ei.value.reason == "closed"

    asyncio.run(main())


def test_plane_stats_shape():
    async def main():
        async with ServingPlane([_svc(), _svc()]) as plane:
            await plane.open_session(_prompt(0))
            st = plane.stats()
            assert st["n_workers"] == 2 and st["live_sessions"] == 1
            assert len(st["workers"]) == 2
            for w in st["workers"]:
                assert w["service"] == "lm"  # worker stats = service stats

    asyncio.run(main())
