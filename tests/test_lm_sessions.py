"""LM sessions: chunked multi-token decode exactness, KV-cache park/resume
bit-identity, seq_cap retirement, int32 position discipline, persistence,
and the mixed fp32/u4/KV churn property test."""

import functools

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import (
    AdmissionError,
    LMSessionService,
    StreamSessionService,
    parked_bytes,
)
from repro.sessions.state import PAGED_MARKER

settings.register_profile("lm", deadline=None, max_examples=10)
settings.load_profile("lm")


@functools.lru_cache(maxsize=None)
def _lm_setup(seed=0):
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    return cfg, bundle, params


def _svc(n_slots=2, seq_cap=48, t_chunk=8, **kw):
    cfg, bundle, params = _lm_setup()
    return LMSessionService(bundle, params, n_slots=n_slots, seq_cap=seq_cap,
                            t_chunk=t_chunk, **kw)


# ---------------------------------------------------------------------------
# chunked decode exactness
# ---------------------------------------------------------------------------

def test_chunked_decode_matches_per_token_decode():
    """decode at t_chunk=16 emits exactly the tokens of t_chunk=1 decoding
    (the cross-program bit-exactness discipline), in ~1/16 the dispatches."""
    chunked = _svc(t_chunk=16)
    stepwise = _svc(t_chunk=1)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    a = chunked.open_session(prompt)
    b = stepwise.open_session(prompt)
    d0c, d0s = chunked.dispatches, stepwise.dispatches
    out_c = chunked.decode({a: 20})[a]
    out_s = stepwise.decode({b: 20})[b]
    assert out_c == out_s
    assert len(out_c) == 20
    # 4 prompt tokens chunk-prefill at open; the last prompt token + 20
    # generated = 20 scan steps: 2 chunked dispatches vs 20
    assert chunked.dispatches - d0c == 2
    assert stepwise.dispatches - d0s == 20


def test_chunk_boundary_invariance():
    """ANY split of the same decode across calls yields the same stream."""
    whole = _svc(t_chunk=8)
    split = _svc(t_chunk=8)
    prompt = np.array([7, 9], np.int32)
    a = whole.open_session(prompt)
    b = split.open_session(prompt)
    out_a = whole.decode({a: 18})[a]
    out_b = []
    for n in (1, 5, 2, 7, 3):
        out_b += split.decode({b: n})[b]
    assert out_a == out_b


def test_interleaved_sessions_do_not_perturb_each_other():
    """Admitting and decoding a second request mid-decode leaves the first
    request's token stream bit-identical (per-lane positions: no snapshot
    or rollback machinery needed)."""
    ctl = _svc(n_slots=2)
    c = ctl.open_session(np.array([7, 9, 4], np.int32))
    want = ctl.decode({c: 11})[c]

    svc = _svc(n_slots=2)
    r = svc.open_session(np.array([7, 9, 4], np.int32))
    got = svc.decode({r: 3})[r]
    r2 = svc.open_session(np.array([1, 2], np.int32))
    got += svc.decode({r: 4, r2: 4})[r]
    got += svc.decode({r: 4})[r]
    assert got == want


def test_recurrent_cache_bundles_masked_by_value():
    """RWKV caches are recurrent states, not position-indexed rows: masked
    steps must freeze them by VALUE (the per-leaf seq_axes discipline), or
    ragged dispatches would silently advance absent lanes.  Pin the whole
    contract: chunk invariance, no cross-lane perturbation, park/resume."""
    cfg = get_config("rwkv6-1.6b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, rwkv_head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    mk = lambda n_slots, **kw: LMSessionService(
        bundle, params, n_slots=n_slots, seq_cap=64, t_chunk=8, **kw)

    ctl = mk(2)
    c = ctl.open_session(np.array([3, 1, 4], np.int32))
    want = ctl.decode({c: 12})[c]

    svc = mk(2, max_sessions=8)
    a = svc.open_session(np.array([3, 1, 4], np.int32))
    got = svc.decode({a: 3})[a]
    b = svc.open_session(np.array([9], np.int32))
    got += svc.decode({a: 2, b: 5})[a]  # ragged: b's lane masks a's tail
    b2 = svc.open_session(np.array([7], np.int32))  # evicts LRU -> parks a
    assert svc.poll(a)["state"] == "parked"
    svc.decode({b: 1, b2: 1})
    got += svc.decode({a: 7})[a]  # resume in whichever slot frees up
    assert got == want
    assert svc.stats()["evictions"] >= 1


# ---------------------------------------------------------------------------
# true chunked prefill
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _prefill_svc(cap):
    """One service per prefill cap, reused across hypothesis examples so
    jitted programs compile once; sessions are closed per example."""
    return _svc(n_slots=2, seq_cap=64, t_chunk=8, prefill_chunk=cap)


def test_chunked_prefill_invariant_to_chunk_schedule():
    """ANY prefill chunk schedule (different pow2 caps, including the old
    token-at-a-time scan prefill at cap 0) yields a bit-identical KV cache
    — asserted on the parked column — and therefore a bit-identical first
    sampled token and stream."""
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        P = int(rng.integers(2, 44))
        prompt = rng.integers(0, 64, size=P).astype(np.int32)
        streams, columns, sids = [], [], []
        caps = [0, 1, int(rng.choice([2, 4, 8, 16])), 64]
        try:
            for cap in caps:
                svc = _prefill_svc(cap)
                sid = svc.open_session(prompt)
                sids.append((svc, sid))
                streams.append(svc.decode({sid: 6})[sid])
                svc.park(sid)
                columns.append(svc.parking[sid])
            for s in streams[1:]:
                assert s == streams[0]
            for col in columns[1:]:
                for a, b in zip(jax.tree.leaves(columns[0]),
                                jax.tree.leaves(col)):
                    np.testing.assert_array_equal(a, b)
        finally:
            for svc, sid in sids:
                svc.close(sid)
    prop()


def test_chunked_prefill_dispatch_budget():
    """A 256-token prompt prefills in <= 8 multi-token chunks (the pow2
    decomposition of 255 at cap 128) instead of 256 scan steps, and the
    first decode only needs the single pending prompt token."""
    svc = _svc(n_slots=2, seq_cap=320, t_chunk=16, prefill_chunk=128)
    prompt = np.random.default_rng(0).integers(0, 64, size=256).astype(np.int32)
    d0 = svc.dispatches
    sid = svc.open_session(prompt)
    prefill_dispatches = svc.dispatches - d0
    assert prefill_dispatches == 8  # 128+64+32+16+8+4+2+1
    assert svc.sessions[sid].steps == 255
    assert svc.poll(sid)["prompt_left"] == 1
    d0 = svc.dispatches
    out = svc.decode({sid: 4})[sid]
    assert len(out) == 4 and svc.dispatches - d0 == 1


def test_chunked_prefill_park_before_first_decode():
    """A session evicted right after open (prefilled, never decoded)
    resumes bit-identically: the parked blob is the truncated prefill."""
    ctl = _svc(n_slots=2, prefill_chunk=16)
    c = ctl.open_session(np.arange(1, 12, dtype=np.int32))
    want = ctl.decode({c: 8})[c]
    svc = _svc(n_slots=2, prefill_chunk=16, max_sessions=8)
    a = svc.open_session(np.arange(1, 12, dtype=np.int32))
    b1 = svc.open_session(np.array([1], np.int32))
    b2 = svc.open_session(np.array([2], np.int32))  # evicts a, never decoded
    assert svc.poll(a)["state"] == "parked"
    svc.decode({b1: 1, b2: 1})
    assert svc.decode({a: 8})[a] == want


def test_chunked_prefill_prompt_ending_at_seq_cap_retires():
    """seq_cap boundary: the longest admissible prompt (seq_cap - 1)
    prefills, emits its first token plus exactly one more, and retires
    cleanly — no wrapped cache writes, slot immediately reusable."""
    svc = _svc(n_slots=2, seq_cap=24, t_chunk=8, prefill_chunk=8)
    prompt = np.random.default_rng(3).integers(0, 64, size=23).astype(np.int32)
    ctl = _svc(n_slots=2, seq_cap=24, t_chunk=8, prefill_chunk=0)
    c = ctl.open_session(prompt)  # scan-prefill control, same geometry
    want = ctl.decode({c: 2})[c]
    a = svc.open_session(prompt)
    assert svc.sessions[a].steps == 22
    out = svc.decode({a: 50})[a]
    assert out == want and len(out) == 2  # 24 - 23 + 1
    assert svc.poll(a)["state"] == "done"
    assert not svc.sched.is_bound(a)
    b = svc.open_session(np.array([4], np.int32))  # slot reusable
    assert len(svc.decode({b: 2})[b]) == 2


def test_chunked_prefill_disabled_on_recurrent_bundles():
    """RWKV/Mamba chunk recurrences are reassociated vs per-token decode,
    so the service refuses to chunk-prefill them (parallel_safe=False) and
    keeps the exact forced-token scan prefill instead."""
    cfg = get_config("rwkv6-1.6b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, rwkv_head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    svc = LMSessionService(bundle, params, n_slots=2, seq_cap=32,
                           t_chunk=8, prefill_chunk=64)
    assert not svc.parallel_safe and svc.prefill_chunk == 0
    d0 = svc.dispatches
    sid = svc.open_session(np.array([3, 1, 4, 1], np.int32))
    assert svc.dispatches == d0  # no prefill dispatches at open
    assert svc.sessions[sid].steps == 0
    assert len(svc.decode({sid: 3})[sid]) == 3


# ---------------------------------------------------------------------------
# KV park/resume
# ---------------------------------------------------------------------------

def test_evict_park_resume_bit_identical():
    """A session evicted mid-generation and resumed (in a different slot)
    emits bit-identical tokens to an uninterrupted control run."""
    ctl = _svc(n_slots=2, max_sessions=8)
    c = ctl.open_session(np.array([5, 6], np.int32))
    want = ctl.decode({c: 16})[c]

    svc = _svc(n_slots=2, max_sessions=8)
    a = svc.open_session(np.array([5, 6], np.int32))
    got = svc.decode({a: 6})[a]
    # two newer sessions force slot pressure; a is LRU -> evicted
    b1 = svc.open_session(np.array([1], np.int32))
    b2 = svc.open_session(np.array([2], np.int32))
    assert svc.poll(a)["state"] == "parked"
    svc.decode({b1: 3, b2: 3})
    got += svc.decode({a: 10})[a]  # resume evicts an idle neighbor
    assert svc.stats()["evictions"] >= 2
    assert got == want


def test_explicit_park_blob_is_o_pos():
    """Parked KV blobs are truncated to the live position: a longer session
    parks strictly more bytes (the non-uniform cost the policy uses)."""
    svc = _svc(n_slots=2, max_sessions=4)
    a = svc.open_session(np.array([1, 2, 3], np.int32))
    b = svc.open_session(np.array([4], np.int32))
    svc.decode({a: 12, b: 2})
    svc.park(a)
    svc.park(b)
    ba, bb = parked_bytes(svc.parking[a]), parked_bytes(svc.parking[b])
    assert ba > bb > 0
    assert svc.kv_park_bytes(svc.sessions[a].steps) == ba
    # ... and the default cost-aware eviction prefers the cheap session:
    # park cost is position-proportional, so with a wide stale window the
    # short session b is evicted before the long-lived a
    svc2 = _svc(n_slots=2, max_sessions=4, stale_window=1 << 30)
    a2 = svc2.open_session(np.array([1, 2, 3], np.int32))
    b2 = svc2.open_session(np.array([4], np.int32))
    svc2.decode({a2: 12, b2: 2})
    svc2.sched.touch(b2)  # a2 is LRU but far more expensive to park
    svc2.open_session(np.array([9], np.int32))
    assert svc2.poll(b2)["state"] == "parked"
    assert svc2.poll(a2)["state"] == "active"


def test_park_resume_roundtrips_through_disk(tmp_path):
    """Spilled KV sessions survive a process restart (fresh service) and
    resume bit-identically — bfloat16 cache columns included."""
    ctl = _svc(n_slots=2)
    c = ctl.open_session(np.array([8, 3], np.int32))
    want = ctl.decode({c: 14})[c]

    svc = _svc(n_slots=2)
    s = svc.open_session(np.array([8, 3], np.int32))
    first = svc.decode({s: 5})[s]
    path = str(tmp_path / "lm_sessions.npz")
    svc.spill_parking(path, include_bound=True)
    assert svc.poll(s)["state"] == "parked"

    fresh = _svc(n_slots=2)  # "restart": brand-new service, same weights
    restored = fresh.restore_parking(path)
    assert restored == [s]
    assert fresh.outputs[s] == first  # generated-so-far came back
    tail = fresh.decode({s: 9})[s]
    assert first + tail == want


# ---------------------------------------------------------------------------
# seq_cap guard + int32 positions
# ---------------------------------------------------------------------------

def test_seq_cap_retires_instead_of_wrapping():
    svc = _svc(n_slots=2, seq_cap=12)
    a = svc.open_session(np.array([1, 2, 3], np.int32))
    out = svc.decode({a: 50})[a]  # asks far past the cap
    # 3 prompt + n gen steps stop at pos == seq_cap: 12 - 3 + 1 = 10 tokens
    assert len(out) == 10
    assert svc.poll(a)["state"] == "done"
    assert svc.sessions[a].steps == 12
    assert not svc.sched.is_bound(a)  # slot freed for reuse
    with pytest.raises(RuntimeError):
        svc.decode({a: 1})
    assert svc.outputs[a] == out  # outputs survive retirement
    b = svc.open_session(np.array([4], np.int32))  # slot immediately reusable
    assert len(svc.decode({b: 2})[b]) == 2


def test_positions_are_int32_end_to_end():
    svc = _svc(n_slots=2)
    a = svc.open_session(np.array([1, 2], np.int32))
    svc.decode({a: 3})
    assert svc.slot_pos.dtype == np.int32
    assert isinstance(svc.sessions[a].steps, int)
    from repro.serving import LMServer, ServeConfig
    cfg, bundle, params = _lm_setup()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=16))
    srv.add_request(np.array([1], np.int32))
    srv.step()
    assert srv.pos.dtype == np.int32


def test_restore_refuses_incompatible_seq_cap(tmp_path):
    """A spill whose sessions sit past this service's seq_cap (or whose
    cache geometry differs) is refused atomically — not accepted and then
    crashed mid-bind on the first decode."""
    src = _svc(n_slots=2, seq_cap=48)
    s = src.open_session(np.array([1, 2], np.int32))
    src.decode({s: 30})  # position 32 > the target's cap
    path = str(tmp_path / "lot.npz")
    src.spill_parking(path, include_bound=True)

    small = _svc(n_slots=2, seq_cap=24)
    with pytest.raises(ValueError, match="seq_cap|does not fit"):
        small.restore_parking(path)
    assert not small.sessions and small.sched.live_sessions == 0
    ok = small.open_session(np.array([3], np.int32))  # service untouched
    assert len(small.decode({ok: 2})[ok]) == 2


def test_oversized_prompt_refused():
    svc = _svc(n_slots=2, seq_cap=8)
    with pytest.raises(ValueError):
        svc.open_session(np.arange(8, dtype=np.int32))


def test_admission_backpressure_and_oversubscription():
    """max_sessions == n_slots keeps the historical no-eviction contract;
    a larger cap switches to park/resume churn."""
    svc = _svc(n_slots=2, max_sessions=2)
    svc.open_session(np.array([1], np.int32))
    svc.open_session(np.array([2], np.int32))
    with pytest.raises(AdmissionError):
        svc.open_session(np.array([3], np.int32))
    over = _svc(n_slots=2, max_sessions=3)
    s1 = over.open_session(np.array([1], np.int32))
    over.decode({s1: 1})
    over.open_session(np.array([2], np.int32))
    s3 = over.open_session(np.array([3], np.int32))  # evicts LRU (s1)
    assert over.poll(s1)["state"] == "parked"
    assert over.sched.is_bound(s3)


# ---------------------------------------------------------------------------
# property: open/push/evict/resume churn across mixed fp32/u4/KV sessions
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _tcn_setup(seed=0):
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    return cfg, bundle, params, tcn_empty_state(cfg)


@functools.lru_cache(maxsize=None)
def _churn_services():
    """Three churning services (2 slots, 3 sessions each — every tick can
    evict) + three never-evicted references (4 slots).  fp32 TCN, u4 TCN,
    and LM KV states coexist under the one scheduler policy."""
    cfg, bundle, params, bn = _tcn_setup()
    mk = lambda n, q: StreamSessionService(bundle, params, bn, n_slots=n,
                                           max_tenants=1, quantize=q,
                                           t_chunk=4, max_sessions=8)
    lcfg, lbundle, lparams = _lm_setup()
    mklm = lambda n, **kw: LMSessionService(lbundle, lparams, n_slots=n,
                                            seq_cap=128, t_chunk=4,
                                            max_sessions=8, **kw)
    # the paged grid churns against the DENSE reference: the cross-layout
    # bit-identity ratchet of the paged slot memory PR
    return ((mk(2, False), mk(4, False)), (mk(2, True), mk(4, True)),
            (mklm(2), mklm(4)), (mklm(2, paged=True), mklm(4)))


def test_churn_property_mixed_services_bit_identical():
    """Property: ANY interleaving of open/push/park/evict/resume across
    fp32 TCN, u4 TCN, and LM KV sessions produces outputs bit-identical to
    never-evicted reference runs."""
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        ((svc_f, ref_f), (svc_q, ref_q),
         *lm_pairs) = _churn_services()  # dense and paged LM grids
        x = rng.normal(size=(3, 40, 2)).astype(np.float32)
        prompts = [rng.integers(0, 64, size=rng.integers(1, 5))
                   .astype(np.int32) for _ in range(3)]
        tcn = [{"svc": s, "ref": r,
                "sids": [s.open_session() for _ in range(3)],
                "rids": [r.open_session() for _ in range(3)],
                "pos": [0, 0, 0]}
               for s, r in ((svc_f, ref_f), (svc_q, ref_q))]
        lms = [{"svc": s, "ref": r,
                "sids": [s.open_session(p) for p in prompts],
                "rids": [r.open_session(p) for p in prompts]}
               for s, r in lm_pairs]
        try:
            for _ in range(6):
                for grp in tcn:
                    picks = [i for i in range(3) if rng.random() < 0.6
                             and grp["pos"][i] < 40][:2]  # <= n_slots a tick
                    if rng.random() < 0.3 and picks:
                        grp["svc"].park(grp["sids"][picks[0]])
                    chunk, refchunk = {}, {}
                    for i in picks:
                        n = int(rng.integers(1, 7))
                        n = min(n, 40 - grp["pos"][i])
                        seg = x[i, grp["pos"][i]:grp["pos"][i] + n]
                        chunk[grp["sids"][i]] = seg
                        refchunk[grp["rids"][i]] = seg
                        grp["pos"][i] += n
                    if not chunk:
                        continue
                    got = grp["svc"].push_audio(chunk)
                    want = grp["ref"].push_audio(refchunk)
                    for i in picks:
                        g = got[grp["sids"][i]]
                        w = want[grp["rids"][i]]
                        np.testing.assert_array_equal(g["emb"], w["emb"])
                        np.testing.assert_array_equal(g["logits"],
                                                      w["logits"])
                for lm in lms:
                    picks = [i for i in range(3) if rng.random() < 0.6][:2]
                    if rng.random() < 0.3 and picks:
                        lm["svc"].park(lm["sids"][picks[0]])
                    wants = {lm["sids"][i]: int(rng.integers(1, 5))
                             for i in picks}
                    if wants:
                        got = lm["svc"].decode(wants)
                        want = lm["ref"].decode(
                            {lm["rids"][i]: wants[lm["sids"][i]]
                             for i in picks})
                        for i in picks:
                            assert got[lm["sids"][i]] == want[lm["rids"][i]]
            assert svc_f.stats()["evictions"] + svc_q.stats()["evictions"] \
                + sum(lm["svc"].stats()["evictions"] for lm in lms) >= 0
        finally:
            for grp in tcn:
                for sid in grp["sids"]:
                    grp["svc"].close(sid)
                for rid in grp["rids"]:
                    grp["ref"].close(rid)
            for lm in lms:
                for sid in lm["sids"]:
                    lm["svc"].close(sid)
                for rid in lm["rids"]:
                    lm["ref"].close(rid)
            for lm in lms:  # paged churn may never leak a block
                if lm["svc"].paged:
                    lm["svc"].pool.check()
                    assert lm["svc"].pool.n_live == len(lm["svc"]._prefix)
    prop()


# ---------------------------------------------------------------------------
# paged slot memory (block-pool cache, CoW prefix sharing)
# ---------------------------------------------------------------------------

def _paged_svc(**kw):
    kw.setdefault("max_sessions", 8)
    return _svc(paged=True, **kw)


def test_paged_decode_bit_identical_to_dense():
    """The tentpole ratchet: the paged service's stream is bit-identical
    to the dense layout through open/decode/park/evict/resume churn."""
    dense = _svc(max_sessions=8)
    paged = _paged_svc()
    assert paged.paged and not dense.paged
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 64, size=n).astype(np.int32)
               for n in (5, 11, 3)]
    outs = {}
    for svc in (dense, paged):
        sids = [svc.open_session(p) for p in prompts]
        got = {s: [] for s in sids}
        for _ in range(4):  # 3 sessions on 2 slots: every round churns
            for sid in sids:
                got[sid].extend(svc.decode({sid: 5})[sid])
        outs[svc] = [got[s] for s in sids]
    assert outs[dense] == outs[paged]
    assert paged.stats()["evictions"] >= 1
    paged.pool.check()


def test_paged_admission_is_o1_and_parked_sessions_hold_no_blocks():
    """Admission sets up a block table instead of scrubbing an O(seq_cap)
    column, and a parked paged session owns ZERO device blocks — its
    bytes live in the host blob only (the capacity lever)."""
    svc = _paged_svc()
    a = svc.open_session(np.array([1, 2, 3], np.int32))
    svc.decode({a: 10})
    held = len(svc._blocks[a])
    assert held == -(-svc.sessions[a].steps // svc.block_len)
    live0 = svc.pool.n_live
    svc.park(a)
    assert svc._blocks.get(a, []) == []  # blocks freed on park
    assert svc.pool.n_live == live0 - held
    assert PAGED_MARKER in svc.parking[a]  # blob carries pool geometry
    # table row of the freed slot is all-NULL: masked writes of future
    # tenants land in the reserved block 0, never in freed memory
    assert (svc._table == 0).all(axis=1).any()
    svc.decode({a: 3})  # resume reallocates and continues


def test_paged_parked_bytes_gauge_tracks_blob_sizes():
    """parked_bytes is a registry gauge kept incrementally in sync with
    the parking lot (satellite: obs export)."""
    svc = _paged_svc()
    g = svc.metrics_registry.gauge("parked_bytes", service="lm")
    assert g.value == 0
    a = svc.open_session(np.array([1, 2, 3], np.int32))
    svc.decode({a: 6})
    svc.park(a)
    want = parked_bytes(svc.parking[a])
    assert svc.parked_blob_bytes == want and g.value == want
    svc.decode({a: 1})  # resume takes the blob back
    assert svc.parked_blob_bytes == 0 and g.value == 0
    svc.park(a)
    svc.close(a)
    assert svc.parked_blob_bytes == 0 and g.value == 0


def test_paged_spill_restore_roundtrip(tmp_path):
    """Paged sessions spill block-granular blobs and resume bit-identically
    in a fresh paged service (different physical block ids are fine — the
    table indirection is invisible to the program)."""
    ctl = _svc(max_sessions=8)
    c = ctl.open_session(np.array([8, 3], np.int32))
    want = ctl.decode({c: 14})[c]

    svc = _paged_svc()
    s = svc.open_session(np.array([8, 3], np.int32))
    first = svc.decode({s: 5})[s]
    path = str(tmp_path / "paged.npz")
    svc.spill_parking(path, include_bound=True)

    fresh = _paged_svc()
    assert fresh.restore_parking(path) == [s]
    assert fresh.outputs[s] == first
    assert first + fresh.decode({s: 9})[s] == want


def test_paged_restore_refuses_layout_and_geometry_mismatch(tmp_path):
    """Satellite: a spill from a differently-paged grid is refused
    atomically — paged<->dense and block_len/n_blocks mismatches alike."""
    paged = _paged_svc()
    s = paged.open_session(np.array([1, 2], np.int32))
    paged.decode({s: 8})
    ppath = str(tmp_path / "paged.npz")
    paged.spill_parking(ppath, include_bound=True)

    dense = _svc(max_sessions=8)
    d = dense.open_session(np.array([1, 2], np.int32))
    dense.decode({d: 8})
    dpath = str(tmp_path / "dense.npz")
    dense.spill_parking(dpath, include_bound=True)

    for svc, path in ((_svc(max_sessions=8), ppath),          # dense <- paged
                      (_paged_svc(), dpath),                  # paged <- dense
                      (_paged_svc(block_len=8), ppath),       # block_len
                      (_paged_svc(n_blocks=2), ppath)):       # pool too small
        with pytest.raises(ValueError, match="incompatible|does not fit"):
            svc.restore_parking(path)
        assert not svc.sessions and svc.sched.live_sessions == 0
        ok = svc.open_session(np.array([3], np.int32))  # service untouched
        assert len(svc.decode({ok: 2})[ok]) == 2


def test_paged_pool_exhaustion_is_admission_backpressure():
    """A pool too small for a new session raises AdmissionError at open
    (not a mid-decode crash), rolls the admission back, and the service
    keeps working; closing a session frees its blocks for the next one."""
    # 3 blocks: one 20-token session holds ceil(21/16)=2, a second 20-token
    # prompt needs 2 more -> exhausted mid-prefill
    svc = _paged_svc(n_blocks=3)
    long = np.arange(1, 21, dtype=np.int32)
    a = svc.open_session(long)
    svc.decode({a: 12})
    # a DISJOINT prompt (no prefix sharing rescue) needs 2 fresh blocks
    # with only 1 free -> exhausted mid-prefill
    with pytest.raises(AdmissionError):
        svc.open_session(np.arange(40, 60, dtype=np.int32))
    assert len(svc.sessions) == 1 and svc.sched.live_sessions == 1
    svc.pool.check()
    assert svc.decode({a: 2})[a]  # survivor unaffected
    svc.close(a)
    b = svc.open_session(long)  # freed blocks make room
    assert len(svc.decode({b: 2})[b]) == 2


def test_paged_prefix_sharing_cow():
    """Two sessions with the same prompt share its full blocks (refcounted,
    registry-pinned) and still emit identical streams; the divergent
    suffix lives in private blocks via copy-on-write."""
    svc = _paged_svc(seq_cap=96)
    prompt = np.arange(1, 40, dtype=np.int32)  # 2 full 16-blocks + tail
    a = svc.open_session(prompt)
    out_a = svc.decode({a: 6})[a]
    assert len(svc._prefix) == 2  # full prompt blocks registered
    live0 = svc.pool.n_live
    b = svc.open_session(prompt)
    # the second session adopted the 2 shared blocks instead of refilling
    assert svc.sessions[b].steps >= 2 * svc.block_len
    assert svc.pool.n_shared >= 2
    assert svc.pool.n_live <= live0 + 2  # tail + first decode block only
    out_b = svc.decode({b: 6})[b]
    assert out_a == out_b  # CoW: b's writes never touched a's blocks
    hits = svc.metrics_registry.counter(
        "prefix_block_hits_total", service="lm").value
    assert hits >= 2
    svc.close(a)
    svc.close(b)
    svc.pool.check()
