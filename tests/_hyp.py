"""`hypothesis` import shim: real library when available, else a tiny
deterministic fallback so the tier-1 suite runs without the optional dep.

The fallback implements just what these tests use — ``given`` over
``strategies.integers`` ranges plus a no-op ``settings`` profile registry —
drawing a fixed number of seeded pseudo-random examples per test.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES = 10

    class _IntStrategy:
        def __init__(self, min_value: int, max_value: int):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng) -> int:
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledStrategy:
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return self.elements[int(rng.integers(0, len(self.elements)))]

    class _TupleStrategy:
        def __init__(self, parts):
            self.parts = parts

        def example(self, rng):
            return tuple(p.example(rng) for p in self.parts)

    class _ListStrategy:
        def __init__(self, elements, min_size: int, max_size: int):
            self.elements = elements
            self.lo, self.hi = int(min_size), int(max_size)

        def example(self, rng):
            n = int(rng.integers(self.lo, self.hi + 1))
            return [self.elements.example(rng) for _ in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def sampled_from(elements) -> _SampledStrategy:
            return _SampledStrategy(elements)

        @staticmethod
        def tuples(*parts) -> _TupleStrategy:
            return _TupleStrategy(parts)

        @staticmethod
        def lists(elements, min_size: int = 0,
                  max_size: int = 10) -> _ListStrategy:
            return _ListStrategy(elements, min_size, max_size)

    st = _Strategies()

    class settings:  # noqa: N801 - mirrors the hypothesis API
        _profiles: dict[str, dict] = {}

        @classmethod
        def register_profile(cls, name: str, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name: str):
            global _MAX_EXAMPLES
            _MAX_EXAMPLES = int(cls._profiles.get(name, {}).get(
                "max_examples", _MAX_EXAMPLES))

    def given(*strategies_, **kw_strategies):
        def deco(fn):
            # NB: no functools.wraps — copying the signature would make
            # pytest treat the drawn arguments as fixtures.
            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(_MAX_EXAMPLES):
                    drawn = {k: s.example(rng)
                             for k, s in kw_strategies.items()}
                    fn(*args, *[s.example(rng) for s in strategies_],
                       **drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
