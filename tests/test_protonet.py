"""PN-as-FC reformulation (paper Eq. 3-8) — the central correctness claims."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import protonet as pn

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _episode(seed, N, k, V):
    key = jax.random.key(seed)
    emb = jax.random.normal(key, (N * k, V))
    labels = jnp.repeat(jnp.arange(N), k)
    return emb, labels


@given(st.integers(0, 10 ** 6), st.integers(2, 12), st.integers(1, 7),
       st.integers(4, 48))
def test_fc_argmax_equals_l2_argmin(seed, N, k, V):
    """Eq. 6: the FC layer's argmax IS the prototype argmin — exactly."""
    emb, labels = _episode(seed, N, k, V)
    s = pn.support_sums(emb, labels, N)
    w, b = pn.pn_fc_from_sums(s, k)
    x = jax.random.normal(jax.random.key(seed + 1), (16, V))
    logits = pn.pn_logits(x, w, b)
    cls, d2 = pn.l2_classify(x, s / k)
    assert jnp.all(jnp.argmax(logits, 1) == cls)


@given(st.integers(0, 10 ** 6))
def test_fc_is_affine_in_squared_distance(seed):
    """logits = -(k/2) (D^2 - ||x||^2): the reformulation is exact, not just
    argmax-preserving."""
    emb, labels = _episode(seed, 6, 4, 32)
    s = pn.support_sums(emb, labels, 6)
    w, b = pn.pn_fc_from_sums(s, 4)
    x = jax.random.normal(jax.random.key(seed + 2), (8, 32))
    logits = pn.pn_logits(x, w, b)
    _, d2 = pn.l2_classify(x, s / 4)
    expect = -(4 / 2.0) * (d2 - jnp.sum(x ** 2, 1, keepdims=True))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_log2_bias_equals_shift_form():
    """Eq. 8: the bias from exponent-doubling equals -(1/2k')||w_q||^2 with
    k' = 2^ceil(log2 k) — i.e. the square really is a bit shift."""
    emb, labels = _episode(3, 5, 5, 64)
    s = pn.support_sums(emb, labels, 5)
    w, b, q, scale = pn.pn_fc_from_sums_log2(s, 5)
    kshift = 2 ** int(np.ceil(np.log2(5)))
    expect = -np.sum(np.asarray(w) ** 2, -1) / (2 * kshift)
    np.testing.assert_allclose(np.asarray(b), expect, rtol=1e-5)


def test_store_uniform_counts_matches_eq6():
    emb, labels = _episode(4, 7, 3, 24)
    s = pn.support_sums(emb, labels, 7)
    w, b = pn.pn_fc_from_sums(s, 3)
    store = pn.store_init(10, 24)
    for j in range(7):
        store = pn.store_add_class(store, emb[labels == j])
    x = jax.random.normal(jax.random.key(9), (32, 24))
    assert jnp.all(pn.store_classify(store, x) ==
                   jnp.argmax(pn.pn_logits(x, w, b), 1))


def test_store_refinement_more_shots_helps():
    """Adding shots to an existing class = adding to the sum (Eq. 3)."""
    V = 16
    rng = jax.random.key(11)
    centers = jax.random.normal(rng, (3, V)) * 3
    store = pn.store_init(4, V)
    for j in range(3):
        shots = centers[j] + jax.random.normal(jax.random.key(j), (1, V))
        store = pn.store_add_class(store, shots)
    # refine class 0 with many more shots
    more = centers[0] + jax.random.normal(jax.random.key(42), (50, V))
    store2 = pn.store_update_class(store, 0, more)
    q = centers[0] + jax.random.normal(jax.random.key(43), (64, V)) * 0.5
    acc1 = float(jnp.mean(pn.store_classify(store, q) == 0))
    acc2 = float(jnp.mean(pn.store_classify(store2, q) == 0))
    assert acc2 >= acc1


def test_unlearned_ways_never_predicted():
    store = pn.store_init(8, 16)
    store = pn.store_add_class(store, jnp.ones((2, 16)))
    store = pn.store_add_class(store, -jnp.ones((2, 16)))
    x = jax.random.normal(jax.random.key(5), (64, 16)) * 5
    preds = pn.store_classify(store, x)
    assert int(preds.max()) <= 1


def test_adapt_through_embedder():
    """adapt() is a pure forward pass through any bundle's embed_fn."""
    from repro.configs import get_config
    from repro.models import build_bundle
    cfg = get_config("chameleon-tcn").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (10, 64, cfg.tcn_in_channels))
    labels = jnp.repeat(jnp.arange(5), 2)
    w, b = pn.adapt(lambda p, bt: bundle.embed_fn(p, bt), params,
                    {"x": x}, labels, n_ways=5, k=2)
    assert w.shape == (5, cfg.embed_dim) and b.shape == (5,)
    assert jnp.all(jnp.isfinite(w)) and jnp.all(jnp.isfinite(b))


def test_store_add_class_overflow_is_masked_noop():
    """At capacity, store_add_class must return the store unchanged: the
    pre-fix dynamic_update_index_in_dim clamp silently overwrote the last
    learned row while n_ways kept counting."""
    store = pn.store_init(2, 4)
    store = pn.store_add_class(store, jnp.ones((2, 4)))
    store = pn.store_add_class(store, 2 * jnp.ones((3, 4)))
    full = jax.tree.map(np.asarray, store)
    store = pn.store_add_class(store, 99 * jnp.ones((1, 4)))  # overflow
    assert int(store.n_ways) == 2  # did not keep counting
    np.testing.assert_array_equal(np.asarray(store.s_sums), full.s_sums)
    np.testing.assert_array_equal(np.asarray(store.counts), full.counts)
    # and the op stays jit-safe (the service-level host raise is separate)
    jitted = jax.jit(pn.store_add_class)(store, jnp.ones((1, 4)))
    assert int(jitted.n_ways) == 2


def test_store_add_class_no_count_residue_after_reset():
    """Re-learning a row after an external n_ways reset must .set counts,
    not .add onto the previous occupant's k."""
    store = pn.store_init(2, 4)
    store = pn.store_add_class(store, jnp.ones((3, 4)))
    store = store._replace(n_ways=jnp.zeros((), jnp.int32))  # host reset
    store = pn.store_add_class(store, jnp.ones((2, 4)))
    assert float(store.counts[0]) == 2.0  # .add would leave 5.0
    np.testing.assert_array_equal(np.asarray(store.s_sums[0]),
                                  np.full(4, 2.0, np.float32))
