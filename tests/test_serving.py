"""Serving engine: slotting, decode continuity, TCN streaming server."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state, tcn_forward
from repro.serving import LMServer, ServeConfig, TCNStreamServer


def _tiny_lm():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_lm_server_slots_and_outputs():
    cfg, bundle, params = _tiny_lm()
    srv = LMServer(bundle, params, ServeConfig(max_batch=4, seq_cap=32))
    r1 = srv.add_request(np.array([1, 2, 3], np.int32))
    r2 = srv.add_request(np.array([4, 5], np.int32))
    for _ in range(6):
        srv.step()
    assert len(srv.outputs[r1]) == 6 and len(srv.outputs[r2]) == 6
    assert all(0 <= t < cfg.vocab_size for t in srv.outputs[r1])
    srv.finish(r1)
    r3 = srv.add_request(np.array([7], np.int32))  # slot reuse
    srv.step()
    assert len(srv.outputs[r3]) >= 1


def test_dual_mode_batch_sizing():
    assert ServeConfig(max_batch=8, mode="throughput").effective_batch() == 8
    assert ServeConfig(max_batch=8, mode="low-power").effective_batch() == 2


def test_lm_server_slot_reused_after_finish():
    """finish() frees the physical slot; the next request lands on it."""
    cfg, bundle, params = _tiny_lm()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    r1 = srv.add_request(np.array([1, 2], np.int32))
    r2 = srv.add_request(np.array([3], np.int32))
    slot1 = srv.sched.slot_of[r1]
    with np.testing.assert_raises(RuntimeError):  # grid full
        srv.add_request(np.array([4], np.int32))
    srv.finish(r1)
    assert not srv.sched.is_bound(r1)
    assert srv.pos[slot1] == 0  # scrubbed: next occupant prefills fresh
    r3 = srv.add_request(np.array([5], np.int32))
    assert srv.sched.slot_of[r3] == slot1  # physical slot reuse
    srv.step()
    assert len(srv.outputs[r3]) == 1 and len(srv.outputs[r2]) >= 1


def test_lm_server_mid_decode_admission_preserves_live_requests():
    """Admitting a new request must not perturb in-flight requests: every
    lane decodes at its own position (sessions/lm.decode_scan), so another
    lane's prefill steps are invisible by construction."""
    cfg, bundle, params = _tiny_lm()
    ctl = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=48))
    c = ctl.add_request(np.array([7, 9, 4], np.int32))
    for _ in range(8):
        ctl.step()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=48))
    r = srv.add_request(np.array([7, 9, 4], np.int32))
    for _ in range(3):
        srv.step()
    srv.add_request(np.array([1, 2], np.int32))  # mid-decode admission
    for _ in range(5):
        srv.step()
    assert srv.outputs[r] == ctl.outputs[c]


def test_lm_server_reused_slot_decodes_like_fresh_slot():
    """A reused slot must not see the previous occupant's KV entries: the
    same prompt yields the same first token as on a fresh server."""
    cfg, bundle, params = _tiny_lm()
    fresh = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    rf = fresh.add_request(np.array([5], np.int32))
    fresh.step()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    r1 = srv.add_request(np.array([1, 2], np.int32))
    srv.step()
    srv.finish(r1)
    r2 = srv.add_request(np.array([5], np.int32))  # lands on r1's slot
    srv.step()
    assert srv.outputs[r2][0] == fresh.outputs[rf][0]


def test_lm_server_oversubscription_parks_and_resumes():
    """ServeConfig(max_sessions > batch) turns the historical full-grid
    RuntimeError into LRU park/resume churn: step() keeps advancing ALL
    live requests (parked ones resume in waves) with bit-identical
    streams."""
    cfg, bundle, params = _tiny_lm()
    ctl = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    c = ctl.add_request(np.array([5, 1], np.int32))
    for _ in range(6):
        ctl.step()
    srv = LMServer(bundle, params,
                   ServeConfig(max_batch=2, seq_cap=32, max_sessions=3))
    r1 = srv.add_request(np.array([5, 1], np.int32))
    for _ in range(2):
        srv.step()
    srv.add_request(np.array([7], np.int32))
    r3 = srv.add_request(np.array([9], np.int32))  # grid full: parks LRU r1
    assert not srv.sched.is_bound(r1) and srv.service.poll(r1)["state"] == "parked"
    for _ in range(4):  # three live requests on two slots: churn every step
        srv.step()
    assert srv.outputs[r1] == ctl.outputs[c]  # parked request never starves
    assert len(srv.outputs[r3]) == 4
    assert srv.service.stats()["evictions"] >= 2


def test_tcn_stream_server_matches_full_sequence():
    """push()-ing a whole clip sample-by-sample ends at the same embedding/
    logits as the full-sequence TCN forward (paper Fig. 8c through the
    serving surface)."""
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    srv = TCNStreamServer(bundle, params, bn, n_streams=2)
    T = 25
    x = np.random.default_rng(3).normal(
        size=(2, T, cfg.tcn_in_channels)).astype(np.float32)
    for t in range(T):
        emb, logits = srv.push(x[:, t])
    emb_full, logits_full, _ = tcn_forward(params, bn, cfg, jnp.asarray(x),
                                           train=False)
    np.testing.assert_allclose(emb, np.asarray(emb_full), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(logits, np.asarray(logits_full),
                               rtol=2e-4, atol=2e-5)


def test_tcn_stream_server():
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    srv = TCNStreamServer(bundle, params, bn, n_streams=3)
    for t in range(20):
        emb, logits = srv.push(np.random.default_rng(t).normal(
            size=(3, cfg.tcn_in_channels)).astype(np.float32))
    assert emb.shape == (3, cfg.embed_dim)
    assert logits.shape == (3, cfg.n_classes)
    assert np.isfinite(logits).all()


# ---------------------------------------------------------------------------
# protocol adapters + deprecation shims
# ---------------------------------------------------------------------------

def test_lm_server_is_protocol_adapter():
    """LMServer exposes the SessionService surface by delegation; the
    protocol verbs drive the same service the shims do."""
    import pytest

    from repro.sessions import SessionService
    cfg, bundle, params = _tiny_lm()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    assert isinstance(srv, SessionService)
    sid = srv.open_session(np.array([1, 2], np.int32))
    toks = srv.push({sid: 3})[sid]
    assert len(toks) == 3 and srv.outputs[sid] == toks
    assert srv.poll(sid)["generated"] == 3
    assert srv.stats()["service"] == "lm" and srv.n_slots == 2
    srv.close(sid)
    assert srv.stats()["live_sessions"] == 0


def test_lm_server_shims_warn_and_delegate():
    import pytest
    cfg, bundle, params = _tiny_lm()
    srv = LMServer(bundle, params, ServeConfig(max_batch=2, seq_cap=32))
    with pytest.warns(DeprecationWarning, match="open_session"):
        rid = srv.add_request(np.array([1, 2], np.int32))
    srv.step()
    assert len(srv.outputs[rid]) == 1
    with pytest.warns(DeprecationWarning, match="close"):
        srv.finish(rid)
    assert srv.service.stats()["live_sessions"] == 0


def test_tcn_server_protocol_push_and_shims_agree():
    """Dict-payload push (protocol) == array push / push_chunk (shims),
    bit for bit, and the shims warn."""
    import pytest

    from repro.sessions import SessionService
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    a = TCNStreamServer(bundle, params, bn, n_streams=2)
    b = TCNStreamServer(bundle, params, bn, n_streams=2)
    assert isinstance(a, SessionService)
    x = np.random.default_rng(5).normal(
        size=(2, 8, cfg.tcn_in_channels)).astype(np.float32)
    res = a.push({sid: x[i] for i, sid in enumerate(a.sids)})
    with pytest.warns(DeprecationWarning, match="push"):
        embs, logits = b.push_chunk(x)
    for i, sid in enumerate(a.sids):
        np.testing.assert_array_equal(res[sid]["emb"], embs[i])
        np.testing.assert_array_equal(res[sid]["logits"], logits[i])
    # the per-sample array shim warns too and matches the dict path
    c = TCNStreamServer(bundle, params, bn, n_streams=2)
    with pytest.warns(DeprecationWarning, match="push"):
        emb1, log1 = c.push(x[:, 0])
    np.testing.assert_array_equal(
        emb1[0], np.asarray(res[a.sids[0]]["emb"][0]))
