"""Serving engine: slotting, decode continuity, TCN streaming server."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.serving import LMServer, ServeConfig, TCNStreamServer


def _tiny_lm():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_lm_server_slots_and_outputs():
    cfg, bundle, params = _tiny_lm()
    srv = LMServer(bundle, params, ServeConfig(max_batch=4, seq_cap=32))
    r1 = srv.add_request(np.array([1, 2, 3], np.int32))
    r2 = srv.add_request(np.array([4, 5], np.int32))
    for _ in range(6):
        srv.step()
    assert len(srv.outputs[r1]) == 6 and len(srv.outputs[r2]) == 6
    assert all(0 <= t < cfg.vocab_size for t in srv.outputs[r1])
    srv.finish(r1)
    r3 = srv.add_request(np.array([7], np.int32))  # slot reuse
    srv.step()
    assert len(srv.outputs[r3]) >= 1


def test_dual_mode_batch_sizing():
    assert ServeConfig(max_batch=8, mode="throughput").effective_batch() == 8
    assert ServeConfig(max_batch=8, mode="low-power").effective_batch() == 2


def test_tcn_stream_server():
    cfg = get_config("chameleon-tcn-kws").smoke()
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    srv = TCNStreamServer(bundle, params, bn, n_streams=3)
    for t in range(20):
        emb, logits = srv.push(np.random.default_rng(t).normal(
            size=(3, cfg.tcn_in_channels)).astype(np.float32))
    assert emb.shape == (3, cfg.embed_dim)
    assert logits.shape == (3, cfg.n_classes)
    assert np.isfinite(logits).all()
