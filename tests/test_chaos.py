"""Fault-tolerant serving plane under seeded fault schedules: crash
recovery from spill epochs, drain handoff, deadline enforcement, work
stealing, and the deterministic fault-injection harness itself.  The
invariants everywhere: ZERO lost sessions, and survivor token streams
bit-identical to a fault-free control."""

import asyncio
import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs.runtime as rt
from repro.configs import RuntimeConfig, get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.obs.metrics import MetricsRegistry
from repro.serving import (CRASHED, DRAINED, HEALTHY, FaultInjector,
                           FaultPlan, Rejected, RetryPolicy, ServingPlane,
                           TransientError, WorkerCrashed)
from repro.serving import faults as faults_mod
from repro.sessions import LMSessionService, StreamSessionService
from repro.sessions.paging import PoolExhausted


@functools.lru_cache(maxsize=None)
def _lm_setup():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=1, d_model=16, d_ff=32, vocab_size=32, head_dim=8)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return bundle, params


def _lm(n_slots=4, max_sessions=8, **kw):
    bundle, params = _lm_setup()
    return LMSessionService(bundle, params, n_slots=n_slots, seq_cap=32,
                            t_chunk=4, max_sessions=max_sessions, **kw)


@functools.lru_cache(maxsize=None)
def _tcn_setup():
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    bn = tcn_empty_state(cfg)
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(
            jax.random.normal(jax.random.key(7), a.shape)), bn)
    return bundle, params, bn


def _tcn(**kw):
    bundle, params, bn = _tcn_setup()
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_tenants", 2)
    kw.setdefault("max_ways", 5)
    return StreamSessionService(bundle, params, bn, paged_bank=True,
                                bank_block_ways=2, **kw)


def _plane(workers, **kw):
    # hermetic metrics: ServingPlane defaults to the process-global
    # default_registry(), which accumulates across every test in the run —
    # exact-count assertions below need a fresh registry per plane
    kw.setdefault("metrics", MetricsRegistry())
    return ServingPlane(workers, **kw)


def _prompt(i):
    return np.array([(i % 7) + 1, ((3 * i) % 7) + 1], np.int32)


def _lm_reference(n_sessions, want):
    """Each session decoded ALONE on a fresh fault-free service."""
    out = {}
    for i in range(n_sessions):
        svc = _lm(n_slots=1, max_sessions=1)
        sid = svc.open_session(_prompt(i))
        out[i] = svc.decode({sid: want})[sid]
        svc.close(sid)
    return out


async def _persist(op, max_attempts=300):
    """Drive one plane verb through retryable rejections — the test-side
    mirror of what RetryPolicy-disciplined clients do under chaos."""
    for attempt in range(max_attempts):
        try:
            return await op()
        except Rejected as e:
            if not e.retryable:
                raise
            await asyncio.sleep(min(0.0005 * (attempt + 1), 0.005))
    raise AssertionError("op did not complete within the retry budget")


# ---------------------------------------------------------------------------
# the harness itself: plans and injectors
# ---------------------------------------------------------------------------

def test_fault_plan_parse_roundtrip_and_seeded_determinism():
    plan = FaultPlan.parse("crash@40, slow@10x5:0.002,storm@60x20,flake@25")
    assert plan.spec() == "slow@10x5:0.002,flake@25,crash@40,storm@60x20"
    assert FaultPlan.parse(plan.spec()) == plan
    assert [e.kind for e in plan.at(12)] == ["slow"]
    assert plan.at(15) == [] and [e.kind for e in plan.at(79)] == ["storm"]
    with pytest.raises(ValueError, match="bad fault event"):
        FaultPlan.parse("explode@3")
    # seeded plans: same seed byte-identical, different seeds differ
    a = FaultPlan.seeded(7, 200, crash_every=50, flake_every=30)
    assert a == FaultPlan.seeded(7, 200, crash_every=50, flake_every=30)
    assert a != FaultPlan.seeded(8, 200, crash_every=50, flake_every=30)
    assert any(e.kind == "crash" for e in a.events)
    assert all(e.at < 200 for e in a.events)


def test_injector_counts_verbs_swaps_service_on_crash():
    svc = _lm()
    inj = FaultInjector(svc, FaultPlan.parse("crash@2"), factory=_lm)
    sid = inj.open_session(_prompt(0))           # op 0
    toks = inj.push({sid: 2})                     # op 1
    assert len(toks[sid]) == 2
    with pytest.raises(WorkerCrashed):
        inj.push({sid: 2})                        # op 2: crash
    assert inj.service is not svc                 # fresh service swapped in
    assert inj.crashes == 1 and (2, "crash") in inj.faults
    assert inj.service.stats()["live_sessions"] == 0  # state is gone
    # non-verb attributes delegate without ticking the fault clock
    ops_before = inj.ops
    assert inj.n_slots == 4 and inj.stats()["service"] == "lm"
    assert inj.ops == ops_before
    with pytest.raises(ValueError, match="factory"):
        FaultInjector(_lm(), FaultPlan.parse("crash@0"))


# ---------------------------------------------------------------------------
# session handoff primitives (sessions layer)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_lm_detach_adopt_roundtrip_bit_identical(paged):
    """Half a decode on one service, detach, adopt on a DIFFERENT service,
    finish there: the combined stream equals the solo fault-free run."""
    runtime = RuntimeConfig(paged=paged)
    a, b = _lm(runtime=runtime), _lm(runtime=runtime)
    sid = a.open_session(_prompt(3))
    first = a.decode({sid: 4})[sid]
    blob, meta = a.detach_session(sid)
    assert sid not in a.sessions and a.stats()["live_sessions"] == 0
    sid2 = b.adopt_session(blob, meta)
    rest = b.decode({sid2: 4})[sid2]
    assert first + rest == _lm_reference(4, 8)[3]
    assert b.poll(sid2)["generated"] == 8        # outputs rode the meta
    b.close(sid2)


def test_tcn_export_adopt_tenant_carries_bank_labels_rehearsal():
    rng = np.random.default_rng(3)
    shots = rng.normal(size=(2, 10, 2)).astype(np.float32)
    x = rng.normal(size=(6, 2)).astype(np.float32)

    # fault-free control: enroll then classify on ONE service
    ctrl = _tcn()
    csid = ctrl.open_session(tenant=1)
    ctrl.enroll_shots(csid, shots, label="cat")
    want = np.asarray(ctrl.push_audio({csid: x})[csid]["tenant_logits"])

    # handoff flow: enroll on src, move session + tenant to dst, classify
    src, dst = _tcn(), _tcn()
    sid = src.open_session(tenant=1)
    src.enroll_shots(sid, shots, label="cat")
    blob, meta = src.detach_session(sid)
    tblob = src.export_tenant(1)
    src.close_tenant(1)
    assert 1 not in src.live_tenants()
    # peer must install the tenant BEFORE the session referencing it
    with pytest.raises(ValueError, match="adopt_tenant first"):
        dst.adopt_session(blob, meta)
    assert dst.adopt_tenant(1, tblob) == 1
    sid2 = dst.adopt_session(blob, meta)
    got = np.asarray(dst.push_audio({sid2: x})[sid2]["tenant_logits"])
    # same bank, same labels, same conv state: classification on the new
    # worker is bit-identical to the never-moved control
    np.testing.assert_array_equal(got, want)
    assert dst._tenant_labels[1] == {"cat": 0}
    if dst.rehearsal is not None:
        assert dst.rehearsal.export_tenant(1)  # reservoirs moved too
    # double-adopt refuses to clobber the installed row
    with pytest.raises(ValueError, match="already in use"):
        dst.adopt_tenant(1, tblob)


# ---------------------------------------------------------------------------
# crash recovery through the plane: zero lost, bit-identical
# ---------------------------------------------------------------------------

def test_crash_mid_batch_recovers_all_sessions_bit_identical():
    """Seeded crashes land during opens AND during batched pushes; every
    client retries through, no session is lost, and every stream equals
    the fault-free solo control."""
    N, CHUNK, ROUNDS = 6, 2, 3

    async def main():
        inj = FaultInjector(_lm(), FaultPlan.parse("crash@2,crash@12"),
                            factory=_lm)
        plane = _plane([inj], checkpoint_every=1)
        async with plane:
            psids = [await _persist(
                lambda i=i: plane.open_session(_prompt(i)))
                for i in range(N)]

            async def client(i):
                toks = []
                for _ in range(ROUNDS):
                    toks += await _persist(
                        lambda: plane.push(psids[i], CHUNK))
                return toks

            outs = await asyncio.gather(*(client(i) for i in range(N)))
            for p in psids:
                await _persist(lambda p=p: plane.close(p))
            return outs, plane.stats(), plane.metrics(), inj.crashes

    outs, stats, m, crashes = asyncio.run(main())
    assert crashes == 2
    assert stats["lost_sessions"] == 0
    assert stats["health"] == [HEALTHY]
    assert m["plane_crashes_total"][0]["value"] == 2
    assert m["plane_recoveries_total"][0]["value"] == 2
    assert m["plane_mttr_us"][0]["count"] == 2
    ref = _lm_reference(N, CHUNK * ROUNDS)
    for i in range(N):
        assert outs[i] == ref[i], f"session {i} diverged across crashes"


def test_crash_during_enroll_lands_bank_after_recovery():
    """A crash on the enroll verb: the retried enroll lands on the
    recovered worker, and classification matches the fault-free control
    exactly (tenant bank + rehearsal + conv state all re-adopted)."""
    rng = np.random.default_rng(11)
    shots = rng.normal(size=(2, 10, 2)).astype(np.float32)
    x = rng.normal(size=(6, 2)).astype(np.float32)

    ctrl = _tcn()
    csid = ctrl.open_session(tenant=0)
    ctrl.enroll_shots(csid, shots)
    want = np.asarray(ctrl.push_audio({csid: x})[csid]["tenant_logits"])

    async def main():
        inj = FaultInjector(_tcn(), FaultPlan.parse("crash@1"),
                            factory=_tcn)
        plane = _plane([inj], checkpoint_every=1)
        async with plane:
            psid = await _persist(lambda: plane.open_session(tenant=0))
            way = await _persist(lambda: plane.enroll(psid, shots))
            res = await _persist(lambda: plane.push(psid, x))
            return way, res, inj.crashes, plane.stats()

    way, res, crashes, stats = asyncio.run(main())
    assert crashes == 1 and way == 0
    assert stats["lost_sessions"] == 0
    np.testing.assert_array_equal(
        np.asarray(res["tenant_logits"]), want)


def test_resume_rehomes_from_journal_when_worker_is_down():
    """Satellite (a): resume(psid) must work even when the session's last
    worker is crashed and not yet recovered — the plane re-adopts it from
    its spill epoch onto a healthy peer."""

    async def main():
        inj = FaultInjector(_lm(), FaultPlan.parse("crash@1"), factory=_lm)
        peer = _lm()
        plane = _plane([inj, peer], checkpoint_every=1,
                             auto_recover=False)
        async with plane:
            psid = await plane.open_session(_prompt(2))
            w0 = plane._sessions[psid][0]
            with pytest.raises(Rejected) as ei:
                await plane.push(psid, 4)       # op 1: injected crash
            assert ei.value.reason == "crash" and ei.value.retryable
            assert plane.stats()["health"][w0.idx] == CRASHED
            await plane.resume(psid)            # re-homes, then binds
            assert plane._sessions[psid][0] is not w0
            toks = await plane.push(psid, 4)
            # the downed worker can still be rebuilt explicitly
            rec = await plane.recover(w0.idx)
            assert rec["recovered"] == 0 and rec["lost"] == 0
            return toks, plane.stats()

    toks, stats = asyncio.run(main())
    assert stats["lost_sessions"] == 0
    assert stats["health"] == [HEALTHY, HEALTHY]
    # the crashed push never happened: the retried stream is the solo one
    assert toks == _lm_reference(3, 4)[2]


# ---------------------------------------------------------------------------
# drain / handoff
# ---------------------------------------------------------------------------

def test_drain_hands_sessions_to_peer_bit_identical():
    N = 4

    async def main():
        w0, w1 = _lm(), _lm()
        plane = _plane([w0, w1], checkpoint_every=1)
        async with plane:
            psids = [await plane.open_session(_prompt(i)) for i in range(N)]
            firsts = await asyncio.gather(
                *(plane.push(p, 2) for p in psids))
            victim = plane._sessions[psids[0]][0]
            moved = [p for p in psids
                     if plane._sessions[p][0] is victim]
            summary = await plane.drain(victim.idx)
            assert summary["moved_sessions"] == len(moved)
            assert plane.stats()["health"][victim.idx] == DRAINED
            # new ops on the drained worker's sessions land on the peer
            for p in psids:
                assert plane._sessions[p][0] is not victim
            with pytest.raises(RuntimeError, match="not drained"):
                plane.undrain(1 - victim.idx)
            plane.undrain(victim.idx)
            assert plane.stats()["health"] == [HEALTHY, HEALTHY]
            rests = await asyncio.gather(*(plane.push(p, 2) for p in psids))
            polls = [await plane.poll(p) for p in psids]
            return firsts, rests, polls, plane.metrics()

    firsts, rests, polls, m = asyncio.run(main())
    ref = _lm_reference(N, 4)
    for i in range(N):
        assert firsts[i] + rests[i] == ref[i], f"session {i} diverged"
        assert polls[i]["generated"] == 4
    assert m["plane_handoffs_total"][0]["value"] >= 1


def test_drain_refuses_without_healthy_peer():
    async def main():
        plane = _plane([_lm()])
        async with plane:
            with pytest.raises(RuntimeError, match="no healthy peer"):
                await plane.drain(0)
            assert plane.stats()["health"] == [HEALTHY]

    asyncio.run(main())


# ---------------------------------------------------------------------------
# deadlines + retry_after (satellite b)
# ---------------------------------------------------------------------------

def test_deadline_expiry_rejects_with_retry_after():
    async def main():
        plane = _plane(_lm(), default_deadline_s=30.0)
        async with plane:
            psid = await plane.open_session(_prompt(1))
            with pytest.raises(Rejected) as ei:
                # already expired when dequeued: enforced at the worker
                await plane.push(psid, 2, deadline_s=-1.0)
            assert ei.value.reason == "deadline" and ei.value.retryable
            assert ei.value.retry_after is not None
            assert ei.value.retry_after > 0
            toks = await plane.push(psid, 2)   # no deadline: fine
            rej = plane.metrics()["plane_rejected_total"]
            reasons = {e["labels"]["reason"]: e["value"] for e in rej}
            return toks, reasons

    toks, reasons = asyncio.run(main())
    # the expired push never ran: the session starts clean on the retry
    assert toks == _lm_reference(2, 2)[1]
    assert reasons.get("deadline") == 1


def test_retry_policy_deterministic_and_floored_by_hint():
    a, b = RetryPolicy(seed=3), RetryPolicy(seed=3)
    assert [a.delay(i) for i in range(6)] == [b.delay(i) for i in range(6)]
    assert RetryPolicy(seed=4).delay(0) != a.delay(0) or \
        RetryPolicy(seed=4).delay(1) != b.delay(1)
    p = RetryPolicy(seed=0, base_s=0.001, cap_s=0.01, jitter=0.5)
    for i in range(8):
        d = p.delay(i)
        assert 0 < d <= 0.015
    assert p.delay(0, retry_after=0.5) == 0.5   # server hint is the floor


# ---------------------------------------------------------------------------
# storms and flakes surface as retryable Rejected
# ---------------------------------------------------------------------------

def test_admission_storm_is_retryable_then_clears():
    async def main():
        inj = FaultInjector(_lm(), FaultPlan.parse("storm@0x2"))
        plane = _plane([inj])
        async with plane:
            with pytest.raises(Rejected) as ei:
                await plane.open_session(_prompt(0))
            assert ei.value.reason == "admission" and ei.value.retryable
            assert isinstance(ei.value.__cause__, PoolExhausted)
            psid = await _persist(lambda: plane.open_session(_prompt(0)))
            toks = await plane.push(psid, 4)
            return toks

    assert asyncio.run(main()) == _lm_reference(1, 4)[0]


def test_transient_flake_rejects_push_then_retry_is_bit_identical():
    async def main():
        inj = FaultInjector(_lm(), FaultPlan.parse("flake@1"))
        plane = _plane([inj])
        async with plane:
            psid = await plane.open_session(_prompt(5))
            with pytest.raises(Rejected) as ei:
                await plane.push(psid, 4)
            assert ei.value.reason == "transient" and ei.value.retryable
            assert isinstance(ei.value.__cause__, TransientError)
            return await plane.push(psid, 4)   # nothing advanced: clean

    assert asyncio.run(main()) == _lm_reference(6, 4)[5]


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------

def test_queue_skew_steals_idle_sessions_bit_identical():
    N = 4

    async def main():
        w0, w1 = _lm(), _lm()
        plane = _plane([w0, w1], steal_threshold=2,
                             checkpoint_every=1)
        async with plane:
            # pin every session to one worker via tenant affinity
            tn = next(s for s in "abcdefgh"
                      if zlib.crc32(s.encode()) % 2 == 0)
            psids = [await plane.open_session(_prompt(i), tenant=tn)
                     for i in range(N)]
            hot = plane._sessions[psids[0]][0]
            assert all(plane._sessions[p][0] is hot for p in psids)
            # pile work onto ONE session; its idle neighbors are steal
            # candidates the moment the queue skew crosses the threshold
            busy = [asyncio.ensure_future(plane.push(psids[0], 1))
                    for _ in range(8)]
            await asyncio.gather(*busy)
            for _ in range(100):
                if not hot.steal_pending:
                    break
                await asyncio.sleep(0.001)
            stolen = [p for p in psids[1:]
                      if plane._sessions[p][0] is not hot]
            assert stolen, "no session was stolen despite queue skew"
            outs = {p: await plane.push(p, 4) for p in psids[1:]}
            toks0 = [t for f in busy for t in f.result()]
            return toks0, outs, psids, plane.metrics()

    toks0, outs, psids, m = asyncio.run(main())
    assert toks0 == _lm_reference(1, 8)[0]
    ref4 = _lm_reference(N, 4)
    for i in range(1, N):
        assert outs[psids[i]] == ref4[i], f"stolen session {i} diverged"
    assert m["plane_steals_total"][0]["value"] >= 1


# ---------------------------------------------------------------------------
# config activation + health surface
# ---------------------------------------------------------------------------

def test_runtime_chaos_field_wraps_workers_and_is_env_pinned():
    assert rt.ENV_CHAOS == faults_mod.ENV_VAR
    plane = _plane(_lm(), runtime=RuntimeConfig(chaos="flake@3"))
    assert isinstance(plane.workers[0].service, FaultInjector)
    assert plane.workers[0].service.plan == FaultPlan.parse("flake@3")
    # chaos unset: no injector anywhere on the call path
    plain = _plane(_lm(), runtime=RuntimeConfig())
    assert isinstance(plain.workers[0].service, LMSessionService)
    # a crash plan without a factory to rebuild workers is refused early
    with pytest.raises(ValueError, match="factory"):
        _plane(_lm(), runtime=RuntimeConfig(chaos="crash@5"))


def test_worker_health_gauges_track_state_machine():
    async def main():
        plane = _plane([_lm(), _lm()])
        async with plane:
            await plane.drain(0)
            m = plane.metrics()
            codes = {e["labels"]["worker"]: e["value"]
                     for e in m["plane_worker_health"]}
            assert codes["0"] == 2 and codes["1"] == 0  # drained, healthy
            assert plane.stats()["health"] == [DRAINED, HEALTHY]
            # routing skips the drained worker: every new session lands on
            # the healthy peer, including ones whose affinity hash would
            # have picked worker 0 from a fully-healthy ring
            psids = [await plane.open_session(_prompt(i), tenant=f"t{i}")
                     for i in range(4)]
            assert all(plane._sessions[p][0].idx == 1 for p in psids)
            plane.undrain(0)
            assert plane.stats()["health"] == [HEALTHY, HEALTHY]

    asyncio.run(main())
