"""Attention / norm / sequence-mixer correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (
    attention_chunked,
    attention_dense,
    layernorm,
    rmsnorm,
    rope_angles,
    apply_rope,
)
from repro.models.rwkv import wkv_chunked, wkv_step
from repro.models.ssm import ssd_chunked, ssd_step


class TestAttention:
    @pytest.mark.parametrize("hq,hkv,chunk", [(4, 4, 16), (4, 2, 24), (8, 1, 32)])
    def test_chunked_equals_dense(self, hq, hkv, chunk):
        B, S, Dh = 2, 64, 16
        q = jax.random.normal(jax.random.key(0), (B, S, hq, Dh))
        k = jax.random.normal(jax.random.key(1), (B, S, hkv, Dh))
        v = jax.random.normal(jax.random.key(2), (B, S, hkv, Dh))
        a = attention_dense(q, k, v, causal=True)
        b = attention_chunked(q, k, v, causal=True, kv_chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_chunked_noncausal(self):
        B, S = 2, 40
        q = jax.random.normal(jax.random.key(0), (B, S, 2, 8))
        k = jax.random.normal(jax.random.key(1), (B, S, 2, 8))
        v = jax.random.normal(jax.random.key(2), (B, S, 2, 8))
        a = attention_dense(q, k, v, causal=False)
        b = attention_chunked(q, k, v, causal=False, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_chunked_gradients_match(self):
        """The remat'd flash body must differentiate to the same grads."""
        B, S = 1, 32
        q = jax.random.normal(jax.random.key(0), (B, S, 2, 8))
        k = jax.random.normal(jax.random.key(1), (B, S, 2, 8))
        v = jax.random.normal(jax.random.key(2), (B, S, 2, 8))
        f1 = lambda q: jnp.sum(attention_dense(q, k, v, causal=True) ** 2)
        f2 = lambda q: jnp.sum(attention_chunked(q, k, v, causal=True, kv_chunk=8) ** 2)
        g1, g2 = jax.grad(f1)(q), jax.grad(f2)(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=5e-4)

    def test_decode_masking(self):
        """kv_len masks unwritten cache slots."""
        B, S = 2, 16
        q = jax.random.normal(jax.random.key(0), (B, 1, 2, 8))
        k = jax.random.normal(jax.random.key(1), (B, S, 2, 8))
        v = jax.random.normal(jax.random.key(2), (B, S, 2, 8))
        out_full = attention_dense(q, k[:, :5], v[:, :5], causal=True, q_offset=4)
        k2 = k.at[:, 5:].set(99.0)  # garbage beyond kv_len
        out_masked = attention_dense(q, k2, v, causal=True, q_offset=4, kv_len=5)
        np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_masked),
                                   atol=1e-5)


class TestNorms:
    def test_rmsnorm_matches_f32_reference(self):
        x = jax.random.normal(jax.random.key(0), (4, 8, 64))
        w = 1 + 0.1 * jax.random.normal(jax.random.key(1), (64,))
        ref = (x / jnp.sqrt(jnp.mean(x ** 2, -1, keepdims=True) + 1e-6)) * w
        np.testing.assert_allclose(np.asarray(rmsnorm(x, w)), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_layernorm_grads_match_autodiff_reference(self):
        x = jax.random.normal(jax.random.key(0), (4, 8, 32))
        w = 1 + 0.1 * jax.random.normal(jax.random.key(1), (32,))
        b = 0.1 * jax.random.normal(jax.random.key(2), (32,))

        def ref(x, w, b):
            mu = x.mean(-1, keepdims=True)
            return ((x - mu) / jnp.sqrt(x.var(-1, keepdims=True) + 1e-5)) * w + b

        for arg in range(3):
            g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(layernorm(*a))), arg)(x, w, b)
            g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(ref(*a))), arg)(x, w, b)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                       rtol=1e-3, atol=1e-4)

    def test_bf16_activations_keep_bf16_cotangents(self):
        """The custom VJP exists to keep x-shaped tensors out of f32
        (EXPERIMENTS §Perf) — pin that contract."""
        x = jax.random.normal(jax.random.key(0), (4, 16), jnp.bfloat16)
        w = jnp.ones((16,))
        g = jax.grad(lambda x: jnp.sum(rmsnorm(x, w).astype(jnp.float32)))(x)
        assert g.dtype == jnp.bfloat16


class TestRope:
    def test_rotation_preserves_norm(self):
        cos, sin = rope_angles(jnp.arange(10), 16, 1e4)
        x = jax.random.normal(jax.random.key(0), (2, 10, 4, 16))
        y = apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        D = 16
        q = jax.random.normal(jax.random.key(0), (1, 1, 1, D))
        k = jax.random.normal(jax.random.key(1), (1, 1, 1, D))
        def score(m, n):
            cm, sm = rope_angles(jnp.array([m]), D, 1e4)
            cn, sn = rope_angles(jnp.array([n]), D, 1e4)
            return float(jnp.sum(apply_rope(q, cm, sm) * apply_rope(k, cn, sn)))
        assert abs(score(3, 1) - score(10, 8)) < 1e-4

    def test_partial_rotary(self):
        """rotary_frac < 1 (stablelm) leaves the tail untouched."""
        D = 16
        cos, sin = rope_angles(jnp.arange(4), D // 4, 1e4)
        x = jax.random.normal(jax.random.key(0), (1, 4, 1, D))
        y = apply_rope(x, cos, sin)
        np.testing.assert_allclose(np.asarray(x[..., D // 4:]),
                                   np.asarray(y[..., D // 4:]))


class TestSequenceMixers:
    def test_wkv6_chunked_equals_recurrence(self):
        B, T, H, Dh = 2, 75, 2, 8
        ks = jax.random.split(jax.random.key(0), 6)
        r, k, v = (jax.random.normal(ks[i], (B, T, H, Dh)) for i in range(3))
        log_w = -jnp.exp(jax.random.normal(ks[3], (B, T, H, Dh)) * 0.5)
        u = jax.random.normal(ks[4], (H, Dh)) * 0.1
        S0 = jax.random.normal(ks[5], (B, H, Dh, Dh)) * 0.1
        ys, S = [], S0
        for t in range(T):
            y, S = wkv_step(r[:, t], k[:, t], v[:, t], log_w[:, t], u, S)
            ys.append(y)
        y_ref = jnp.stack(ys, 1)
        y_c, S_c = wkv_chunked(r, k, v, log_w, u, S0)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_c), atol=5e-4)
        np.testing.assert_allclose(np.asarray(S), np.asarray(S_c), atol=5e-4)

    def test_ssd_chunked_equals_recurrence(self):
        B, T, H, P, N = 2, 70, 2, 8, 8
        ks = jax.random.split(jax.random.key(1), 6)
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, T, N))
        Cm = jax.random.normal(ks[4], (B, T, N))
        S0 = jax.random.normal(ks[5], (B, H, N, P)) * 0.1
        ys, S = [], S0
        for t in range(T):
            y, S = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], S)
            ys.append(y)
        y_ref = jnp.stack(ys, 1)
        y_c, S_c = ssd_chunked(x, dt, A, Bm, Cm, S0)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_c), atol=5e-4)
        np.testing.assert_allclose(np.asarray(S), np.asarray(S_c), atol=5e-4)
