"""Streaming continual learning as a service: paged tenant banks over the
block pool, bounded rehearsal replay, the plane enroll verb, and the
overflow contracts of the prototype-store ops."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.obs.metrics import MetricsRegistry
from repro.serving import ServingPlane
from repro.sessions import (
    PagedBankPool,
    RehearsalBuffer,
    StreamSessionService,
    bank_add_class,
    bank_init,
    paged_bank_fc,
)
from repro.sessions.paging import NULL_BLOCK, PoolExhausted


def _setup(seed=0):
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(seed))
    bn = tcn_empty_state(cfg)
    bn = jax.tree.map(
        lambda a: a + 0.05 * jnp.abs(
            jax.random.normal(jax.random.key(7), a.shape)), bn)
    return cfg, bundle, params, bn


def _svc(paged, **kw):
    cfg, bundle, params, bn = _setup()
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_tenants", 2)
    kw.setdefault("max_ways", 5)
    return StreamSessionService(bundle, params, bn, paged_bank=paged,
                                bank_block_ways=2, **kw)


def _fc_rows(pool, tenant):
    tables, ways = pool.slot_tables(np.array([tenant], np.int32))
    w, b = paged_bank_fc(pool.s_sums, pool.counts,
                         jnp.asarray(tables), jnp.asarray(ways))
    return np.asarray(w[0]), np.asarray(b[0])


# ---------------------------------------------------------------------------
# bankpool.py: the paged tenant bank
# ---------------------------------------------------------------------------

def test_bankpool_grows_block_at_a_time_to_cap():
    pool = PagedBankPool(8, block_ways=3, dim=4, max_tenant_blocks=2)
    pool.create(0)
    assert pool.row_bytes(0) == 0
    rng = np.random.default_rng(0)
    for j in range(6):
        assert pool.add_class(0, rng.normal(size=(2, 4))) == j
        assert len(pool.tables[0]) == j // 3 + 1
    assert pool.pool.n_live == 2
    assert pool.row_bytes(0) == 2 * 3 * 5 * 4  # blocks * BW * (V+1) * fp32
    with pytest.raises(RuntimeError, match="max_ways"):
        pool.add_class(0, rng.normal(size=(2, 4)))


def test_bankpool_park_unpark_bit_identical_and_zero_rows():
    pool = PagedBankPool(8, block_ways=2, dim=4, max_tenant_blocks=3)
    pool.create(0)
    rng = np.random.default_rng(1)
    for _ in range(3):
        pool.add_class(0, rng.normal(size=(2, 4)))
    w0, b0 = _fc_rows(pool, 0)
    pool.park(0)
    assert pool.pool.n_live == 0 and pool.row_bytes(0) == 0
    assert not pool.is_resident(0)
    tables, ways = pool.slot_tables(np.array([0], np.int32))
    assert (tables == NULL_BLOCK).all() and ways[0] == 0  # parked = masked
    pool.park(0)  # idempotent
    pool.unpark(0)
    w1, b1 = _fc_rows(pool, 0)
    np.testing.assert_array_equal(w0, w1)
    np.testing.assert_array_equal(b0, b1)


def test_bankpool_exhaustion_and_failed_unpark_stays_parked():
    pool = PagedBankPool(1, block_ways=2, dim=4, max_tenant_blocks=2)
    pool.create(0)
    pool.create(1)
    x = np.ones((1, 4), np.float32)
    pool.add_class(0, x)
    with pytest.raises(PoolExhausted):
        pool.add_class(1, x)  # the single block is taken
    pool.park(0)
    pool.add_class(1, 2 * x)  # freed block recycled
    with pytest.raises(PoolExhausted):
        pool.unpark(0)
    assert not pool.is_resident(0)  # blob intact, still parked
    pool.drop(1)
    pool.unpark(0)
    w, _ = _fc_rows(pool, 0)
    np.testing.assert_array_equal(w[0], x[0])


def test_bankpool_recycled_block_carries_no_residue():
    pool = PagedBankPool(1, block_ways=2, dim=4, max_tenant_blocks=1)
    pool.create(0)
    pool.add_class(0, np.full((2, 4), 3.0, np.float32))
    pool.add_class(0, np.full((1, 4), 5.0, np.float32))
    pool.drop(0)
    pool.create(1)
    pool.add_class(1, np.ones((1, 4), np.float32))
    bid = pool.tables[1][0]
    # way 1 of the recycled block must be zeroed, not tenant 0's old sums
    assert float(np.asarray(pool.counts[bid, 1])) == 0.0
    np.testing.assert_array_equal(np.asarray(pool.s_sums[bid, 1]),
                                  np.zeros(4, np.float32))


def test_bankpool_pack_adopt_roundtrip_parked():
    pool = PagedBankPool(4, block_ways=2, dim=4, max_tenant_blocks=2)
    pool.create(0)
    rng = np.random.default_rng(2)
    for _ in range(3):
        pool.add_class(0, rng.normal(size=(2, 4)))
    w0, b0 = _fc_rows(pool, 0)
    blob = pool.pack(0)
    other = PagedBankPool(4, block_ways=2, dim=4, max_tenant_blocks=2)
    other.adopt(7, blob)
    assert not other.is_resident(7)  # adopted parked: zero device rows
    assert other.pool.n_live == 0
    other.unpark(7)
    w1, b1 = _fc_rows(other, 7)
    np.testing.assert_array_equal(w0, w1)
    np.testing.assert_array_equal(b0, b1)


# ---------------------------------------------------------------------------
# tenancy.py: bank_add_class overflow contract (satellite of the
# store_add_class fix — same silent-clamp audit)
# ---------------------------------------------------------------------------

def test_bank_add_class_overflow_masked_noop():
    bank = bank_init(2, 2, 4)
    rng = np.random.default_rng(3)
    a, b = rng.normal(size=(2, 4)), rng.normal(size=(3, 4))
    bank = bank_add_class(bank, 0, jnp.asarray(a))
    bank = bank_add_class(bank, 0, jnp.asarray(b))
    before = jax.tree.map(np.asarray, bank)
    bank = bank_add_class(bank, 0, jnp.asarray(10 * a))  # tenant 0 is full
    assert int(bank.n_ways[0]) == 2  # did NOT clamp-overwrite way 1
    np.testing.assert_array_equal(np.asarray(bank.s_sums), before.s_sums)
    np.testing.assert_array_equal(np.asarray(bank.counts), before.counts)
    bank = bank_add_class(bank, 1, jnp.asarray(b))  # neighbor still open
    assert int(bank.n_ways[1]) == 1


# ---------------------------------------------------------------------------
# rehearsal.py: bounded latent replay
# ---------------------------------------------------------------------------

def test_rehearsal_reservoir_bounded_bytes_and_rebuild():
    buf = RehearsalBuffer(cap_per_class=4, seed=0)
    rng = np.random.default_rng(4)
    buf.add(0, 0, rng.normal(size=(10, 6)))
    assert buf.n_shots(0, 0) == 4
    s, k = buf.rebuild(0, 0, 6)
    assert k == 4 and s.shape == (6,) and s.dtype == np.float32
    assert buf.nbytes(0) == 4 * (3 + 4)  # 6 nibbles packed + fp32 scale
    assert buf.nbytes() == buf.nbytes(0)
    buf.add(1, 0, rng.normal(size=(2, 6)))
    assert buf.nbytes() > buf.nbytes(0)
    buf.drop(0)
    with pytest.raises(KeyError):
        buf.rebuild(0, 0, 6)


def test_rehearsal_under_cap_keeps_every_shot():
    buf = RehearsalBuffer(cap_per_class=8, seed=0)
    emb = np.random.default_rng(5).normal(size=(3, 5)).astype(np.float32)
    buf.add(0, 2, emb)
    s, k = buf.rebuild(0, 2, 5)
    assert k == 3
    # u4 log2 codes keep sign and coarse magnitude: the rebuilt sum must
    # point the same way as the exact sum
    exact = emb.sum(axis=0)
    cos = float(np.dot(s, exact) /
                (np.linalg.norm(s) * np.linalg.norm(exact)))
    assert cos > 0.8


# ---------------------------------------------------------------------------
# service: paged vs dense, growth, parking, label-keyed enrollment
# ---------------------------------------------------------------------------

def test_paged_service_bit_identical_to_dense():
    """Same enrolls, same pushes: the paged bank path must produce
    bit-identical tenant logits to the dense enroll-once bank."""
    rng = np.random.default_rng(6)
    shots = [rng.normal(size=(2, 10, 2)).astype(np.float32)
             for _ in range(5)]
    x = rng.normal(size=(12, 2)).astype(np.float32)

    def run(paged):
        svc = _svc(paged)
        sid = svc.open_session(tenant=None)
        outs = []
        for s in shots:  # grows past the 2-way block boundary twice
            svc.enroll_shots(sid, s)
            outs.append(svc.push_audio({sid: x})[sid])
        return svc, outs

    dsvc, dense = run(False)
    psvc, paged = run(True)
    assert psvc.bankpool.pool.n_live == 3  # ceil(5 ways / 2 per block)
    for rd, rp in zip(dense, paged):
        assert rd["tenant_logits"].shape == rp["tenant_logits"].shape
        np.testing.assert_array_equal(rd["tenant_logits"],
                                      rp["tenant_logits"])
        np.testing.assert_array_equal(rd["emb"], rp["emb"])
        assert rd["pred"] == rp["pred"]


def test_paged_tenant_parks_on_park_and_push_restores_bit_identical():
    rng = np.random.default_rng(7)
    shots = rng.normal(size=(2, 10, 2)).astype(np.float32)
    x1 = rng.normal(size=(8, 2)).astype(np.float32)
    x2 = rng.normal(size=(8, 2)).astype(np.float32)

    def run(with_park):
        svc = _svc(True)
        sid = svc.open_session(tenant=None)
        tenant = svc.sessions[sid].tenant
        svc.enroll_shots(sid, shots)
        svc.push_audio({sid: x1})
        if with_park:
            svc.park(sid)  # last bound session leaves -> bank parks
            assert svc.bankpool.stats()["blocks_live"] == 0
            assert not svc.bankpool.is_resident(tenant)
            assert svc.stats()["bank_pool_blocks_live"] == 0
        return svc.push_audio({sid: x2})[sid]  # lazy rebind + unpark

    plain, parked = run(False), run(True)
    np.testing.assert_array_equal(plain["tenant_logits"],
                                  parked["tenant_logits"])
    assert plain["pred"] == parked["pred"]


def test_paged_tenant_parks_on_eviction():
    """The eviction path bypasses _on_unbind; the _on_evict hook must
    still release the outgoing tenant's bank rows."""
    rng = np.random.default_rng(8)
    svc = _svc(True, n_slots=1)
    s1 = svc.open_session(tenant=None)
    t1 = svc.sessions[s1].tenant
    svc.enroll_shots(s1, rng.normal(size=(2, 10, 2)).astype(np.float32))
    assert svc.bankpool.is_resident(t1)
    s2 = svc.open_session(tenant=None)  # binding evicts s1 from the grid
    assert not svc.bankpool.is_resident(t1)  # evicted tenant parked
    svc.enroll_shots(s2, rng.normal(size=(1, 10, 2)).astype(np.float32))
    r = svc.push_audio({s1: rng.normal(size=(4, 2)).astype(np.float32)})
    assert svc.bankpool.is_resident(t1)  # pushing restored residency
    assert np.isfinite(r[s1]["tenant_logits"][-1][0])


def test_enroll_label_keyed_streaming_append_then_refine():
    for paged in (False, True):
        svc = _svc(paged)
        sid = svc.open_session(tenant=None)
        # integer-valued embeddings make the running-mean fold exact, so
        # label-refinement must EQUAL enrolling all shots at once
        a1 = np.array([[2., 0., 4.] + [0.] * 9], np.float32)
        a2 = np.array([[4., 2., 0.] + [0.] * 9], np.float32)
        b = np.array([[0., 8., 2.] + [0.] * 9], np.float32)
        assert svc.enroll_shots(sid, a1, embedded=True, label="cat") == 0
        assert svc.enroll_shots(sid, b, embedded=True, label="dog") == 1
        assert svc.enroll_shots(sid, a2, embedded=True, label="cat") == 0
        assert svc.poll(sid)["n_ways"] == 2
        ref = _svc(paged)
        rid = ref.open_session(tenant=None)
        ref.enroll_shots(rid, np.concatenate([a1, a2]), embedded=True)
        ref.enroll_shots(rid, b, embedded=True)
        if paged:
            w0, b0 = _fc_rows(svc.bankpool, svc.sessions[sid].tenant)
            w1, b1 = _fc_rows(ref.bankpool, ref.sessions[rid].tenant)
        else:
            w0, b0 = (np.asarray(svc.bank.s_sums[0]),
                      np.asarray(svc.bank.counts[0]))
            w1, b1 = (np.asarray(ref.bank.s_sums[0]),
                      np.asarray(ref.bank.counts[0]))
        np.testing.assert_array_equal(w0, w1)
        np.testing.assert_array_equal(b0, b1)
        with pytest.raises(ValueError, match="not both"):
            svc.enroll_shots(sid, a1, embedded=True, label="cat", way=0)


def test_enroll_past_max_ways_raises_not_clamps():
    for paged in (False, True):
        svc = _svc(paged, max_ways=2)
        sid = svc.open_session(tenant=None)
        one = np.ones((1, 12), np.float32)
        svc.enroll_shots(sid, one, embedded=True)
        svc.enroll_shots(sid, 2 * one, embedded=True)
        with pytest.raises(RuntimeError, match="max_ways"):
            svc.enroll_shots(sid, 3 * one, embedded=True)
        assert svc.poll(sid)["n_ways"] == 2


def test_paged_enroll_pool_exhaustion_is_admission_error():
    # 1 shared block for 2 tenants: the second tenant's first enroll must
    # surface the paging back-pressure type, not corrupt the first
    svc = _svc(True, bank_blocks=1)
    s1 = svc.open_session(tenant=0)
    s2 = svc.open_session(tenant=1)
    one = np.ones((1, 12), np.float32)
    svc.enroll_shots(s1, one, embedded=True)
    with pytest.raises(PoolExhausted):
        svc.enroll_shots(s2, one, embedded=True)
    assert svc.poll(s1)["n_ways"] == 1 and svc.poll(s2)["n_ways"] == 0


def test_rehearse_tenant_rebuilds_from_buffer():
    svc = _svc(True, rehearsal_cap=8)
    sid = svc.open_session(tenant=None)
    tenant = svc.sessions[sid].tenant
    # well-separated axis-aligned prototypes survive u4 log2 replay
    emb = np.zeros((3, 2, 12), np.float32)
    for c in range(3):
        emb[c, :, 4 * c] = (8.0, 4.0)
    for c in range(3):
        svc.enroll_shots(sid, emb[c], embedded=True)
    w0, _ = _fc_rows(svc.bankpool, tenant)
    assert svc.rehearse_tenant(tenant) == 3
    w1, b1 = _fc_rows(svc.bankpool, tenant)
    for c in range(3):  # direction preserved: each way still argmaxes
        q = jnp.asarray(w0[c][None])
        logits = np.asarray(jnp.einsum("bv,nv->bn", q, jnp.asarray(w1))
                            + jnp.asarray(b1)[None])
        assert logits[0, :3].argmax() == c
    svc2 = _svc(True)  # rehearsal disabled
    sid2 = svc2.open_session(tenant=None)
    with pytest.raises(RuntimeError, match="rehearsal"):
        svc2.rehearse_tenant(svc2.sessions[sid2].tenant)


def test_paged_spill_restore_roundtrip(tmp_path):
    """Persistence: a paged tenant's bank rides the spill as the same
    JSON blob schema, restores PARKED, and classifies identically."""
    rng = np.random.default_rng(9)
    shots = rng.normal(size=(2, 10, 2)).astype(np.float32)
    x1 = rng.normal(size=(8, 2)).astype(np.float32)
    x2 = rng.normal(size=(8, 2)).astype(np.float32)
    svc = _svc(True)
    sid = svc.open_session(tenant=None)
    svc.enroll_shots(sid, shots)
    svc.push_audio({sid: x1})
    svc.park(sid)
    path = tmp_path / "spill.json"
    svc.spill_parking(str(path))
    fresh = _svc(True)
    assert fresh.restore_parking(str(path)) == [sid]
    assert fresh.bankpool.pool.n_live == 0  # restored parked
    # both resume the SAME parked stream state; the restored replica must
    # continue it bit-identically, bank rows included
    want = svc.push_audio({sid: x2})[sid]
    got = fresh.push_audio({sid: x2})[sid]
    np.testing.assert_array_equal(want["tenant_logits"],
                                  got["tenant_logits"])


# ---------------------------------------------------------------------------
# serving plane: the enroll verb
# ---------------------------------------------------------------------------

def test_plane_enroll_verb_routes_and_orders_fifo():
    rng = np.random.default_rng(10)
    shots1 = rng.normal(size=(2, 10, 2)).astype(np.float32)
    shots2 = rng.normal(size=(1, 10, 2)).astype(np.float32)
    x = rng.normal(size=(6, 2)).astype(np.float32)
    svc = _svc(True)
    plane = ServingPlane(svc, metrics=svc.metrics_registry)

    async def main():
        async with plane:
            # tenant is forwarded to the tenant-aware TCN service, not
            # just used for routing
            psid = await plane.open_session(tenant=1)
            assert (await plane.poll(psid))["tenant"] == 1
            assert await plane.enroll(psid, shots1) == 0
            # enroll queued BEFORE a push must update the bank the push
            # classifies with (FIFO within the session)
            fe = asyncio.ensure_future(plane.enroll(psid, shots2))
            fp = asyncio.ensure_future(plane.push(psid, x))
            way, res = await asyncio.gather(fe, fp)
            assert way == 1
            assert np.isfinite(res["tenant_logits"][-1][1])  # sees way 1
            return res

    res = asyncio.run(main())
    assert svc.metrics()["plane_enrolls_total"][0]["value"] == 2
    enrolls = [e["value"] for e in svc.metrics()["enrolls_total"]
               if e["labels"].get("service") == "tcn"]
    assert enrolls == [2]
    assert res["pred"] == int(res["tenant_logits"][-1].argmax())


def test_enroll_metrics_and_stats_surface():
    svc = _svc(True, rehearsal_cap=2)
    sid = svc.open_session(tenant=None)
    svc.enroll_shots(sid, np.ones((3, 12), np.float32), embedded=True)
    snap = svc.metrics()
    get = lambda name: [e for e in snap[name]
                        if e["labels"].get("service") == "tcn"][0]
    assert get("enrolls_total")["value"] == 1
    assert get("enroll_shots_total")["value"] == 3
    assert get("enroll_latency_us")["count"] == 1
    assert get("bank_pool_blocks_live")["value"] == 1
    assert get("rehearsal_bytes")["value"] > 0
    st = svc.stats()
    assert st["paged_bank"] is True
    assert st["tenant_row_bytes"] == 2 * 13 * 4  # block_ways * (V+1) * fp32
    assert st["bank_pool_blocks_live"] == 1
    assert st["rehearsal_bytes"] > 0


# ---------------------------------------------------------------------------
# serving plane: tenant handoff under drain
# ---------------------------------------------------------------------------

def test_tenant_bank_mutated_during_drain_lands_on_peer_post_enroll():
    """An enroll accepted just before drain() must apply on the old worker
    (drain waits for the accepted queue), and the handoff must carry the
    POST-enroll bank: the peer classifies with both prototypes, exactly
    like a never-drained control."""
    rng = np.random.default_rng(12)
    shots1 = rng.normal(size=(2, 10, 2)).astype(np.float32)
    shots2 = rng.normal(size=(1, 10, 2)).astype(np.float32)
    x = rng.normal(size=(6, 2)).astype(np.float32)

    ctrl = _svc(True)
    csid = ctrl.open_session(tenant=0)
    ctrl.enroll_shots(csid, shots1)
    ctrl.enroll_shots(csid, shots2)
    want = np.asarray(ctrl.push_audio({csid: x})[csid]["tenant_logits"])

    async def main():
        # fresh registry: the default_registry() is process-global and
        # other suites read exact plane counter values off it
        plane = ServingPlane([_svc(True), _svc(True)],
                             metrics=MetricsRegistry())
        async with plane:
            psid = await plane.open_session(tenant=0)
            assert await plane.enroll(psid, shots1) == 0
            victim = plane._sessions[psid][0]
            # enqueue the second enroll, THEN start draining its worker:
            # the already-accepted enroll must land before the handoff
            fe = asyncio.ensure_future(plane.enroll(psid, shots2))
            await asyncio.sleep(0)  # the enroll op is now queued
            way, summary = await asyncio.gather(
                fe, plane.drain(victim.idx))
            assert way == 1
            assert summary["moved_sessions"] == 1
            assert summary["moved_tenants"] == 1
            peer = plane._sessions[psid][0]
            assert peer is not victim
            poll = await plane.poll(psid)
            assert poll["n_ways"] == 2  # the peer's bank is post-enroll
            res = await plane.push(psid, x)
            # a THIRD enroll keeps streaming on the peer: handoff did not
            # freeze the bank
            assert await plane.enroll(psid, shots2) == 2
            return res

    res = asyncio.run(main())
    np.testing.assert_array_equal(
        np.asarray(res["tenant_logits"]), want)
