"""Telemetry plane (repro.obs): metrics registry semantics, trace spans,
device-side counters, and — the load-bearing contract — bit-identity of
instrumented services vs uninstrumented ones on every state leaf and
every emitted output."""

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.obs import (
    MetricsRegistry,
    Tracer,
    acceptance_stats,
    decode_occupancy,
    occupancy_stats,
    valid_stats,
)
from repro.obs.metrics import Histogram
from repro.sessions import (
    LMSessionService,
    SpeculativeDecoder,
    StreamSessionService,
    ngram_drafter,
)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", service="tcn")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("reqs_total", service="tcn") is c  # get-or-create
    assert reg.counter("reqs_total", service="lm") is not c  # labels split
    with pytest.raises(ValueError):
        c.inc(-1)  # counters are monotonic
    g = reg.gauge("bound")
    g.set(3)
    g.inc()
    g.dec(2)
    assert g.value == 2


def test_metric_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x", a=1)
    with pytest.raises(TypeError):
        reg.gauge("x", a=1)


def test_histogram_log2_buckets_and_quantiles():
    h = Histogram()
    for v in (1, 2, 3, 1000):
        h.record(v)
    # 1 -> bucket 0, 2 -> bucket 1, 3 -> bucket 2, 1000 -> bucket 10
    assert h.to_dict()["buckets"] == {"0": 1, "1": 1, "2": 1, "10": 1}
    assert h.count == 4 and h.sum == 1006
    assert h.min == 1 and h.max == 1000
    assert h.mean == pytest.approx(251.5)
    # quantiles are bucket-approximate but clamped to observed extremes
    assert h.percentile(0) == 1
    assert h.percentile(100) == 1000
    assert 1 <= h.percentile(50) <= 3
    with pytest.raises(ValueError):
        h.record(-1)
    h.reset()
    assert h.count == 0 and h.percentile(99) == 0.0


def test_histogram_percentile_within_bucket_error_bound():
    """Quantile error is bounded by the log2 bucket width (factor of 2)."""
    rng = np.random.default_rng(0)
    vals = rng.uniform(10, 10000, size=2000)
    h = Histogram()
    for v in vals:
        h.record(v)
    for q in (50, 90, 99):
        exact = np.percentile(vals, q)
        approx = h.percentile(q)
        assert exact / 2 <= approx <= exact * 2


def test_snapshot_and_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("evictions_total", service="tcn").inc(2)
    reg.histogram("lat_us", service="tcn", shape="T16").record(100)
    snap = reg.snapshot()
    assert snap["evictions_total"] == [
        {"labels": {"service": "tcn"}, "type": "counter", "value": 2}]
    [h] = snap["lat_us"]
    assert h["labels"] == {"service": "tcn", "shape": "T16"}
    assert h["count"] == 1
    json.dumps(snap)  # pure-JSON contract
    text = reg.prometheus()
    assert "# TYPE evictions_total counter" in text
    assert 'evictions_total{service="tcn"} 2' in text
    # cumulative le buckets + _sum/_count for histograms
    assert 'lat_us_bucket{service="tcn",shape="T16",le="128.0"} 1' in text
    assert 'lat_us_bucket{service="tcn",shape="T16",le="+Inf"} 1' in text
    assert 'lat_us_count{service="tcn",shape="T16"} 1' in text
    reg.reset()
    assert reg.counter("evictions_total", service="tcn").value == 0


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    t = Tracer()
    with t.span("dispatch", cat="tcn", shape="T16"):
        pass
    t.instant("evict", sid=1)
    t.counter("sessions", bound=2)
    assert t.events() == []


def test_span_and_instant_events(tmp_path):
    t = Tracer(enabled=True)
    with t.span("dispatch", cat="tcn", shape="T16", lanes=3):
        pass
    t.instant("evict", cat="tcn", victim=7)
    t.counter("sessions", bound=2, parked=1)
    evs = t.events()
    assert [e["ph"] for e in evs] == ["X", "i", "C"]
    x = evs[0]
    assert x["name"] == "dispatch" and x["cat"] == "tcn"
    assert x["dur"] >= 0 and x["args"] == {"shape": "T16", "lanes": 3}
    assert evs[1]["args"]["victim"] == 7
    # export is a Perfetto/chrome://tracing-loadable JSON document
    path = t.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"] == evs
    assert doc["otherData"]["dropped_events"] == 0


def test_trace_ring_buffer_drops_oldest():
    t = Tracer(enabled=True, capacity=4)
    for i in range(10):
        t.instant(f"e{i}")
    evs = t.events()
    assert len(evs) == 4
    assert [e["name"] for e in evs] == ["e6", "e7", "e8", "e9"]
    assert t.dropped == 6
    t.clear()
    assert t.events() == [] and t.dropped == 0


# ---------------------------------------------------------------------------
# device-side counters (pure functions)
# ---------------------------------------------------------------------------

def test_occupancy_stats_vector():
    lengths = jnp.asarray([5, 0, 3, 8])
    stats = np.asarray(occupancy_stats(lengths, 8))
    assert stats.tolist() == [16, 32, 3, 4]
    occ = decode_occupancy(stats)
    assert occ["live_step_ratio"] == pytest.approx(0.5)
    assert occ["lane_occupancy"] == pytest.approx(0.75)
    # waste within live lanes: 3 live lanes x 8 padded = 24 extent, 16 live
    assert occ["pad_waste"] == pytest.approx(1 - 16 / 24)


def test_valid_stats_matches_lengths():
    lengths = np.asarray([2, 0, 4])
    valid = np.arange(4)[None, :] < lengths[:, None]
    np.testing.assert_array_equal(np.asarray(valid_stats(valid)),
                                  np.asarray(occupancy_stats(lengths, 4)))


def test_acceptance_stats_matching_prefix():
    ys = jnp.asarray([[1, 2, 3, 9],    # full match (3 drafts)
                      [1, 9, 3, 9],    # mismatch at draft 1
                      [5, 6, 7, 9],    # n_draft=0: nothing to accept
                      [1, 2, 9, 9]])   # match 2 then mismatch
    draft = jnp.asarray([[1, 2, 3],
                         [1, 2, 3],
                         [5, 6, 7],
                         [1, 2, 3]])
    n_draft = jnp.asarray([3, 3, 0, 3])
    acc = np.asarray(acceptance_stats(ys, draft, n_draft))
    assert acc.tolist() == [3, 1, 0, 2]


# ---------------------------------------------------------------------------
# instrumented services: bit-identity + wiring
# ---------------------------------------------------------------------------

def _tcn_setup():
    cfg = get_config("chameleon-tcn").replace(
        tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
        embed_dim=12, n_classes=4)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params, tcn_empty_state(cfg)


@functools.lru_cache(maxsize=None)
def _lm_setup():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_tcn_instrumented_scan_bit_identical():
    """device_counters=True threads extra in-jit outputs through the scan;
    embeddings, logits, AND every state leaf must match the plain service
    bit for bit."""
    cfg, bundle, params, bn = _tcn_setup()
    mk = lambda dev: StreamSessionService(
        bundle, params, bn, n_slots=3, max_tenants=1, t_chunk=8,
        device_counters=dev)
    plain, inst = mk(False), mk(True)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(3, 21, 2)).astype(np.float32)
    for svc in (plain, inst):
        sids = [svc.open_session() for _ in range(3)]
        svc._out = svc.push_audio(
            {sid: x[i] for i, sid in enumerate(sids)})
    for a, b in zip(plain._out.values(), inst._out.values()):
        np.testing.assert_array_equal(a["emb"], b["emb"])
        np.testing.assert_array_equal(a["logits"], b["logits"])
    for la, lb in zip(jax.tree.leaves(plain.states),
                      jax.tree.leaves(inst.states)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # ...and the instrumented service actually ingested occupancy
    snap = inst.metrics()
    [live] = snap["device_live_steps_total"]
    assert live["value"] == 3 * 21
    assert plain.metrics().get("device_live_steps_total") is None


def test_lm_instrumented_decode_bit_identical():
    cfg, bundle, params = _lm_setup()
    mk = lambda dev: LMSessionService(
        bundle, params, n_slots=2, seq_cap=48, t_chunk=8,
        device_counters=dev)
    plain, inst = mk(False), mk(True)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    outs = []
    for svc in (plain, inst):
        a = svc.open_session(prompt)
        b = svc.open_session(prompt[:2])
        outs.append(svc.decode({a: 12, b: 12}))
    assert list(outs[0].values()) == list(outs[1].values())
    for la, lb in zip(jax.tree.leaves(plain.cache),
                      jax.tree.leaves(inst.cache)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    snap = inst.metrics()
    assert snap["device_live_steps_total"][0]["value"] > 0
    # masked + live = n_slots * t_pad per dispatch, always
    total = (snap["device_live_steps_total"][0]["value"]
             + snap["device_masked_steps_total"][0]["value"])
    assert total % plain.n_slots == 0


def test_speculative_device_acceptance_matches_host():
    """The in-jit per-lane acceptance counts equal the host rollback
    arithmetic, and the instrumented verify emits the same stream."""
    cfg, bundle, params = _lm_setup()
    prompts = [np.array([3, 1, 4, 1, 5, 1, 4, 1], np.int32),
               np.array([2, 7, 2, 7, 2], np.int32)]

    def run(dev):
        svc = LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                               t_chunk=8, device_counters=dev)
        sp = SpeculativeDecoder(svc, ngram_drafter(), k=3)
        sids = [svc.open_session(p) for p in prompts]
        out = sp.decode({sid: 24 for sid in sids})
        return svc, sp, [out[sid] for sid in sids]

    _, sp_plain, stream_plain = run(False)
    svc, sp, stream = run(True)
    assert stream == stream_plain
    assert sp._verify_inst is not None
    assert sp.last_device_accepts is not None
    # total device-counted acceptance == total host-counted acceptance
    assert sp.accepted == sp_plain.accepted
    dev_acc = svc.metrics()["spec_device_accepted_total"][0]["value"]
    assert dev_acc == sp.accepted
    # the registry's drafted/accepted counters mirror the plain ints
    snap = svc.metrics()
    assert snap["spec_drafted_total"][0]["value"] == sp.drafted
    assert snap["spec_accepted_total"][0]["value"] == sp.accepted


def test_dispatch_latency_histograms_per_shape():
    """Every jitted dispatch lands one sample in the per-compiled-shape
    log2 histogram; counts equal the dispatch counter."""
    cfg, bundle, params, bn = _tcn_setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1,
                               t_chunk=8)
    sid = svc.open_session()
    svc.push_audio({sid: np.zeros((11, 2), np.float32)})  # T8 + T4 buckets
    svc.push_audio({sid: np.zeros((2,), np.float32)})     # T1 bucket
    snap = svc.metrics()
    hists = {h["labels"]["shape"]: h for h in snap["dispatch_latency_us"]}
    assert set(hists) == {"T8", "T4", "T1"}
    assert sum(h["count"] for h in hists.values()) == svc.dispatches == 3
    for h in hists.values():
        assert h["p50"] <= h["p99"] <= h["max"]


def test_tracer_records_service_lifecycle(tmp_path):
    """A private enabled tracer sees dispatch spans, evict instants with
    the victim sid, and park/resume — the Perfetto story of the grid."""
    cfg, bundle, params, bn = _tcn_setup()
    t = Tracer(enabled=True)
    svc = StreamSessionService(bundle, params, bn, n_slots=1, max_tenants=1,
                               max_sessions=4, tracer=t)
    a = svc.open_session()
    svc.push_audio({a: np.zeros((2,), np.float32)})
    b = svc.open_session()          # grid of 1: evicts a
    svc.push_audio({a: np.zeros((2,), np.float32)})  # resumes a, evicts b
    names = [e["name"] for e in t.events()]
    for expected in ("bind", "dispatch", "pack", "evict", "unpack", "resume"):
        assert expected in names, f"missing {expected!r} in {names}"
    evict = next(e for e in t.events() if e["name"] == "evict")
    assert evict["args"]["victim"] == a
    dispatch = next(e for e in t.events() if e["name"] == "dispatch")
    assert dispatch["args"]["shape"] == "T1" and dispatch["dur"] >= 0
    doc = json.load(open(t.export(str(tmp_path / "t.json"))))
    assert len(doc["traceEvents"]) == len(t.events())


def test_backward_compat_counter_properties():
    """The historical bare-int surface (svc.dispatches / svc.evictions,
    including += writes) routes through the registry and can't disagree
    with metrics()."""
    cfg, bundle, params, bn = _tcn_setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    assert svc.dispatches == 0
    svc.dispatches += 5
    assert svc.metrics()["dispatches_total"][0]["value"] == 5
    svc.evictions = 2
    assert svc.stats()["evictions"] == 2
    assert svc.metrics()["evictions_total"][0]["value"] == 2


def test_park_unknown_sid_raises():
    """park() has _touch_and_bind's contract: unknown sids raise KeyError
    instead of silently no-oping; parking a parked session stays a no-op."""
    cfg, bundle, params, bn = _tcn_setup()
    svc = StreamSessionService(bundle, params, bn, n_slots=2, max_tenants=1)
    sid = svc.open_session()
    with pytest.raises(KeyError):
        svc.park(sid + 999)
    svc.park(sid)
    svc.park(sid)  # already parked: no-op, no raise
    assert svc.poll(sid)["state"] == "parked"

    cfg2, bundle2, params2 = _lm_setup()
    lm = LMSessionService(bundle2, params2, n_slots=2, seq_cap=32)
    with pytest.raises(KeyError):
        lm.park(123)
