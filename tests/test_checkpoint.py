"""Fault tolerance: atomic checkpoints, corrupt fallback, bitwise resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config
from repro.data import lm_batch
from repro.models import build_bundle
from repro.training import TrainConfig, Trainer


def _tiny():
    cfg = get_config("olmo-1b").smoke().replace(
        n_layers=2, d_model=32, d_ff=64, vocab_size=128, head_dim=16)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.key(0))
    data = lambda step: {k: jnp.asarray(v)
                         for k, v in lm_batch(step, 4, 32, cfg.vocab_size).items()}
    return bundle, params, data


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32)}}
    store.save(str(tmp_path), 7, tree)
    step, flat = store.restore_flat(str(tmp_path))
    assert step == 7
    got = store.restore_into(str(tmp_path), tree)
    assert got[0] == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got[1])):
        np.testing.assert_array_equal(x, y)


def test_corrupt_checkpoint_falls_back(tmp_path):
    tree = {"w": np.ones((4, 4), np.float32)}
    store.save(str(tmp_path), 1, tree)
    store.save(str(tmp_path), 2, {"w": np.full((4, 4), 2.0, np.float32)})
    # corrupt the newest checkpoint (simulated node failure mid-write)
    ck = store.list_checkpoints(str(tmp_path))[-1][1]
    for f in os.listdir(ck):
        if f.endswith(".npy"):
            with open(os.path.join(ck, f), "r+b") as fh:
                fh.seek(-4, 2)
                fh.write(b"\x00\x00\x00\x01")
    step, tree2 = store.restore_into(str(tmp_path), tree)
    assert step == 1  # fell back to the older valid checkpoint
    np.testing.assert_array_equal(tree2["w"], np.ones((4, 4)))


def test_atomic_write_no_partial_visible(tmp_path):
    """A temp dir left behind by a crash is never listed as a checkpoint."""
    os.makedirs(tmp_path / ".tmp_step_9")
    (tmp_path / ".tmp_step_9" / "arr_00000.npy").write_bytes(b"garbage")
    assert store.list_checkpoints(str(tmp_path)) == []


def test_bitwise_resume_after_kill(tmp_path):
    bundle, params, data = _tiny()
    cfg_t = lambda d: TrainConfig(steps=12, ckpt_dir=str(d), ckpt_every=5,
                                  log_every=100)
    # uninterrupted run
    d1 = tmp_path / "a"
    tr = Trainer(bundle.loss_fn, params, cfg_t(d1), data)
    st, _ = tr.run()
    ref = np.asarray(jax.tree.leaves(st.params)[0])
    # killed at step 7 -> resume
    d2 = tmp_path / "b"
    tr1 = Trainer(bundle.loss_fn, params, cfg_t(d2), data)
    tr1.run(steps=7)
    tr1.ckpt.wait()
    tr2 = Trainer(bundle.loss_fn, params, cfg_t(d2), data)
    resumed = tr2.maybe_resume()
    assert resumed > 0
    st2, _ = tr2.run()
    np.testing.assert_array_equal(ref, np.asarray(jax.tree.leaves(st2.params)[0]))


def test_gc_keeps_newest(tmp_path):
    tree = {"w": np.zeros(2, np.float32)}
    for s in range(6):
        store.save(str(tmp_path), s, tree)
    store.gc_checkpoints(str(tmp_path), keep=2)
    steps = [s for s, _ in store.list_checkpoints(str(tmp_path))]
    assert steps == [4, 5]


def test_elastic_reshard_via_device_put(tmp_path):
    """Checkpoints are mesh-independent: restore with explicit shardings."""
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    store.save(str(tmp_path), 3, tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    step, out = store.restore_into(str(tmp_path), tree,
                                   shardings={"w": sharding})
    assert out["w"].sharding == sharding
